//! Quickstart: the whole stack in ~60 lines.
//!
//! 1. load the AOT artifacts (run `make artifacts` once first);
//! 2. pick the ILMPQ-2 quantization config (65:30:5) from the manifest;
//! 3. run one quantized inference through PJRT;
//! 4. show Figure 1 (the intra-layer row assignment) for one layer;
//! 5. simulate the same config on the XC7Z045 FPGA model.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use ilmpq::experiments::figure1;
use ilmpq::fpga::{simulate, DeviceModel, Mode, NetConfig};
use ilmpq::model::zoo;
use ilmpq::runtime::{HostTensor, Runtime};

fn main() -> anyhow::Result<()> {
    // ---- 1. runtime -------------------------------------------------------
    let rt = Runtime::load_default()?;
    let m = &rt.manifest;
    println!(
        "loaded {} ({} params, {} quantized layers) on {}",
        m.model_name,
        m.params.len(),
        m.quantized_layers.len(),
        rt.engine.platform()
    );

    // ---- 2. quantization config ------------------------------------------
    // Named plans resolve through the first-class plan API (the legacy
    // `default_masks` table re-expressed as `QuantPlan`s).
    let masks = m.plan("ilmpq2")?.masks;
    let params = m.load_init_params()?;

    // ---- 3. one quantized inference ----------------------------------------
    let (x_test, y_test) = m.data.load_test()?;
    let img = m.data.image_elems();
    let mut inputs = params.clone();
    inputs.extend(m.mask_tensors(&masks));
    inputs.push(HostTensor::f32(
        vec![1, m.data.height, m.data.width, m.data.channels],
        x_test[..img].to_vec(),
    ));
    let out = rt.run("infer_b1", &inputs)?;
    let logits = out[0].as_f32();
    let pred = logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(k, _)| k)
        .unwrap();
    println!(
        "\ninfer_b1: predicted class {pred} (true {}), logits[..4] = {:?}",
        y_test[0],
        &logits[..4]
    );

    // ---- 4. Figure 1: the row map for the first conv stage ----------------
    println!();
    println!("{}", figure1::render_layer(masks.layer("s0/c1/w").unwrap()));
    println!("{}", figure1::render_layer(masks.layer("s0/c2/w").unwrap()));

    // ---- 5. FPGA simulation of this config --------------------------------
    let net = zoo::tinyresnet(m.height, m.width, m.channels, &m.widths, m.classes);
    let cfg = NetConfig::from_masks("ilmpq2", masks.layers.clone());
    let device = DeviceModel::xc7z045();
    let report = simulate(&net, &cfg, &device, Mode::IntraLayer);
    println!("\nsimulated on {}:", device.name);
    println!("{}", report.row());
    Ok(())
}
