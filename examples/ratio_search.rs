//! The paper's offline ratio determination (§II-B): sweep the PoT share on
//! each device and report the throughput-optimal PoT:Fixed4:Fixed8 split.
//!
//! Expected result (paper): ~60:35:5 on XC7Z020 and ~65:30:5 on XC7Z045 —
//! the bigger part has proportionally more LUT bandwidth, so its optimum
//! leans further PoT.
//!
//! ```sh
//! cargo run --release --example ratio_search -- --net resnet18
//! ```

use ilmpq::coordinator::ratio_search;
use ilmpq::fpga::DeviceModel;
use ilmpq::model::zoo;
use ilmpq::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(
        "ratio_search",
        1,
        &[
            ("net", "workload: resnet18|vgg11|cnn-small|tinyresnet"),
            ("fixed8", "Fixed-8 percentage (default 5)"),
            ("step", "sweep granularity in % (default 1)"),
            ("out", "save each device's winning assignment as <out>-<device>.json"),
        ],
    );
    let net_name = args.str_or("net", "resnet18");
    let net = zoo::by_name(net_name)
        .ok_or_else(|| anyhow::anyhow!("unknown net {net_name}"))?;
    let fixed8 = args.f64_or("fixed8", 5.0);
    let step = args.f64_or("step", 1.0);

    println!(
        "ratio search on {} ({:.2} GOPs), Fixed-8 pinned at {fixed8}%\n",
        net.name,
        net.total_gops()
    );
    for device in DeviceModel::all() {
        let r = ratio_search::search(&net, &device, fixed8, step, 95.0 - fixed8);
        println!(
            "{}: optimum {} -> {:.1} GOP/s ({:.1} ms)   [paper: {}]",
            device.name,
            r.best.ratio.label(),
            r.best.throughput_gops,
            r.best.latency_s * 1e3,
            if device.name == "xc7z020" { "60:35:5" } else { "65:30:5" },
        );
        // Compact sweep curve (every 5th point).
        print!("  sweep: ");
        for p in r.sweep.iter().step_by(5) {
            print!("{:.0}%→{:.0}  ", p.ratio.pot4, p.throughput_gops);
        }
        println!("\n");
        if let Some(out) = args.get("out") {
            // The winner as a first-class, loadable quantization plan.
            let path =
                format!("{}-{}.json", out.trim_end_matches(".json"), device.name);
            let plan = r.winning_plan(&net);
            plan.save(std::path::Path::new(&path))?;
            println!("  wrote winning plan to {path}");
        }
    }
    Ok(())
}
