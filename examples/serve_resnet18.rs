//! Serving example: batched quantized inference behind the dynamic batcher,
//! with the FPGA-sim timing overlay (the codesign view: numerics run on the
//! chosen execution backend, timing is what the Zynq accelerator would
//! take).
//!
//! A Poisson open-loop client drives the server at `--rate` req/s; the
//! report shows end-to-end latency percentiles, batch occupancy, and the
//! simulated FPGA cost per batch. The backend is picked by name through
//! `backend::registry()` — `--backend qgemm` serves the native packed-code
//! integer path and works on `--no-default-features` builds (no PJRT /
//! xla_extension needed). The Table-I context (what the same config does on
//! the full ResNet-18 on both boards) is printed at the end.
//!
//! With `--listen ADDR` the same pipeline is exposed over the dependency-
//! free HTTP/1.1 front end instead of the in-process client: `POST
//! /v1/infer` takes `{"image": [f32, ...]}` and the typed admission errors
//! map to 400/429/500/503 (drive it with `ilmpq loadgen --url`).
//!
//! ```sh
//! cargo run --release --example serve_resnet18 -- --rate 3000 --requests 2000
//! cargo run --no-default-features --example serve_resnet18 -- --backend qgemm
//! cargo run --no-default-features --example serve_resnet18 -- \
//!     --backend qgemm --listen 127.0.0.1:8080
//! ```

use std::time::Duration;

use ilmpq::backend::{self, InferenceBackend};
use ilmpq::coordinator::{HttpConfig, HttpServer, ServeConfig, Server};
use ilmpq::experiments::table1;
use ilmpq::model::resnet18;
use ilmpq::quant::QuantSource;
use ilmpq::runtime::Manifest;
use ilmpq::util::{Args, Rng};

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(
        "serve_resnet18",
        1,
        &[
            ("rate", "arrival rate req/s (default 2000)"),
            ("requests", "total requests (default 1024)"),
            ("ratio", "named quantization plan (default ilmpq2)"),
            ("plan", "serve a saved plan file (see `ilmpq plan derive`)"),
            ("device", "FPGA-sim device (default xc7z045)"),
            ("workers", "worker threads (default 2)"),
            ("max-wait-ms", "batcher deadline (default 5)"),
            ("queue-depth", "admission queue bound (default 1024)"),
            ("backend", "execution backend: pjrt|qgemm|float (default pjrt)"),
            ("no-frozen!", "disable the pre-quantized-weights fast path"),
            ("listen", "expose the pipeline over HTTP on this address instead"),
        ],
    );
    let backend_name = args.str_or("backend", "pjrt").to_string();
    backend::spec(&backend_name)?;
    let manifest = Manifest::load(&Manifest::default_dir())?;
    // One resolution path for the quantization config — the same
    // `from_cli` mapping the `ilmpq` binary uses.
    let source = QuantSource::from_cli(args.get("plan"), args.get("ratio"), None, "ilmpq2")?;
    let frozen = !args.flag("no-frozen");
    let (be, plan) =
        backend::create_serving(&backend_name, &manifest, &source, frozen, None)?;
    let cfg = ServeConfig {
        workers: args.usize_or("workers", 2),
        max_wait: Duration::from_millis(args.u64_or("max-wait-ms", 5)),
        queue_depth: args.usize_or("queue-depth", 1024),
        plan,
        device: args.str_or("device", "xc7z045").to_string(),
        frozen,
    };
    let device_name = cfg.device.clone();
    println!("backend: {}", be.name());
    let server = Server::start(&manifest, be, cfg)?;
    if let Some(p) = &server.plan {
        println!("plan {:?}: {}", p.name, p.provenance.describe());
    }
    println!("sim-FPGA model for this config: {}", server.sim.row());

    if let Some(addr) = args.get("listen") {
        let mut front = HttpServer::start(
            server,
            &manifest,
            HttpConfig { addr: addr.to_string(), ..Default::default() },
        )?;
        println!(
            "listening on http://{} — POST /v1/infer, GET /v1/healthz, \
             GET /v1/metrics (drive with `ilmpq loadgen --url`)",
            front.local_addr()
        );
        front.wait();
        return Ok(());
    }

    let n = args.usize_or("requests", 1024);
    let rate = args.f64_or("rate", 2000.0);
    println!("open-loop Poisson client: {n} requests at {rate} req/s\n");
    let img = manifest.data.image_elems();
    let (x_test, _) = manifest.data.load_test()?;
    let mut rng = Rng::new(42);
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        let idx = rng.below(manifest.data.n_test);
        pending.push(server.submit(x_test[idx * img..(idx + 1) * img].to_vec()));
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rate)));
    }
    let mut preds = vec![0usize; manifest.classes];
    let mut done = 0usize;
    let mut errors = 0usize;
    let mut lost = 0usize;
    for rx in pending {
        match rx.recv() {
            Ok(Ok(resp)) => {
                preds[resp.pred] += 1;
                done += 1;
            }
            // Typed serving errors (shed under overload, failed batch…) —
            // every request is answered; a closed channel (`lost`) would be
            // a dropped-reply regression.
            Ok(Err(_)) => errors += 1,
            Err(_) => lost += 1,
        }
    }
    let metrics = server.stop();
    println!(
        "completed {done}/{n} ({errors} typed errors, {lost} lost channels); \
         prediction histogram {preds:?}\n"
    );
    println!("{}", metrics.report());

    // Table-I context for the chosen device.
    let net = resnet18();
    if let Some(device) = ilmpq::fpga::DeviceModel::by_name(&device_name) {
        let rows = table1::run_device(&device, &net);
        println!("\nResNet-18 Table-I context on {}:", device.name);
        for r in rows.iter().filter(|r| {
            r.cfg.label.starts_with("(1)") || r.cfg.label.starts_with("ILMPQ")
        }) {
            println!("{}", r.sim.row());
        }
        println!("speedup: {:.2}x", table1::speedup(&rows));
    }
    Ok(())
}
