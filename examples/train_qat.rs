//! End-to-end driver (DESIGN.md §4, exp T1-acc): quantization-aware training
//! of the AOT model from Rust, proving all three layers compose — the Pallas
//! fake-quant kernels (L1) inside the lowered train step (L2) driven by the
//! coordinator (L3), with Python nowhere at runtime.
//!
//! Default: one ILMPQ-2 run with the loss curve logged. `--all-configs`
//! reproduces every Table-I accuracy row (the ImageNet substitute; see
//! EXPERIMENTS.md §T1-acc for the recorded run).
//!
//! ```sh
//! cargo run --release --example train_qat -- --steps 400
//! cargo run --release --example train_qat -- --all-configs --steps 300
//! ```

use ilmpq::coordinator::trainer::Trainer;
use ilmpq::experiments::accuracy;
use ilmpq::runtime::Runtime;
use ilmpq::util::stats::Stopwatch;
use ilmpq::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(
        "train_qat",
        1,
        &[
            ("steps", "QAT steps (default 400)"),
            ("ratio", "manifest ratio name (default ilmpq2)"),
            ("all-configs!", "run every Table-I accuracy row"),
            ("seed", "data order seed (default 2021)"),
            ("seeds", "seed count for --all-configs averaging (default 3)"),
        ],
    );
    let steps = args.usize_or("steps", 400);
    let seed = args.u64_or("seed", 2021);
    let rt = Runtime::load_default()?;
    let mut watch = Stopwatch::new();

    if args.flag("all-configs") {
        let n_seeds = args.usize_or("seeds", 3);
        let seeds: Vec<u64> = (0..n_seeds as u64).map(|i| seed + i).collect();
        let rows = accuracy::run_all(&rt, steps, &seeds, |s| println!("{s}"))?;
        println!("{}", accuracy::render(&rows));
        println!("total {:.1}s", watch.total().as_secs_f64());
        return Ok(());
    }

    let name = args.str_or("ratio", "ilmpq2");
    let masks = rt.manifest.plan(name)?.masks;
    println!(
        "QAT {} with {} ({} steps, batch {})",
        rt.manifest.model_name, name, steps, rt.manifest.train_batch
    );
    let mut tr = Trainer::new(&rt, &masks, seed)?;
    tr.train(steps, 20, |s| {
        println!(
            "step {:>4}  loss {:.4}  train-acc {:.3}  lr {:.4}",
            s.step, s.loss, s.acc, s.lr
        );
    })?;
    let train_time = watch.lap();
    let ev = tr.evaluate()?;
    println!(
        "\nfinal: test loss {:.4}  test acc {:.2}%  ({} steps in {:.1}s, {:.1} ms/step)",
        ev.loss,
        ev.acc * 100.0,
        steps,
        train_time.as_secs_f64(),
        train_time.as_secs_f64() * 1e3 / steps as f64
    );
    let stats = rt.engine.stats();
    println!(
        "engine: {} executions, {:.1}s execute / {:.1}s stage / {:.1}s fetch",
        stats.executions, stats.execute_seconds, stats.stage_seconds, stats.fetch_seconds
    );
    Ok(())
}
