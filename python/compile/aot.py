"""AOT compile path: lower the Layer-2 graphs to HLO text + data artifacts.

Run once via ``make artifacts`` (no-op when inputs are unchanged); after it
completes, the Rust binary is self-contained: Python never executes on the
request path.

Interchange format is **HLO text**, not serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example and
DESIGN.md §6).

Artifacts written to ``artifacts/``:

* ``train_step.hlo.txt``  — QAT fwd+bwd+SGD (masks are runtime inputs).
* ``infer_b{1,8,64}.hlo.txt`` — quantized forward at the serving batch sizes.
* ``eval_batch.hlo.txt``  — loss + accuracy over an eval batch.
* ``hessian_hvp.hlo.txt`` — Hessian-vector product for on-device sensitivity.
* ``params_init.bin``     — He-init parameters (f32, manifest order).
* ``x_train/y_train/x_test/y_test.bin`` — the synthetic dataset (§5).
* ``manifest.json``       — shapes, orders, artifact input/output specs, and
  the default ILMPQ masks (Hessian+variance assignment at init weights).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import assign, data, hessian
from . import model as M

TRAIN_BATCH = 64
EVAL_BATCH = 256
INFER_BATCHES = (1, 8, 64)
HVP_BATCH = 128


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32 if dtype == "f32" else jnp.int32)


def _io_entry(name, arr_spec):
    dt = "i32" if arr_spec.dtype == jnp.int32 else "f32"
    return {"name": name, "shape": list(arr_spec.shape), "dtype": dt}


class Flattener:
    """Positional <-> named packing shared by every artifact.

    Order: params (layer_defs order), then per quantized layer is8/is_pot,
    then the extra inputs. Rust mirrors this from the manifest.
    """

    def __init__(self, cfg: M.ModelConfig):
        self.cfg = cfg
        self.pnames = M.param_names(cfg)
        self.pshapes = dict(M.layer_defs(cfg))
        self.qlayers = M.quantized_layers(cfg)

    def param_specs(self):
        return [(n, _spec(self.pshapes[n])) for n in self.pnames]

    def mask_specs(self):
        out = []
        for name, rows in self.qlayers:
            out.append((name + ":is8", _spec((rows,))))
            out.append((name + ":is_pot", _spec((rows,))))
        return out

    def unpack_params(self, flat):
        return dict(zip(self.pnames, flat))

    def unpack_masks(self, flat):
        return {n: a for (n, _), a in zip(self.mask_specs(), flat)}

    def pack_params(self, params):
        return [params[n] for n in self.pnames]


def build_fns(cfg: M.ModelConfig):
    """The four AOT entry points as positional-arg functions."""
    fl = Flattener(cfg)
    np_ = len(fl.pnames)
    nm = len(fl.mask_specs())

    def train_step(*args):
        params = fl.unpack_params(args[:np_])
        masks = fl.unpack_masks(args[np_ : np_ + nm])
        x, y, lr = args[np_ + nm :]
        new, loss, acc = M.train_step(params, x, y, masks, lr, cfg)
        return tuple(fl.pack_params(new)) + (loss, acc)

    def infer(*args):
        params = fl.unpack_params(args[:np_])
        masks = fl.unpack_masks(args[np_ : np_ + nm])
        (x,) = args[np_ + nm :]
        return (
            M.apply(params, x, masks, cfg, quantize=True, inference_qgemm=True),
        )

    def infer_frozen(*args):
        """Serving fast path: weights arrive PRE-quantized (the Rust
        coordinator freezes them once per config with its bit-exact
        quantizer mirror — the analogue of the FPGA's pre-quantized BRAM
        image), so the graph carries no fake-quant ops at all."""
        params = fl.unpack_params(args[:np_])
        (x,) = args[np_:]
        return (M.apply(params, x, {}, cfg, quantize=False),)

    def eval_batch(*args):
        params = fl.unpack_params(args[:np_])
        masks = fl.unpack_masks(args[np_ : np_ + nm])
        x, y = args[np_ + nm :]
        loss, acc = M.loss_and_acc(params, x, y, masks, cfg)
        return (loss, acc)

    def hvp_fn(*args):
        params = fl.unpack_params(args[:np_])
        v = fl.unpack_params(args[np_ : 2 * np_])
        x, y = args[2 * np_ :]
        hv = hessian.hvp(params, v, x, y, cfg)
        return tuple(fl.pack_params(hv))

    return fl, train_step, infer, infer_frozen, eval_batch, hvp_fn


def _input_hash() -> str:
    """Hash of every compile-path source file — the Makefile staleness key."""
    root = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()[:16]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--seed", type=int, default=2021)
    ap.add_argument("--hessian-iters", type=int, default=6)
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    cfg = M.ModelConfig()
    spec = data.DataSpec(
        height=cfg.height, width=cfg.width, channels=cfg.channels, classes=cfg.classes
    )
    fl, train_step, infer, infer_frozen, eval_batch, hvp_fn = build_fns(cfg)

    # ---- dataset + init params -------------------------------------------
    print("[aot] generating dataset ...")
    data.save(out, spec)
    params = M.init_params(jax.random.key(args.seed), cfg)
    flat = np.concatenate(
        [np.asarray(params[n]).reshape(-1) for n in fl.pnames]
    ).astype("<f4")
    flat.tofile(os.path.join(out, "params_init.bin"))

    # ---- default masks: Hessian eigs at init + variance schemes ----------
    print("[aot] per-filter Hessian power iteration ...")
    ds = data.generate(spec)
    xh = jnp.asarray(ds["x_train"][:HVP_BATCH])
    yh = jnp.asarray(ds["y_train"][:HVP_BATCH])
    eigs = hessian.filter_eigs(params, xh, yh, cfg, iters=args.hessian_iters)
    default_masks = {}
    for rname, ratio in assign.RATIOS.items():
        masks = assign.make_masks(params, cfg, ratio, eigs)
        default_masks[rname] = {
            k: np.asarray(v).astype(int).tolist() for k, v in masks.items()
        }

    # ---- lower the entry points ------------------------------------------
    pspecs = fl.param_specs()
    mspecs = fl.mask_specs()
    manifest_artifacts = {}

    def lower(name, fn, extra_in, outs, n_params_groups=1):
        ins = []
        for g in range(n_params_groups):
            suffix = "" if g == 0 else ":v"
            ins += [(n + suffix, s) for n, s in pspecs]
        # hessian_hvp is unquantized; infer_frozen takes pre-quantized
        # weights — neither carries mask inputs.
        if name != "hessian_hvp" and not name.startswith("infer_frozen"):
            ins += mspecs
        ins += extra_in
        print(f"[aot] lowering {name} ({len(ins)} inputs) ...")
        lowered = jax.jit(fn).lower(*[s for _, s in ins])
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out, fname), "w") as f:
            f.write(text)
        manifest_artifacts[name] = {
            "file": fname,
            "inputs": [_io_entry(n, s) for n, s in ins],
            "outputs": outs,
        }

    hw = (cfg.height, cfg.width, cfg.channels)
    lower(
        "train_step",
        train_step,
        [
            ("x", _spec((TRAIN_BATCH,) + hw)),
            ("y", _spec((TRAIN_BATCH,), "i32")),
            ("lr", _spec(())),
        ],
        [_io_entry(n, s) for n, s in pspecs]
        + [_io_entry("loss", _spec(())), _io_entry("acc", _spec(()))],
    )
    for b in INFER_BATCHES:
        lower(
            f"infer_b{b}",
            infer,
            [("x", _spec((b,) + hw))],
            [_io_entry("logits", _spec((b, cfg.classes)))],
        )
        lower(
            f"infer_frozen_b{b}",
            infer_frozen,
            [("x", _spec((b,) + hw))],
            [_io_entry("logits", _spec((b, cfg.classes)))],
        )
    lower(
        "eval_batch",
        eval_batch,
        [
            ("x", _spec((EVAL_BATCH,) + hw)),
            ("y", _spec((EVAL_BATCH,), "i32")),
        ],
        [_io_entry("loss", _spec(())), _io_entry("acc", _spec(()))],
    )
    lower(
        "hessian_hvp",
        hvp_fn,
        [
            ("x", _spec((HVP_BATCH,) + hw)),
            ("y", _spec((HVP_BATCH,), "i32")),
        ],
        [_io_entry(n, s) for n, s in pspecs],
        n_params_groups=2,
    )

    # ---- manifest ---------------------------------------------------------
    manifest = {
        "version": 1,
        "input_hash": _input_hash(),
        "model": {
            "name": cfg.name,
            "height": cfg.height,
            "width": cfg.width,
            "channels": cfg.channels,
            "classes": cfg.classes,
            "widths": list(cfg.widths),
        },
        "params": [
            {"name": n, "shape": list(s.shape)} for n, s in pspecs
        ],
        "quantized_layers": [
            {
                "name": n,
                "rows": r,
                "fan_in": int(np.prod(fl.pshapes[n][:-1]))
                if len(fl.pshapes[n]) == 4
                else int(fl.pshapes[n][1]),
            }
            for n, r in fl.qlayers
        ],
        "data": {
            "height": spec.height,
            "width": spec.width,
            "channels": spec.channels,
            "classes": spec.classes,
            "n_train": spec.n_train,
            "n_test": spec.n_test,
            "noise": spec.noise,
            "seed": spec.seed,
            "files": {
                "x_train": "x_train.bin",
                "y_train": "y_train.bin",
                "x_test": "x_test.bin",
                "y_test": "y_test.bin",
                "params_init": "params_init.bin",
            },
        },
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "infer_batches": list(INFER_BATCHES),
        "hvp_batch": HVP_BATCH,
        "artifacts": manifest_artifacts,
        "eigs": {n: np.asarray(e).tolist() for n, e in eigs.items()},
        "default_masks": default_masks,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
