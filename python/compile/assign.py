"""Precision & scheme assignment (paper §II-C, steps 1–2).

Given per-filter Hessian eigenvalues and the weight tensors:

1. **bits**: the top ``frac8`` (paper: 5%) filters by eigenvalue in every
   layer are assigned Fixed-8; everything else is 4-bit. At least one row
   per layer is promoted whenever ``frac8 > 0`` so tiny layers (e.g. a
   16-filter stem) still get the paper's "8-bit rescue rows".
2. **scheme**: among the 4-bit rows, those with the *smallest variance* are
   assigned PoT (its levels are densest around zero), the rest Fixed-4.
   The PoT share comes from the offline hardware ratio search
   (``rust/src/coordinator/ratio_search.rs`` — 60:35:5 on XC7Z020,
   65:30:5 on XC7Z045).

Outputs are f32 0/1 masks keyed ``"<layer>:is8"`` / ``"<layer>:is_pot"`` —
the runtime inputs of every AOT artifact. The Rust side re-implements the
same policy (``rust/src/quant/assign.rs``) and the integration tests check
the two agree on identical inputs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


@dataclasses.dataclass(frozen=True)
class Ratio:
    """PoT-4 : Fixed-4 : Fixed-8 percentage split (Table I first column)."""

    pot4: float
    fixed4: float
    fixed8: float

    def __post_init__(self):
        total = self.pot4 + self.fixed4 + self.fixed8
        if abs(total - 100.0) > 1e-6:
            raise ValueError(f"ratio must sum to 100, got {total}")

    @property
    def frac8(self) -> float:
        return self.fixed8 / 100.0

    @property
    def pot_share_of_4bit(self) -> float:
        """Fraction of the 4-bit rows that are PoT."""
        four = self.pot4 + self.fixed4
        return 0.0 if four == 0 else self.pot4 / four

    def label(self) -> str:
        return f"{self.pot4:g}:{self.fixed4:g}:{self.fixed8:g}"


# Table I rows, by name.
RATIOS: dict[str, Ratio] = {
    "fixed4": Ratio(0, 100, 0),
    "pot4": Ratio(100, 0, 0),
    "mixed_50_50": Ratio(50, 50, 0),
    "mixed_60_40": Ratio(60, 40, 0),
    "mixed_67_33": Ratio(67, 33, 0),
    "ilmpq1": Ratio(60, 35, 5),
    "ilmpq2": Ratio(65, 30, 5),
}


def assign_bits(eigs: np.ndarray, frac8: float) -> np.ndarray:
    """Top-``frac8`` rows by eigenvalue -> 8-bit. Returns f32 0/1 ``is8``.

    Ties break toward lower row index (stable argsort) so the assignment is
    deterministic — required for the Rust/Python agreement tests.
    """
    rows = eigs.shape[0]
    n8 = 0 if frac8 <= 0 else max(1, int(round(rows * frac8)))
    is8 = np.zeros(rows, dtype=np.float32)
    if n8 > 0:
        order = np.argsort(-eigs, kind="stable")
        is8[order[:n8]] = 1.0
    return is8


def assign_schemes(
    w_rows: np.ndarray, is8: np.ndarray, pot_share: float
) -> np.ndarray:
    """Low-variance 4-bit rows -> PoT. Returns f32 0/1 ``is_pot``.

    ``w_rows`` is the (rows, fan_in) GEMM view; variance is per row. 8-bit
    rows never get PoT (they are the high-sensitivity fixed-point rows).
    """
    rows = w_rows.shape[0]
    var = w_rows.var(axis=1)
    four_bit = np.where(is8 < 0.5)[0]
    n_pot = int(round(len(four_bit) * pot_share))
    is_pot = np.zeros(rows, dtype=np.float32)
    if n_pot > 0:
        order = four_bit[np.argsort(var[four_bit], kind="stable")]
        is_pot[order[:n_pot]] = 1.0
    return is_pot


def gemm_view_np(w: np.ndarray) -> np.ndarray:
    if w.ndim == 4:
        return np.transpose(w, (3, 0, 1, 2)).reshape(w.shape[3], -1)
    return w.reshape(w.shape[0], -1)


def make_masks(
    params: dict[str, jax.Array],
    cfg: M.ModelConfig,
    ratio: Ratio,
    eigs: dict[str, jax.Array] | None = None,
    *,
    first_last_8bit: bool = False,
) -> dict[str, jax.Array]:
    """Full mask dict for every quantized layer.

    ``eigs=None`` falls back to row L2 norm as the sensitivity proxy (used
    by tests that don't want an HVP); the real pipeline passes
    ``hessian.filter_eigs`` output. ``first_last_8bit=True`` reproduces the
    prior-work baseline rows of Table I ("First/Last Layer Quantization"
    column *unchecked*): stem and fc forced entirely to Fixed-8.
    """
    masks: dict[str, jax.Array] = {}
    qlayers = M.quantized_layers(cfg)
    first, last = qlayers[0][0], qlayers[-1][0]
    for name, rows in qlayers:
        w = np.asarray(params[name])
        w2 = gemm_view_np(w)
        if first_last_8bit and name in (first, last):
            is8 = np.ones(rows, dtype=np.float32)
            ipot = np.zeros(rows, dtype=np.float32)
        else:
            e = (
                np.asarray(eigs[name])
                if eigs is not None
                else np.linalg.norm(w2, axis=1)
            )
            is8 = assign_bits(e, ratio.frac8)
            ipot = assign_schemes(w2, is8, ratio.pot_share_of_4bit)
        masks[name + ":is8"] = jnp.asarray(is8)
        masks[name + ":is_pot"] = jnp.asarray(ipot)
    return masks


def mask_stats(masks: dict[str, jax.Array]) -> dict[str, tuple[int, int, int]]:
    """Per-layer (n_pot4, n_fixed4, n_fixed8) row counts, for reporting."""
    out = {}
    layers = sorted({k.rsplit(":", 1)[0] for k in masks})
    for layer in layers:
        is8 = np.asarray(masks[layer + ":is8"])
        ipot = np.asarray(masks[layer + ":is_pot"])
        n8 = int(is8.sum())
        npot = int(ipot.sum())
        out[layer] = (npot, len(is8) - n8 - npot, n8)
    return out
