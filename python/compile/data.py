"""Synthetic structured image dataset (the ImageNet stand-in, see DESIGN.md §5).

The paper trains ResNet-18 on ImageNet; neither the dataset nor 50-epoch GPU
QAT is available here, so accuracy experiments run on a generated
classification task that is (a) deterministic, (b) shared bit-for-bit between
the Python tests and the Rust end-to-end driver (it is written into
``artifacts/`` at AOT time), and (c) hard enough that quantization schemes
separate: class templates are smooth low-frequency patterns, samples add
per-sample contrast jitter, spatial shift, and broadband noise.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """Shape/content description, mirrored in artifacts/manifest.json."""

    height: int = 16
    width: int = 16
    channels: int = 3
    classes: int = 10
    n_train: int = 4096
    n_test: int = 1024
    # Calibrated so quantization schemes *separate*: at 1.25 the task is
    # hard enough that 4-bit rounding error costs accuracy (ILMPQ's 8-bit
    # rescue rows then measurably help: fp32 0.73 > ilmpq 0.64 > pot4 0.63 >
    # fixed4 0.61 at 400 steps) but easy enough that QAT converges in a few
    # hundred steps. See EXPERIMENTS.md §T1-acc.
    noise: float = 1.25
    seed: int = 2021


def _templates(rng: np.random.Generator, spec: DataSpec) -> np.ndarray:
    """Per-class smooth templates: sum of a few random 2-D cosine modes."""
    h, w, c = spec.height, spec.width, spec.channels
    yy, xx = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    out = np.zeros((spec.classes, h, w, c), dtype=np.float64)
    for k in range(spec.classes):
        for _ in range(4):
            fy, fx = rng.uniform(0.5, 2.5, size=2)
            ph = rng.uniform(0, 2 * np.pi, size=c)
            amp = rng.uniform(0.5, 1.0)
            wave = np.cos(
                2 * np.pi * (fy * yy / h + fx * xx / w)[..., None] + ph
            )
            out[k] += amp * wave
        out[k] /= np.max(np.abs(out[k]))
    return out.astype(np.float32)


def _sample(
    rng: np.random.Generator, templates: np.ndarray, spec: DataSpec, n: int
) -> tuple[np.ndarray, np.ndarray]:
    h, w = spec.height, spec.width
    labels = rng.integers(0, spec.classes, size=n).astype(np.int32)
    x = np.empty((n, h, w, spec.channels), dtype=np.float32)
    for i, lab in enumerate(labels):
        t = templates[lab]
        # Random circular shift (translation invariance pressure).
        sy, sx = rng.integers(-2, 3, size=2)
        t = np.roll(np.roll(t, sy, axis=0), sx, axis=1)
        contrast = rng.uniform(0.7, 1.3)
        noise = rng.normal(0.0, spec.noise, size=t.shape)
        x[i] = contrast * t + noise
    return x, labels


def generate(spec: DataSpec = DataSpec()) -> dict[str, np.ndarray]:
    """Full deterministic dataset: train/test splits from one seeded stream."""
    rng = np.random.default_rng(spec.seed)
    templates = _templates(rng, spec)
    xtr, ytr = _sample(rng, templates, spec, spec.n_train)
    xte, yte = _sample(rng, templates, spec, spec.n_test)
    return {
        "templates": templates,
        "x_train": xtr,
        "y_train": ytr,
        "x_test": xte,
        "y_test": yte,
    }


def save(dirpath: str, spec: DataSpec = DataSpec()) -> dict[str, str]:
    """Write raw little-endian binaries the Rust loader mmaps. Returns paths."""
    import os

    ds = generate(spec)
    paths = {}
    for name in ("x_train", "y_train", "x_test", "y_test"):
        p = os.path.join(dirpath, f"{name}.bin")
        ds[name].astype("<f4" if ds[name].dtype == np.float32 else "<i4").tofile(p)
        paths[name] = p
    return paths
