"""Per-filter Hessian sensitivity (paper §II-C, step 1).

The paper assigns 8-bit precision to the filters whose Hessian diagonal
block has the largest top eigenvalue ("more bits to the most sensitive
weights", a HAWQ-style criterion). We estimate those eigenvalues with
*blockwise power iteration* on Hessian-vector products:

* one HVP per iteration covers *all* filters of a layer at once — filters
  occupy disjoint parameter slices, so keeping an independent probe vector
  per filter row and re-normalizing each row between iterations power-iterates
  every diagonal block simultaneously;
* the per-row Rayleigh quotient ``<v_r, (Hv)_r> / <v_r, v_r>`` after the last
  iteration is the eigenvalue estimate.

The same HVP computation is AOT-lowered (``hessian_hvp`` artifact) so the
Rust coordinator can re-derive sensitivities on device without Python.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import model as M


def hvp(
    params: dict[str, jax.Array],
    v: dict[str, jax.Array],
    x: jax.Array,
    y: jax.Array,
    cfg: M.ModelConfig,
    *,
    quantize: bool = False,
) -> dict[str, jax.Array]:
    """Hessian-vector product of the (unquantized by default) training loss.

    Sensitivity is measured on the float model — the paper computes it
    before QAT to decide the assignment, and the round/clip ops in the
    fake-quantizers have zero second derivative almost everywhere anyway.
    """

    def loss(p):
        return M.loss_and_acc(
            p, x, y, {}, cfg, quantize=quantize, use_pallas=False
        )[0]

    return jax.jvp(jax.grad(loss), (params,), (v,))[1]


def _row_view(a: jax.Array) -> jax.Array:
    """Filter-major 2-D view: HWIO conv -> (out_rows, fan_in)."""
    if a.ndim == 4:
        return jnp.transpose(a, (3, 0, 1, 2)).reshape(a.shape[3], -1)
    return a.reshape(a.shape[0], -1)


def filter_eigs(
    params: dict[str, jax.Array],
    x: jax.Array,
    y: jax.Array,
    cfg: M.ModelConfig,
    *,
    iters: int = 8,
    seed: int = 0,
) -> dict[str, jax.Array]:
    """Largest eigenvalue of each filter's Hessian block, for every layer.

    Returns ``{layer_name: (rows,) eigenvalue estimates}`` for every
    quantized layer. Deterministic given ``seed``.
    """
    key = jax.random.key(seed)
    qnames = [n for n, _ in M.quantized_layers(cfg)]
    v = {}
    for n in params:
        key, sub = jax.random.split(key)
        v[n] = (
            jax.random.normal(sub, params[n].shape, jnp.float32)
            if n in qnames
            else jnp.zeros_like(params[n])
        )

    def renorm(t: jax.Array) -> jax.Array:
        t2 = _row_view(t)
        norms = jnp.maximum(jnp.linalg.norm(t2, axis=1, keepdims=True), 1e-12)
        flat = t2 / norms
        if t.ndim == 4:
            o = t.shape[3]
            return jnp.transpose(
                flat.reshape(o, t.shape[0], t.shape[1], t.shape[2]),
                (1, 2, 3, 0),
            )
        return flat.reshape(t.shape)

    v = {n: (renorm(t) if n in qnames else t) for n, t in v.items()}
    hv = v
    for _ in range(iters):
        hv = hvp(params, v, x, y, cfg)
        # Project: keep only the layer's own block (block-diagonal approx),
        # renormalize per filter row.
        v = {
            n: (renorm(hv[n]) if n in qnames else jnp.zeros_like(hv[n]))
            for n in hv
        }
    # Rayleigh quotient per row from the *last* (v, Hv) pair.
    hv = hvp(params, v, x, y, cfg)
    eigs = {}
    for n in qnames:
        vr = _row_view(v[n])
        hr = _row_view(hv[n])
        eigs[n] = jnp.sum(vr * hr, axis=1)
    return eigs


def hutchinson_trace(
    params: dict[str, jax.Array],
    x: jax.Array,
    y: jax.Array,
    cfg: M.ModelConfig,
    *,
    probes: int = 4,
    seed: int = 0,
) -> dict[str, jax.Array]:
    """Per-filter Hessian trace via Hutchinson probes (fast proxy, ablation).

    ``tr(H_r) = E[v^T H v]`` with Rademacher ``v`` — used by the ablation
    bench to compare against the paper's top-eigenvalue criterion.
    """
    key = jax.random.key(seed)
    qnames = [n for n, _ in M.quantized_layers(cfg)]
    acc = {n: jnp.zeros((_row_view(params[n]).shape[0],)) for n in qnames}
    for _ in range(probes):
        v = {}
        for n in params:
            key, sub = jax.random.split(key)
            v[n] = (
                jnp.sign(jax.random.normal(sub, params[n].shape)).astype(
                    jnp.float32
                )
                if n in qnames
                else jnp.zeros_like(params[n])
            )
        hv = hvp(params, v, x, y, cfg)
        for n in qnames:
            acc[n] = acc[n] + jnp.sum(
                _row_view(v[n]) * _row_view(hv[n]), axis=1
            )
    return {n: a / probes for n, a in acc.items()}
