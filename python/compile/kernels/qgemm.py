"""Layer-1 Pallas kernel: mixed-scheme quantized GEMM.

Software model of the paper's FPGA compute core pair — ``GEMM_Fixed`` (DSP
slices) and ``GEMM_PoT`` (LUT shift-add fabric) — fused into one tiled TPU
kernel. Weight rows arrive as integer codes plus a per-row scale and per-row
scheme masks; the kernel dequantizes a weight tile in VMEM and feeds a dense
f32 contraction to the MXU.

TPU mapping (DESIGN.md §3): the FPGA schedules the two arithmetic lanes in
parallel *within every layer*; on TPU the same intra-layer homogeneity means
every ``(BN, BK)`` weight tile dequantizes with the same vector recipe
(mask-select between shift and multiply) and the MXU never stalls on a
per-layer reconfiguration — the exact analogue of the paper's "uniform PE
configuration for all layers".

Grid is ``(M/BM, N/BN, K/BK)`` with K innermost; the output tile is revisited
across the K steps and accumulated in place (standard Pallas reduction
pattern). ``interpret=True`` for CPU-PJRT executability.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: MXU-aligned 128 lanes on N, 8-row sublanes on M. On real TPU
# BM=BN=128, BK=512 keeps x-tile + w-tile + out-tile < 1 MB VMEM; interpret
# mode uses the same shapes so the lowered structure matches.
DEFAULT_BM = 32
DEFAULT_BN = 32
DEFAULT_BK = 128


def _dequant_tile(codes, scale, is8, ipot):
    """Dequantize a (BN, BK) weight-code tile. Vector-only, no transcendentals.

    fixed: w = c * s / Q          (Q = 7 or 127 by row)
    pot:   w = sign(c) * 2^-(|c|-1) * s, 0 when c == 0
    """
    qmax = jnp.where(is8 > 0.5, 127.0, 7.0)
    fixed = codes * (scale / qmax)
    mag = jnp.abs(codes)
    pot = jnp.sign(codes) * jnp.exp2(-(mag - 1.0)) * scale
    pot = jnp.where(mag < 0.5, 0.0, pot)
    return jnp.where(ipot > 0.5, pot, fixed)


def _mixed_gemm_block(x_ref, c_ref, s_ref, is8_ref, ipot_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    scale = s_ref[...].reshape(-1, 1)
    is8 = is8_ref[...].reshape(-1, 1)
    ipot = ipot_ref[...].reshape(-1, 1)
    w = _dequant_tile(c_ref[...], scale, is8, ipot)
    # (BM, BK) x (BK, BN) on the MXU; accumulate in f32.
    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _pad_to(a: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [( 0, (-d) % m) for d, m in zip(a.shape, mults)]
    if any(p for _, p in pads):
        return jnp.pad(a, pads)
    return a


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def mixed_gemm(
    x: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    is8: jax.Array,
    is_pot: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
) -> jax.Array:
    """``y = x @ dequant(codes).T`` with row-wise mixed schemes.

    ``x``      — ``(M, K)`` activations.
    ``codes``  — ``(N, K)`` integer weight codes as f32 (rows = output chans).
    ``scale``  — ``(N,)`` per-row scales; ``is8``/``is_pot`` — ``(N,)`` masks.
    Returns ``(M, N)`` f32. Oracle: ``ref.mixed_gemm_reference``.
    """
    m, k = x.shape
    n, k2 = codes.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    xp = _pad_to(x, (bm_, bk_))
    cp = _pad_to(codes, (bn_, bk_))
    sp = _pad_to(scale, (bn_,))
    # Padded scale rows are 0 -> qmax division is safe (scale/qmax = 0).
    i8p = _pad_to(is8, (bn_,))
    ipp = _pad_to(is_pot, (bn_,))
    grid = (xp.shape[0] // bm_, cp.shape[0] // bn_, xp.shape[1] // bk_)
    out = pl.pallas_call(
        _mixed_gemm_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn_, bk_), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn_,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn_,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn_,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], cp.shape[0]), jnp.float32),
        interpret=True,
    )(xp, cp, sp, i8p, ipp)
    return out[:m, :n]


def vmem_bytes(bm: int, bn: int, bk: int) -> int:
    """Static VMEM footprint of one grid step (f32): x, codes, 3 row vecs, out.

    Used by the §Perf analysis and asserted < 16 MB by the tests for the
    default and TPU-target tile shapes.
    """
    return 4 * (bm * bk + bn * bk + 3 * bn + bm * bn)


def mxu_utilization(bm: int, bn: int, bk: int) -> float:
    """Fraction of 128x128 MXU lanes a (bm, bn, bk) tile keeps busy."""
    def eff(d: int, lanes: int) -> float:
        full, rem = divmod(d, lanes)
        tiles = full + (1 if rem else 0)
        return d / (tiles * lanes)

    return eff(bm, 128) * eff(bn, 128) * eff(bk, 128)
