"""Layer-1 Pallas kernel: row-wise mixed-scheme fake quantization.

This is the QAT hot-spot: every training step fake-quantizes every weight
matrix row-by-row with the row's assigned (scheme, bits). On FPGA the
corresponding operation is free (weights are stored pre-quantized); on the
training accelerator it is a bandwidth-bound elementwise pass, so the kernel
is tiled over row blocks with the full row resident in VMEM — the per-row
max-reduction (scale) then never leaves the tile.

TPU mapping (see DESIGN.md §3): one grid step processes a ``(BR, cols)``
tile; ``BR`` is picked so the tile plus its three quantized variants fit
VMEM. ``interpret=True`` everywhere — the CPU PJRT client cannot execute
Mosaic custom-calls; numerics are identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12

# Row-block size. 8 rows x up to a few thousand f32 columns x 4 scheme
# variants stays well under a VMEM budget (~16 MB) while keeping the grid
# short; the lane dimension (cols) stays contiguous for the VPU.
DEFAULT_BLOCK_ROWS = 8


def _fake_quant_block(w_ref, is8_ref, ipot_ref, o_ref):
    """Kernel body: mixed fake-quant of one (BR, cols) row block."""
    w = w_ref[...]
    # Per-row scale: max |w| over the full row (whole row is in the tile).
    s = jnp.maximum(jnp.max(jnp.abs(w), axis=1, keepdims=True), _EPS)
    wn = w / s

    # Fixed-point variants (4- and 8-bit symmetric uniform).
    q4 = jnp.clip(jnp.round(wn * 7.0), -7.0, 7.0) * (1.0 / 7.0)
    q8 = jnp.clip(jnp.round(wn * 127.0), -127.0, 127.0) * (1.0 / 127.0)

    # PoT-4: exponents 0..6, zero deadzone below 2^-6.5.
    mag = jnp.abs(wn)
    e = jnp.clip(jnp.round(-jnp.log2(jnp.maximum(mag, _EPS))), 0.0, 6.0)
    p4 = jnp.where(mag < 2.0 ** -6.5, 0.0, jnp.sign(wn) * jnp.exp2(-e))

    is8 = is8_ref[...].reshape(-1, 1)
    ipot = ipot_ref[...].reshape(-1, 1)
    sel = is8 * q8 + (1.0 - is8) * (ipot * p4 + (1.0 - ipot) * q4)
    o_ref[...] = sel * s


@functools.partial(jax.jit, static_argnames=("block_rows",))
def fake_quant_rows(
    w: jax.Array,
    is8: jax.Array,
    is_pot: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> jax.Array:
    """Row-wise mixed-scheme fake-quant of a ``(rows, cols)`` matrix.

    ``is8`` / ``is_pot`` are ``(rows,)`` f32 masks (see
    ``quant.mixed_fake_quant_reference`` for the exact semantics — this kernel
    is asserted allclose against it by ``python/tests/test_kernels.py``).
    """
    rows, cols = w.shape
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
        is8 = jnp.pad(is8, (0, pad))
        is_pot = jnp.pad(is_pot, (0, pad))
    grid = (w.shape[0] // br,)
    out = pl.pallas_call(
        _fake_quant_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        interpret=True,
    )(w, is8, is_pot)
    return out[:rows] if pad else out


def _quant_codes_block(w_ref, is8_ref, ipot_ref, code_ref, scale_ref):
    """Kernel body: emit integer codes + per-row scales for the Rust packer.

    Code convention (matches ``rust/src/quant/packing.rs``):
      * fixed rows  — signed integer code in [-Q, Q] (Q = 7 or 127);
      * PoT rows    — ``sign * (e + 1)`` with 0 the zero code (e in [0, 6]).
    """
    w = w_ref[...]
    s = jnp.maximum(jnp.max(jnp.abs(w), axis=1, keepdims=True), _EPS)
    wn = w / s
    c4 = jnp.clip(jnp.round(wn * 7.0), -7.0, 7.0)
    c8 = jnp.clip(jnp.round(wn * 127.0), -127.0, 127.0)
    mag = jnp.abs(wn)
    e = jnp.clip(jnp.round(-jnp.log2(jnp.maximum(mag, _EPS))), 0.0, 6.0)
    cp = jnp.where(mag < 2.0 ** -6.5, 0.0, jnp.sign(wn) * (e + 1.0))
    is8 = is8_ref[...].reshape(-1, 1)
    ipot = ipot_ref[...].reshape(-1, 1)
    code_ref[...] = is8 * c8 + (1.0 - is8) * (ipot * cp + (1.0 - ipot) * c4)
    scale_ref[...] = s[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def quant_codes_rows(
    w: jax.Array,
    is8: jax.Array,
    is_pot: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
) -> tuple[jax.Array, jax.Array]:
    """Integer codes (as f32) + per-row scales, for packing/inspection."""
    rows, cols = w.shape
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
        is8 = jnp.pad(is8, (0, pad))
        is_pot = jnp.pad(is_pot, (0, pad))
    grid = (w.shape[0] // br,)
    codes, scales = pl.pallas_call(
        _quant_codes_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct((w.shape[0],), w.dtype),
        ],
        interpret=True,
    )(w, is8, is_pot)
    if pad:
        codes, scales = codes[:rows], scales[:rows]
    return codes, scales
