"""Pure-jnp oracles for the Layer-1 Pallas kernels.

Every kernel in this package has an exact reference here; pytest +
hypothesis assert allclose across random shapes, ratios and magnitudes.
The quantizer semantics live in ``compile.quant`` (single source of truth);
this module composes them into the kernel-shaped signatures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import quant


def fake_quant_rows_reference(
    w: jax.Array, is8: jax.Array, is_pot: jax.Array
) -> jax.Array:
    """Oracle for ``quantize.fake_quant_rows``."""
    return quant.mixed_fake_quant_reference(w, is8, is_pot)


def quant_codes_rows_reference(
    w: jax.Array, is8: jax.Array, is_pot: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Oracle for ``quantize.quant_codes_rows``."""
    s = quant.row_scale(w)
    c4 = quant.fixed_codes(w, 4, s)
    c8 = quant.fixed_codes(w, 8, s)
    cp = quant.pot_codes(w, 4, s)
    is8c = is8.reshape(-1, 1)
    ipc = is_pot.reshape(-1, 1)
    codes = is8c * c8 + (1.0 - is8c) * (ipc * cp + (1.0 - ipc) * c4)
    return codes, s[:, 0]


def dequant_codes_reference(
    codes: jax.Array, scale: jax.Array, is8: jax.Array, is_pot: jax.Array
) -> jax.Array:
    """Dequantize integer codes back to f32 weights (rows = output chans)."""
    scale = scale.reshape(-1, 1)
    qmax = jnp.where(is8.reshape(-1, 1) > 0.5, 127.0, 7.0)
    fixed = codes * (scale / qmax)
    mag = jnp.abs(codes)
    pot = jnp.sign(codes) * jnp.exp2(-(mag - 1.0)) * scale
    pot = jnp.where(mag < 0.5, 0.0, pot)
    return jnp.where(is_pot.reshape(-1, 1) > 0.5, pot, fixed)


def mixed_gemm_reference(
    x: jax.Array,
    codes: jax.Array,
    scale: jax.Array,
    is8: jax.Array,
    is_pot: jax.Array,
) -> jax.Array:
    """Oracle for ``qgemm.mixed_gemm``: dequantize then dense matmul."""
    w = dequant_codes_reference(codes, scale, is8, is_pot)
    return x @ w.T


def roundtrip_reference(
    w: jax.Array, is8: jax.Array, is_pot: jax.Array
) -> jax.Array:
    """codes -> dequant must equal the fake-quant output (pack invariant)."""
    codes, s = quant_codes_rows_reference(w, is8, is_pot)
    return dequant_codes_reference(codes, s, is8, is_pot)
