"""Layer-2 JAX model: QAT-capable residual CNN with ILMPQ row-wise masks.

Pure-JAX (no flax): params are a flat ``{name: array}`` dict so the AOT
boundary (Rust feeds/receives positional literals in sorted-name order) stays
trivial. Every conv/fc weight is fake-quantized through the Layer-1 Pallas
kernel with per-row (= per-filter) scheme/precision masks — the paper's
intra-layer multi-precision. Masks are *runtime inputs*, so one lowered
artifact serves every PoT:Fixed4:Fixed8 ratio and every assignment policy.

The architecture is a scaled-down ResNet (stem + 3 residual stages + GAP +
fc), structurally the same family as the paper's ResNet-18; the full
ImageNet ResNet-18 geometry lives in ``rust/src/model/resnet18.rs`` where it
drives the FPGA performance model (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import quant


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    height: int = 16
    width: int = 16
    channels: int = 3
    widths: tuple[int, ...] = (16, 32, 64)
    classes: int = 10

    @property
    def name(self) -> str:
        return "tinyresnet-" + "-".join(map(str, self.widths))


# ---------------------------------------------------------------------------
# Layer inventory. Each quantized layer is (name, out_rows, kind).
# ---------------------------------------------------------------------------


def layer_defs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, weight-shape) for every parameter. HWIO conv layout."""
    defs: list[tuple[str, tuple[int, ...]]] = []
    w0 = cfg.widths[0]
    defs.append(("stem/w", (3, 3, cfg.channels, w0)))
    prev = w0
    for si, wch in enumerate(cfg.widths):
        defs.append((f"s{si}/c1/w", (3, 3, prev, wch)))
        defs.append((f"s{si}/c2/w", (3, 3, wch, wch)))
        if prev != wch:
            defs.append((f"s{si}/proj/w", (1, 1, prev, wch)))
        prev = wch
    defs.append(("fc/w", (cfg.classes, prev)))
    defs.append(("fc/b", (cfg.classes,)))
    return defs


def quantized_layers(cfg: ModelConfig) -> list[tuple[str, int]]:
    """(name, rows) for every weight that carries ILMPQ masks.

    Rows = output channels: a "row" of the GEMM view is one filter, exactly
    the paper's Figure 1 granularity. The fc bias is never quantized.
    """
    out = []
    for name, shape in layer_defs(cfg):
        if name.endswith("/w"):
            rows = shape[-1] if len(shape) == 4 else shape[0]
            out.append((name, rows))
    return out


def param_names(cfg: ModelConfig) -> list[str]:
    return [n for n, _ in layer_defs(cfg)]


def init_params(key: jax.Array, cfg: ModelConfig) -> dict[str, jax.Array]:
    """He-normal init; fc weights scaled down 10x.

    The network has no normalization layers (weights-only quantization keeps
    the hardware story clean), so He-init logits come out ~10x too hot and
    softmax saturates — the 0.1 factor on the head restores initial loss
    ~ln(classes) and is what makes plain SGD converge here.
    """
    params = {}
    for name, shape in layer_defs(cfg):
        key, sub = jax.random.split(key)
        if name == "fc/b":
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = (2.0 / fan_in) ** 0.5
            if name == "fc/w":
                std *= 0.1
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------


def _gemm_view(w: jax.Array) -> jax.Array:
    """HWIO conv weight -> (out_rows, fan_in) GEMM view (rows = filters)."""
    if w.ndim == 4:
        return jnp.transpose(w, (3, 0, 1, 2)).reshape(w.shape[3], -1)
    return w


def _from_gemm_view(w2: jax.Array, like: jax.Array) -> jax.Array:
    if like.ndim == 4:
        h, ww, i, o = like.shape
        return jnp.transpose(w2.reshape(o, h, ww, i), (1, 2, 3, 0))
    return w2


def quantize_weight(
    w: jax.Array,
    masks: dict[str, jax.Array],
    name: str,
    *,
    use_pallas: bool = True,
    enabled: bool = True,
) -> jax.Array:
    """Mixed fake-quant + STE of one weight tensor via its per-row masks."""
    if not enabled:
        return w
    w2 = _gemm_view(w)
    wq2 = quant.mixed_fake_quant_ste(
        w2, masks[name + ":is8"], masks[name + ":is_pot"], use_pallas=use_pallas
    )
    return _from_gemm_view(wq2, w)


def _conv(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def apply(
    params: dict[str, jax.Array],
    x: jax.Array,
    masks: dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    quantize: bool = True,
    use_pallas: bool = True,
    inference_qgemm: bool = False,
) -> jax.Array:
    """Forward pass -> logits ``(batch, classes)``.

    ``inference_qgemm=True`` routes the fc layer through the Layer-1
    ``mixed_gemm`` kernel on integer codes (the FPGA-style integer GEMM) —
    used by the inference artifact; training keeps the STE fake-quant path.
    """
    q: Callable[[str], jax.Array] = lambda n: quantize_weight(
        params[n], masks, n, use_pallas=use_pallas, enabled=quantize
    )
    h = jax.nn.relu(_conv(x, q("stem/w")))
    prev = cfg.widths[0]
    for si, wch in enumerate(cfg.widths):
        stride = 1 if prev == wch else 2
        y = jax.nn.relu(_conv(h, q(f"s{si}/c1/w"), stride))
        y = _conv(y, q(f"s{si}/c2/w"))
        skip = h if prev == wch else _conv(h, q(f"s{si}/proj/w"), stride)
        h = jax.nn.relu(y + skip)
        prev = wch
    h = jnp.mean(h, axis=(1, 2))  # global average pool
    wfc = params["fc/w"]
    if quantize and inference_qgemm:
        from .kernels.quantize import quant_codes_rows
        from .kernels.qgemm import mixed_gemm

        codes, scales = quant_codes_rows(
            wfc, masks["fc/w:is8"], masks["fc/w:is_pot"]
        )
        logits = mixed_gemm(
            h, codes, scales, masks["fc/w:is8"], masks["fc/w:is_pot"]
        )
    else:
        logits = h @ _gemm_view(q("fc/w")).T
    return logits + params["fc/b"]


# ---------------------------------------------------------------------------
# Loss / steps.
# ---------------------------------------------------------------------------


def loss_and_acc(
    params: dict[str, jax.Array],
    x: jax.Array,
    y: jax.Array,
    masks: dict[str, jax.Array],
    cfg: ModelConfig,
    **kw,
) -> tuple[jax.Array, jax.Array]:
    logits = apply(params, x, masks, cfg, **kw)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
    return loss, acc


def train_step(
    params: dict[str, jax.Array],
    x: jax.Array,
    y: jax.Array,
    masks: dict[str, jax.Array],
    lr: jax.Array,
    cfg: ModelConfig,
    *,
    weight_decay: float = 1e-4,
    use_pallas: bool = True,
    quantize: bool = True,
) -> tuple[dict[str, jax.Array], jax.Array, jax.Array]:
    """One QAT SGD step (STE gradients through the fake-quantizers)."""

    def lf(p):
        return loss_and_acc(
            p, x, y, masks, cfg, use_pallas=use_pallas, quantize=quantize
        )

    (loss, acc), grads = jax.value_and_grad(lf, has_aux=True)(params)
    new = {
        n: params[n] - lr * (grads[n] + weight_decay * params[n])
        for n in params
    }
    return new, loss, acc
