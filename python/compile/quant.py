"""Layer-2 quantizers for ILMPQ (fixed-point + power-of-two, row-wise mixed).

Implements the paper's three weight representations:

* ``Fixed-b``  — symmetric uniform fixed-point with ``b`` bits
                 (sign + ``b-1`` magnitude bits), per-row scale.
* ``PoT-b``    — power-of-two: levels ``{0, +/- 2^-e}`` for
                 ``e in [0, 2^(b-1) - 2]``, per-row scale. Multiplication by a
                 PoT weight is a shift on FPGA fabric (LUTs), which is why the
                 low-variance rows are routed to this scheme.
* the ILMPQ mix — every row of a weight matrix carries a (scheme, bits)
                 tag; 5% of rows (most Hessian-sensitive filters) get
                 Fixed-8, the rest split PoT-4 / Fixed-4 by row variance.

All quantizers are *fake-quant* (quantize -> dequantize in f32) wrapped in a
straight-through estimator (STE) for QAT, matching the paper's PyTorch
training setup. The Pallas kernel in ``kernels/quantize.py`` computes the
same function; ``kernels/ref.py`` re-exports these as the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Deadzone guard: |w|/scale below 2^-(emax + 0.5) rounds to exactly 0 in the
# PoT scheme (the all-zeros code). Also used to keep log2 well-defined.
_EPS = 1e-12


def row_scale(w: jax.Array) -> jax.Array:
    """Per-row quantization scale: max |w| along every axis but the first.

    ``w`` is the GEMM view of a weight tensor — shape ``(rows, cols)`` where a
    row is one filter (conv) or one output neuron (fc). Returns ``(rows, 1)``.
    """
    w2 = w.reshape(w.shape[0], -1)
    s = jnp.max(jnp.abs(w2), axis=1, keepdims=True)
    return jnp.maximum(s, _EPS)


def quantize_fixed(w: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """Symmetric uniform fixed-point fake-quant. ``scale`` broadcasts to ``w``.

    Levels: ``q/Q * scale`` for integer ``q in [-Q, Q]``, ``Q = 2^(bits-1)-1``.
    """
    qmax = float(2 ** (bits - 1) - 1)
    wn = w / scale
    q = jnp.clip(jnp.round(wn * qmax), -qmax, qmax)
    return q * (scale / qmax)


def quantize_pot(w: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """Power-of-two fake-quant: levels ``{0} ∪ {± scale * 2^-e}``.

    ``e`` ranges over ``[0, 2^(bits-1) - 2]`` — with 4 bits that is e in
    [0, 6]: one code for zero, one sign bit, seven magnitudes. Exponent is
    the nearest integer to ``-log2(|w|/scale)`` (round-to-nearest in log
    domain), with a deadzone that flushes tiny weights to the zero code.
    """
    emax = float(2 ** (bits - 1) - 2)
    wn = w / scale
    mag = jnp.abs(wn)
    e = jnp.clip(jnp.round(-jnp.log2(jnp.maximum(mag, _EPS))), 0.0, emax)
    pot = jnp.sign(wn) * jnp.exp2(-e)
    # Zero code: anything that would round below the smallest magnitude.
    dead = mag < 2.0 ** (-(emax + 0.5))
    return jnp.where(dead, 0.0, pot) * scale


def fixed_codes(w: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """Integer codes (as f32) for the fixed-point scheme: ``q in [-Q, Q]``."""
    qmax = float(2 ** (bits - 1) - 1)
    return jnp.clip(jnp.round(w / scale * qmax), -qmax, qmax)


def pot_codes(w: jax.Array, bits: int, scale: jax.Array) -> jax.Array:
    """PoT codes as f32: ``sign * (e + 1)`` with 0 reserved for the zero code.

    This is the representation the Rust packer stores in simulated BRAM:
    sign bit + (bits-1)-bit exponent index.
    """
    emax = float(2 ** (bits - 1) - 2)
    wn = w / scale
    mag = jnp.abs(wn)
    e = jnp.clip(jnp.round(-jnp.log2(jnp.maximum(mag, _EPS))), 0.0, emax)
    dead = mag < 2.0 ** (-(emax + 0.5))
    return jnp.where(dead, 0.0, jnp.sign(wn) * (e + 1.0))


def mixed_fake_quant_reference(
    w: jax.Array, is8: jax.Array, is_pot: jax.Array
) -> jax.Array:
    """Pure-jnp ILMPQ row-wise mixed fake-quant (the oracle semantics).

    ``w``      — ``(rows, cols)`` GEMM-view weights.
    ``is8``    — ``(rows,)`` f32 mask, 1.0 where the row is Fixed-8.
    ``is_pot`` — ``(rows,)`` f32 mask, 1.0 where the row is PoT-4.
    Rows with both masks 0 are Fixed-4. Masks are runtime inputs so a single
    lowered artifact serves any PoT:Fixed4:Fixed8 ratio.
    """
    s = row_scale(w)
    f4 = quantize_fixed(w, 4, s)
    f8 = quantize_fixed(w, 8, s)
    p4 = quantize_pot(w, 4, s)
    is8c = is8.reshape(-1, 1)
    ipc = is_pot.reshape(-1, 1)
    return is8c * f8 + (1.0 - is8c) * (ipc * p4 + (1.0 - ipc) * f4)


def ste(w: jax.Array, wq: jax.Array) -> jax.Array:
    """Straight-through estimator: forward ``wq``, gradient of identity."""
    return w + jax.lax.stop_gradient(wq - w)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _fq_ste(w, is8, is_pot, use_pallas):
    """Mixed fake-quant with a custom STE VJP.

    The Pallas kernel (interpret mode) defines no autodiff rules, and the STE
    gradient is the identity anyway, so the whole quantizer is wrapped in a
    ``custom_vjp``: forward runs the kernel, backward passes the cotangent
    straight through to ``w`` (zeros to the masks).
    """
    if use_pallas:
        from .kernels.quantize import fake_quant_rows

        return fake_quant_rows(w, is8, is_pot)
    return mixed_fake_quant_reference(w, is8, is_pot)


def _fq_ste_fwd(w, is8, is_pot, use_pallas):
    return _fq_ste(w, is8, is_pot, use_pallas), None


def _fq_ste_bwd(use_pallas, _res, g):
    return g, None, None


_fq_ste.defvjp(_fq_ste_fwd, _fq_ste_bwd)


def mixed_fake_quant_ste(
    w: jax.Array, is8: jax.Array, is_pot: jax.Array, *, use_pallas: bool = True
) -> jax.Array:
    """QAT entry point: mixed fake-quant with STE.

    ``use_pallas`` selects the Layer-1 Pallas kernel (interpret mode) or the
    pure-jnp oracle; both compute the identical function and pytest asserts
    allclose between them.
    """
    w2 = w.reshape(w.shape[0], -1)
    wq = _fq_ste(w2, is8, is_pot, use_pallas)
    return wq.reshape(w.shape)


def quant_error(w: jax.Array, wq: jax.Array) -> jax.Array:
    """Mean squared quantization error — used by tests and the assign sweep."""
    return jnp.mean((w - wq) ** 2)
