"""Assignment policy (paper §II-C): bits by sensitivity, schemes by variance."""

import hypothesis.strategies as st
import jax
import numpy as np
from hypothesis import given

from compile import assign
from compile import model as M


@given(st.integers(0, 2**31 - 1), st.integers(4, 128))
def test_assign_bits_count(seed, rows):
    rng = np.random.default_rng(seed)
    eigs = rng.random(rows)
    is8 = assign.assign_bits(eigs, 0.05)
    assert is8.sum() == max(1, round(rows * 0.05))
    # Selected rows are exactly the top eigenvalues.
    thresh = np.sort(eigs)[-int(is8.sum())]
    assert np.all(eigs[is8 > 0.5] >= thresh)


def test_assign_bits_zero_frac():
    assert assign.assign_bits(np.ones(10), 0.0).sum() == 0


def test_assign_bits_deterministic_ties():
    eigs = np.array([1.0, 1.0, 1.0, 1.0])
    np.testing.assert_array_equal(
        assign.assign_bits(eigs, 0.5), np.array([1, 1, 0, 0], dtype=np.float32)
    )


@given(st.integers(0, 2**31 - 1))
def test_assign_schemes_low_variance_first(seed):
    rng = np.random.default_rng(seed)
    rows = 20
    w = rng.normal(size=(rows, 16)).astype(np.float32)
    w *= np.linspace(0.01, 2.0, rows)[:, None]  # increasing variance
    is8 = np.zeros(rows, dtype=np.float32)
    ipot = assign.assign_schemes(w, is8, 0.5)
    n = int(ipot.sum())
    var = w.var(axis=1)
    chosen = var[ipot > 0.5]
    rest = var[(ipot < 0.5)]
    assert chosen.max() <= rest.min() + 1e-9
    assert n == 10


def test_schemes_exclude_8bit_rows():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(10, 8)).astype(np.float32)
    is8 = np.zeros(10, dtype=np.float32)
    is8[[2, 5]] = 1.0
    ipot = assign.assign_schemes(w, is8, 1.0)
    assert ipot[2] == 0 and ipot[5] == 0
    assert ipot.sum() == 8


def test_ratio_validation():
    with np.testing.assert_raises(Exception):
        assign.Ratio(60, 35, 10)
    r = assign.Ratio(60, 35, 5)
    assert abs(r.pot_share_of_4bit - 60 / 95) < 1e-12
    assert r.label() == "60:35:5"


def test_make_masks_full_model():
    cfg = M.ModelConfig()
    params = M.init_params(jax.random.key(0), cfg)
    masks = assign.make_masks(params, cfg, assign.RATIOS["ilmpq2"])
    stats = assign.mask_stats(masks)
    for (name, rows) in M.quantized_layers(cfg):
        npot, nf4, n8 = stats[name]
        assert npot + nf4 + n8 == rows, name
        assert n8 >= 1, f"{name}: intra-layer 8-bit rescue rows missing"
    # Aggregate mix should be near 65:30:5.
    tot = np.array([v for v in stats.values()]).sum(axis=0)
    frac = tot / tot.sum()
    assert abs(frac[0] - 0.65) < 0.08
    assert abs(frac[2] - 0.05) < 0.05


def test_make_masks_first_last_8bit():
    cfg = M.ModelConfig()
    params = M.init_params(jax.random.key(0), cfg)
    masks = assign.make_masks(
        params, cfg, assign.RATIOS["fixed4"], first_last_8bit=True
    )
    q = M.quantized_layers(cfg)
    first, last = q[0][0], q[-1][0]
    assert np.all(np.asarray(masks[first + ":is8"]) == 1.0)
    assert np.all(np.asarray(masks[last + ":is8"]) == 1.0)
    # Middle layers stay 4-bit fixed.
    mid = q[1][0]
    assert np.asarray(masks[mid + ":is8"]).sum() == 0
    assert np.asarray(masks[mid + ":is_pot"]).sum() == 0


def test_masks_with_eigs_prefer_sensitive_filters():
    cfg = M.ModelConfig()
    params = M.init_params(jax.random.key(1), cfg)
    # Fake eigs: filter 0 of each layer is the most sensitive.
    eigs = {}
    for name, rows in M.quantized_layers(cfg):
        e = np.linspace(1.0, 0.0, rows)
        eigs[name] = np.asarray(e)
    masks = assign.make_masks(params, cfg, assign.RATIOS["ilmpq1"], eigs)
    for name, rows in M.quantized_layers(cfg):
        assert np.asarray(masks[name + ":is8"])[0] == 1.0, name
