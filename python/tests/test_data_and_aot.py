"""Dataset determinism + AOT lowering smoke tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, data
from compile import model as M


def test_dataset_deterministic():
    spec = data.DataSpec(n_train=64, n_test=16)
    a = data.generate(spec)
    b = data.generate(spec)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_dataset_seed_changes_data():
    a = data.generate(data.DataSpec(n_train=32, n_test=8, seed=1))
    b = data.generate(data.DataSpec(n_train=32, n_test=8, seed=2))
    assert not np.array_equal(a["x_train"], b["x_train"])


def test_dataset_shapes_and_labels():
    spec = data.DataSpec(n_train=48, n_test=16)
    ds = data.generate(spec)
    assert ds["x_train"].shape == (48, 16, 16, 3)
    assert ds["y_train"].shape == (48,)
    assert ds["y_train"].min() >= 0 and ds["y_train"].max() < spec.classes
    assert ds["x_train"].dtype == np.float32


def test_dataset_is_learnable_but_not_trivial():
    """Nearest-template classification should beat chance but not saturate
    — the noise level is what separates the quantization configs."""
    spec = data.DataSpec(n_train=256, n_test=64)
    ds = data.generate(spec)
    t = ds["templates"].reshape(spec.classes, -1)
    x = ds["x_test"].reshape(len(ds["x_test"]), -1)
    # Correlation classifier.
    xn = x / np.linalg.norm(x, axis=1, keepdims=True)
    tn = t / np.linalg.norm(t, axis=1, keepdims=True)
    pred = (xn @ tn.T).argmax(axis=1)
    acc = (pred == ds["y_test"]).mean()
    assert acc > 0.5, f"too hard: {acc}"
    assert acc < 1.0, f"too easy: {acc}"


def test_save_writes_little_endian(tmp_path=None):
    with tempfile.TemporaryDirectory() as d:
        spec = data.DataSpec(n_train=8, n_test=4)
        paths = data.save(d, spec)
        x = np.fromfile(paths["x_train"], dtype="<f4")
        assert x.shape[0] == 8 * 16 * 16 * 3
        y = np.fromfile(paths["y_train"], dtype="<i4")
        assert y.shape[0] == 8


def test_hlo_text_lowering_smoke():
    """The aot helper must emit parseable HLO text with the right entry."""
    cfg = M.ModelConfig(widths=(8, 16), height=8, width=8)
    fl, train_step, infer, infer_frozen, eval_batch, hvp_fn = aot.build_fns(cfg)
    pspecs = fl.param_specs()
    mspecs = fl.mask_specs()
    ins = [s for _, s in pspecs] + [s for _, s in mspecs]
    ins += [
        jax.ShapeDtypeStruct((4, 8, 8, 3), jnp.float32),
    ]
    lowered = jax.jit(infer).lower(*ins)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True -> tuple root.
    assert "tuple" in text


def test_flattener_roundtrip():
    cfg = M.ModelConfig()
    fl = aot.Flattener(cfg)
    params = M.init_params(jax.random.key(0), cfg)
    flat = fl.pack_params(params)
    back = fl.unpack_params(flat)
    assert set(back.keys()) == set(params.keys())
    for n in params:
        np.testing.assert_array_equal(back[n], params[n])


def test_input_hash_stable():
    a = aot._input_hash()
    b = aot._input_hash()
    assert a == b and len(a) == 16
