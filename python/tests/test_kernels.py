"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, scheme mixes, and magnitudes; every kernel output
must match `ref.py` to float tolerance. This is the CORE correctness signal
for the compute layer (the same kernels are embedded in every AOT artifact).
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile import quant
from compile.kernels import qgemm, quantize, ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=30, derandomize=True
)
hypothesis.settings.load_profile("ci")


def _masks(rng, rows, p8=0.2, ppot=0.5):
    is8 = (rng.random(rows) < p8).astype(np.float32)
    is_pot = ((rng.random(rows) < ppot) & (is8 < 0.5)).astype(np.float32)
    return jnp.asarray(is8), jnp.asarray(is_pot)


@st.composite
def matrix_case(draw):
    rows = draw(st.integers(1, 40))
    cols = draw(st.integers(1, 70))
    seed = draw(st.integers(0, 2**31 - 1))
    scale = draw(st.floats(0.01, 100.0))
    return rows, cols, seed, scale


@given(matrix_case())
def test_fake_quant_rows_matches_reference(case):
    rows, cols, seed, scale = case
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32) * scale)
    is8, is_pot = _masks(rng, rows)
    got = quantize.fake_quant_rows(w, is8, is_pot)
    want = ref.fake_quant_rows_reference(w, is8, is_pot)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5 * scale)


@given(matrix_case())
def test_quant_codes_match_reference(case):
    rows, cols, seed, scale = case
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32) * scale)
    is8, is_pot = _masks(rng, rows)
    codes, scales = quantize.quant_codes_rows(w, is8, is_pot)
    codes_ref, scales_ref = ref.quant_codes_rows_reference(w, is8, is_pot)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(codes_ref))
    np.testing.assert_allclose(scales, scales_ref, rtol=1e-6)


@given(matrix_case())
def test_codes_are_integers_in_range(case):
    rows, cols, seed, scale = case
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32) * scale)
    is8, is_pot = _masks(rng, rows)
    codes = np.asarray(quantize.quant_codes_rows(w, is8, is_pot)[0])
    assert np.all(codes == np.round(codes))
    lim = np.where(np.asarray(is8)[:, None] > 0.5, 127.0, 7.0)
    assert np.all(np.abs(codes) <= lim)


@given(matrix_case(), st.integers(1, 24))
def test_mixed_gemm_matches_reference(case, m):
    rows, cols, seed, scale = case
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(rows, cols)).astype(np.float32) * scale)
    x = jnp.asarray(rng.normal(size=(m, cols)).astype(np.float32))
    is8, is_pot = _masks(rng, rows)
    codes, scales = ref.quant_codes_rows_reference(w, is8, is_pot)
    got = qgemm.mixed_gemm(x, codes, scales, is8, is_pot)
    want = ref.mixed_gemm_reference(x, codes, scales, is8, is_pot)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * cols)


def test_mixed_gemm_tiling_independence():
    """Result must not depend on the tile shape (pure scheduling knob)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(33, 130)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(37, 130)).astype(np.float32))
    is8, is_pot = _masks(rng, 37)
    codes, scales = ref.quant_codes_rows_reference(w, is8, is_pot)
    base = qgemm.mixed_gemm(x, codes, scales, is8, is_pot)
    for bm, bn, bk in [(8, 8, 32), (16, 32, 64), (32, 16, 128)]:
        out = qgemm.mixed_gemm(x, codes, scales, is8, is_pot, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(out, base, rtol=1e-5, atol=1e-4)


def test_dequant_roundtrip_equals_fake_quant():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(20, 31)).astype(np.float32))
    is8, is_pot = _masks(rng, 20)
    rt = ref.roundtrip_reference(w, is8, is_pot)
    fq = ref.fake_quant_rows_reference(w, is8, is_pot)
    np.testing.assert_allclose(rt, fq, rtol=1e-6, atol=1e-6)


def test_block_rows_padding_path():
    """Rows not divisible by the block size exercise the padding path."""
    rng = np.random.default_rng(11)
    w = jnp.asarray(rng.normal(size=(13, 17)).astype(np.float32))
    is8, is_pot = _masks(rng, 13)
    a = quantize.fake_quant_rows(w, is8, is_pot, block_rows=8)
    b = quantize.fake_quant_rows(w, is8, is_pot, block_rows=13)
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_vmem_budget_of_default_tiles():
    """Perf guardrail: default + TPU-target tiles fit a 16 MB VMEM."""
    assert qgemm.vmem_bytes(qgemm.DEFAULT_BM, qgemm.DEFAULT_BN, qgemm.DEFAULT_BK) < 16 * 2**20
    assert qgemm.vmem_bytes(128, 128, 512) < 16 * 2**20


def test_mxu_utilization_model():
    assert qgemm.mxu_utilization(128, 128, 128) == 1.0
    assert qgemm.mxu_utilization(64, 128, 128) == 0.5
    assert 0.0 < qgemm.mxu_utilization(32, 32, 128) < 0.1


def test_all_pot_masks():
    """Degenerate mixes: 100% PoT and 100% Fixed-8 still agree with ref."""
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(size=(9, 12)).astype(np.float32))
    ones = jnp.ones(9)
    zeros = jnp.zeros(9)
    for is8, ipot in [(zeros, ones), (ones, zeros), (zeros, zeros)]:
        got = quantize.fake_quant_rows(w, is8, ipot)
        want = ref.fake_quant_rows_reference(w, is8, ipot)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_ste_gradient_is_identity_for_weights():
    """The custom VJP must pass cotangents straight through to w."""
    rng = np.random.default_rng(9)
    w = jnp.asarray(rng.normal(size=(6, 10)).astype(np.float32))
    is8, is_pot = _masks(rng, 6)

    def f(w):
        return jnp.sum(quant.mixed_fake_quant_ste(w, is8, is_pot) ** 2 / 2)

    g = jax.grad(f)(w)
    # STE: d/dw sum(q(w)^2/2) = q(w) * dq/dw = q(w) * 1.
    q = quant.mixed_fake_quant_reference(w, is8, is_pot)
    np.testing.assert_allclose(g, q, rtol=1e-5, atol=1e-6)
