"""L2 model: shapes, gradient flow, QAT step behaviour, pallas/ref parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import assign, data, hessian
from compile import model as M

CFG = M.ModelConfig()


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.key(0), CFG)


@pytest.fixture(scope="module")
def masks(params):
    return assign.make_masks(params, CFG, assign.RATIOS["ilmpq1"])


@pytest.fixture(scope="module")
def batch():
    ds = data.generate(data.DataSpec(n_train=128, n_test=32))
    return jnp.asarray(ds["x_train"][:16]), jnp.asarray(ds["y_train"][:16])


def test_param_shapes_match_layer_defs(params):
    for name, shape in M.layer_defs(CFG):
        assert params[name].shape == shape, name


def test_quantized_layers_rows(params):
    for name, rows in M.quantized_layers(CFG):
        w = params[name]
        expected = w.shape[-1] if w.ndim == 4 else w.shape[0]
        assert rows == expected, name


def test_forward_shapes(params, masks, batch):
    x, _ = batch
    logits = M.apply(params, x, masks, CFG)
    assert logits.shape == (16, CFG.classes)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_pallas_and_reference_paths_agree(params, masks, batch):
    x, _ = batch
    a = M.apply(params, x, masks, CFG, use_pallas=True)
    b = M.apply(params, x, masks, CFG, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_inference_qgemm_path_agrees(params, masks, batch):
    x, _ = batch
    a = M.apply(params, x, masks, CFG, inference_qgemm=True)
    b = M.apply(params, x, masks, CFG, inference_qgemm=False)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_unquantized_differs_from_quantized(params, masks, batch):
    x, _ = batch
    q = M.apply(params, x, masks, CFG, quantize=True)
    f = M.apply(params, x, masks, CFG, quantize=False)
    assert float(jnp.max(jnp.abs(q - f))) > 1e-4


def test_gradients_flow_through_ste(params, masks, batch):
    x, y = batch

    def loss(p):
        return M.loss_and_acc(p, x, y, masks, CFG)[0]

    grads = jax.grad(loss)(params)
    for name, g in grads.items():
        norm = float(jnp.linalg.norm(g))
        assert np.isfinite(norm), name
        assert norm > 0, f"{name}: zero gradient (STE broken)"


def test_train_step_reduces_loss(params, masks, batch):
    x, y = batch
    p = params
    first = None
    for _ in range(8):
        p, loss, _ = M.train_step(p, x, y, masks, jnp.float32(0.05), CFG)
        first = first if first is not None else float(loss)
    assert float(loss) < first, f"loss {first} -> {float(loss)}"


def test_train_step_keeps_shapes(params, masks, batch):
    x, y = batch
    new, loss, acc = M.train_step(params, x, y, masks, jnp.float32(0.01), CFG)
    for name in params:
        assert new[name].shape == params[name].shape
    assert loss.shape == () and acc.shape == ()
    assert 0.0 <= float(acc) <= 1.0


def test_hvp_linearity(params, batch):
    """H(a v + b w) == a Hv + b Hw — exact for any network (finite
    differences are useless here: ReLU makes the loss piecewise linear, so
    FD across kinks is garbage; linearity/symmetry are the right checks)."""
    x, y = batch
    k1, k2 = jax.random.split(jax.random.key(5))
    v = {n: jax.random.normal(k1, p.shape, p.dtype) for n, p in params.items()}
    w = {n: jax.random.normal(k2, p.shape, p.dtype) for n, p in params.items()}
    a, b = 0.7, -1.3
    lin = hessian.hvp(
        params, {n: a * v[n] + b * w[n] for n in params}, x, y, CFG
    )
    hv = hessian.hvp(params, v, x, y, CFG)
    hw = hessian.hvp(params, w, x, y, CFG)
    for name in params:
        want = a * np.asarray(hv[name]) + b * np.asarray(hw[name])
        got = np.asarray(lin[name])
        scale = np.abs(want).max() + 1e-5
        assert np.abs(got - want).max() / scale < 1e-3, name


def test_hvp_symmetry(params, batch):
    """<u, Hv> == <v, Hu> (Hessian symmetry), a global exact identity."""
    x, y = batch
    k1, k2 = jax.random.split(jax.random.key(6))
    u = {n: jax.random.normal(k1, p.shape, p.dtype) for n, p in params.items()}
    v = {n: jax.random.normal(k2, p.shape, p.dtype) for n, p in params.items()}
    hv = hessian.hvp(params, v, x, y, CFG)
    hu = hessian.hvp(params, u, x, y, CFG)
    dot = lambda a, b: sum(
        float(jnp.vdot(a[n], b[n])) for n in a
    )
    uhv, vhu = dot(u, hv), dot(v, hu)
    assert abs(uhv - vhu) / (abs(uhv) + 1e-6) < 1e-3, (uhv, vhu)


def test_filter_eigs_shapes_and_nonnegative_mass(params, batch):
    x, y = batch
    eigs = hessian.filter_eigs(params, x, y, CFG, iters=3)
    for name, rows in M.quantized_layers(CFG):
        assert eigs[name].shape == (rows,), name
    # Power iteration on a loss Hessian: the dominant per-row values should
    # be mostly positive (the loss is locally convex in most filters).
    all_vals = np.concatenate([np.asarray(v) for v in eigs.values()])
    assert (all_vals > 0).mean() > 0.6
