"""Numpy/JAX mirror of the Rust `quant::qgemm` subsystem.

This is the validation artifact for the native packed-code GEMM: it mirrors,
in numpy integer arithmetic, exactly what `rust/src/quant/qgemm.rs` computes
per scheme — Fixed-8 i8 MACs, Fixed-4 nibble pairs, PoT-4 branch-free
shift-adds with the 2^-emax epilogue fold — on codes produced by the same
packing rules as `rust/src/quant/packing.rs` (row max-abs scale, fixed
`clip(round(w/s·Q))`, PoT `sign·(e+1)`), and checks that

  1. each integer kernel equals the dequantize-then-f32-GEMM reference on
     the operands the kernel actually sees (per-scheme parity),
  2. the Fixed-8 path is exactly integer-deterministic across row
     partitions (the thread-split invariant),
  3. the Rust `im2col` recipe (fan-in order (kh, kw, in_ch), TF/JAX SAME
     padding, ceil(in/stride) output) matches `jax.lax.conv_general_dilated`.

Unlike the other files in this directory it needs only numpy + jax (no
hypothesis), and can be run standalone: `python3 tests/test_qgemm_mirror.py`.
"""

import numpy as np

ACT_QMAX = 127.0
POT_EMAX = 6  # 4-bit PoT: e in [0, 6], code = sign * (e + 1)


# ---------------------------------------------------------------- packing --

def row_scale(row):
    return np.float32(max(np.abs(row.astype(np.float32)).max(), 1e-12))

def fixed_codes(row, bits, scale):
    q = float(2 ** (bits - 1) - 1)
    c = np.round(row.astype(np.float32) / scale * np.float32(q))
    return np.clip(c, -q, q).astype(np.int32)

def pot_codes(row, scale):
    wn = row.astype(np.float32) / scale
    mag = np.abs(wn)
    e = np.round(-np.log2(np.maximum(mag, 1e-12))).clip(0, POT_EMAX)
    code = np.where(wn < 0, -(e + 1), e + 1).astype(np.int32)
    return np.where(mag < 2.0 ** -(POT_EMAX + 0.5), 0, code)

def dequant_codes(codes, scheme, scale):
    if scheme == "pot4":
        e = np.abs(codes) - 1
        mag = np.where(codes == 0, 0.0, 2.0 ** (-e.astype(np.float64)))
        return (np.sign(codes) * mag * scale).astype(np.float32)
    q = 127.0 if scheme == "fixed8" else 7.0
    return (codes.astype(np.float32) * np.float32(scale / q))

def pack(w, schemes):
    """Per-row codes + scales under a per-row scheme assignment."""
    scales = np.array([row_scale(r) for r in w], dtype=np.float32)
    codes = []
    for r, scheme in zip(w, schemes):
        if scheme == "fixed8":
            codes.append(fixed_codes(r, 8, scales[len(codes)]))
        elif scheme == "fixed4":
            codes.append(fixed_codes(r, 4, scales[len(codes)]))
        else:
            codes.append(pot_codes(r, scales[len(codes)]))
    return codes, scales


# ------------------------------------------------------------ activations --

def quantize_acts(x):
    """Per-row signed 8-bit with max-abs scale; mirrors QuantizedActs."""
    scales = np.maximum(np.abs(x).max(axis=1), 1e-12).astype(np.float32)
    inv = np.float32(ACT_QMAX) / scales[:, None]
    codes = np.clip(np.round(x * inv), -ACT_QMAX, ACT_QMAX).astype(np.int32)
    return codes, (scales / np.float32(ACT_QMAX)).astype(np.float32)


# -------------------------------------------------------- integer kernels --

def qgemm_mirror(act_codes, act_scales, w_codes, w_schemes, w_scales):
    """Integer GEMM over codes, one f32 epilogue multiply per element —
    the exact arithmetic of `row_block` in qgemm.rs."""
    m = act_codes.shape[0]
    out = np.zeros((m, len(w_codes)), dtype=np.float32)
    for r, (codes, scheme) in enumerate(zip(w_codes, w_schemes)):
        if scheme == "pot4":
            # acc += sign(c) * (x << (7 - |c|)); scale/64 epilogue fold.
            shift = (7 - np.abs(codes)).clip(0, 7)
            term = np.sign(codes) * (act_codes * (1 << shift).astype(np.int64))
            acc = term.sum(axis=1)
            post = np.float32(w_scales[r] / 64.0)
        else:
            q = 127.0 if scheme == "fixed8" else 7.0
            acc = (act_codes.astype(np.int64) * codes.astype(np.int64)).sum(axis=1)
            post = np.float32(w_scales[r] / q)
        out[:, r] = (acc.astype(np.float32) * (act_scales * post)).astype(np.float32)
    return out


def reference(act_codes, act_scales, w_codes, w_schemes, w_scales):
    """Dequantize both operands, f32 GEMM — what the Rust prop test uses."""
    acts = act_codes.astype(np.float32) * act_scales[:, None]
    w = np.stack([
        dequant_codes(c, s, sc)
        for c, s, sc in zip(w_codes, w_schemes, w_scales)
    ])
    return acts @ w.T


def random_case(rng, rows, cols, m, schemes=None):
    w = (rng.standard_normal((rows, cols)) * rng.uniform(0.1, 3.0)).astype(np.float32)
    x = (rng.standard_normal((m, cols)) * 2.0).astype(np.float32)
    if schemes is None:
        schemes = rng.choice(["fixed8", "fixed4", "pot4"], size=rows)
    w_codes, w_scales = pack(w, schemes)
    a_codes, a_scales = quantize_acts(x)
    return a_codes, a_scales, w_codes, schemes, w_scales


def test_kernel_parity_all_schemes():
    rng = np.random.default_rng(81)
    worst = 0.0
    for scheme in ["fixed8", "fixed4", "pot4", None]:  # None = mixed rows
        for _ in range(8):
            rows, cols, m = rng.integers(1, 16), rng.integers(1, 40), rng.integers(1, 7)
            sch = None if scheme is None else np.array([scheme] * rows)
            case = random_case(rng, int(rows), int(cols), int(m), sch)
            got = qgemm_mirror(*case)
            want = reference(*case)
            denom = max(1.0, np.abs(want).max())
            worst = max(worst, float(np.abs(got - want).max() / denom))
    print(f"kernel parity worst rel err: {worst:.3g}")
    assert worst < 1e-4


def test_fixed8_integer_determinism_across_partitions():
    """Same accumulations regardless of how rows are partitioned — the
    bit-exactness-across-thread-counts invariant, replayed in int64."""
    rng = np.random.default_rng(17)
    case = random_case(rng, 48, 384, 32, np.array(["fixed8"] * 48))
    whole = qgemm_mirror(*case)
    a_codes, a_scales, w_codes, schemes, w_scales = case
    for split in [2, 3, 5]:
        parts = []
        for idx in np.array_split(np.arange(48), split):
            parts.append(qgemm_mirror(
                a_codes, a_scales,
                [w_codes[i] for i in idx], schemes[idx], w_scales[idx]))
        stitched = np.concatenate(parts, axis=1)
        assert np.array_equal(whole.view(np.uint32), stitched.view(np.uint32))


# ----------------------------------------------------------------- im2col --

def im2col_mirror(x, kh, kw, stride):
    """The Rust recipe: SAME padding, ceil(in/stride) out, (kh, kw, ic) order."""
    b, ih, iw, ic = x.shape
    oh, ow = -(-ih // stride), -(-iw // stride)
    pt = max((oh - 1) * stride + kh - ih, 0) // 2
    pl = max((ow - 1) * stride + kw - iw, 0) // 2
    out = np.zeros((b * oh * ow, kh * kw * ic), dtype=np.float32)
    row = 0
    for bi in range(b):
        for oy in range(oh):
            for ox in range(ow):
                patch = np.zeros((kh, kw, ic), dtype=np.float32)
                for ky in range(kh):
                    iy = oy * stride + ky - pt
                    if not 0 <= iy < ih:
                        continue
                    for kx in range(kw):
                        ix = ox * stride + kx - pl
                        if 0 <= ix < iw:
                            patch[ky, kx] = x[bi, iy, ix]
                out[row] = patch.reshape(-1)
                row += 1
    return out, oh, ow


def test_im2col_matches_jax_same_conv():
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(21)
    worst = 0.0
    for (ih, iw, ic, kk, stride, oc) in [
        (6, 6, 3, 3, 1, 4), (7, 5, 2, 3, 2, 3), (8, 8, 4, 1, 2, 5), (5, 5, 1, 3, 1, 2),
    ]:
        b = 2
        x = rng.standard_normal((b, ih, iw, ic)).astype(np.float32)
        w_hwio = rng.standard_normal((kk, kk, ic, oc)).astype(np.float32)
        col, oh, ow = im2col_mirror(x, kk, kk, stride)
        # GEMM weight rows are (out_ch, kh*kw*ic) in the same fan-in order.
        w_rows = np.moveaxis(w_hwio, -1, 0).reshape(oc, -1)
        got = (col @ w_rows.T).reshape(b, oh, ow, oc)
        want = jax.lax.conv_general_dilated(
            jnp.asarray(x), jnp.asarray(w_hwio),
            window_strides=(stride, stride), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        worst = max(worst, float(np.abs(got - np.asarray(want)).max()))
    print(f"im2col vs jax SAME conv worst abs err: {worst:.3g}")
    assert worst < 1e-4


if __name__ == "__main__":
    test_kernel_parity_all_schemes()
    test_fixed8_integer_determinism_across_partitions()
    test_im2col_matches_jax_same_conv()
    print("qgemm mirror: all checks passed")
