"""L2 quantizer properties: fixed-point and PoT semantics (paper §II)."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from compile import quant


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8]))
def test_fixed_error_bound(seed, bits):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(5, 17)).astype(np.float32))
    s = quant.row_scale(w)
    wq = quant.quantize_fixed(w, bits, s)
    step = np.asarray(s) / (2 ** (bits - 1) - 1)
    assert np.all(np.abs(np.asarray(w - wq)) <= step / 2 + 1e-7)


@given(st.integers(0, 2**31 - 1))
def test_fixed_idempotent(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(4, 9)).astype(np.float32))
    s = quant.row_scale(w)
    once = quant.quantize_fixed(w, 4, s)
    twice = quant.quantize_fixed(once, 4, s)
    np.testing.assert_allclose(once, twice, atol=1e-7)


@given(st.integers(0, 2**31 - 1))
def test_pot_levels_are_powers_of_two(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(6, 11)).astype(np.float32))
    s = quant.row_scale(w)
    wq = np.asarray(quant.quantize_pot(w, 4, s)) / np.asarray(s)
    nz = wq[np.abs(wq) > 0]
    logs = np.log2(np.abs(nz))
    np.testing.assert_allclose(logs, np.round(logs), atol=1e-5)
    assert np.all(np.round(logs) <= 0) and np.all(np.round(logs) >= -6)


def test_pot_deadzone_flushes_to_zero():
    w = jnp.asarray([[1.0, 0.005, 0.012, -0.002]], dtype=jnp.float32)
    s = quant.row_scale(w)
    wq = np.asarray(quant.quantize_pot(w, 4, s))[0]
    assert wq[1] == 0.0 and wq[3] == 0.0
    assert wq[0] == 1.0
    assert wq[2] != 0.0  # 0.012 > 2^-6.5 ~ 0.0110


def test_pot_resolution_denser_near_zero_than_fixed():
    """The paper's §II-C rationale: for small weights PoT has finer steps."""
    small = jnp.asarray([[1.0, 0.031, 0.033, 0.06]], dtype=jnp.float32)
    s = quant.row_scale(small)
    pot_err = float(quant.quant_error(small, quant.quantize_pot(small, 4, s)))
    fix_err = float(quant.quant_error(small, quant.quantize_fixed(small, 4, s)))
    assert pot_err < fix_err


def test_fixed_better_for_uniform_mass():
    """Conversely, fixed-point wins on weights spread across the range."""
    w = jnp.asarray([np.linspace(-1, 1, 64).astype(np.float32)])
    s = quant.row_scale(w)
    pot_err = float(quant.quant_error(w, quant.quantize_pot(w, 4, s)))
    fix_err = float(quant.quant_error(w, quant.quantize_fixed(w, 4, s)))
    assert fix_err < pot_err


@given(st.integers(0, 2**31 - 1))
def test_mixed_reference_selects_by_mask(seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(8, 13)).astype(np.float32))
    s = quant.row_scale(w)
    is8 = jnp.asarray((rng.random(8) < 0.3).astype(np.float32))
    ipot = jnp.asarray(
        ((rng.random(8) < 0.5) & (np.asarray(is8) < 0.5)).astype(np.float32)
    )
    out = np.asarray(quant.mixed_fake_quant_reference(w, is8, ipot))
    f4 = np.asarray(quant.quantize_fixed(w, 4, s))
    f8 = np.asarray(quant.quantize_fixed(w, 8, s))
    p4 = np.asarray(quant.quantize_pot(w, 4, s))
    for r in range(8):
        want = f8[r] if is8[r] > 0.5 else (p4[r] if ipot[r] > 0.5 else f4[r])
        np.testing.assert_allclose(out[r], want, atol=1e-7)


def test_error_ordering_8bit_beats_4bit():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    s = quant.row_scale(w)
    e8 = float(quant.quant_error(w, quant.quantize_fixed(w, 8, s)))
    e4 = float(quant.quant_error(w, quant.quantize_fixed(w, 4, s)))
    assert e8 < e4 / 10  # 16x finer steps -> ~256x lower MSE


def test_row_scale_shape_and_floor():
    w = jnp.zeros((3, 4))
    s = np.asarray(quant.row_scale(w))
    assert s.shape == (3, 1)
    assert np.all(s > 0)
