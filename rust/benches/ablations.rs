//! Bench: ablations of the paper's §II-C design choices.
//!
//! 1. **Scheme policy** — quantization error (MSE) of variance-sorted PoT
//!    assignment vs random vs inverse, on the real init weights: the paper's
//!    low-variance→PoT rule should have the lowest error.
//! 2. **Bits policy** — Hessian-eig 8-bit pick vs random, measured as the
//!    total sensitivity mass (Σ eig over 8-bit rows) the policy protects.
//! 3. **Intra vs inter** — the execution-mode ablation across every mixed
//!    ratio, isolating the paper's central architectural claim.
//! 4. **frac8 sweep** — how much Fixed-8 the intra-layer budget can afford
//!    before the DSP lane becomes the bottleneck (why the paper picks 5%).
//!
//! ```sh
//! cargo bench --bench ablations
//! ```

use ilmpq::baselines::ablation::Policy;
use ilmpq::fpga::{simulate, DeviceModel, Mode, NetConfig};
use ilmpq::model::resnet18;
use ilmpq::quant::{fixed, gemm_rows, pot, Ratio, Scheme};
use ilmpq::runtime::Runtime;
use ilmpq::util::Rng;

fn quant_mse(rows: &[Vec<f32>], masks: &ilmpq::quant::LayerMasks) -> f64 {
    let (mut err, mut n) = (0f64, 0usize);
    for (r, row) in rows.iter().enumerate() {
        let scale = ilmpq::quant::row_scale(row);
        for &w in row {
            let q = match masks.scheme_of(r) {
                Scheme::Pot4 => pot::fake_quant(w, 4, scale),
                Scheme::Fixed4 => fixed::fake_quant(w, 4, scale),
                Scheme::Fixed8 => fixed::fake_quant(w, 8, scale),
            };
            err += ((w - q) as f64).powi(2);
            n += 1;
        }
    }
    err / n as f64
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load_default()?;
    let m = &rt.manifest;
    let params = m.load_init_params()?;
    let ratio = Ratio::parse("65:30:5").unwrap();

    // ---- 1+2: assignment-policy ablation on real weights -------------------
    println!("== §II-C ablation: assignment policy vs quantization error ==");
    println!(
        "{:<24} {:>14} {:>18}",
        "policy", "mean MSE", "protected eig mass"
    );
    for policy in Policy::all() {
        let mut rng = Rng::new(99);
        let (mut mse_sum, mut eig_mass, mut layers) = (0f64, 0f64, 0usize);
        for (name, _rows, _) in &m.quantized_layers {
            let idx = m.params.iter().position(|(n, _)| n == name).unwrap();
            let w_rows = gemm_rows(&params[idx]);
            let eigs = m.eigs.get(name).unwrap();
            let masks = policy.assign(name, &w_rows, eigs, ratio, &mut rng);
            mse_sum += quant_mse(&w_rows, &masks);
            eig_mass += masks
                .is8
                .iter()
                .enumerate()
                .filter(|(_, &v)| v > 0.5)
                .map(|(i, _)| eigs[i].max(0.0))
                .sum::<f64>();
            layers += 1;
        }
        println!(
            "{:<24} {:>14.6e} {:>18.4}",
            policy.label(),
            mse_sum / layers as f64,
            eig_mass
        );
    }

    // ---- 3: intra- vs inter-layer deployment ---------------------------------
    // The inter-layer penalty exists precisely when layers are
    // precision-uniform (8-bit first/last + one-scheme middles): the 8-bit
    // DSP pool idles through the whole middle of the network. ILMPQ's
    // intra-layer mix keeps the identical engine busy in every layer. We
    // compare the two *deployments* at matched middle-layer schemes.
    let net = resnet18();
    println!("\n== deployment ablation: inter-layer (fl8) vs intra-layer (ILMPQ), XC7Z045 ==");
    println!(
        "{:<14} {:>16} {:>10} {:>16} {:>8}",
        "middle scheme", "inter GOP/s", "DSP idle", "intra GOP/s", "gain"
    );
    let device = DeviceModel::xc7z045();
    for (label, inter_ratio, intra_ratio) in [
        ("fixed-4", "0:100:0", "0:95:5"),
        ("pot-4", "100:0:0", "95:0:5"),
        ("50:50 mix", "50:50:0", "50:45:5"),
        ("65:35 mix", "67:33:0", "65:30:5"),
    ] {
        let inter_cfg = NetConfig::from_ratio(
            &net,
            Ratio::parse(inter_ratio).unwrap(),
            true, // first/last pinned to Fixed-8: the prior-work deployment
            label,
        );
        let intra_cfg = NetConfig::from_ratio(
            &net,
            Ratio::parse(intra_ratio).unwrap(),
            false, // every layer carries the mix incl. its 5% rescue rows
            label,
        );
        let inter = simulate(&net, &inter_cfg, &device, Mode::InterLayer);
        let intra = simulate(&net, &intra_cfg, &device, Mode::IntraLayer);
        println!(
            "{:<14} {:>16.1} {:>9.1}% {:>16.1} {:>7.2}x",
            label,
            inter.throughput_gops,
            inter.dsp_idle_frac * 100.0,
            intra.throughput_gops,
            intra.throughput_gops / inter.throughput_gops
        );
    }

    // ---- 4: frac8 sweep ------------------------------------------------------
    println!("\n== Fixed-8 share sweep (intra-layer, XC7Z045, PoT share rebalanced) ==");
    println!("{:<8} {:>12} {:>10}", "f8 %", "GOP/s", "ms");
    for f8 in [0.0, 2.0, 5.0, 10.0, 20.0, 40.0] {
        let pot = (100.0 - f8) * 0.65;
        let r = Ratio::new(pot, 100.0 - f8 - pot, f8);
        let cfg = NetConfig::from_ratio(&net, r, false, "sweep");
        let s = simulate(&net, &cfg, &device, Mode::IntraLayer);
        println!(
            "{:<8.0} {:>12.1} {:>10.1}",
            f8,
            s.throughput_gops,
            s.latency_s * 1e3
        );
    }
    println!("\n(the knee above ~5-10% Fixed-8 is why the paper protects only 5% of rows)");

    // ---- 5: generality across networks ---------------------------------------
    // §II-A: "can be applied to all layers in a DNN model" — the same engine
    // allocation + a per-network ratio search must transfer to other nets.
    println!("\n== generality: ratio search + speedup across networks (XC7Z045) ==");
    println!(
        "{:<12} {:>8} {:>12} {:>14} {:>12}",
        "network", "GOPs", "optimum", "ILMPQ GOP/s", "speedup"
    );
    for name in ["resnet18", "vgg11", "cnn-small", "tinyresnet"] {
        let net = ilmpq::model::zoo::by_name(name).unwrap();
        let search =
            ilmpq::coordinator::ratio_search::search(&net, &device, 5.0, 5.0, 90.0);
        let baseline = simulate(
            &net,
            &NetConfig::from_ratio(&net, Ratio::parse("0:100:0").unwrap(), true, "fl8"),
            &device,
            Mode::InterLayer,
        );
        let ilmpq_cfg = NetConfig::from_ratio(&net, search.best.ratio, false, "ilmpq");
        let ilmpq_run = simulate(&net, &ilmpq_cfg, &device, Mode::IntraLayer);
        println!(
            "{:<12} {:>8.2} {:>12} {:>14.1} {:>11.2}x",
            name,
            net.total_gops(),
            search.best.ratio.label(),
            ilmpq_run.throughput_gops,
            baseline.latency_s / ilmpq_run.latency_s
        );
    }
    println!("(optima cluster in the same PoT-heavy band; the speedup transfers)");
    Ok(())
}
