//! Bench: the L3 serving path — PJRT execute cost per batch size, dynamic
//! batcher behaviour under load, and closed-loop serving throughput/latency
//! percentiles. Requires artifacts (`make artifacts`).
//!
//! ```sh
//! cargo bench --bench coordinator [-- --rates 500,2000,8000]
//! ```

use std::sync::Arc;
use std::time::Duration;

use ilmpq::coordinator::{loadgen, ServeConfig, Server};
use ilmpq::runtime::{HostTensor, Runtime};
use ilmpq::util::stats::{bench, Summary};
use ilmpq::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env(
        "bench coordinator",
        1,
        &[
            ("rates", "comma-separated arrival rates (req/s)"),
            ("requests", "requests per rate point (default 768)"),
            ("workers", "worker threads (default 2)"),
        ],
    );
    let rt = Arc::new(Runtime::load_default()?);
    let m = &rt.manifest;
    // Resolved through the first-class plan API (one resolution path).
    let plan = m.plan("ilmpq2")?;
    let masks = plan.masks.clone();
    let params = m.load_init_params()?;

    // ---- raw engine cost per batch size (fake-quant vs frozen path) --------
    println!("== PJRT execute cost per infer batch size ==");
    let mask_tensors = m.mask_tensors(&masks);
    let names: Vec<String> = m.params.iter().map(|(n, _)| n.clone()).collect();
    let frozen_params = ilmpq::quant::freeze::freeze_params(&params, &names, &masks);
    for &b in &m.infer_batches {
        let x = HostTensor::zeros(vec![b, m.data.height, m.data.width, m.data.channels]);
        let mut masked_in = params.clone();
        masked_in.extend(mask_tensors.iter().cloned());
        masked_in.push(x.clone());
        let mut frozen_in = frozen_params.clone();
        frozen_in.push(x);
        let masked_name = format!("infer_b{b}");
        let frozen_name = format!("infer_frozen_b{b}");
        let sm = Summary::of(&bench(3, 30, || {
            rt.run(&masked_name, &masked_in).expect("infer");
        }));
        let sf = Summary::of(&bench(3, 30, || {
            rt.run(&frozen_name, &frozen_in).expect("infer frozen");
        }));
        println!(
            "  b={b:<3} fake-quant {}\n        frozen     {}  ({:.2}x faster, {:.0} img/s)",
            sm,
            sf,
            sm.p50 / sf.p50,
            b as f64 / sf.p50
        );
    }

    // ---- closed-loop serving under Poisson load -----------------------------
    let rates = args.f64_list_or("rates", "500,2000,6000");
    let n = args.usize_or("requests", 768);
    println!("\n== serving under open-loop Poisson load (ilmpq2 masks) ==");
    for rate in rates {
        let cfg = ServeConfig {
            workers: args.usize_or("workers", 2),
            max_wait: Duration::from_millis(5),
            plan: Some(plan.clone()),
            device: "xc7z045".into(),
            ..Default::default()
        };
        let server = Server::start_pjrt(rt.clone(), params.clone(), &masks, cfg)?;
        // The shared open-loop driver — same pacing and reply
        // classification as `ilmpq loadgen` and benches/serving.rs.
        let spec = loadgen::LoadSpec {
            requests: n,
            rate,
            seed: 1234,
            ..Default::default()
        };
        let (report, _metrics) = loadgen::run(server, &rt.manifest, &spec);
        println!(
            "rate {:>6.0} req/s: {}/{} ok, goodput {:>7.0} req/s, occupancy {:>5.1}%, e2e {}",
            rate,
            report.done,
            report.requests,
            report.goodput_rps,
            report.occupancy * 100.0,
            report.e2e
        );
    }

    // ---- batcher microbench -------------------------------------------------
    println!("\n== batcher microbench (assemble 64 from 200 queued) ==");
    use ilmpq::coordinator::{BatchPolicy, Batcher};
    let samples = bench(10, 200, || {
        let now = std::time::Instant::now();
        let mut b: Batcher<u32> =
            Batcher::new(BatchPolicy::new(vec![1, 8, 64], Duration::from_millis(5)));
        for i in 0..200 {
            b.push(i, now);
        }
        while b.try_assemble(now).is_some() {}
    });
    println!("  {}", Summary::of(&samples));
    Ok(())
}
