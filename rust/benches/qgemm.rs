//! Bench: native packed-code GEMM (`quant::qgemm`) vs the dequantize-to-f32
//! baseline, on ResNet-18 layer shapes (batch 1, im2col view).
//!
//! The baseline is what the frozen-model eval effectively paid before this
//! subsystem existed: an f32 GEMM over pre-dequantized weight rows (the
//! unpack itself is *excluded* — it happens once per model, not per call).
//! The packed path is timed end to end per call: activation quantization +
//! integer GEMM over the packed codes. Both sides use the same row-blocked
//! thread pool, so the comparison isolates arithmetic + memory traffic
//! (4-bit rows move an 8x smaller weight image than f32).
//!
//! Writes machine-readable results to `BENCH_qgemm.json` at the repo root.
//!
//! ```sh
//! cargo bench --bench qgemm [-- --iters 5 --threads 8 --full --out PATH]
//! ```

use ilmpq::backend::{synth, FloatRefBackend, InferenceBackend, QgemmBackend};
use ilmpq::model::resnet18;
use ilmpq::quant::qgemm::{self, QuantizedActs};
use ilmpq::quant::{assign, PackedMatrix, Ratio, Scheme};
use ilmpq::util::stats::{bench, mean};
use ilmpq::util::{Args, Json, Rng};

const REPRESENTATIVE: &[&str] = &[
    "conv1",
    "layer1.0.conv1",
    "layer2.1.conv2",
    "layer3.0.conv1",
    "layer4.1.conv2",
    "fc",
];

fn masks_for(label: &str, w: &[Vec<f32>], rng: &mut Rng) -> ilmpq::quant::LayerMasks {
    match label {
        "fixed8" => assign::assign_uniform_layer("bench", w.len(), Scheme::Fixed8),
        "fixed4" => assign::assign_uniform_layer("bench", w.len(), Scheme::Fixed4),
        "pot4" => assign::assign_uniform_layer("bench", w.len(), Scheme::Pot4),
        "ilmpq2" => {
            let eigs: Vec<f64> = (0..w.len()).map(|_| rng.f64()).collect();
            assign::assign_layer("bench", w, &eigs, Ratio::new(65.0, 30.0, 5.0))
        }
        other => panic!("unknown scheme label {other}"),
    }
}

fn main() {
    let a = Args::parse_env(
        "bench qgemm",
        1,
        &[
            ("iters", "timed iterations per case (default 5)"),
            ("threads", "worker threads (default: all cores)"),
            ("out", "output JSON path (default: repo-root BENCH_qgemm.json)"),
            ("full!", "bench every ResNet-18 layer, not the representative set"),
        ],
    );
    let iters = a.usize_or("iters", 5);
    let threads = a.usize_or("threads", qgemm::default_threads());
    let out_path = a
        .str_or(
            "out",
            if std::path::Path::new("../ROADMAP.md").exists() {
                "../BENCH_qgemm.json"
            } else {
                "BENCH_qgemm.json"
            },
        )
        .to_string();

    let net = resnet18();
    let layers: Vec<_> = net
        .layers
        .iter()
        .filter(|l| a.flag("full") || REPRESENTATIVE.contains(&l.name.as_str()))
        .collect();

    println!(
        "== quant::qgemm vs dequant+f32 GEMM (ResNet-18 shapes, batch 1, {threads} threads, {iters} iters) =="
    );
    println!(
        "{:<18} {:>16} {:>10} | {:>18} {:>18} {:>18} {:>18}",
        "layer", "(M,K,N)", "f32 GOP/s", "fixed8", "fixed4", "pot4", "ilmpq2 65:30:5"
    );

    let mut rng = Rng::new(2021);
    let mut cases = Vec::new();
    let mut speedups_4bit: Vec<f64> = Vec::new();
    for layer in layers {
        let g = layer.gemm();
        // Weight rows (N = out channels = g.m packed rows), im2col acts
        // (g.n patch rows of fan-in g.k).
        let w: Vec<Vec<f32>> = (0..g.m)
            .map(|_| (0..g.k).map(|_| rng.normal() * 0.2).collect())
            .collect();
        let x: Vec<f32> = (0..g.n * g.k).map(|_| rng.normal()).collect();
        let macs = (g.m * g.k * g.n) as f64;
        let gops_of = |secs: f64| 2.0 * macs / secs / 1e9;

        // Baseline: f32 GEMM over pre-dequantized rows (4-bit dequant so the
        // value distribution matches; cost is scheme-independent).
        let base_rows = PackedMatrix::pack(
            &w,
            &assign::assign_uniform_layer("bench", g.m, Scheme::Fixed4),
        )
        .unpack();
        let base_s = mean(&bench(1, iters, || {
            let _ = qgemm::f32_gemm_rows(&x, g.n, g.k, &base_rows, threads);
        }));

        let mut scheme_cells = Vec::new();
        let mut line = format!(
            "{:<18} {:>16} {:>10.2} |",
            layer.name,
            format!("({},{},{})", g.m, g.k, g.n),
            gops_of(base_s)
        );
        for label in ["fixed8", "fixed4", "pot4", "ilmpq2"] {
            let masks = masks_for(label, &w, &mut rng);
            let packed = PackedMatrix::pack(&w, &masks);
            let secs = mean(&bench(1, iters, || {
                let acts = QuantizedActs::quantize(&x, g.n, g.k);
                let _ = qgemm::qgemm(&acts, &packed, threads);
            }));
            let speedup = base_s / secs;
            if label == "fixed4" || label == "pot4" {
                speedups_4bit.push(speedup);
            }
            line.push_str(&format!(" {:>9.2} ({:>4.2}x)", gops_of(secs), speedup));
            scheme_cells.push((
                label,
                Json::obj(vec![
                    ("seconds", Json::Num(secs)),
                    ("gops", Json::Num(gops_of(secs))),
                    ("speedup_vs_f32", Json::Num(speedup)),
                ]),
            ));
        }
        println!("{line}");
        cases.push(Json::obj(vec![
            ("layer", Json::Str(layer.name.clone())),
            ("m", Json::Num(g.m as f64)),
            ("k", Json::Num(g.k as f64)),
            ("n", Json::Num(g.n as f64)),
            ("baseline_f32_seconds", Json::Num(base_s)),
            ("baseline_f32_gops", Json::Num(gops_of(base_s))),
            ("schemes", Json::obj(scheme_cells)),
        ]));
    }

    // Cheap correctness spot check (fc shape): packed path == dequant GEMM
    // over the quantized activations, within f32 accumulation noise.
    {
        let w: Vec<Vec<f32>> = (0..64).map(|_| (0..512).map(|_| rng.normal()).collect()).collect();
        let masks = masks_for("ilmpq2", &w, &mut rng);
        let packed = PackedMatrix::pack(&w, &masks);
        let x: Vec<f32> = (0..4 * 512).map(|_| rng.normal()).collect();
        let acts = QuantizedActs::quantize(&x, 4, 512);
        let got = qgemm::qgemm(&acts, &packed, threads);
        let want = qgemm::f32_gemm_rows(&acts.dequant(), 4, 512, &packed.unpack(), 1);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() < 1e-3 + 1e-4 * b.abs(),
                "parity check failed at {i}: {a} vs {b}"
            );
        }
    }

    // ---- whole-model forward through the unified backend API ---------------
    // The same `InferenceBackend::run_batch` call every consumer (server,
    // PTQ, integration tests) makes: packed integer (qgemm) vs the float
    // reference on the synthetic default-geometry TinyResNet, batch 8. The
    // pack happens once in `prepare()` and is excluded from the timing.
    let model_forward = {
        let m = synth::tiny_manifest(16, 16, 3, &[16, 32, 64], 10);
        let params = synth::random_params(&m, &mut rng);
        let masks = synth::random_masks(&m, Ratio::new(65.0, 30.0, 5.0), &mut rng);
        let batch = 8usize;
        let x: Vec<f32> = (0..batch * 16 * 16 * 3).map(|_| rng.normal()).collect();
        println!(
            "\n== whole-model forward via InferenceBackend (TinyResNet 16x16x3, batch {batch}) =="
        );
        let qb =
            QgemmBackend::new(m.clone(), params.clone(), masks).with_threads(threads);
        let fb = FloatRefBackend::new(m, params).with_threads(threads);
        let mut cells = Vec::new();
        for (label, be) in
            [("qgemm", &qb as &dyn InferenceBackend), ("float", &fb as &dyn InferenceBackend)]
        {
            be.prepare().expect("prepare");
            let secs = mean(&bench(1, iters, || {
                be.run_batch(&x, batch).expect("run_batch");
            }));
            println!(
                "  {label:<6} {:>9.1} img/s  ({:.3} ms/batch)",
                batch as f64 / secs,
                secs * 1e3
            );
            cells.push((
                label,
                Json::obj(vec![
                    ("seconds_per_batch", Json::Num(secs)),
                    ("images_per_s", Json::Num(batch as f64 / secs)),
                ]),
            ));
        }
        Json::obj(cells)
    };

    let min_4bit = speedups_4bit.iter().copied().fold(f64::INFINITY, f64::min);
    let geomean_4bit = (speedups_4bit.iter().map(|s| s.ln()).sum::<f64>()
        / speedups_4bit.len().max(1) as f64)
        .exp();
    println!(
        "\n4-bit (fixed4/pot4) speedup vs f32 baseline: min {min_4bit:.2}x, geomean {geomean_4bit:.2}x"
    );
    if min_4bit < 2.0 {
        println!("WARNING: below the 2x acceptance target on this machine");
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("qgemm".into())),
        ("status", Json::Str("measured".into())),
        ("workload", Json::Str("resnet18 layer shapes, batch 1, im2col view".into())),
        ("threads", Json::Num(threads as f64)),
        ("iters", Json::Num(iters as f64)),
        ("cases", Json::Arr(cases)),
        ("model_forward", model_forward),
        (
            "summary",
            Json::obj(vec![
                ("min_speedup_4bit", Json::Num(min_4bit)),
                ("geomean_speedup_4bit", Json::Num(geomean_4bit)),
            ]),
        ),
    ]);
    std::fs::write(&out_path, doc.to_string_compact())
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("wrote {out_path}");
}
