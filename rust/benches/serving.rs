//! Bench: the serving admission pipeline end to end — open-loop Poisson
//! offered-load sweep over the dynamic batcher + worker pool, on the
//! artifact-free synthetic TinyResNet driven through the `qgemm` backend.
//!
//! Reports, per offered rate: p50/p99 end-to-end latency, batch occupancy,
//! shed rate (the queue bound's overload response), and goodput. The high
//! rate points are *meant* to saturate the backend — the shed rate curve is
//! the admission pipeline working, not a failure. Needs no PJRT and no
//! `make artifacts`: `--no-default-features` builds and runs it, so the CI
//! `serving-bench` job measures it on every push.
//!
//! A second sweep re-runs the same workload **over the wire**: the HTTP/1.1
//! front end (`coordinator::http`) on a loopback socket, driven by the
//! remote load generator (`loadgen::run_remote`) — once per wire encoding
//! (`json` and `raw` little-endian f32 bodies), so the JSON records the
//! in-process pipeline cost, the full network-path cost, and the
//! serialization delta between the encodings side by side (each wire point
//! carries an `encoding` tag).
//!
//! Writes machine-readable results to `BENCH_serving.json` at the repo root.
//!
//! ```sh
//! cargo bench --no-default-features --bench serving \
//!     [-- --rates 500,2000,8000 --requests 512 --queue-depth 256 --skip-wire]
//! ```

use std::sync::Arc;
use std::time::Duration;

use ilmpq::coordinator::{
    loadgen, Encoding, HttpConfig, HttpServer, ServeConfig, Server, ServerPool,
};
use ilmpq::util::{Args, Json};

fn main() -> anyhow::Result<()> {
    let a = Args::parse_env(
        "bench serving",
        1,
        &[
            ("rates", "comma-separated offered loads req/s (default 500,2000,8000)"),
            ("requests", "requests per rate point (default 512)"),
            ("workers", "worker threads (default 2)"),
            ("queue-depth", "admission queue bound (default 256)"),
            ("threads", "backend CPU threads (default 2; 0 = all cores)"),
            ("backend", "execution backend (default qgemm)"),
            ("seed", "workload seed (default 42)"),
            ("out", "output JSON path (default: repo-root BENCH_serving.json)"),
            ("conns", "client connections for the over-the-wire sweep (default 8)"),
            (
                "http-workers",
                "HTTP handler threads for the over-the-wire sweep (default 16; \
                 must be >= conns or starved connections distort tail latency)",
            ),
            ("skip-wire!", "skip the over-the-wire (HTTP loopback) sweep"),
        ],
    );
    let rates = a.f64_list_or("rates", "500,2000,8000");
    let requests = a.usize_or("requests", 512);
    let workers = a.usize_or("workers", 2);
    let queue_depth = a.usize_or("queue-depth", 256);
    // Same convention as `ilmpq loadgen`: 0 = all cores. Default 2 keeps
    // hosted-runner numbers stable.
    let threads = match a.usize_or("threads", 2) {
        0 => None,
        t => Some(t),
    };
    let backend_name = a.str_or("backend", "qgemm").to_string();
    let seed = a.u64_or("seed", 42);
    // cwd-independent default: the repo root is one level above the crate.
    let out_path = a
        .str_or("out", concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serving.json"))
        .to_string();

    println!(
        "== serving admission pipeline under open-loop Poisson load \
         ({backend_name} backend, synthetic TinyResNet, {workers} workers, \
         queue depth {queue_depth}) =="
    );
    let mut points = Vec::new();
    for &rate in &rates {
        // Fresh server (and metrics) per point; the pack is cheap at this
        // model size and isolation keeps the percentiles per-rate.
        let (m, be, plan) =
            loadgen::synth_fixture(&backend_name, "bench", threads, seed)?;
        let cfg = ServeConfig {
            workers,
            max_wait: Duration::from_millis(2),
            queue_depth,
            plan: Some(plan),
            device: "xc7z045".into(),
            ..Default::default()
        };
        let server = Server::start(&m, be, cfg)?;
        let spec = loadgen::LoadSpec { requests, rate, seed, ..Default::default() };
        let (report, _metrics) = loadgen::run(server, &m, &spec);
        assert_eq!(
            report.lost, 0,
            "typed-error pipeline must answer every request"
        );
        println!(
            "rate {:>7.0} req/s (achieved {:>6.0}): done {:>4}/{} shed {:>4} ({:>5.1}%), \
             occupancy {:>5.1}%, e2e p50 {:>8.3} ms p99 {:>8.3} ms, \
             goodput {:>6.0} req/s",
            rate,
            report.achieved_rate,
            report.done,
            report.requests,
            report.shed,
            report.shed_rate * 100.0,
            report.occupancy * 100.0,
            report.e2e.p50 * 1e3,
            report.e2e.p99 * 1e3,
            report.goodput_rps,
        );
        points.push(report.to_json());
    }

    // Over-the-wire sweep: identical workload, but spoken as HTTP/1.1 over
    // a loopback socket through the network front end. Handlers must cover
    // every concurrent keep-alive connection (each handler owns one until
    // it closes), or the surplus connections starve and pollute the p99.
    let conns = a.usize_or("conns", 8);
    let http_workers = a.usize_or("http-workers", 16);
    let mut wire_points = Vec::new();
    if !a.flag("skip-wire") {
        println!(
            "\n== same workload over the HTTP/1.1 front end (loopback, \
             {conns} client connections, {http_workers} handler threads, \
             json + raw encodings) =="
        );
        // Both wire encodings, same workload: the delta between a json and
        // a raw point at the same rate is the serialization cost (client
        // encode + server parse) alone — everything else is identical.
        for &encoding in &[Encoding::Json, Encoding::Raw] {
            for &rate in &rates {
                let (m, be, plan) =
                    loadgen::synth_fixture(&backend_name, "bench", threads, seed)?;
                let cfg = ServeConfig {
                    workers,
                    max_wait: Duration::from_millis(2),
                    queue_depth,
                    plan: Some(plan),
                    device: "xc7z045".into(),
                    ..Default::default()
                };
                let server = Server::start(&m, be, cfg)?;
                let front = HttpServer::start(
                    server,
                    &m,
                    HttpConfig {
                        addr: "127.0.0.1:0".into(),
                        workers: http_workers,
                        ..Default::default()
                    },
                )?;
                let url = format!("http://{}", front.local_addr());
                let spec =
                    loadgen::LoadSpec { requests, rate, seed, encoding, ..Default::default() };
                let (report, _server_metrics) = loadgen::run_remote(&url, &spec, conns)?;
                front.stop();
                println!(
                    "wire [{:>4}] rate {:>7.0} req/s (achieved {:>6.0}): done {:>4}/{} \
                     shed {:>4}, slow {:>3}, lost {:>3}, server e2e p50 {:>8.3} ms \
                     p99 {:>8.3} ms, client rtt p99 {:>8.3} ms, goodput {:>6.0} req/s",
                    encoding.name(),
                    rate,
                    report.achieved_rate,
                    report.done,
                    report.requests,
                    report.shed,
                    report.slow,
                    report.lost,
                    report.e2e.p50 * 1e3,
                    report.e2e.p99 * 1e3,
                    report.client_rtt.p99 * 1e3,
                    report.goodput_rps,
                );
                let mut point = report.to_json();
                if let Json::Obj(map) = &mut point {
                    map.insert("encoding".into(), Json::Str(encoding.name().into()));
                }
                wire_points.push(point);
            }
        }
    }

    // Multi-model point: the built-in two-model synthetic pool behind one
    // listener, the multi scenario skewing 80/20 toward the default model —
    // what one process serving several (network, plan) pairs costs on the
    // wire, next to the single-model sweep above.
    let mut multi_point = Json::Null;
    if !a.flag("skip-wire") {
        let rate = rates.first().copied().unwrap_or(500.0);
        println!(
            "\n== multi-model pool over the same front end (two synthetic \
             models, 80/20 default-model skew, rate {rate:.0} req/s) =="
        );
        let pool = ServerPool::synthetic_pair(seed)?;
        let front = HttpServer::start_pool(
            Arc::new(pool),
            HttpConfig {
                addr: "127.0.0.1:0".into(),
                workers: http_workers,
                ..Default::default()
            },
        )?;
        let url = format!("http://{}", front.local_addr());
        let spec = loadgen::LoadSpec {
            requests,
            rate,
            seed,
            scenario: loadgen::Scenario::Multi,
            ..Default::default()
        };
        let (report, _metrics) = loadgen::run_remote(&url, &spec, conns)?;
        front.stop();
        assert_eq!(report.lost, 0, "pool front end must answer every request");
        for m in &report.models {
            println!(
                "model {:>8}: offered {:>4} done {:>4} failed {:>3}, \
                 e2e p50 {:>8.3} ms p99 {:>8.3} ms",
                m.model,
                m.offered,
                m.done,
                m.failed,
                m.e2e.p50 * 1e3,
                m.e2e.p99 * 1e3,
            );
        }
        multi_point = report.to_json();
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("serving".into())),
        ("status", Json::Str("measured".into())),
        (
            "workload",
            Json::Str(
                "synthetic TinyResNet 16x16x3 widths [8,16], open-loop Poisson sweep"
                    .into(),
            ),
        ),
        ("backend", Json::Str(backend_name)),
        ("requests_per_point", Json::Num(requests as f64)),
        ("workers", Json::Num(workers as f64)),
        ("queue_depth", Json::Num(queue_depth as f64)),
        // 0 = all cores (unbounded pool), mirroring the CLI convention.
        ("threads", Json::Num(threads.unwrap_or(0) as f64)),
        ("points", Json::Arr(points)),
        (
            "wire",
            Json::obj(vec![
                ("transport", Json::Str("http/1.1 loopback".into())),
                ("conns", Json::Num(conns as f64)),
                ("http_workers", Json::Num(http_workers as f64)),
                (
                    "note",
                    Json::Str(
                        "e2e/queue_wait are server-reported per-request timings \
                         (same definition as the in-process points); client_rtt \
                         adds client-side connection queueing. Delivery is \
                         bounded by `conns` synchronous connections, so rates \
                         beyond conns/round-trip arrive late (visible in \
                         client_rtt) instead of shedding like the in-process \
                         sweep. Each point's `encoding` tag names its wire \
                         encoding (json | raw); compare same-rate points to \
                         isolate serialization cost."
                            .into(),
                    ),
                ),
                ("points", Json::Arr(wire_points)),
            ]),
        ),
        (
            "multi_model",
            Json::obj(vec![
                (
                    "workload",
                    Json::Str(
                        "two-model synthetic pool (tiny TinyResNet + narrow \
                         VGG stack), 80/20 default-model skew over per-model \
                         HTTP routes"
                            .into(),
                    ),
                ),
                ("point", multi_point),
            ]),
        ),
    ]);
    std::fs::write(&out_path, doc.to_string_compact())
        .unwrap_or_else(|e| panic!("write {out_path}: {e}"));
    println!("wrote {out_path}");
    Ok(())
}
