//! Bench: the paper's §III headline claim — ILMPQ end-to-end speedup over
//! the fixed-point baseline (3.01x on XC7Z020, 3.65x on XC7Z045) — plus the
//! per-layer lane-balance breakdown that explains *why* (the intra-layer
//! point: both lanes busy in every layer; the inter-layer baseline idles
//! its 8-bit pool through the middle of the network).
//!
//! ```sh
//! cargo bench --bench speedup
//! ```

use ilmpq::experiments::table1;
use ilmpq::fpga::sim::Bound;
use ilmpq::fpga::{simulate, DeviceModel, Mode, NetConfig};
use ilmpq::model::resnet18;
use ilmpq::quant::Ratio;

fn main() {
    let net = resnet18();
    println!("== §III headline speedups (simulated, ResNet-18) ==");
    for (device, rows) in table1::run_all() {
        let paper = if device.name == "xc7z020" { 3.01 } else { 3.65 };
        let s = table1::speedup(&rows);
        println!(
            "{:<10} simulated {:.2}x   paper {:.2}x   rel-err {:>5.1}%",
            device.name,
            s,
            paper,
            (s - paper).abs() / paper * 100.0
        );
    }

    // Why: per-layer breakdown for ILMPQ-2 on XC7Z045.
    let device = DeviceModel::xc7z045();
    let ratio = Ratio::parse("65:30:5").unwrap();
    let cfg = NetConfig::from_ratio(&net, ratio, false, "ILMPQ-2");
    let r = simulate(&net, &cfg, &device, Mode::IntraLayer);
    println!("\n== per-layer lane balance: ILMPQ-2 on {} ==", device.name);
    println!(
        "{:<22} {:>9} {:>9} {:>9} {:>9} {:>9}  bound",
        "layer", "fixed ms", "pot ms", "ddr ms", "buf ms", "total ms"
    );
    for t in &r.per_layer {
        println!(
            "{:<22} {:>9.3} {:>9.3} {:>9.3} {:>9.3} {:>9.3}  {:?}",
            t.name,
            t.fixed_s * 1e3,
            t.pot_s * 1e3,
            t.ddr_s * 1e3,
            t.buffer_s * 1e3,
            t.total_s * 1e3,
            t.bound
        );
    }
    let balanced = r
        .per_layer
        .iter()
        .filter(|t| {
            matches!(t.bound, Bound::FixedLane | Bound::PotLane)
                && t.fixed_s > 0.0
                && t.pot_s > 0.0
                && (t.fixed_s / t.pot_s).max(t.pot_s / t.fixed_s) < 2.0
        })
        .count();
    println!(
        "\nlane-balanced layers (within 2x): {}/{} — the ratio search's goal",
        balanced,
        r.per_layer.len()
    );

    // Inter-layer waste: the same mix forced into the prior-work execution.
    println!("\n== inter-layer idle waste (prior-work execution of fl8 configs) ==");
    for device in DeviceModel::all() {
        let fl8 = NetConfig::from_ratio(
            &net,
            Ratio::parse("0:100:0").unwrap(),
            true,
            "fixed fl8",
        );
        let inter = simulate(&net, &fl8, &device, Mode::InterLayer);
        println!(
            "{:<10} latency {:>7.1} ms, DSP idle {:>5.1}% (intra-layer ILMPQ: 0% by construction)",
            device.name,
            inter.latency_s * 1e3,
            inter.dsp_idle_frac * 100.0
        );
    }
}
