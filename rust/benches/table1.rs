//! Bench: regenerate Table I (both devices, all 8 rows each) and time the
//! simulator itself. `harness = false` (no criterion offline) — the shared
//! measurement loop lives in `ilmpq::util::stats::bench`.
//!
//! ```sh
//! cargo bench --bench table1 [-- --device xc7z020]
//! ```

use ilmpq::experiments::table1;
use ilmpq::fpga::DeviceModel;
use ilmpq::model::resnet18;
use ilmpq::util::stats::{bench, Summary};
use ilmpq::util::Args;

fn main() {
    let args = Args::parse_env("bench table1", 1, &[("device", "xc7z020|xc7z045|all")]);
    let which = args.str_or("device", "all");
    let net = resnet18();
    let devices = if which == "all" {
        DeviceModel::all()
    } else {
        vec![DeviceModel::by_name(which).expect("unknown device")]
    };

    for device in devices {
        let rows = table1::run_device(&device, &net);
        println!("{}", table1::render(&device, &rows));
        println!(
            "headline speedup vs (1): {:.2}x   (paper: {})",
            table1::speedup(&rows),
            if device.name == "xc7z020" { "3.01x" } else { "3.65x" }
        );
        // Shape checks the bench asserts loudly (not a test, but the bench
        // should scream if the reproduction regresses).
        let max_tp = rows
            .iter()
            .map(|r| r.sim.throughput_gops)
            .fold(0.0f64, f64::max);
        let ilmpq_tp = rows
            .iter()
            .find(|r| r.cfg.label.starts_with("ILMPQ"))
            .unwrap()
            .sim
            .throughput_gops;
        assert!(
            (ilmpq_tp - max_tp).abs() < 1e-9,
            "REGRESSION: ILMPQ is no longer the fastest row on {}",
            device.name
        );

        // Cell-level comparison table.
        println!("\nper-row relative error vs paper (throughput):");
        for r in &rows {
            if let Some(err) = r.throughput_rel_err() {
                println!("  {:<20} {:>6.1}%", r.cfg.label, err * 100.0);
            }
        }

        // Time the simulator (the L3 hot path of the search loops).
        let cfg = rows.last().unwrap().cfg.clone();
        let nc = cfg.net_config(&net);
        let samples = bench(3, 50, || {
            let _ = ilmpq::fpga::simulate(&net, &nc, &device, cfg.mode);
        });
        println!(
            "\nsimulate() on {}: {}\n",
            device.name,
            Summary::of(&samples)
        );
    }
}
