//! A small hand-rolled Rust lexer — just enough structure for the `ilmpq
//! analyze` rules (same no-dependency discipline as `util/json.rs`).
//!
//! The lexer produces a flat token stream (identifiers, punctuation,
//! literals, lifetimes) with 1-based line numbers, skipping comments and
//! the *contents* of string literals so that rule matching never triggers
//! on prose. Line comments are additionally scanned for the suppression
//! pragma `// analyze:allow(reason)` — it must start the comment, so prose
//! that merely mentions it is not a suppression; a pragma whose reason is
//! missing or empty is recorded separately so the analyzer can reject it (a
//! suppression without a justification is itself a finding).
//!
//! This is not a full Rust lexer — shebangs, nested raw-identifier edge
//! cases and exotic literal suffixes are out of scope — but it handles
//! everything that appears in this crate: nested block comments, raw
//! strings (`r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`), char literals vs.
//! lifetimes, and float/int/hex literals.

use std::collections::BTreeMap;

/// Token classification. Rules mostly care about `Ident` vs `Punct`;
/// string literals keep their contents so R4 can match JSON keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Str,
    Num,
    Char,
    Lifetime,
}

#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: usize,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, s: &str) -> bool {
        self.kind == TokKind::Punct && self.text == s
    }
}

/// One lexed source file: the token stream plus pragma bookkeeping.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    /// `line -> reason` for each well-formed `// analyze:allow(reason)`.
    pub pragmas: BTreeMap<usize, String>,
    /// Lines carrying an `analyze:allow` with a missing or empty reason.
    pub bad_pragmas: Vec<usize>,
}

impl Lexed {
    /// A finding on `line` is suppressed by a pragma on the same line or
    /// on the line directly above it.
    pub fn suppressed(&self, line: usize) -> bool {
        self.pragmas.contains_key(&line)
            || (line > 1 && self.pragmas.contains_key(&(line - 1)))
    }
}

const PRAGMA: &str = "analyze:allow";

fn scan_pragma(comment: &str, line: usize, out: &mut Lexed) {
    // The pragma must *start* the comment (after `//`/`///`/`//!` and
    // whitespace) — prose that merely mentions `analyze:allow` mid-sentence
    // (like this comment) is not a suppression attempt.
    let head = comment.trim_start_matches('/').trim_start_matches('!').trim_start();
    if !head.starts_with(PRAGMA) {
        return;
    }
    let rest = &head[PRAGMA.len()..];
    let reason = rest
        .strip_prefix('(')
        .and_then(|r| r.rfind(')').map(|end| r[..end].trim().to_string()));
    match reason {
        Some(r) if !r.is_empty() => {
            out.pragmas.insert(line, r);
        }
        _ => out.bad_pragmas.push(line),
    }
}

/// Lex one file. Never fails: unterminated constructs are consumed to EOF.
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1usize;

    let push = |out: &mut Lexed, kind: TokKind, text: String, line: usize| {
        out.tokens.push(Token { kind, text, line });
    };

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (also doc comments) — scan for the pragma.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            scan_pragma(&text, line, &mut out);
            continue;
        }
        // Block comment; Rust block comments nest.
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // Raw / byte string literals: r"…", r#"…"#, b"…", br#"…"#.
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            if c == 'b' && j < n && b[j] == 'r' {
                j += 1;
            }
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                // Only treat as a string when a quote actually follows the
                // prefix (so `r#ident` raw identifiers fall through below).
                let start_line = line;
                j += 1;
                let mut text = String::new();
                'outer: while j < n {
                    if b[j] == '\n' {
                        line += 1;
                    }
                    if b[j] == '"' {
                        // Need `hashes` trailing #s to close.
                        let mut k = 0usize;
                        while k < hashes && j + 1 + k < n && b[j + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            j += 1 + hashes;
                            break 'outer;
                        }
                    }
                    text.push(b[j]);
                    j += 1;
                }
                push(&mut out, TokKind::Str, text, start_line);
                i = j;
                continue;
            }
            // Not a string: fall through to identifier handling.
        }
        // Plain string literal with escapes.
        if c == '"' {
            let start_line = line;
            i += 1;
            let mut text = String::new();
            while i < n {
                match b[i] {
                    '\\' => {
                        if i + 1 < n {
                            if b[i + 1] == '\n' {
                                line += 1;
                            }
                            text.push(b[i + 1]);
                        }
                        i += 2;
                    }
                    '"' => {
                        i += 1;
                        break;
                    }
                    ch => {
                        if ch == '\n' {
                            line += 1;
                        }
                        text.push(ch);
                        i += 1;
                    }
                }
            }
            push(&mut out, TokKind::Str, text, start_line);
            continue;
        }
        // Char literal vs. lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // Escaped char literal: consume to the closing quote.
                let start_line = line;
                i += 2;
                while i < n && b[i] != '\'' {
                    if b[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
                i += 1;
                push(&mut out, TokKind::Char, String::new(), start_line);
            } else if i + 2 < n && b[i + 2] == '\'' {
                push(&mut out, TokKind::Char, b[i + 1].to_string(), line);
                i += 3;
            } else {
                let start = i + 1;
                i += 1;
                while i < n && (b[i] == '_' || b[i].is_alphanumeric()) {
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                push(&mut out, TokKind::Lifetime, text, line);
            }
            continue;
        }
        // Identifier / keyword.
        if c == '_' || c.is_alphabetic() {
            let start = i;
            i += 1;
            while i < n && (b[i] == '_' || b[i].is_alphanumeric()) {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            push(&mut out, TokKind::Ident, text, line);
            continue;
        }
        // Numeric literal. A `.` joins only when followed by a digit, so
        // ranges like `0..len` stay three tokens and `len` stays an ident.
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                let ch = b[i];
                if ch.is_ascii_alphanumeric() || ch == '_' {
                    if (ch == 'e' || ch == 'E')
                        && i + 2 < n
                        && (b[i + 1] == '+' || b[i + 1] == '-')
                        && b[i + 2].is_ascii_digit()
                    {
                        i += 2; // consume the exponent sign too
                    }
                    i += 1;
                } else if ch == '.' && i + 1 < n && b[i + 1].is_ascii_digit() {
                    i += 1;
                } else {
                    break;
                }
            }
            let text: String = b[start..i].iter().collect();
            push(&mut out, TokKind::Num, text, line);
            continue;
        }
        // Anything else is single-character punctuation.
        push(&mut out, TokKind::Punct, c.to_string(), line);
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(lx: &Lexed) -> Vec<&str> {
        lx.tokens
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect()
    }

    #[test]
    fn comments_and_strings_are_not_tokens() {
        let lx = lex("// unwrap()\n/* panic! /* nested */ */ let s = \"x.unwrap()\";");
        assert_eq!(idents(&lx), vec!["let", "s"]);
        // The string literal is kept (R4 matches JSON keys), contents intact.
        assert!(lx.tokens.iter().any(|t| t.kind == TokKind::Str && t.text == "x.unwrap()"));
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let lx = lex("let q = r#\"{\"k\": 1}\"#; fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(lx.tokens.iter().any(|t| t.kind == TokKind::Str && t.text.contains("\"k\"")));
        assert!(lx.tokens.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "a"));
        assert!(lx.tokens.iter().any(|t| t.kind == TokKind::Char && t.text == "x"));
    }

    #[test]
    fn line_numbers_track_newlines() {
        let lx = lex("a\nb\n\n \"s1\nstill s1\" c");
        let find = |name: &str| lx.tokens.iter().find(|t| t.text == name).map(|t| t.line);
        assert_eq!(find("a"), Some(1));
        assert_eq!(find("b"), Some(2));
        assert_eq!(find("c"), Some(5));
    }

    #[test]
    fn ranges_do_not_swallow_idents() {
        let lx = lex("for i in 0..n_workers { x[1..] }");
        assert!(idents(&lx).contains(&"n_workers"));
        let nums: Vec<&str> = lx
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["0", "1"]);
    }

    #[test]
    fn float_and_hex_literals() {
        let lx = lex("let x = 1.5e-3 + 0x1f + 10_000u64;");
        let nums: Vec<&str> = lx
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["1.5e-3", "0x1f", "10_000u64"]);
    }

    #[test]
    fn pragma_with_reason_is_recorded() {
        let lx = lex("// analyze:allow(worker pool invariant)\nx.unwrap();");
        assert_eq!(lx.pragmas.get(&1).map(String::as_str), Some("worker pool invariant"));
        assert!(lx.bad_pragmas.is_empty());
        assert!(lx.suppressed(1));
        assert!(lx.suppressed(2));
        assert!(!lx.suppressed(3));
    }

    #[test]
    fn pragma_without_reason_is_rejected() {
        let lx = lex("// analyze:allow()\n// analyze:allow\n// analyze:allow(  )");
        assert!(lx.pragmas.is_empty());
        assert_eq!(lx.bad_pragmas, vec![1, 2, 3]);
    }

    #[test]
    fn prose_mentioning_the_pragma_is_not_a_pragma() {
        let lx = lex("//! suppress with a `// analyze:allow(reason)` comment\n// docs say analyze:allow needs a reason\n//! analyze:allow(starts the comment, so this one counts)");
        assert_eq!(lx.pragmas.keys().copied().collect::<Vec<_>>(), vec![3]);
        assert!(lx.bad_pragmas.is_empty());
    }
}
