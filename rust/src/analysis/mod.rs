//! `ilmpq analyze` — a project-specific static analyzer for the crate's own
//! source, dependency-free by the same discipline as `util/json.rs`.
//!
//! The serving stack's invariants (answer-exactly-once replies, bounded
//! admission, typed-error exhaustiveness, balanced `Metrics` ledgers) were
//! previously enforced only dynamically, by chaos/pool smoke tests sampling
//! a few schedules. This module enforces them *statically*: a hand-rolled
//! lexer ([`lexer`]) feeds per-rule visitors ([`rules`]) that fail the build
//! on violation. The runtime twin is [`crate::coordinator::Metrics::audit`],
//! which checks the same ledger invariants on every drained server stop.
//!
//! Suppression: `// analyze:allow(reason)` on the flagged line or the line
//! above. The reason is mandatory — an empty one is itself a finding (P0).

pub mod lexer;
pub mod rules;

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

/// One source file, with a `/`-separated path relative to the analyzed root.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

/// The unit of analysis: a set of source files. Built either from a
/// directory walk ([`Project::load`]) or from in-memory fixtures in tests.
#[derive(Debug, Clone, Default)]
pub struct Project {
    pub files: Vec<SourceFile>,
}

/// One rule violation at a file:line.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub message: String,
}

impl Project {
    /// Recursively load every `.rs` file under `root`.
    pub fn load(root: &Path) -> Result<Project> {
        fn walk(root: &Path, dir: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
            let entries =
                std::fs::read_dir(dir).with_context(|| format!("read_dir {}", dir.display()))?;
            for entry in entries {
                let p = entry?.path();
                if p.is_dir() {
                    walk(root, &p, out)?;
                } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
                    let rel = p
                        .strip_prefix(root)
                        .unwrap_or(&p)
                        .components()
                        .map(|c| c.as_os_str().to_string_lossy().into_owned())
                        .collect::<Vec<_>>()
                        .join("/");
                    let text = std::fs::read_to_string(&p)
                        .with_context(|| format!("read {}", p.display()))?;
                    out.push(SourceFile { path: rel, text });
                }
            }
            Ok(())
        }
        let mut files = Vec::new();
        walk(root, root, &mut files)?;
        anyhow::ensure!(!files.is_empty(), "no .rs files under {}", root.display());
        files.sort_by(|a, b| a.path.cmp(&b.path));
        Ok(Project { files })
    }

    /// Build a project from in-memory fixtures (tests).
    pub fn from_memory(files: &[(&str, &str)]) -> Project {
        Project {
            files: files
                .iter()
                .map(|(p, t)| SourceFile { path: (*p).to_string(), text: (*t).to_string() })
                .collect(),
        }
    }
}

/// Run every rule; findings come back sorted by (path, line, rule).
pub fn analyze(project: &Project) -> Vec<Finding> {
    rules::run_all(project)
}

/// Human-readable report: one `path:line [rule] message` per finding plus a
/// summary line. Clean runs say so explicitly.
pub fn render_text(project: &Project, findings: &[Finding]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{} [{}] {}\n", f.path, f.line, f.rule, f.message));
    }
    out.push_str(&format!(
        "ilmpq analyze: {} finding{} in {} file{}\n",
        findings.len(),
        if findings.len() == 1 { "" } else { "s" },
        project.files.len(),
        if project.files.len() == 1 { "" } else { "s" },
    ));
    out
}

/// Machine-readable report for the CI gate (`ilmpq analyze --json`).
pub fn report_json(project: &Project, findings: &[Finding]) -> Json {
    Json::obj(vec![
        ("files", Json::Num(project.files.len() as f64)),
        ("clean", Json::Bool(findings.is_empty())),
        (
            "rules",
            Json::Arr(
                rules::RULES
                    .iter()
                    .map(|(id, summary)| {
                        Json::obj(vec![
                            ("id", Json::Str((*id).to_string())),
                            ("summary", Json::Str((*summary).to_string())),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "findings",
            Json::Arr(
                findings
                    .iter()
                    .map(|f| {
                        Json::obj(vec![
                            ("rule", Json::Str(f.rule.to_string())),
                            ("path", Json::Str(f.path.clone())),
                            ("line", Json::Num(f.line as f64)),
                            ("message", Json::Str(f.message.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_shapes() {
        let p = Project::from_memory(&[("coordinator/a.rs", "fn f() { x.unwrap(); }")]);
        let findings = analyze(&p);
        assert_eq!(findings.len(), 1);
        let text = render_text(&p, &findings);
        assert!(text.contains("coordinator/a.rs:1 [R1]"), "{text}");
        let j = report_json(&p, &findings);
        assert_eq!(j.get("clean"), Some(&Json::Bool(false)));
        assert_eq!(j.get("findings").and_then(Json::as_arr).map(|a| a.len()), Some(1));
    }
}
