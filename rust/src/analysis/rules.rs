//! The `ilmpq analyze` rule set.
//!
//! Each rule encodes one documented serving-stack invariant (see ROADMAP
//! "Architecture: static analysis & invariant audit"):
//!
//! | id | invariant |
//! |----|-----------|
//! | P0 | an `analyze:allow` pragma must carry a non-empty reason |
//! | R1 | no `unwrap`/`expect`/`panic!` in serving-path non-test code |
//! | R2 | no `let _ =` on a `send`/`reply` call (answer-exactly-once) |
//! | R3 | every `ServeError` variant is mapped in `http.rs` and `loadgen.rs` |
//! | R4 | every `Metrics` counter is emitted by `report()` and `to_json()` |
//! | R5 | no held lock guard whose scope runs a blocking call |
//! | R6 | every wire `Encoding` variant is handled in `http.rs` and `loadgen.rs` |
//! | R7 | every `ArtifactError` variant is mapped in `main.rs` and `http.rs` |
//!
//! Rules work on the `lexer` token stream — no syn, no rustc. They are
//! deliberately conservative pattern matchers: a miss is possible, a false
//! positive is answered with `// analyze:allow(reason)` at the flagged line.

use super::lexer::{Lexed, TokKind, Token};
use super::{Finding, Project};

/// Rule table used by the CLI/JSON report.
pub const RULES: &[(&str, &str)] = &[
    ("P0", "analyze:allow pragma requires a non-empty reason"),
    ("R1", "no unwrap/expect/panic! in serving-path non-test code"),
    ("R2", "no `let _ =` on a send/reply call (answer-exactly-once)"),
    ("R3", "every ServeError variant mapped in http.rs and loadgen.rs"),
    ("R4", "every Metrics counter emitted by report() and to_json()"),
    ("R5", "no held lock guard whose scope runs a blocking call"),
    ("R6", "every wire Encoding variant handled in http.rs and loadgen.rs"),
    ("R7", "every ArtifactError variant mapped in main.rs and http.rs"),
];

/// One lexed file plus its test-code token ranges, shared by all rules.
pub struct FileView<'a> {
    pub path: &'a str,
    pub lx: Lexed,
    excluded: Vec<(usize, usize)>,
}

impl<'a> FileView<'a> {
    pub fn new(path: &'a str, text: &str) -> FileView<'a> {
        let lx = super::lexer::lex(text);
        let excluded = test_ranges(&lx.tokens);
        FileView { path, lx, excluded }
    }

    fn toks(&self) -> &[Token] {
        &self.lx.tokens
    }

    /// True when token `idx` sits inside `#[cfg(test)]`/`#[test]` code.
    fn in_test_code(&self, idx: usize) -> bool {
        self.excluded.iter().any(|&(s, e)| idx >= s && idx <= e)
    }

    /// Last path component, e.g. `server.rs`.
    fn file_name(&self) -> &str {
        self.path.rsplit('/').next().unwrap_or(self.path)
    }

    fn push(&self, out: &mut Vec<Finding>, rule: &'static str, line: usize, msg: String) {
        if !self.lx.suppressed(line) {
            out.push(Finding { rule, path: self.path.to_string(), line, message: msg });
        }
    }
}

fn is_punct_at(toks: &[Token], idx: usize, s: &str) -> bool {
    toks.get(idx).is_some_and(|t| t.is_punct(s))
}

fn is_ident_at(toks: &[Token], idx: usize, s: &str) -> bool {
    toks.get(idx).is_some_and(|t| t.is_ident(s))
}

/// Index of the `}` matching the `{` at `open` (or the last token on
/// unbalanced input).
fn match_brace(toks: &[Token], open: usize) -> usize {
    let mut d = 0i64;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct("{") {
            d += 1;
        } else if t.is_punct("}") {
            d -= 1;
            if d == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// Index of the `]` matching the `[` at `open`.
fn match_bracket(toks: &[Token], open: usize) -> usize {
    let mut d = 0i64;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct("[") {
            d += 1;
        } else if t.is_punct("]") {
            d -= 1;
            if d == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// Index of the `)` matching the `(` at `open`.
fn match_paren(toks: &[Token], open: usize) -> usize {
    let mut d = 0i64;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct("(") {
            d += 1;
        } else if t.is_punct(")") {
            d -= 1;
            if d == 0 {
                return j;
            }
        }
    }
    toks.len().saturating_sub(1)
}

/// `#[test]` / `#[cfg(test)]` attribute contents (`#[cfg(not(test))]` is
/// *not* a test marker).
fn attr_is_test(toks: &[Token]) -> bool {
    let first = toks.iter().find(|t| t.kind == TokKind::Ident);
    match first.map(|t| t.text.as_str()) {
        Some("test") => true,
        Some("cfg") => {
            toks.iter().any(|t| t.is_ident("test")) && !toks.iter().any(|t| t.is_ident("not"))
        }
        _ => false,
    }
}

/// Token-index ranges covered by `#[cfg(test)] mod … { }` / `#[test] fn … { }`.
fn test_ranges(toks: &[Token]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < toks.len() {
        if is_punct_at(toks, i, "#") && is_punct_at(toks, i + 1, "[") {
            let close = match_bracket(toks, i + 1);
            if attr_is_test(&toks[i + 2..close]) {
                // Skip any further attributes on the same item.
                let mut j = close + 1;
                while is_punct_at(toks, j, "#") && is_punct_at(toks, j + 1, "[") {
                    j = match_bracket(toks, j + 1) + 1;
                }
                // Find the item body; a `;` first means no body (skip).
                let mut open = None;
                let mut k = j;
                while k < toks.len() && k < j + 64 {
                    if is_punct_at(toks, k, ";") {
                        break;
                    }
                    if is_punct_at(toks, k, "{") {
                        open = Some(k);
                        break;
                    }
                    k += 1;
                }
                if let Some(open) = open {
                    let end = match_brace(toks, open);
                    out.push((i, end));
                    i = end + 1;
                    continue;
                }
            }
            i = close + 1;
            continue;
        }
        i += 1;
    }
    out
}

// ---------------------------------------------------------------- P0

pub fn p0_bad_pragmas(file: &FileView, out: &mut Vec<Finding>) {
    for &line in &file.lx.bad_pragmas {
        out.push(Finding {
            rule: "P0",
            path: file.path.to_string(),
            line,
            message: "analyze:allow pragma without a reason — a suppression must \
                      justify itself: `// analyze:allow(why this is sound)`"
                .to_string(),
        });
    }
}

// ---------------------------------------------------------------- R1

fn r1_in_scope(path: &str) -> bool {
    path.contains("coordinator/") || path.contains("backend/") || path.ends_with("quant/plan.rs")
}

/// No `unwrap()`/`expect()`/`panic!`-family macros in serving-path non-test
/// code. A panic on the serving path tears down a worker and (before the
/// supervision layers existed) the whole answer-exactly-once story.
pub fn r1_no_unwrap(file: &FileView, out: &mut Vec<Finding>) {
    if !r1_in_scope(file.path) {
        return;
    }
    let toks = file.toks();
    for (idx, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || file.in_test_code(idx) {
            continue;
        }
        match t.text.as_str() {
            "unwrap" | "expect" => {
                if idx > 0 && toks[idx - 1].is_punct(".") && is_punct_at(toks, idx + 1, "(") {
                    file.push(
                        out,
                        "R1",
                        t.line,
                        format!(
                            "`.{}()` in serving-path code: return a typed error \
                             (ServeError / anyhow) or justify with \
                             `// analyze:allow(reason)`",
                            t.text
                        ),
                    );
                }
            }
            "panic" | "todo" | "unimplemented" => {
                if is_punct_at(toks, idx + 1, "!") {
                    file.push(
                        out,
                        "R1",
                        t.line,
                        format!(
                            "`{}!` in serving-path code: the serving path must \
                             degrade, not die — return a typed error or justify \
                             with `// analyze:allow(reason)`",
                            t.text
                        ),
                    );
                }
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------- R2

fn r2_in_scope(name: &str) -> bool {
    matches!(name, "server.rs" | "pool.rs" | "http.rs")
}

/// No `let _ = …send(…)` / `let _ = …reply(…)`: silently discarding a send
/// result can drop a reply channel and break answer-exactly-once. Either
/// handle the `Err` (count it, answer the members) or annotate why the
/// receiver being gone is fine.
pub fn r2_no_dropped_reply(file: &FileView, out: &mut Vec<Finding>) {
    if !r2_in_scope(file.file_name()) {
        return;
    }
    let toks = file.toks();
    for (idx, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || file.in_test_code(idx) {
            continue;
        }
        if !(t.text == "send" || t.text == "reply") {
            continue;
        }
        if !(idx > 0 && toks[idx - 1].is_punct(".") && is_punct_at(toks, idx + 1, "(")) {
            continue;
        }
        // Walk back to the start of the statement…
        let mut j = idx;
        while j > 0 {
            let p = &toks[j - 1];
            if p.is_punct(";") || p.is_punct("{") || p.is_punct("}") {
                break;
            }
            j -= 1;
        }
        // …and check whether it opens with `let _ =`.
        if is_ident_at(toks, j, "let")
            && is_ident_at(toks, j + 1, "_")
            && is_punct_at(toks, j + 2, "=")
        {
            file.push(
                out,
                "R2",
                t.line,
                format!(
                    "`let _ =` discards the result of `.{}()` — a dropped reply \
                     breaks answer-exactly-once; handle the Err (count it, answer \
                     the members) or justify with `// analyze:allow(reason)`",
                    t.text
                ),
            );
        }
    }
}

// ---------------------------------------------------------------- R3

/// Variants of `enum <name> { … }` with their declaration lines.
fn enum_variants(toks: &[Token], name: &str) -> Option<Vec<(String, usize)>> {
    let mut i = 0usize;
    let open = loop {
        if i + 1 >= toks.len() {
            return None;
        }
        if is_ident_at(toks, i, "enum") && is_ident_at(toks, i + 1, name) {
            let mut k = i + 2;
            while k < toks.len() && !toks[k].is_punct("{") {
                k += 1;
            }
            break k;
        }
        i += 1;
    };
    let close = match_brace(toks, open);
    let mut vars = Vec::new();
    let mut depth = 0i64;
    let mut expect_variant = true;
    let mut j = open + 1;
    while j < close {
        let t = &toks[j];
        if depth == 0 && t.is_punct("#") && is_punct_at(toks, j + 1, "[") {
            j = match_bracket(toks, j + 1) + 1;
            continue;
        }
        match t.text.as_str() {
            "{" | "(" | "[" if t.kind == TokKind::Punct => depth += 1,
            "}" | ")" | "]" if t.kind == TokKind::Punct => depth -= 1,
            "," if t.kind == TokKind::Punct && depth == 0 => expect_variant = true,
            _ => {
                if depth == 0 && expect_variant && t.kind == TokKind::Ident {
                    vars.push((t.text.clone(), t.line));
                    expect_variant = false;
                }
            }
        }
        j += 1;
    }
    Some(vars)
}

/// Does the file mention `ServeError::<variant>` anywhere?
fn mentions_variant(toks: &[Token], enum_name: &str, variant: &str) -> bool {
    toks.iter().enumerate().any(|(i, t)| {
        t.is_ident(enum_name)
            && is_punct_at(toks, i + 1, ":")
            && is_punct_at(toks, i + 2, ":")
            && is_ident_at(toks, i + 3, variant)
    })
}

/// Every `ServeError` variant must appear in the HTTP status mapping and in
/// loadgen's outcome-class fold — adding a variant without wiring both is a
/// build failure, not a silent `_ =>` bucket.
pub fn r3_error_mapping(files: &[FileView], out: &mut Vec<Finding>) {
    let Some(server) = files.iter().find(|f| f.file_name() == "server.rs") else { return };
    let Some(variants) = enum_variants(server.toks(), "ServeError") else { return };
    for consumer in ["http.rs", "loadgen.rs"] {
        let Some(target) = files.iter().find(|f| f.file_name() == consumer) else { continue };
        for (variant, line) in &variants {
            if !mentions_variant(target.toks(), "ServeError", variant) {
                server.push(
                    out,
                    "R3",
                    *line,
                    format!(
                        "ServeError::{variant} is never matched in {consumer} — \
                         wire the new variant into its status mapping / outcome \
                         fold (R3: error-mapping exhaustiveness)"
                    ),
                );
            }
        }
    }
}

/// Every wire `Encoding` variant (declared in `http.rs`) must appear in
/// both halves of the wire contract: the server's decode + content-type
/// mapping (`http.rs`) and the client's encode path (`loadgen.rs`). Same
/// cross-file shape as R3 — adding an encoding without wiring both sides
/// would silently serve 415s to the new clients or generate bodies the
/// server cannot decode.
pub fn r6_encoding_mapping(files: &[FileView], out: &mut Vec<Finding>) {
    let Some(http) = files.iter().find(|f| f.file_name() == "http.rs") else { return };
    let Some(variants) = enum_variants(http.toks(), "Encoding") else { return };
    for consumer in ["http.rs", "loadgen.rs"] {
        let Some(target) = files.iter().find(|f| f.file_name() == consumer) else { continue };
        for (variant, line) in &variants {
            if !mentions_variant(target.toks(), "Encoding", variant) {
                http.push(
                    out,
                    "R6",
                    *line,
                    format!(
                        "Encoding::{variant} is never matched in {consumer} — \
                         wire the new encoding into its decode/content-type \
                         mapping and client encode path (R6: wire-encoding \
                         exhaustiveness)"
                    ),
                );
            }
        }
    }
}

/// Every `ArtifactError` variant (declared in `artifact/store.rs`) must
/// appear in both consumers of the typed artifact failures: the CLI error
/// rendering (`main.rs`, actionable hints) and the HTTP status mapping
/// (`http.rs`, the live `/verify` route). Same cross-file shape as R3 —
/// adding a variant without wiring both would surface a new failure mode
/// as an unhinted blob of text or an unmapped 500.
pub fn r7_artifact_error_mapping(files: &[FileView], out: &mut Vec<Finding>) {
    let Some(store) = files.iter().find(|f| f.file_name() == "store.rs") else { return };
    let Some(variants) = enum_variants(store.toks(), "ArtifactError") else { return };
    for consumer in ["main.rs", "http.rs"] {
        let Some(target) = files.iter().find(|f| f.file_name() == consumer) else { continue };
        for (variant, line) in &variants {
            if !mentions_variant(target.toks(), "ArtifactError", variant) {
                store.push(
                    out,
                    "R7",
                    *line,
                    format!(
                        "ArtifactError::{variant} is never matched in {consumer} — \
                         wire the new variant into its error rendering / status \
                         mapping (R7: artifact-error exhaustiveness)"
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------- R4

/// Fields of `struct <name> { … }` whose type mentions one of `counter_tys`.
fn struct_counter_fields(toks: &[Token], name: &str, counter_tys: &[&str]) -> Vec<(String, usize)> {
    let mut i = 0usize;
    let open = loop {
        if i + 1 >= toks.len() {
            return Vec::new();
        }
        if is_ident_at(toks, i, "struct") && is_ident_at(toks, i + 1, name) {
            let mut k = i + 2;
            while k < toks.len() && !toks[k].is_punct("{") {
                k += 1;
            }
            break k;
        }
        i += 1;
    };
    let close = match_brace(toks, open);
    let mut fields = Vec::new();
    // Split the body into `,`-separated segments at depth 0.
    let mut depth = 0i64;
    let mut seg: Vec<usize> = Vec::new();
    let mut j = open + 1;
    let mut flush = |seg: &mut Vec<usize>, fields: &mut Vec<(String, usize)>| {
        // Segment shape: [attrs] [pub] <name> : <type tokens…>
        let colon = seg.iter().position(|&k| toks[k].is_punct(":"));
        if let Some(c) = colon {
            let name_idx = seg[..c]
                .iter()
                .rev()
                .find(|&&k| toks[k].kind == TokKind::Ident && toks[k].text != "pub")
                .copied();
            let has_counter_ty = seg[c..]
                .iter()
                .any(|&k| counter_tys.iter().any(|ty| toks[k].is_ident(ty)));
            if let (Some(ni), true) = (name_idx, has_counter_ty) {
                fields.push((toks[ni].text.clone(), toks[ni].line));
            }
        }
        seg.clear();
    };
    while j < close {
        let t = &toks[j];
        if depth == 0 && t.is_punct("#") && is_punct_at(toks, j + 1, "[") {
            j = match_bracket(toks, j + 1) + 1;
            continue;
        }
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "{" | "(" | "[" => depth += 1,
                "}" | ")" | "]" => depth -= 1,
                "," if depth == 0 => {
                    flush(&mut seg, &mut fields);
                    j += 1;
                    continue;
                }
                _ => {}
            }
        }
        seg.push(j);
        j += 1;
    }
    flush(&mut seg, &mut fields);
    fields
}

/// Body token range of `fn <name>` inside `impl <ty> { … }`.
fn impl_fn_body(toks: &[Token], ty: &str, fn_name: &str) -> Option<(usize, usize)> {
    let mut i = 0usize;
    while i + 2 < toks.len() {
        if is_ident_at(toks, i, "impl")
            && is_ident_at(toks, i + 1, ty)
            && is_punct_at(toks, i + 2, "{")
        {
            let close = match_brace(toks, i + 2);
            let mut j = i + 3;
            while j < close {
                if is_ident_at(toks, j, "fn") && is_ident_at(toks, j + 1, fn_name) {
                    let mut k = j + 2;
                    while k < close && !toks[k].is_punct("{") {
                        k += 1;
                    }
                    return Some((k, match_brace(toks, k)));
                }
                j += 1;
            }
            return None;
        }
        i += 1;
    }
    None
}

/// A counter is "emitted" by a body when the body mentions the field ident,
/// a `<field>_name` helper, or a string literal equal to the field (JSON key).
fn body_emits(toks: &[Token], body: (usize, usize), field: &str) -> bool {
    let helper = format!("{field}_name");
    toks[body.0..=body.1].iter().any(|t| {
        (t.kind == TokKind::Ident && (t.text == field || t.text == helper))
            || (t.kind == TokKind::Str && t.text == field)
    })
}

/// Every `Metrics` counter (AtomicU64 / LatencyTrack field) must be emitted
/// by both `report()` and `to_json()` — counters that exist but never
/// surface are how ledgers silently drift.
pub fn r4_counter_completeness(files: &[FileView], out: &mut Vec<Finding>) {
    let Some(metrics) = files.iter().find(|f| f.file_name() == "metrics.rs") else { return };
    let toks = metrics.toks();
    let fields = struct_counter_fields(toks, "Metrics", &["AtomicU64", "LatencyTrack"]);
    if fields.is_empty() {
        return;
    }
    for (emitter, label) in [("report", "report()"), ("to_json", "to_json()")] {
        let Some(body) = impl_fn_body(toks, "Metrics", emitter) else {
            metrics.push(
                out,
                "R4",
                1,
                format!("Metrics has counters but no `{label}` emitter (R4)"),
            );
            continue;
        };
        for (field, line) in &fields {
            if !body_emits(toks, body, field) {
                metrics.push(
                    out,
                    "R4",
                    *line,
                    format!(
                        "Metrics::{field} is never emitted by {label} — every \
                         counter must surface in both the human report and the \
                         JSON export (R4: counter completeness)"
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------- R5

fn r5_in_scope(name: &str) -> bool {
    matches!(name, "server.rs" | "pool.rs")
}

const LOCK_CALLS: &[&str] = &["lock", "plock", "write", "pwrite"];
const CHAIN_OK: &[&str] = &["unwrap", "expect", "unwrap_or_else"];
const BLOCKING: &[&str] =
    &["run_batch", "recv", "recv_timeout", "join", "sleep", "build_server", "prepare"];

/// Flag `let guard = …lock()…;` bindings whose remaining scope performs a
/// blocking call (backend execution, channel recv, thread join/sleep,
/// server build) while the guard is held. Intentional cases — the shared
/// worker receiver, the swap gate — carry `analyze:allow` pragmas.
pub fn r5_lock_scope(file: &FileView, out: &mut Vec<Finding>) {
    if !r5_in_scope(file.file_name()) {
        return;
    }
    let toks = file.toks();
    let mut i = 0usize;
    while i < toks.len() {
        if !is_ident_at(toks, i, "let") || file.in_test_code(i) {
            i += 1;
            continue;
        }
        // Binding name (skip `mut`; skip `_` which drops immediately and
        // destructuring patterns which we don't model).
        let mut k = i + 1;
        if is_ident_at(toks, k, "mut") {
            k += 1;
        }
        let Some(bind) = toks.get(k) else { break };
        if bind.kind != TokKind::Ident || bind.text == "_" {
            i += 1;
            continue;
        }
        let name = bind.text.clone();
        // Optional `: Type` annotation, then `=`.
        let mut e = k + 1;
        while e < toks.len() && !toks[e].is_punct("=") && !toks[e].is_punct(";") {
            e += 1;
        }
        if !is_punct_at(toks, e, "=") {
            i += 1;
            continue;
        }
        // Scan the RHS at depth 0 up to the statement's `;`.
        let mut depth = 0i64;
        let mut j = e + 1;
        let mut lock_end: Option<usize> = None; // index after `)` of the lock call
        let stmt_end = loop {
            let Some(t) = toks.get(j) else { break toks.len() - 1 };
            if t.kind == TokKind::Punct {
                match t.text.as_str() {
                    "{" | "(" | "[" => depth += 1,
                    "}" | ")" | "]" => depth -= 1,
                    ";" if depth == 0 => break j,
                    _ => {}
                }
            }
            if depth == 0
                && t.kind == TokKind::Ident
                && LOCK_CALLS.contains(&t.text.as_str())
                && j > 0
                && toks[j - 1].is_punct(".")
                && is_punct_at(toks, j + 1, "(")
            {
                let close = match_paren(toks, j + 1);
                // Allow a trailing `.unwrap()` / `.unwrap_or_else(…)` chain.
                let mut m = close + 1;
                while is_punct_at(toks, m, ".")
                    && toks.get(m + 1).is_some_and(|t| {
                        t.kind == TokKind::Ident && CHAIN_OK.contains(&t.text.as_str())
                    })
                    && is_punct_at(toks, m + 2, "(")
                {
                    m = match_paren(toks, m + 2) + 1;
                }
                lock_end = Some(m);
                depth += 1; // we are about to re-walk from inside the parens
                j += 2; // step past `(`
                continue;
            }
            j += 1;
        };
        // A guard binding = the lock/chain runs right up to the `;`.
        let is_guard = lock_end == Some(stmt_end);
        if is_guard {
            // Scan the guard's scope: from after `;` to the end of the
            // enclosing block, stopping early at an explicit `drop(name)`.
            let mut d = 0i64;
            let mut s = stmt_end + 1;
            while s < toks.len() {
                let t = &toks[s];
                if t.kind == TokKind::Punct {
                    match t.text.as_str() {
                        "{" => d += 1,
                        "}" => {
                            d -= 1;
                            if d < 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                }
                if t.is_ident("drop")
                    && is_punct_at(toks, s + 1, "(")
                    && is_ident_at(toks, s + 2, &name)
                    && is_punct_at(toks, s + 3, ")")
                {
                    break;
                }
                if t.kind == TokKind::Ident
                    && BLOCKING.contains(&t.text.as_str())
                    && is_punct_at(toks, s + 1, "(")
                {
                    file.push(
                        out,
                        "R5",
                        toks[i].line,
                        format!(
                            "lock guard `{name}` is held across a blocking \
                             `{}()` call — shrink the guard's scope (drop it or \
                             bind inside a block) or justify with \
                             `// analyze:allow(reason)`",
                            t.text
                        ),
                    );
                    break; // one finding per guard
                }
                s += 1;
            }
        }
        // Advance one token, not to `stmt_end`: a block-valued RHS can
        // contain nested `let` guard bindings that must be analyzed too.
        i += 1;
    }
}

/// Run every rule over the project.
pub fn run_all(project: &Project) -> Vec<Finding> {
    let files: Vec<FileView> =
        project.files.iter().map(|f| FileView::new(&f.path, &f.text)).collect();
    let mut out = Vec::new();
    for f in &files {
        p0_bad_pragmas(f, &mut out);
        r1_no_unwrap(f, &mut out);
        r2_no_dropped_reply(f, &mut out);
        r5_lock_scope(f, &mut out);
    }
    r3_error_mapping(&files, &mut out);
    r4_counter_completeness(&files, &mut out);
    r6_encoding_mapping(&files, &mut out);
    r7_artifact_error_mapping(&files, &mut out);
    out.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn project(files: &[(&str, &str)]) -> Project {
        Project {
            files: files
                .iter()
                .map(|(p, t)| super::super::SourceFile {
                    path: (*p).to_string(),
                    text: (*t).to_string(),
                })
                .collect(),
        }
    }

    #[test]
    fn test_ranges_cover_cfg_test_mod() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n  fn t() { y.unwrap(); }\n}\n";
        let findings = run_all(&project(&[("coordinator/a.rs", src)]));
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].line, 1);
    }

    #[test]
    fn r1_ignores_out_of_scope_paths() {
        let findings = run_all(&project(&[("util/a.rs", "fn f() { x.unwrap(); }")]));
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn r1_does_not_match_unwrap_or_else() {
        let src = "fn f() { m.lock().unwrap_or_else(|e| e.into_inner()); }";
        let findings = run_all(&project(&[("coordinator/a.rs", src)]));
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn r5_sees_nested_guard_bindings() {
        // The guard binding lives inside an outer `let`'s block-valued RHS;
        // the scanner must not skip over it.
        let src = "fn f() { let msg = { let rx = ch.plock(); rx.recv() }; msg; }";
        let findings = run_all(&project(&[("coordinator/server.rs", src)]));
        assert!(findings.iter().any(|f| f.rule == "R5"), "{findings:?}");
    }

    #[test]
    fn r6_fires_per_consumer_and_quiets_when_wired() {
        let decl = "pub enum Encoding { Json, Raw }\nfn d() { match e { Encoding::Json => 1, Encoding::Raw => 2 }; }";
        // loadgen only encodes Json: Raw must be flagged there (and only there).
        let half = "fn enc() { let _x = Encoding::Json; }";
        let findings = run_all(&project(&[
            ("coordinator/http.rs", decl),
            ("coordinator/loadgen.rs", half),
        ]));
        let r6: Vec<_> = findings.iter().filter(|f| f.rule == "R6").collect();
        assert_eq!(r6.len(), 1, "{findings:?}");
        assert!(r6[0].message.contains("Encoding::Raw") && r6[0].message.contains("loadgen.rs"));
        let full = "fn enc() { match e { Encoding::Json => 1, Encoding::Raw => 2 }; }";
        let findings = run_all(&project(&[
            ("coordinator/http.rs", decl),
            ("coordinator/loadgen.rs", full),
        ]));
        assert!(findings.iter().all(|f| f.rule != "R6"), "{findings:?}");
    }

    #[test]
    fn enum_variant_parse_handles_payloads() {
        let lx = super::super::lexer::lex(
            "pub enum E { A, B(String), C { x: u32, y: Vec<u8> }, D }",
        );
        let vars: Vec<String> =
            enum_variants(&lx.tokens, "E").unwrap().into_iter().map(|(v, _)| v).collect();
        assert_eq!(vars, vec!["A", "B", "C", "D"]);
    }
}
