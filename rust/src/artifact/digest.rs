//! Hand-rolled SHA-256 and the `Digest` identity type.
//!
//! The artifact store addresses every blob by the SHA-256 of its bytes, so
//! the hash is the trust root of the whole subsystem. It is implemented
//! from the FIPS 180-4 specification with no dependencies and pinned
//! against the NIST test vectors (empty, "abc", the 448-bit two-block
//! message, and one million 'a's) in the unit tests below — if the
//! compression function is wrong in any bit, the pins catch it.

use std::fmt;

use super::store::ArtifactError;

/// A SHA-256 digest: the identity of a stored blob.
///
/// Formats as 64 lowercase hex characters; parses strictly (exactly 64
/// hex digits, case-insensitive input, canonical lowercase output).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Digest([u8; 32]);

impl Digest {
    /// Hash `bytes` in one shot.
    pub fn of(bytes: &[u8]) -> Digest {
        let mut h = Sha256::new();
        h.update(bytes);
        h.finalize()
    }

    /// The canonical lowercase-hex rendering.
    pub fn to_hex(&self) -> String {
        let mut s = String::with_capacity(64);
        for b in &self.0 {
            s.push(HEX[(b >> 4) as usize] as char);
            s.push(HEX[(b & 0xf) as usize] as char);
        }
        s
    }

    /// Strict parse of a 64-hex-char digest string.
    pub fn parse(s: &str) -> Result<Digest, ArtifactError> {
        let bytes = s.as_bytes();
        if bytes.len() != 64 {
            return Err(ArtifactError::BadDigest {
                input: s.to_string(),
                reason: format!("expected 64 hex chars, got {}", bytes.len()),
            });
        }
        let mut out = [0u8; 32];
        for (i, pair) in bytes.chunks(2).enumerate() {
            let hi = hex_val(pair[0]);
            let lo = hex_val(pair[1]);
            match (hi, lo) {
                (Some(h), Some(l)) => out[i] = (h << 4) | l,
                _ => {
                    return Err(ArtifactError::BadDigest {
                        input: s.to_string(),
                        reason: format!("non-hex character at offset {}", i * 2),
                    })
                }
            }
        }
        Ok(Digest(out))
    }

    /// The raw 32 digest bytes.
    pub fn as_bytes(&self) -> &[u8; 32] {
        &self.0
    }
}

impl fmt::Display for Digest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_hex())
    }
}

impl fmt::Debug for Digest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Digest({})", self.to_hex())
    }
}

const HEX: &[u8; 16] = b"0123456789abcdef";

fn hex_val(c: u8) -> Option<u8> {
    match c {
        b'0'..=b'9' => Some(c - b'0'),
        b'a'..=b'f' => Some(c - b'a' + 10),
        b'A'..=b'F' => Some(c - b'A' + 10),
        _ => None,
    }
}

/// Incremental SHA-256 hasher (FIPS 180-4).
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total_len: u64,
}

/// First 32 bits of the fractional parts of the cube roots of the first
/// 64 primes — the round constants of FIPS 180-4 §4.2.2.
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state: fractional parts of the square roots of the first
/// eight primes (FIPS 180-4 §5.3.3).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 { state: H0, buf: [0u8; 64], buf_len: 0, total_len: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let need = 64 - self.buf_len;
            let take = need.min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> Digest {
        let bit_len = self.total_len.wrapping_mul(8);
        // Padding: 0x80, zeros, then the 64-bit big-endian bit length.
        self.pad_byte(0x80);
        while self.buf_len != 56 {
            self.pad_byte(0x00);
        }
        let len_bytes = bit_len.to_be_bytes();
        self.buf[56..64].copy_from_slice(&len_bytes);
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, word) in self.state.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
        }
        Digest(out)
    }

    fn pad_byte(&mut self, b: u8) {
        self.buf[self.buf_len] = b;
        self.buf_len += 1;
        if self.buf_len == 64 {
            let block = self.buf;
            self.compress(&block);
            self.buf_len = 0;
        }
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16].wrapping_add(s0).wrapping_add(w[i - 7]).wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let big_s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h.wrapping_add(big_s1).wrapping_add(ch).wrapping_add(K[i]).wrapping_add(w[i]);
            let big_s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = big_s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
        self.state[5] = self.state[5].wrapping_add(f);
        self.state[6] = self.state[6].wrapping_add(g);
        self.state[7] = self.state[7].wrapping_add(h);
    }
}

impl Default for Sha256 {
    fn default() -> Self {
        Sha256::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nist_vector_empty() {
        assert_eq!(
            Digest::of(b"").to_hex(),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn nist_vector_abc() {
        assert_eq!(
            Digest::of(b"abc").to_hex(),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn nist_vector_448_bit_two_block_message() {
        assert_eq!(
            Digest::of(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq").to_hex(),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn nist_vector_one_million_a() {
        let data = vec![b'a'; 1_000_000];
        assert_eq!(
            Digest::of(&data).to_hex(),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn incremental_matches_oneshot_across_odd_chunk_sizes() {
        let data: Vec<u8> = (0..1000u32).flat_map(|i| i.to_le_bytes()).collect();
        let oneshot = Digest::of(&data);
        for chunk in [1usize, 3, 63, 64, 65, 127, 997] {
            let mut h = Sha256::new();
            for piece in data.chunks(chunk) {
                h.update(piece);
            }
            assert_eq!(h.finalize(), oneshot, "chunk size {chunk}");
        }
    }

    #[test]
    fn hex_roundtrip_and_strict_parse() {
        let d = Digest::of(b"round-trip");
        let parsed = Digest::parse(&d.to_hex()).expect("canonical hex parses");
        assert_eq!(parsed, d);
        // Uppercase input is accepted, renders back to lowercase.
        let upper = d.to_hex().to_uppercase();
        assert_eq!(Digest::parse(&upper).expect("uppercase hex parses"), d);

        let short = Digest::parse("abc123");
        assert!(short.is_err(), "short strings must be rejected");
        let bad = Digest::parse(&"zz".repeat(32));
        assert!(bad.is_err(), "non-hex characters must be rejected");
        let err = format!("{}", bad.expect_err("non-hex rejected"));
        assert!(err.contains("non-hex"), "{err}");
    }

    #[test]
    fn display_is_hex() {
        let d = Digest::of(b"abc");
        assert_eq!(format!("{d}"), d.to_hex());
        assert!(format!("{d:?}").contains(&d.to_hex()));
    }
}
