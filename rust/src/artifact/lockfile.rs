//! The `Bundle` lockfile: a named serving unit pinned by digest.
//!
//! `ilmpq.lock.json` names every model a pool should serve and pins the
//! exact bytes behind it — manifest descriptor, params blob, and
//! QuantPlan JSON — by SHA-256. Parsing is strict in the `FaultSpec`
//! style: unknown keys are an error at both the bundle and the model
//! level, so a typo in a deployment lockfile fails loudly instead of
//! silently serving something else.
//!
//! Schema (version 1):
//!
//! ```json
//! {
//!   "ilmpq_bundle": 1,
//!   "default": "tiny",
//!   "models": [
//!     {
//!       "name": "tiny", "backend": "cpu", "geometry": "tinyresnet",
//!       "model": "tinyresnet-8", "manifest": "<64 hex>",
//!       "params": "<64 hex>", "plan": "<64 hex>"
//!     }
//!   ]
//! }
//! ```

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::Json;

use super::digest::Digest;

/// Lockfile schema version this build reads and writes.
pub const BUNDLE_VERSION: u64 = 1;

/// One model pinned by a bundle: identity plus the three blob digests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BundleModel {
    /// Pool entry name (route key under `/v1/models/{name}`).
    pub name: String,
    /// Backend registry key the entry is built on.
    pub backend: String,
    /// Synthetic geometry the manifest descriptor must resolve to.
    pub geometry: String,
    /// Manifest `model_name`, cross-checked at load.
    pub model: String,
    /// Digest of the manifest descriptor JSON blob.
    pub manifest: Digest,
    /// Digest of the flat little-endian f32 params blob.
    pub params: Digest,
    /// Digest of the QuantPlan JSON blob.
    pub plan: Digest,
}

impl BundleModel {
    fn from_json(j: &Json) -> Result<BundleModel> {
        let Some(obj) = j.as_obj() else {
            bail!("bundle model must be a JSON object");
        };
        let mut name = None;
        let mut backend = None;
        let mut geometry = None;
        let mut model = None;
        let mut manifest = None;
        let mut params = None;
        let mut plan = None;
        for (key, val) in obj {
            let text = || {
                val.as_str()
                    .map(str::to_string)
                    .with_context(|| format!("bundle model key {key:?}: expected a string"))
            };
            match key.as_str() {
                "name" => name = Some(text()?),
                "backend" => backend = Some(text()?),
                "geometry" => geometry = Some(text()?),
                "model" => model = Some(text()?),
                "manifest" => manifest = Some(parse_digest(&text()?, "manifest")?),
                "params" => params = Some(parse_digest(&text()?, "params")?),
                "plan" => plan = Some(parse_digest(&text()?, "plan")?),
                _ => bail!(
                    "bundle model: unknown key {key:?} (known: name, backend, \
                     geometry, model, manifest, params, plan)"
                ),
            }
        }
        let require = |field: &str| format!("bundle model: missing key {field:?}");
        Ok(BundleModel {
            name: name.with_context(|| require("name"))?,
            backend: backend.with_context(|| require("backend"))?,
            geometry: geometry.with_context(|| require("geometry"))?,
            model: model.with_context(|| require("model"))?,
            manifest: manifest.with_context(|| require("manifest"))?,
            params: params.with_context(|| require("params"))?,
            plan: plan.with_context(|| require("plan"))?,
        })
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("backend", Json::Str(self.backend.clone())),
            ("geometry", Json::Str(self.geometry.clone())),
            ("model", Json::Str(self.model.clone())),
            ("manifest", Json::Str(self.manifest.to_hex())),
            ("params", Json::Str(self.params.to_hex())),
            ("plan", Json::Str(self.plan.to_hex())),
        ])
    }
}

fn parse_digest(s: &str, field: &str) -> Result<Digest> {
    Digest::parse(s).with_context(|| format!("bundle model key {field:?}"))
}

/// A versioned lockfile naming a serving unit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bundle {
    pub version: u64,
    /// Name of the model `/v1/infer` routes to.
    pub default: String,
    pub models: Vec<BundleModel>,
}

impl Bundle {
    /// Strict parse: exact key set, version check, nonempty unique model
    /// names, and a `default` that names one of them.
    pub fn from_json(j: &Json) -> Result<Bundle> {
        let Some(obj) = j.as_obj() else {
            bail!("bundle lockfile must be a JSON object");
        };
        let mut version = None;
        let mut default = None;
        let mut models: Option<Vec<BundleModel>> = None;
        for (key, val) in obj {
            match key.as_str() {
                "ilmpq_bundle" => {
                    version = Some(
                        val.as_f64()
                            .filter(|v| *v >= 0.0 && v.fract() == 0.0)
                            .context("bundle: \"ilmpq_bundle\" must be a version integer")?
                            as u64,
                    )
                }
                "default" => {
                    default = Some(
                        val.as_str()
                            .context("bundle: \"default\" must be a string")?
                            .to_string(),
                    )
                }
                "models" => {
                    let rows = val.as_arr().context("bundle: \"models\" must be an array")?;
                    let mut parsed = Vec::with_capacity(rows.len());
                    for (i, row) in rows.iter().enumerate() {
                        parsed.push(
                            BundleModel::from_json(row)
                                .with_context(|| format!("bundle models[{i}]"))?,
                        );
                    }
                    models = Some(parsed);
                }
                _ => bail!(
                    "bundle: unknown key {key:?} (known: ilmpq_bundle, default, models)"
                ),
            }
        }
        let version = version.context("bundle: missing key \"ilmpq_bundle\"")?;
        if version != BUNDLE_VERSION {
            bail!("bundle: version {version} is not supported (this build reads {BUNDLE_VERSION})");
        }
        let default = default.context("bundle: missing key \"default\"")?;
        let models = models.context("bundle: missing key \"models\"")?;
        if models.is_empty() {
            bail!("bundle: \"models\" must name at least one model");
        }
        for (i, m) in models.iter().enumerate() {
            if models[..i].iter().any(|prev| prev.name == m.name) {
                bail!("bundle: duplicate model name {:?}", m.name);
            }
        }
        if !models.iter().any(|m| m.name == default) {
            let names: Vec<&str> = models.iter().map(|m| m.name.as_str()).collect();
            bail!("bundle: default {default:?} names no model (models: {names:?})");
        }
        Ok(Bundle { version, default, models })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ilmpq_bundle", Json::Num(self.version as f64)),
            ("default", Json::Str(self.default.clone())),
            ("models", Json::Arr(self.models.iter().map(BundleModel::to_json).collect())),
        ])
    }

    /// Look up a model by name.
    pub fn model(&self, name: &str) -> Option<&BundleModel> {
        self.models.iter().find(|m| m.name == name)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let text = self.to_json().to_string_compact();
        std::fs::write(path, text.as_bytes())
            .with_context(|| format!("writing bundle lockfile {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Bundle> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bundle lockfile {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .with_context(|| format!("parsing bundle lockfile {}", path.display()))?;
        Bundle::from_json(&j).with_context(|| format!("bundle lockfile {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Bundle {
        Bundle {
            version: BUNDLE_VERSION,
            default: "tiny".to_string(),
            models: vec![
                BundleModel {
                    name: "tiny".to_string(),
                    backend: "cpu".to_string(),
                    geometry: "tinyresnet".to_string(),
                    model: "tinyresnet-8".to_string(),
                    manifest: Digest::of(b"manifest-a"),
                    params: Digest::of(b"params-a"),
                    plan: Digest::of(b"plan-a"),
                },
                BundleModel {
                    name: "narrow".to_string(),
                    backend: "cpu".to_string(),
                    geometry: "vggnarrow".to_string(),
                    model: "vggnarrow-7".to_string(),
                    manifest: Digest::of(b"manifest-b"),
                    params: Digest::of(b"params-b"),
                    plan: Digest::of(b"plan-b"),
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let b = fixture();
        let back = Bundle::from_json(&b.to_json()).expect("roundtrip");
        assert_eq!(back, b);
        assert!(back.model("narrow").is_some());
        assert!(back.model("absent").is_none());
    }

    #[test]
    fn unknown_keys_are_rejected_at_both_levels() {
        let mut top = b_json();
        if let Json::Obj(map) = &mut top {
            map.insert("extra".to_string(), Json::Bool(true));
        }
        let err = Bundle::from_json(&top).expect_err("unknown top-level key");
        assert!(format!("{err:#}").contains("unknown key"), "{err:#}");

        let mut nested = b_json();
        if let Some(Json::Arr(rows)) = nested_models_mut(&mut nested) {
            if let Some(Json::Obj(m)) = rows.first_mut() {
                m.insert("sneaky".to_string(), Json::Num(1.0));
            }
        }
        let err = Bundle::from_json(&nested).expect_err("unknown model key");
        assert!(format!("{err:#}").contains("unknown key"), "{err:#}");
    }

    fn b_json() -> Json {
        fixture().to_json()
    }

    fn nested_models_mut(j: &mut Json) -> Option<&mut Json> {
        match j {
            Json::Obj(map) => map.get_mut("models"),
            _ => None,
        }
    }

    #[test]
    fn truncated_digest_is_rejected() {
        let mut j = b_json();
        if let Some(Json::Arr(rows)) = nested_models_mut(&mut j) {
            if let Some(Json::Obj(m)) = rows.first_mut() {
                m.insert("plan".to_string(), Json::Str("abc123".to_string()));
            }
        }
        let err = Bundle::from_json(&j).expect_err("truncated digest");
        assert!(format!("{err:#}").contains("64 hex"), "{err:#}");
    }

    #[test]
    fn duplicate_names_missing_default_and_wrong_version() {
        let mut dup = fixture();
        dup.models[1].name = "tiny".to_string();
        let err = Bundle::from_json(&dup.to_json()).expect_err("duplicate names");
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");

        let mut nodef = fixture();
        nodef.default = "ghost".to_string();
        let err = Bundle::from_json(&nodef.to_json()).expect_err("default names no model");
        assert!(format!("{err:#}").contains("names no model"), "{err:#}");

        let mut vers = fixture();
        vers.version = 99;
        let err = Bundle::from_json(&vers.to_json()).expect_err("unsupported version");
        assert!(format!("{err:#}").contains("not supported"), "{err:#}");

        let mut empty = fixture();
        empty.models.clear();
        // An empty models list also orphans `default`; the emptiness
        // check fires first.
        let err = Bundle::from_json(&empty.to_json()).expect_err("empty models");
        assert!(format!("{err:#}").contains("at least one"), "{err:#}");
    }

    #[test]
    fn save_load_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("ilmpq-lock-test-{}.json", std::process::id()));
        let b = fixture();
        b.save(&path).expect("save");
        let back = Bundle::load(&path).expect("load");
        assert_eq!(back, b);
        let _ = std::fs::remove_file(&path);
    }
}
