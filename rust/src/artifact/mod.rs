//! Content-addressed artifact store: checksummed weights and plans with
//! a lockfile pinning what a serving fleet executes.
//!
//! Three layers, dependency-free in the same discipline as `util::json`:
//!
//! - [`digest`] — hand-rolled SHA-256 pinned against NIST vectors,
//!   exposed as [`Digest`] with strict hex parse/format.
//! - [`store`] — a local CAS directory ([`Store`]): blobs addressed by
//!   digest with two-char fan-out, temp-then-rename writes so torn
//!   writes are never addressable, and full re-hash on every read.
//! - [`lockfile`] — the [`Bundle`] lockfile (`ilmpq.lock.json`) naming a
//!   serving unit: model → {manifest, params, plan} digests plus the
//!   backend and geometry needed to boot it.
//!
//! The serving stack consumes this through `ilmpq bundle pack|verify|show`
//! and `ilmpq serve --bundle`, which boots a `ServerPool` that resolves
//! every byte it executes from the store by digest — a mismatch is a
//! startup error, never a silent fallback.

pub mod digest;
pub mod lockfile;
pub mod store;

pub use digest::{Digest, Sha256};
pub use lockfile::{Bundle, BundleModel, BUNDLE_VERSION};
pub use store::{ArtifactError, Store};
