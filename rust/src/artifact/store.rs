//! Local content-addressed blob store.
//!
//! Layout: `<root>/<first two hex chars>/<remaining 62>` — one file per
//! blob, named by the SHA-256 of its bytes. Writes go to a temp file in
//! the same fan-out directory and are renamed into place, so a torn
//! write can never be addressable (the temp name is not a digest path).
//! Every `get` re-hashes the full file and returns a typed
//! `ArtifactError::DigestMismatch` naming expected vs actual on any
//! corruption — there is no fast path that trusts the filename.

use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::digest::Digest;

/// Typed failure surface of the artifact store. Every variant must be
/// mapped in the CLI error rendering (`main.rs`) and the HTTP status
/// mapping (`coordinator/http.rs`) — enforced by analyzer rule R7.
#[derive(Debug)]
pub enum ArtifactError {
    /// Stored bytes hash to something other than their address.
    DigestMismatch { blob: String, expected: Digest, actual: Digest },
    /// A referenced blob is absent from the store.
    MissingBlob { blob: String, digest: Digest },
    /// A digest string failed to parse.
    BadDigest { input: String, reason: String },
    /// Filesystem failure while touching a blob.
    Io { blob: String, op: &'static str, source: std::io::Error },
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::DigestMismatch { blob, expected, actual } => write!(
                f,
                "digest mismatch for blob {blob}: expected {expected}, actual {actual}"
            ),
            ArtifactError::MissingBlob { blob, digest } => {
                write!(f, "missing blob {blob}: {digest} is not in the store")
            }
            ArtifactError::BadDigest { input, reason } => {
                write!(f, "bad digest {input:?}: {reason}")
            }
            ArtifactError::Io { blob, op, source } => {
                write!(f, "artifact io failure ({op} {blob}): {source}")
            }
        }
    }
}

impl std::error::Error for ArtifactError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ArtifactError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Monotonic counter so concurrent writers in one process never share a
/// temp file name.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// A content-addressed store rooted at a local directory.
#[derive(Debug, Clone)]
pub struct Store {
    root: PathBuf,
}

impl Store {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: &Path) -> Result<Store, ArtifactError> {
        std::fs::create_dir_all(root).map_err(|e| ArtifactError::Io {
            blob: root.display().to_string(),
            op: "create store root",
            source: e,
        })?;
        Ok(Store { root: root.to_path_buf() })
    }

    /// Default store root: `$ILMPQ_STORE`, else `$HOME/.ilmpq/store`,
    /// else `./.ilmpq-store`.
    pub fn default_root() -> PathBuf {
        if let Ok(dir) = std::env::var("ILMPQ_STORE") {
            if !dir.is_empty() {
                return PathBuf::from(dir);
            }
        }
        if let Ok(home) = std::env::var("HOME") {
            if !home.is_empty() {
                return PathBuf::from(home).join(".ilmpq").join("store");
            }
        }
        PathBuf::from(".ilmpq-store")
    }

    /// The directory this store lives in.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Filesystem path a digest resolves to (two-char fan-out).
    pub fn path_of(&self, d: &Digest) -> PathBuf {
        let hex = d.to_hex();
        self.root.join(&hex[..2]).join(&hex[2..])
    }

    /// Store `bytes`, returning their digest. Idempotent: an existing
    /// blob is trusted by address here (reads re-verify). The write is
    /// temp-then-rename so a crash mid-write leaves only an
    /// unaddressable `*.tmp.*` file behind.
    pub fn put(&self, bytes: &[u8]) -> Result<Digest, ArtifactError> {
        let digest = Digest::of(bytes);
        let path = self.path_of(&digest);
        if path.is_file() {
            return Ok(digest);
        }
        let dir = path.parent().unwrap_or(&self.root);
        std::fs::create_dir_all(dir).map_err(|e| ArtifactError::Io {
            blob: digest.to_hex(),
            op: "create fan-out dir",
            source: e,
        })?;
        let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = dir.join(format!("{}.tmp.{}.{}", digest.to_hex(), std::process::id(), seq));
        std::fs::write(&tmp, bytes).map_err(|e| ArtifactError::Io {
            blob: digest.to_hex(),
            op: "write temp blob",
            source: e,
        })?;
        std::fs::rename(&tmp, &path).map_err(|e| {
            // Best-effort cleanup; the original error is what matters.
            let _ = std::fs::remove_file(&tmp);
            ArtifactError::Io { blob: digest.to_hex(), op: "rename blob into place", source: e }
        })?;
        Ok(digest)
    }

    /// Whether a blob with this digest is present (no content check).
    pub fn has(&self, d: &Digest) -> bool {
        self.path_of(d).is_file()
    }

    /// Fetch a blob by digest, verifying the full contents. `blob` is a
    /// human-readable label (e.g. `"tiny/params"`) carried into errors.
    pub fn get(&self, d: &Digest, blob: &str) -> Result<Vec<u8>, ArtifactError> {
        let path = self.path_of(d);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(ArtifactError::MissingBlob { blob: blob.to_string(), digest: *d })
            }
            Err(e) => {
                return Err(ArtifactError::Io {
                    blob: blob.to_string(),
                    op: "read blob",
                    source: e,
                })
            }
        };
        let actual = Digest::of(&bytes);
        if actual != *d {
            return Err(ArtifactError::DigestMismatch {
                blob: blob.to_string(),
                expected: *d,
                actual,
            });
        }
        Ok(bytes)
    }

    /// Re-hash a blob without returning its bytes.
    pub fn verify(&self, d: &Digest, blob: &str) -> Result<(), ArtifactError> {
        self.get(d, blob).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_store(tag: &str) -> Store {
        let dir = std::env::temp_dir()
            .join(format!("ilmpq-store-test-{}-{}", std::process::id(), tag));
        let _ = std::fs::remove_dir_all(&dir);
        Store::open(&dir).expect("store opens")
    }

    #[test]
    fn put_get_roundtrip_and_idempotence() {
        let s = temp_store("roundtrip");
        let d1 = s.put(b"hello artifact").expect("put");
        let d2 = s.put(b"hello artifact").expect("second put is idempotent");
        assert_eq!(d1, d2);
        assert!(s.has(&d1));
        assert_eq!(s.get(&d1, "t/blob").expect("get"), b"hello artifact");
        s.verify(&d1, "t/blob").expect("verify");
    }

    #[test]
    fn corrupt_blob_is_rejected_on_get() {
        let s = temp_store("corrupt");
        let d = s.put(b"precious bytes").expect("put");
        let path = s.path_of(&d);
        let mut bytes = std::fs::read(&path).expect("read back");
        bytes[0] ^= 0x01;
        std::fs::write(&path, &bytes).expect("corrupt in place");
        let err = s.get(&d, "t/params").expect_err("corruption must be detected");
        match &err {
            ArtifactError::DigestMismatch { blob, expected, actual } => {
                assert_eq!(blob, "t/params");
                assert_eq!(*expected, d);
                assert_ne!(actual, expected);
            }
            other => panic!("expected DigestMismatch, got {other}"),
        }
        let msg = format!("{err}");
        assert!(msg.contains("expected") && msg.contains("actual"), "{msg}");
        assert!(s.verify(&d, "t/params").is_err());
    }

    #[test]
    fn missing_blob_is_a_typed_error() {
        let s = temp_store("missing");
        let d = Digest::of(b"never stored");
        assert!(!s.has(&d));
        let err = s.get(&d, "t/plan").expect_err("absent blob");
        match err {
            ArtifactError::MissingBlob { blob, digest } => {
                assert_eq!(blob, "t/plan");
                assert_eq!(digest, d);
            }
            other => panic!("expected MissingBlob, got {other}"),
        }
    }

    #[test]
    fn torn_write_is_not_addressable() {
        let s = temp_store("torn");
        // Simulate a crash mid-put: a temp file exists in the fan-out
        // directory but was never renamed to its digest path.
        let bytes = b"half-written";
        let d = Digest::of(bytes);
        let hex = d.to_hex();
        let dir = s.root().join(&hex[..2]);
        std::fs::create_dir_all(&dir).expect("fan-out dir");
        std::fs::write(dir.join(format!("{hex}.tmp.999.0")), &bytes[..6]).expect("torn temp");
        assert!(!s.has(&d), "a temp file must never be addressable");
        let err = s.get(&d, "t/manifest").expect_err("torn write invisible to get");
        assert!(matches!(err, ArtifactError::MissingBlob { .. }), "{err}");
        // A real put still lands cleanly next to the debris.
        let d2 = s.put(bytes).expect("put after torn write");
        assert_eq!(d2, d);
        assert_eq!(s.get(&d, "t/manifest").expect("get"), bytes);
    }

    #[test]
    fn bad_digest_parse_is_typed() {
        let err = Digest::parse("not-a-digest").expect_err("reject");
        assert!(matches!(err, ArtifactError::BadDigest { .. }), "{err}");
    }
}
