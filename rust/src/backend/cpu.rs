//! The pure-CPU backends: packed-code integer execution and the f32
//! reference — both thin [`InferenceBackend`] shells over
//! [`runtime::qforward::PackedModel`](crate::runtime::PackedModel).
//!
//! The packing cost lives here, not on the request path: construction
//! records (manifest, params, masks) and the BRAM-image pack happens once —
//! in `prepare()` or lazily on the first `run_batch` — then is reused for
//! the whole eval/serve lifetime. (The pre-trait `eval_frozen_qgemm` helper
//! re-packed every layer on each evaluation call.)

use std::sync::OnceLock;
use std::time::Instant;

use anyhow::Result;

use crate::quant::MaskSet;
use crate::runtime::{HostTensor, Manifest, PackedModel};

use super::{batch_output, BatchOutput, InferenceBackend};

/// Shared state of the two CPU backends: the pack inputs + the cached model.
struct PackedState {
    manifest: Manifest,
    params: Vec<HostTensor>,
    /// `Some` packs integer codes (the qgemm path); `None` keeps f32 rows.
    masks: Option<MaskSet>,
    threads: Option<usize>,
    model: OnceLock<PackedModel>,
}

impl PackedState {
    fn new(
        manifest: Manifest,
        params: Vec<HostTensor>,
        masks: Option<MaskSet>,
    ) -> PackedState {
        PackedState { manifest, params, masks, threads: None, model: OnceLock::new() }
    }

    /// The packed network, building it on first use. Two threads racing the
    /// cold build both pack (identical, deterministic models); the first
    /// `set` wins and the loser's copy is dropped.
    fn model(&self) -> Result<&PackedModel> {
        if self.model.get().is_none() {
            let mut m =
                PackedModel::build(&self.manifest, &self.params, self.masks.as_ref())?;
            if let Some(t) = self.threads {
                m = m.with_threads(t);
            }
            let _ = self.model.set(m);
        }
        // analyze:allow(OnceLock invariant: the branch above just set the model on this path)
        Ok(self.model.get().expect("set above"))
    }

    fn run(&self, images: &[f32], batch: usize) -> Result<BatchOutput> {
        // Same geometry source as the PJRT backend and the server's batch
        // padding; `PackedModel::forward` still asserts the model dims.
        super::check_batch_len(images, batch, self.manifest.data.image_elems())?;
        let model = self.model()?;
        let t = Instant::now();
        let logits = model.forward(images, batch);
        batch_output(logits, batch, self.manifest.classes, t.elapsed())
    }
}

/// The native packed-code GEMM backend: weights packed into their
/// [`crate::quant::PackedMatrix`] BRAM image once, every batch driven
/// through `quant::qgemm` — integer arithmetic end to end, exactly as on
/// the board. Builds and runs under `--no-default-features`.
pub struct QgemmBackend {
    state: PackedState,
}

impl QgemmBackend {
    /// Pack `params` under `masks`. Raw and frozen params produce identical
    /// codes (fake-quant is idempotent and scale-preserving), so callers
    /// need not freeze first.
    pub fn new(manifest: Manifest, params: Vec<HostTensor>, masks: MaskSet) -> QgemmBackend {
        QgemmBackend { state: PackedState::new(manifest, params, Some(masks)) }
    }

    /// Override the worker-pool size (default: all cores). Only effective
    /// before the model is packed.
    pub fn with_threads(mut self, threads: usize) -> QgemmBackend {
        self.state.threads = Some(threads.max(1));
        self
    }
}

impl InferenceBackend for QgemmBackend {
    fn name(&self) -> &str {
        "qgemm"
    }

    fn supports_frozen(&self) -> bool {
        true
    }

    fn prepare(&self) -> Result<()> {
        self.state.model().map(|_| ())
    }

    fn active_masks(&self) -> Option<&MaskSet> {
        self.state.masks.as_ref()
    }

    fn run_batch(&self, images: &[f32], batch: usize) -> Result<BatchOutput> {
        self.state.run(images, batch)
    }
}

/// The f32 GEMM-view reference backend: the same topology and row layout as
/// the packed path, but float arithmetic throughout — the PJRT path's
/// numerics without PJRT. Used for cross-checks and the PTQ float-reference
/// row; runs whatever params it is given (freeze first for a
/// frozen-faithful reference).
pub struct FloatRefBackend {
    state: PackedState,
}

impl FloatRefBackend {
    pub fn new(manifest: Manifest, params: Vec<HostTensor>) -> FloatRefBackend {
        FloatRefBackend { state: PackedState::new(manifest, params, None) }
    }

    /// Override the worker-pool size (default: all cores). Only effective
    /// before the model is built.
    pub fn with_threads(mut self, threads: usize) -> FloatRefBackend {
        self.state.threads = Some(threads.max(1));
        self
    }
}

impl InferenceBackend for FloatRefBackend {
    fn name(&self) -> &str {
        "float"
    }

    fn supports_frozen(&self) -> bool {
        false
    }

    fn prepare(&self) -> Result<()> {
        self.state.model().map(|_| ())
    }

    fn run_batch(&self, images: &[f32], batch: usize) -> Result<BatchOutput> {
        self.state.run(images, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::super::synth;
    use super::*;
    use crate::quant::Ratio;
    use crate::util::Rng;

    fn fixture() -> (Manifest, Vec<HostTensor>, MaskSet) {
        let mut rng = Rng::new(31);
        let m = synth::tiny_manifest(8, 8, 3, &[4, 8], 5);
        let params = synth::random_params(&m, &mut rng);
        let masks = synth::random_masks(&m, Ratio::new(65.0, 30.0, 5.0), &mut rng);
        (m, params, masks)
    }

    #[test]
    fn qgemm_run_batch_shapes_and_preds() {
        let (m, params, masks) = fixture();
        let be = QgemmBackend::new(m, params, masks).with_threads(2);
        be.prepare().unwrap();
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..3 * 8 * 8 * 3).map(|_| rng.normal()).collect();
        let out = be.run_batch(&x, 3).unwrap();
        assert_eq!(out.logits.len(), 3 * 5);
        assert_eq!(out.preds.len(), 3);
        assert_eq!(out.classes, 5);
        assert!(out.logits.iter().all(|v| v.is_finite()));
        for (i, &p) in out.preds.iter().enumerate() {
            assert_eq!(p, super::super::argmax(&out.logits[i * 5..(i + 1) * 5]));
        }
    }

    #[test]
    fn run_batch_works_without_prepare_and_is_cached() {
        let (m, params, masks) = fixture();
        let be = QgemmBackend::new(m, params, masks).with_threads(1);
        let mut rng = Rng::new(9);
        let x: Vec<f32> = (0..2 * 8 * 8 * 3).map(|_| rng.normal()).collect();
        // Lazy pack on first use, then bit-identical reuse of the cache.
        let a = be.run_batch(&x, 2).unwrap();
        be.prepare().unwrap(); // idempotent after the lazy build
        let b = be.run_batch(&x, 2).unwrap();
        assert!(a
            .logits
            .iter()
            .zip(&b.logits)
            .all(|(x1, x2)| x1.to_bits() == x2.to_bits()));
    }

    #[test]
    fn wrong_image_length_is_an_error() {
        let (m, params, masks) = fixture();
        let be = QgemmBackend::new(m, params, masks);
        let err = be.run_batch(&[0.0; 10], 2).unwrap_err();
        assert!(format!("{err:#}").contains("expected"));
    }

    #[test]
    fn names_and_frozen_flags() {
        let (m, params, masks) = fixture();
        let q = QgemmBackend::new(m.clone(), params.clone(), masks);
        let f = FloatRefBackend::new(m, params);
        assert_eq!(q.name(), "qgemm");
        assert_eq!(f.name(), "float");
        assert!(q.supports_frozen());
        assert!(!f.supports_frozen());
    }
}
