//! Deterministic fault injection: a wrapper backend that makes every
//! execution-side failure mode reachable on demand.
//!
//! The serving loop's resilience machinery (watchdog deadline, singleton
//! retry/quarantine, circuit breaker, fallback chain — see
//! `coordinator::server`) is only testable if the failures it guards
//! against can be produced *deterministically* and *artifact-free*.
//! [`FaultyBackend`] wraps any [`InferenceBackend`] and, driven by a seeded
//! [`FaultSpec`] schedule, injects:
//!
//! * **panics** — the contained-panic path (`catch_unwind` in the worker);
//! * **errors** — ordinary `run_batch` failures (`ServeError::BackendFailed`);
//! * **stalls** — a sleep long enough to trip `ServeConfig::execute_deadline`
//!   (`ServeError::Timeout`; the watchdog abandons the call);
//! * **garbage outputs** — NaN logits or a truncated logits buffer, which
//!   the server's output validation must reject instead of serving;
//! * **failure bursts** — N consecutive failed batches every M batches, the
//!   shape that opens (and, once past, re-closes) the circuit breaker;
//! * **poison requests** — an image whose first element is [`POISON_MAGIC`]
//!   fails *every batch containing it*, deterministically. Only the
//!   singleton-retry re-split can isolate it, which is exactly what the
//!   quarantine tests assert.
//!
//! All randomness comes from one seeded [`crate::util::Rng`] advanced in a
//! fixed draw order per batch, so a given spec produces the same fault
//! schedule on every run. Specs round-trip through JSON (`util::Json`, no
//! serde) so the CLI can load them from a file: `ilmpq serve --fault
//! spec.json`, or `--fault chaos` for the built-in mixed schedule.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::quant::MaskSet;
use crate::util::sync::LockExt;
use crate::util::{Json, Rng};

use super::{BatchOutput, InferenceBackend};

/// Sentinel first-element value marking a poison request. Finite (so it
/// passes admission's finiteness scan) and exactly representable in f32,
/// f64, and a JSON number, so it survives the HTTP wire format bit-exactly.
pub const POISON_MAGIC: f32 = 1.0e12;

/// A seeded, deterministic fault schedule. All probabilities are per-batch
/// and drawn in a fixed order from one RNG, so the schedule is a pure
/// function of `(seed, batch index)`. The default spec injects nothing.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// RNG seed for the per-batch fault draws.
    pub seed: u64,
    /// Probability a batch execution panics (contained by the worker).
    pub panic_prob: f64,
    /// Probability a batch returns an injected `Err`.
    pub error_prob: f64,
    /// Probability a batch stalls `stall_ms` before executing — long enough
    /// to trip the execution deadline when one is configured.
    pub stall_prob: f64,
    /// Stall duration in milliseconds.
    pub stall_ms: u64,
    /// Fixed latency in milliseconds added to *every* batch.
    pub latency_ms: u64,
    /// Probability a batch's output is corrupted after the inner run:
    /// alternating between NaN-poisoned logits and a truncated buffer.
    pub garbage_prob: f64,
    /// Every `burst_period` batches, fail the first `burst_len` of them
    /// (by batch index; `0` disables). `burst_period == u64::MAX` with a
    /// nonzero `burst_len` yields one leading burst — the deterministic way
    /// to open the breaker and then let it recover.
    pub burst_period: u64,
    /// Consecutive batches failed per burst window.
    pub burst_len: u64,
    /// Detect poison requests: fail any batch containing an image whose
    /// first element equals [`POISON_MAGIC`]. Deterministic (no RNG draw),
    /// so batch-level retries keep failing until the re-split isolates the
    /// poison member.
    pub poison: bool,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 0,
            panic_prob: 0.0,
            error_prob: 0.0,
            stall_prob: 0.0,
            stall_ms: 1_000,
            latency_ms: 0,
            garbage_prob: 0.0,
            burst_period: 0,
            burst_len: 0,
            poison: true,
        }
    }
}

impl FaultSpec {
    /// The built-in mixed schedule (`--fault chaos`): ≥10% each of panics,
    /// deadline-tripping stalls, garbage outputs, and plain errors, plus a
    /// leading failure burst that opens the circuit breaker before the
    /// healthy tail lets it re-close.
    pub fn chaos(seed: u64) -> FaultSpec {
        FaultSpec {
            seed,
            panic_prob: 0.10,
            error_prob: 0.10,
            stall_prob: 0.10,
            stall_ms: 1_000,
            latency_ms: 0,
            garbage_prob: 0.10,
            burst_period: u64::MAX,
            burst_len: 5,
            poison: true,
        }
    }

    /// Parse a spec from its JSON object form. Missing keys take the
    /// [`FaultSpec::default`] value; unknown keys are an error so a typo in
    /// a CI spec file fails loudly instead of silently injecting nothing.
    pub fn from_json(j: &Json) -> Result<FaultSpec> {
        let Some(obj) = j.as_obj() else {
            bail!("fault spec must be a JSON object");
        };
        let mut spec = FaultSpec::default();
        for (key, val) in obj {
            let num = |what: &str| -> Result<f64> {
                val.as_f64()
                    .with_context(|| format!("fault spec key {key:?}: expected a {what}"))
            };
            match key.as_str() {
                "seed" => spec.seed = num("number")? as u64,
                "panic_prob" => spec.panic_prob = num("probability")?,
                "error_prob" => spec.error_prob = num("probability")?,
                "stall_prob" => spec.stall_prob = num("probability")?,
                "stall_ms" => spec.stall_ms = num("millisecond count")? as u64,
                "latency_ms" => spec.latency_ms = num("millisecond count")? as u64,
                "garbage_prob" => spec.garbage_prob = num("probability")?,
                "burst_period" => spec.burst_period = num("batch count")? as u64,
                "burst_len" => spec.burst_len = num("batch count")? as u64,
                "poison" => match val {
                    Json::Bool(b) => spec.poison = *b,
                    _ => bail!("fault spec key \"poison\": expected a bool"),
                },
                _ => bail!(
                    "fault spec: unknown key {key:?} (known: seed, panic_prob, \
                     error_prob, stall_prob, stall_ms, latency_ms, garbage_prob, \
                     burst_period, burst_len, poison)"
                ),
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("panic_prob", Json::Num(self.panic_prob)),
            ("error_prob", Json::Num(self.error_prob)),
            ("stall_prob", Json::Num(self.stall_prob)),
            ("stall_ms", Json::Num(self.stall_ms as f64)),
            ("latency_ms", Json::Num(self.latency_ms as f64)),
            ("garbage_prob", Json::Num(self.garbage_prob)),
            ("burst_period", Json::Num(self.burst_period as f64)),
            ("burst_len", Json::Num(self.burst_len as f64)),
            ("poison", Json::Bool(self.poison)),
        ])
    }

    /// Load a spec from a JSON file, or the named built-in (`"chaos"`).
    pub fn load(path: &std::path::Path) -> Result<FaultSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read fault spec {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parse fault spec {}", path.display()))?;
        Self::from_json(&j)
            .with_context(|| format!("fault spec {} rejected", path.display()))
    }

    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("panic_prob", self.panic_prob),
            ("error_prob", self.error_prob),
            ("stall_prob", self.stall_prob),
            ("garbage_prob", self.garbage_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                bail!("fault spec: {name} = {p} is not a probability in [0, 1]");
            }
        }
        if self.burst_period > 0 && self.burst_len > self.burst_period {
            bail!(
                "fault spec: burst_len {} exceeds burst_period {} (every batch \
                 would fail; use error_prob = 1 for that)",
                self.burst_len,
                self.burst_period
            );
        }
        Ok(())
    }
}

/// Per-batch fault decisions, drawn under the state lock in a fixed order.
struct FaultDraw {
    index: u64,
    stall: bool,
    panic: bool,
    error: bool,
    garbage: bool,
}

struct FaultState {
    rng: Rng,
    batch_index: u64,
}

/// An [`InferenceBackend`] wrapper that injects the [`FaultSpec`] schedule
/// around (and into) an inner backend. Delegates `name` (prefixed
/// `"faulty:"`), `supports_frozen`, `prepare`, and — load-bearing for the
/// server's plan cross-check — `active_masks`.
pub struct FaultyBackend {
    inner: Arc<dyn InferenceBackend>,
    spec: FaultSpec,
    name: String,
    state: Mutex<FaultState>,
}

impl FaultyBackend {
    pub fn new(inner: Arc<dyn InferenceBackend>, spec: FaultSpec) -> FaultyBackend {
        let name = format!("faulty:{}", inner.name());
        let state = Mutex::new(FaultState { rng: Rng::new(spec.seed), batch_index: 0 });
        FaultyBackend { inner, spec, name, state }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Advance the schedule one batch. Draw order is fixed (stall, panic,
    /// error, garbage) so the schedule for batch N never depends on which
    /// faults earlier batches actually exercised.
    fn draw(&self) -> FaultDraw {
        let mut st = self.state.plock();
        let index = st.batch_index;
        st.batch_index += 1;
        FaultDraw {
            index,
            stall: st.rng.bool(self.spec.stall_prob),
            panic: st.rng.bool(self.spec.panic_prob),
            error: st.rng.bool(self.spec.error_prob),
            garbage: st.rng.bool(self.spec.garbage_prob),
        }
    }

    fn in_burst(&self, index: u64) -> bool {
        self.spec.burst_period > 0
            && self.spec.burst_len > 0
            && index % self.spec.burst_period < self.spec.burst_len
    }
}

impl InferenceBackend for FaultyBackend {
    fn name(&self) -> &str {
        &self.name
    }

    fn supports_frozen(&self) -> bool {
        self.inner.supports_frozen()
    }

    fn prepare(&self) -> Result<()> {
        self.inner.prepare()
    }

    fn active_masks(&self) -> Option<&MaskSet> {
        self.inner.active_masks()
    }

    fn run_batch(&self, images: &[f32], batch: usize) -> Result<BatchOutput> {
        // Poison detection first: deterministic, independent of the RNG
        // schedule, so co-batched neighbors of a poison request fail every
        // batch-level attempt until a singleton re-split isolates it.
        if self.spec.poison && batch > 0 && images.len() % batch == 0 {
            let stride = images.len() / batch;
            if stride > 0 {
                for i in 0..batch {
                    if images[i * stride] == POISON_MAGIC {
                        bail!(
                            "injected fault: poison request at batch slot {i} \
                             (image[0] == {POISON_MAGIC:e})"
                        );
                    }
                }
            }
        }
        let draw = self.draw();
        if self.spec.latency_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.spec.latency_ms));
        }
        if self.in_burst(draw.index) {
            bail!("injected fault: failure burst (batch {})", draw.index);
        }
        if draw.stall {
            // The stall itself is the fault: after sleeping, execution
            // proceeds normally. With a watchdog deadline shorter than
            // `stall_ms` the call has already been abandoned and this
            // (correct, late) result is dropped with the channel.
            std::thread::sleep(Duration::from_millis(self.spec.stall_ms));
        }
        if draw.panic {
            // analyze:allow(the injected panic IS this backend's product; the supervision layers contain it)
            panic!("injected fault: panic (batch {})", draw.index);
        }
        if draw.error {
            bail!("injected fault: backend error (batch {})", draw.index);
        }
        let mut out = self.inner.run_batch(images, batch)?;
        if draw.garbage {
            // Corrupt *after* the inner run: the inner backend's argmax
            // must never see the NaN (it panics on NaN by contract), and
            // the corruption must reach the server's output validation.
            if draw.index % 2 == 0 {
                for v in out.logits.iter_mut() {
                    *v = f32::NAN;
                }
            } else {
                out.logits.pop();
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::synth;
    use super::*;
    use crate::quant::{Provenance, QuantPlan, Ratio};

    fn inner() -> (Arc<dyn InferenceBackend>, usize) {
        let mut rng = Rng::new(5);
        let m = synth::tiny_manifest(8, 8, 3, &[4, 8], 5);
        let img = m.data.image_elems();
        let params = synth::random_params(&m, &mut rng);
        let masks = synth::random_masks(&m, Ratio::new(65.0, 30.0, 5.0), &mut rng);
        let plan = QuantPlan::from_mask_set(
            masks,
            Provenance::Synthetic { seed: 5, ratio: "65:30:5".into() },
        );
        let init = super::super::BackendInit {
            plan: Some(plan),
            ..super::super::BackendInit::new(m, params)
        };
        (Arc::from(super::super::create("qgemm", &init).unwrap()), img)
    }

    #[test]
    fn default_spec_is_a_transparent_wrapper() {
        let (be, img) = inner();
        let reference = be.run_batch(&vec![0.25; 2 * img], 2).unwrap();
        let faulty = FaultyBackend::new(be, FaultSpec::default());
        assert_eq!(faulty.name(), "faulty:qgemm");
        assert!(faulty.active_masks().is_some(), "must delegate active_masks");
        let out = faulty.run_batch(&vec![0.25; 2 * img], 2).unwrap();
        assert_eq!(out.logits, reference.logits);
        assert_eq!(out.preds, reference.preds);
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let spec = FaultSpec { error_prob: 0.5, seed: 9, ..Default::default() };
        let (be, img) = inner();
        let x = vec![0.25; img];
        let run = |be: Arc<dyn InferenceBackend>| -> Vec<bool> {
            let f = FaultyBackend::new(be, spec.clone());
            (0..32).map(|_| f.run_batch(&x, 1).is_ok()).collect()
        };
        let a = run(be.clone());
        let b = run(be);
        assert_eq!(a, b, "same seed must produce the same fault schedule");
        assert!(a.iter().any(|ok| *ok) && a.iter().any(|ok| !ok), "{a:?}");
    }

    #[test]
    fn poison_fails_every_batch_containing_it_and_only_those() {
        let (be, img) = inner();
        let f = FaultyBackend::new(be, FaultSpec::default());
        let mut x = vec![0.25; 4 * img];
        x[2 * img] = POISON_MAGIC; // slot 2 is the poison request
        let err = f.run_batch(&x, 4).unwrap_err();
        assert!(format!("{err:#}").contains("poison"), "{err:#}");
        // The same poison image alone still fails; clean singletons pass.
        let err = f.run_batch(&x[2 * img..3 * img], 1).unwrap_err();
        assert!(format!("{err:#}").contains("poison"), "{err:#}");
        assert!(f.run_batch(&x[..img], 1).is_ok());
    }

    #[test]
    fn garbage_corrupts_after_the_inner_run() {
        let (be, img) = inner();
        let f = FaultyBackend::new(be, FaultSpec { garbage_prob: 1.0, ..Default::default() });
        let x = vec![0.25; img];
        // Batch index 0: NaN logits; index 1: truncated buffer. Both are
        // Ok(...) from the wrapper — rejecting them is the *server's* job.
        let out = f.run_batch(&x, 1).unwrap();
        assert!(out.logits.iter().all(|v| v.is_nan()), "{:?}", out.logits);
        let out = f.run_batch(&x, 1).unwrap();
        assert!(!out.logits.is_empty() && out.logits.len() < out.classes, "{:?}", out.logits);
    }

    #[test]
    fn burst_fails_the_leading_batches_then_recovers() {
        let (be, img) = inner();
        let spec = FaultSpec { burst_period: u64::MAX, burst_len: 3, ..Default::default() };
        let f = FaultyBackend::new(be, spec);
        let x = vec![0.25; img];
        let outcomes: Vec<bool> = (0..6).map(|_| f.run_batch(&x, 1).is_ok()).collect();
        assert_eq!(outcomes, vec![false, false, false, true, true, true]);
    }

    #[test]
    fn json_round_trip_and_validation() {
        let spec = FaultSpec::chaos(17);
        let back = FaultSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(spec, back);
        // Missing keys default; unknown keys and bad probabilities error.
        let partial = Json::parse(r#"{"error_prob": 0.25, "seed": 3}"#).unwrap();
        let spec = FaultSpec::from_json(&partial).unwrap();
        assert_eq!(spec.error_prob, 0.25);
        assert_eq!(spec.seed, 3);
        assert_eq!(spec.panic_prob, 0.0);
        let bad = Json::parse(r#"{"eror_prob": 0.25}"#).unwrap();
        assert!(FaultSpec::from_json(&bad).is_err(), "typo must be rejected");
        let bad = Json::parse(r#"{"panic_prob": 1.5}"#).unwrap();
        assert!(FaultSpec::from_json(&bad).is_err(), "prob > 1 must be rejected");
    }
}
