//! Execution backends: one batch-first API over every inference path.
//!
//! The paper's core claim is that intra-layer multi-precision *uniforms the
//! hardware configuration* so a single compute path serves every layer. This
//! module applies the same idea one level up, to the software stack: the
//! repository grew three divergent ways to run the quantized TinyResNet —
//! the PJRT/XLA engine over AOT artifacts, the native packed-code
//! `quant::qgemm` path, and the f32 GEMM-view reference — each with its own
//! call signature, and a serving stack hardwired to PJRT. Everything now
//! goes through one trait:
//!
//! * [`InferenceBackend`] — `run_batch(images, batch) -> BatchOutput`
//!   (logits + argmax + per-batch timing), plus `name()`,
//!   `supports_frozen()`, and a `prepare()` warm-up hook;
//! * [`PjrtBackend`] — the XLA/PJRT engine over the `infer[_frozen]_b{N}`
//!   artifacts. Constructible only when the `pjrt` cargo feature is compiled
//!   in (it needs a live [`crate::runtime::Engine`]); the type itself builds
//!   everywhere so consumers stay feature-free;
//! * [`QgemmBackend`] — the packed-code integer path: weights packed into
//!   the BRAM image once (in `prepare()`), every batch driven through
//!   `quant::qgemm`. Pure CPU; builds and runs under
//!   `--no-default-features`;
//! * [`FloatRefBackend`] — the f32 GEMM-view reference with the PJRT path's
//!   numerics, for cross-checks and the PTQ float-reference row;
//! * [`FaultyBackend`] — seeded, deterministic fault injection wrapped
//!   around any inner backend (`faulty:<name>` registry keys, or
//!   `--fault spec.json` from the CLI), so every execution failure mode the
//!   serving loop guards against is reachable artifact-free.
//!
//! Backends are resolved by name through [`registry()`] — the single source
//! of truth for `--backend` parsing (`create(name, &init)` errors list the
//! available names). Consumers — `coordinator::server`, `experiments::ptq`,
//! `experiments::accuracy`, the benches and integration tests — only ever
//! see `dyn InferenceBackend`, so adding a backend (sharded, cached,
//! remote-board…) is a one-file registry addition.
//!
//! Feature story: the trait, registry, and both CPU backends build with
//! `--no-default-features`; selecting `"pjrt"` there fails at `create()`
//! time with a clear message instead of at compile time.

pub mod cpu;
pub mod fault;
pub mod pjrt;
pub mod registry;
pub mod synth;

pub use cpu::{FloatRefBackend, QgemmBackend};
pub use fault::{FaultSpec, FaultyBackend, POISON_MAGIC};
pub use pjrt::PjrtBackend;
pub use registry::{
    available_names, create, create_serving, registry, spec, BackendInit, BackendSpec,
};

use std::ops::Deref;
use std::time::Duration;

use anyhow::Result;

use crate::quant::MaskSet;

/// One owned, flattened-NHWC image buffer: the request payload's single
/// representation from ingress decode to batch assembly.
///
/// The serving path used to copy the image at every hop (HTTP body → parsed
/// vector → `Request.image` → batch concat). `ImageBuf` pins the contract
/// instead: the f32 data is written exactly once at decode time (JSON lazy
/// scan or raw little-endian bytes) and once more into the batch buffer —
/// every hop in between moves or borrows. `Deref<Target = [f32]>` keeps the
/// validators ([`validate_image_len`], [`validate_image_finite`]) and batch
/// assembly reading it in place.
#[derive(Debug, Clone, PartialEq)]
pub struct ImageBuf(Vec<f32>);

impl ImageBuf {
    /// Decode a little-endian f32 raw-tensor body (`application/x-raw-f32`)
    /// into an owned buffer. This is the wire format's *only* decode step:
    /// byte length must be a multiple of 4; element count and finiteness are
    /// admission's job ([`validate_image_len`] / [`validate_image_finite`]),
    /// so non-finite bit patterns decode fine here and are rejected there.
    pub fn from_raw_le_bytes(bytes: &[u8]) -> std::result::Result<ImageBuf, String> {
        if bytes.len() % 4 != 0 {
            return Err(format!(
                "raw f32 tensor body is {} bytes, not a multiple of 4",
                bytes.len()
            ));
        }
        let mut out = Vec::with_capacity(bytes.len() / 4);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(ImageBuf(out))
    }

    /// Consume the buffer, yielding the underlying vector (no copy).
    pub fn into_vec(self) -> Vec<f32> {
        self.0
    }
}

impl From<Vec<f32>> for ImageBuf {
    /// Wrap an already-decoded vector (in-process callers, tests) — a move,
    /// not a copy.
    fn from(v: Vec<f32>) -> ImageBuf {
        ImageBuf(v)
    }
}

impl Deref for ImageBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.0
    }
}

/// Logits + argmax + timing for one executed batch.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Row-major `(batch, classes)` logits.
    pub logits: Vec<f32>,
    /// Per-sample argmax (ties resolve to the *last* maximal index — the
    /// PJRT path's historic `max_by` behaviour, shared by every backend).
    pub preds: Vec<usize>,
    pub classes: usize,
    /// Wall-clock spent executing this batch (staging + compute + fetch;
    /// excludes any request queueing done by the caller).
    pub elapsed: Duration,
}

/// The unified batch-first inference API.
///
/// A backend owns its weights (packed codes, frozen tensors, or raw params +
/// masks — construction policy, not call-site policy) and executes flattened
/// NHWC image batches. Implementations must be `Send + Sync`: the serving
/// worker pool shares one backend across threads behind an `Arc`.
pub trait InferenceBackend: Send + Sync {
    /// Registry name of this backend (`"pjrt"`, `"qgemm"`, `"float"`, …).
    fn name(&self) -> &str;

    /// True when the backend executes a pre-quantized ("frozen") weight
    /// image natively — integer codes or frozen artifacts, no per-request
    /// fake-quant. The float reference runs whatever params it was built
    /// with and has no dedicated frozen path.
    fn supports_frozen(&self) -> bool;

    /// Warm-up hook: compile/pack everything so `run_batch` never pays
    /// one-time costs on the request path. Idempotent; `run_batch` must
    /// also work without it (paying the cost lazily on first use).
    fn prepare(&self) -> Result<()> {
        Ok(())
    }

    /// The mask set this backend retains and executes, when it keeps one
    /// (the packed `qgemm` path and fake-quant PJRT do; frozen PJRT bakes
    /// the masks into the weight image and the float reference freezes up
    /// front, so they have nothing left to report). Lets the serving layer
    /// cross-check the *advertised* quantization plan against what
    /// actually executes.
    fn active_masks(&self) -> Option<&MaskSet> {
        None
    }

    /// Execute `batch` images (`batch * image_elems` floats, flattened
    /// NHWC). Padded tail slots are the caller's concern — the batcher pads
    /// with zeros and drops the extra outputs.
    fn run_batch(&self, images: &[f32], batch: usize) -> Result<BatchOutput>;
}

/// Argmax with the shared tie rule (last maximal index). Uses the IEEE total
/// order, so a NaN logit ranks above every finite score instead of
/// panicking; an empty row maps to class 0.
pub fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, c| a.1.total_cmp(c.1))
        .map_or(0, |(k, _)| k)
}

/// O(1) half of admission validation: the image must hold exactly
/// `image_elems` floats. This is the corruption-dangerous class — batch
/// buffers are built by concatenation, so a wrong-length image admitted
/// into a batch would shift every subsequent image's offset.
pub fn validate_image_len(
    image: &[f32],
    image_elems: usize,
) -> std::result::Result<(), String> {
    if image.len() != image_elems {
        return Err(format!(
            "image has {} elements, model expects {image_elems}",
            image.len()
        ));
    }
    Ok(())
}

/// O(n) half of admission validation: every value must be finite. The
/// serving front door runs this *after* its cheap admission checks so
/// requests shed under overload never pay the full scan.
pub fn validate_image_finite(image: &[f32]) -> std::result::Result<(), String> {
    if let Some(i) = image.iter().position(|v| !v.is_finite()) {
        return Err(format!("image[{i}] is not finite ({})", image[i]));
    }
    Ok(())
}

/// Full admission-time request validation (length + finiteness), for
/// ingresses without an overload fast path.
pub fn validate_image(image: &[f32], image_elems: usize) -> std::result::Result<(), String> {
    validate_image_len(image, image_elems)?;
    validate_image_finite(image)
}

/// Shared `run_batch` input guard: `images` must hold exactly
/// `batch * image_elems` floats.
pub(crate) fn check_batch_len(images: &[f32], batch: usize, image_elems: usize) -> Result<()> {
    anyhow::ensure!(
        images.len() == batch * image_elems,
        "expected {} floats for batch {batch} ({image_elems} per image), got {}",
        batch * image_elems,
        images.len()
    );
    Ok(())
}

/// Assemble a [`BatchOutput`] from raw logits, validating the shape and
/// deriving the per-sample argmax.
pub(crate) fn batch_output(
    logits: Vec<f32>,
    batch: usize,
    classes: usize,
    elapsed: Duration,
) -> Result<BatchOutput> {
    anyhow::ensure!(
        logits.len() == batch * classes,
        "backend returned {} logits for batch {batch} x {classes} classes",
        logits.len()
    );
    let preds = (0..batch)
        .map(|i| argmax(&logits[i * classes..(i + 1) * classes]))
        .collect();
    Ok(BatchOutput { logits, preds, classes, elapsed })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_tie_rule_is_last_maximal() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9]), 2);
        assert_eq!(argmax(&[3.0, 1.0]), 0);
    }

    #[test]
    fn batch_output_derives_preds() {
        let out =
            batch_output(vec![0.0, 1.0, 5.0, -1.0], 2, 2, Duration::ZERO).unwrap();
        assert_eq!(out.preds, vec![1, 0]);
        assert_eq!(out.classes, 2);
    }

    #[test]
    fn batch_output_rejects_bad_shape() {
        assert!(batch_output(vec![0.0; 3], 2, 2, Duration::ZERO).is_err());
    }

    #[test]
    fn image_buf_roundtrips_le_bytes_bit_exactly() {
        let src = vec![0.0f32, -1.5, f32::MIN_POSITIVE, 3.0e7];
        let mut bytes = Vec::new();
        for v in &src {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let buf = ImageBuf::from_raw_le_bytes(&bytes).unwrap();
        assert_eq!(&*buf, &src[..]);
        // Non-finite bit patterns decode (rejection is admission's job)…
        let nan = ImageBuf::from_raw_le_bytes(&f32::NAN.to_le_bytes()).unwrap();
        assert!(nan[0].is_nan());
        // …but a torn length is a decode error.
        let err = ImageBuf::from_raw_le_bytes(&bytes[..7]).unwrap_err();
        assert!(err.contains("multiple of 4"), "{err}");
    }

    #[test]
    fn image_buf_wraps_and_unwraps_without_surprises() {
        let buf = ImageBuf::from(vec![1.0f32, 2.0]);
        assert_eq!(buf.len(), 2);
        assert!(validate_image(&buf, 2).is_ok());
        assert_eq!(buf.into_vec(), vec![1.0, 2.0]);
    }

    #[test]
    fn validate_image_checks_length_and_finiteness() {
        assert!(validate_image(&[0.0; 4], 4).is_ok());
        let err = validate_image(&[0.0; 3], 4).unwrap_err();
        assert!(err.contains("3") && err.contains("4"), "{err}");
        let err = validate_image(&[0.0, f32::NAN, 0.0, 0.0], 4).unwrap_err();
        assert!(err.contains("not finite"), "{err}");
        let err = validate_image(&[0.0, 0.0, f32::INFINITY, 0.0], 4).unwrap_err();
        assert!(err.contains("index") || err.contains("[2]"), "{err}");
    }
}
