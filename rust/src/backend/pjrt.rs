//! The PJRT/XLA backend: the AOT `infer[_frozen]_b{N}` artifacts behind the
//! unified [`InferenceBackend`] API.
//!
//! The type compiles with or without the `pjrt` cargo feature (it only
//! needs the [`Runtime`] *type*, which exists in both modes); actually
//! constructing one requires a loaded runtime, which `Engine::cpu()` refuses
//! to create without the feature — so feature policy lives in one place
//! (`registry::create`) instead of `#[cfg]` forks at every call site.
//!
//! Weight policy is decided at construction, mirroring what the server and
//! PTQ paths did by hand before this module existed:
//!
//! * **frozen** — quantize the weights once up front (the BRAM-image
//!   analogue) and serve the mask-free `infer_frozen_b{N}` artifacts: no
//!   fake-quant ops per request, ~3x lower execute cost, numerically
//!   identical (the quantizers are idempotent);
//! * **fake-quant** — raw params + per-layer mask tensors through
//!   `infer_b{N}`, quantizing inside the graph on every request.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::quant::{freeze, MaskSet};
use crate::runtime::{HostTensor, Runtime};

use super::{batch_output, BatchOutput, InferenceBackend};

/// PJRT execution of the AOT artifacts (see module docs).
pub struct PjrtBackend {
    rt: Arc<Runtime>,
    /// Frozen or raw params, AOT positional order.
    params: Vec<HostTensor>,
    /// Per-layer (is8, is_pot) tensors — empty on the frozen path.
    mask_tensors: Vec<HostTensor>,
    /// The retained mask set on the fake-quant path (`frozen = false`),
    /// where masks are live runtime inputs — reported via `active_masks`
    /// so the serving layer can cross-check the advertised plan. The
    /// frozen path bakes masks into the weight image and keeps nothing.
    masks: Option<MaskSet>,
    /// `"infer_frozen_b"` or `"infer_b"`; `run_batch` appends the size.
    prefix: &'static str,
}

impl PjrtBackend {
    /// Build from raw (trained/init) params and a mask set; `frozen` picks
    /// the weight policy described in the module docs.
    pub fn new(
        rt: Arc<Runtime>,
        params: Vec<HostTensor>,
        masks: &MaskSet,
        frozen: bool,
    ) -> PjrtBackend {
        let (params, mask_tensors, retained, prefix) = if frozen {
            (
                freeze::freeze_for_manifest(&rt.manifest, &params, masks),
                Vec::new(),
                None,
                "infer_frozen_b",
            )
        } else {
            let mask_tensors = rt.manifest.mask_tensors(masks);
            (params, mask_tensors, Some(masks.clone()), "infer_b")
        };
        PjrtBackend { rt, params, mask_tensors, masks: retained, prefix }
    }

    /// Serve already-prepared params through the frozen artifacts as-is —
    /// the PTQ/eval path, where the caller freezes (or deliberately does
    /// not, for the unquantized reference row).
    pub fn frozen_as_given(rt: Arc<Runtime>, params: Vec<HostTensor>) -> PjrtBackend {
        PjrtBackend {
            rt,
            params,
            mask_tensors: Vec::new(),
            masks: None,
            prefix: "infer_frozen_b",
        }
    }
}

impl InferenceBackend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn supports_frozen(&self) -> bool {
        true
    }

    fn active_masks(&self) -> Option<&MaskSet> {
        self.masks.as_ref()
    }

    /// Pre-compile every infer artifact this backend can serve, so no
    /// request ever stalls behind a cold XLA compile.
    fn prepare(&self) -> Result<()> {
        let m = &self.rt.manifest;
        for &b in &m.infer_batches {
            self.rt.engine.load(m.artifact(&format!("{}{b}", self.prefix))?)?;
        }
        Ok(())
    }

    fn run_batch(&self, images: &[f32], batch: usize) -> Result<BatchOutput> {
        let m = &self.rt.manifest;
        super::check_batch_len(images, batch, m.data.image_elems())?;
        let mut inputs =
            Vec::with_capacity(self.params.len() + self.mask_tensors.len() + 1);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.mask_tensors.iter().cloned());
        inputs.push(HostTensor::f32(
            vec![batch, m.data.height, m.data.width, m.data.channels],
            images.to_vec(),
        ));
        let t = Instant::now();
        let out = self.rt.run(&format!("{}{batch}", self.prefix), &inputs)?;
        let elapsed = t.elapsed();
        batch_output(out[0].as_f32().to_vec(), batch, m.classes, elapsed)
    }
}
