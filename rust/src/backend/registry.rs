//! String-keyed backend registry — the single source of truth for
//! `--backend` parsing and construction.
//!
//! Every consumer that lets a user pick an execution path goes through
//! [`create`] (or validates early with [`spec`]): unknown names error with
//! the full list of registered backends, and names whose cargo feature is
//! compiled out error with what to rebuild with — nothing silently
//! defaults. Adding a backend is one [`BackendSpec`] entry here plus its
//! implementation file.

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::quant::{QuantPlan, QuantSource};
use crate::runtime::{HostTensor, Manifest, Runtime};

use super::{
    FaultSpec, FaultyBackend, FloatRefBackend, InferenceBackend, PjrtBackend, QgemmBackend,
};

/// Everything a backend constructor may need. Callers fill what they have;
/// each builder validates what it actually requires.
pub struct BackendInit {
    pub manifest: Manifest,
    /// Trained/init params in AOT positional order, **raw** — freezing is
    /// backend policy, applied inside the builders where it belongs.
    pub params: Vec<HostTensor>,
    /// Quantization plan (per-row masks + provenance). Required by `qgemm`
    /// and by fake-quant `pjrt`; `None` runs unquantized weights where the
    /// backend allows it.
    pub plan: Option<QuantPlan>,
    /// Serve the pre-quantized weight image where the backend has one.
    pub frozen: bool,
    /// Engine-bearing runtime; required by the PJRT-class backends only.
    pub runtime: Option<Arc<Runtime>>,
    /// Worker threads for the CPU backends (`None` = all cores).
    pub threads: Option<usize>,
    /// Fault-injection schedule: when set, [`create`] wraps the constructed
    /// backend in a [`FaultyBackend`] driving that schedule. A `faulty:`
    /// name prefix without a spec here wraps with [`FaultSpec::chaos`].
    pub fault: Option<FaultSpec>,
}

impl BackendInit {
    /// Minimal init: manifest + params, frozen, no plan/runtime, no faults.
    pub fn new(manifest: Manifest, params: Vec<HostTensor>) -> BackendInit {
        BackendInit {
            manifest,
            params,
            plan: None,
            frozen: true,
            runtime: None,
            threads: None,
            fault: None,
        }
    }
}

type Build = fn(&BackendInit) -> Result<Box<dyn InferenceBackend>>;

/// One registered backend: metadata for listings/help + the constructor.
pub struct BackendSpec {
    pub name: &'static str,
    pub description: &'static str,
    /// False when the backend's cargo feature is compiled out of this build.
    pub available: bool,
    /// True when the builder needs `BackendInit::runtime` (an artifact dir
    /// plus a live PJRT engine); callers use this to skip loading the
    /// engine for pure-CPU backends.
    pub needs_runtime: bool,
    /// True when the backend cannot run without a mask set (no unquantized
    /// mode) — consumers that evaluate an unquantized reference substitute
    /// the `float` backend for these.
    pub masks_required: bool,
    build: Build,
}

impl BackendSpec {
    pub fn build(&self, init: &BackendInit) -> Result<Box<dyn InferenceBackend>> {
        (self.build)(init)
    }
}

fn build_pjrt(init: &BackendInit) -> Result<Box<dyn InferenceBackend>> {
    if !cfg!(feature = "pjrt") {
        bail!(
            "backend \"pjrt\" is compiled out of this build (rebuild with the \
             `pjrt` cargo feature and XLA_EXTENSION_DIR set)"
        );
    }
    let rt = init.runtime.clone().ok_or_else(|| {
        anyhow!("backend \"pjrt\" needs a loaded Runtime (artifacts + PJRT engine)")
    })?;
    let be = match (&init.plan, init.frozen) {
        (Some(plan), frozen) => {
            PjrtBackend::new(rt, init.params.clone(), &plan.masks, frozen)
        }
        // No plan + frozen: run the params as given through the frozen
        // artifacts (the PTQ unquantized-reference row).
        (None, true) => PjrtBackend::frozen_as_given(rt, init.params.clone()),
        (None, false) => {
            bail!("backend \"pjrt\" fake-quant serving needs a quantization plan (mask set)")
        }
    };
    Ok(Box::new(be))
}

fn build_qgemm(init: &BackendInit) -> Result<Box<dyn InferenceBackend>> {
    if !init.frozen {
        // No silent fallback: qgemm executes the packed integer image only.
        bail!(
            "backend \"qgemm\" only executes the pre-quantized packed image \
             (no fake-quant path); drop --no-frozen or use the pjrt backend"
        );
    }
    let plan = init.plan.as_ref().ok_or_else(|| {
        anyhow!("backend \"qgemm\" needs a quantization plan (mask set)")
    })?;
    let mut be =
        QgemmBackend::new(init.manifest.clone(), init.params.clone(), plan.masks.clone());
    if let Some(t) = init.threads {
        be = be.with_threads(t);
    }
    Ok(Box::new(be))
}

fn build_float(init: &BackendInit) -> Result<Box<dyn InferenceBackend>> {
    // With a plan + frozen, freeze up front so the reference sees the same
    // weight image as the deployment backends; otherwise run params as-is.
    let params = match (&init.plan, init.frozen) {
        (Some(plan), true) => crate::quant::freeze::freeze_for_manifest(
            &init.manifest,
            &init.params,
            &plan.masks,
        ),
        _ => init.params.clone(),
    };
    let mut be = FloatRefBackend::new(init.manifest.clone(), params);
    if let Some(t) = init.threads {
        be = be.with_threads(t);
    }
    Ok(Box::new(be))
}

/// All registered backends, in listing order.
pub fn registry() -> &'static [BackendSpec] {
    static SPECS: [BackendSpec; 3] = [
        BackendSpec {
            name: "pjrt",
            description: "XLA/PJRT engine over the AOT infer[_frozen]_b{N} artifacts",
            available: cfg!(feature = "pjrt"),
            needs_runtime: true,
            masks_required: false,
            build: build_pjrt,
        },
        BackendSpec {
            name: "qgemm",
            description: "native packed-code integer GEMM (BRAM-image execution, pure CPU)",
            available: true,
            needs_runtime: false,
            masks_required: true,
            build: build_qgemm,
        },
        BackendSpec {
            name: "float",
            description: "f32 GEMM-view reference (PJRT numerics without PJRT)",
            available: true,
            needs_runtime: false,
            masks_required: false,
            build: build_float,
        },
    ];
    &SPECS
}

/// Comma-separated names of every registered backend (for error messages).
fn names_line() -> String {
    registry()
        .iter()
        .map(|s| s.name)
        .collect::<Vec<_>>()
        .join(", ")
}

/// Names of the backends usable in this build.
pub fn available_names() -> Vec<&'static str> {
    registry().iter().filter(|s| s.available).map(|s| s.name).collect()
}

/// Look up a backend by name; unknown names error with the full list. A
/// `faulty:` prefix resolves to the wrapped backend's spec (the wrapper has
/// no construction requirements of its own).
pub fn spec(name: &str) -> Result<&'static BackendSpec> {
    let inner = name.strip_prefix("faulty:").unwrap_or(name);
    registry().iter().find(|s| s.name == inner).ok_or_else(|| {
        anyhow!(
            "unknown backend {name:?}; registered backends: {} \
             (any of them wrappable as faulty:<name>)",
            names_line()
        )
    })
}

/// Resolve + construct a backend by name. Two routes into fault injection
/// compose here: `init.fault` wraps *any* name with that schedule, and a
/// `faulty:` name prefix forces a wrapper even without a spec (defaulting
/// to [`FaultSpec::chaos`] seeded from the spec's default seed).
pub fn create(name: &str, init: &BackendInit) -> Result<Box<dyn InferenceBackend>> {
    let forced = name.starts_with("faulty:");
    let be = spec(name)?
        .build(init)
        .with_context(|| format!("initialize backend {name:?}"))?;
    let fault = match (&init.fault, forced) {
        (Some(spec), _) => Some(spec.clone()),
        (None, true) => Some(FaultSpec::chaos(0)),
        (None, false) => None,
    };
    Ok(match fault {
        Some(spec) => {
            spec.validate().context("fault spec rejected")?;
            Box::new(FaultyBackend::new(Arc::from(be), spec))
        }
        None => be,
    })
}

/// Serving convenience shared by the CLI and the examples — the whole
/// recipe from an already-loaded manifest: resolve the [`QuantSource`] to a
/// validated plan (one resolution path — plan file, named ratio, fresh
/// derivation, or unquantized), load the init params, attach a PJRT runtime
/// only when the backend needs one (and this build has it — compiled-out
/// backends fall through to `create`'s curated error), and construct.
/// `threads` caps the CPU backends' worker pool (`None` = all cores; PJRT
/// ignores it). Returns the backend together with the resolved plan so the
/// serving layer can advertise it (`GET /v1/plan`).
pub fn create_serving(
    name: &str,
    manifest: &Manifest,
    source: &QuantSource,
    frozen: bool,
    threads: Option<usize>,
) -> Result<(Arc<dyn InferenceBackend>, Option<QuantPlan>)> {
    let s = spec(name)?;
    let params = manifest.load_init_params()?;
    // Params-aware resolution: `Derived` reuses the tensors just loaded
    // instead of reading the whole weight file a second time.
    let plan = source.resolve_with_params(manifest, &params)?;
    let runtime = if s.needs_runtime && s.available {
        Some(Arc::new(Runtime::from_manifest(manifest.clone())?))
    } else {
        None
    };
    let init = BackendInit {
        plan: plan.clone(),
        frozen,
        runtime,
        threads,
        ..BackendInit::new(manifest.clone(), params)
    };
    Ok((Arc::from(create(name, &init)?), plan))
}

#[cfg(test)]
mod tests {
    use super::super::synth;
    use super::*;
    use crate::quant::{Provenance, Ratio};
    use crate::util::Rng;

    fn init() -> BackendInit {
        let mut rng = Rng::new(5);
        let m = synth::tiny_manifest(8, 8, 3, &[4, 8], 5);
        let params = synth::random_params(&m, &mut rng);
        let masks = synth::random_masks(&m, Ratio::new(65.0, 30.0, 5.0), &mut rng);
        let plan = QuantPlan::from_mask_set(
            masks,
            Provenance::Synthetic { seed: 5, ratio: "65:30:5".into() },
        );
        BackendInit { plan: Some(plan), ..BackendInit::new(m, params) }
    }

    #[test]
    fn unknown_backend_error_lists_registry_names() {
        let err = create("tpu", &init()).unwrap_err();
        let msg = format!("{err:#}");
        for name in ["pjrt", "qgemm", "float"] {
            assert!(msg.contains(name), "{msg}");
        }
    }

    #[test]
    fn qgemm_without_a_plan_is_a_clear_error() {
        let mut i = init();
        i.plan = None;
        let err = create("qgemm", &i).unwrap_err();
        assert!(format!("{err:#}").contains("quantization plan"), "{err:#}");
    }

    #[test]
    fn qgemm_rejects_fake_quant_serving() {
        let mut i = init();
        i.frozen = false;
        let err = create("qgemm", &i).unwrap_err();
        assert!(format!("{err:#}").contains("pre-quantized"), "{err:#}");
    }

    #[test]
    fn pjrt_without_runtime_or_feature_errors() {
        // With the feature: fails for the missing runtime. Without it:
        // fails as compiled-out. Either way the message names the backend.
        let err = create("pjrt", &init()).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
    }

    #[test]
    fn cpu_backends_are_always_available() {
        let names = available_names();
        assert!(names.contains(&"qgemm") && names.contains(&"float"));
        assert!(!spec("qgemm").unwrap().needs_runtime);
        assert!(spec("pjrt").unwrap().needs_runtime);
        assert_eq!(spec("pjrt").unwrap().available, cfg!(feature = "pjrt"));
        assert!(spec("qgemm").unwrap().masks_required);
        assert!(!spec("float").unwrap().masks_required);
        assert!(!spec("pjrt").unwrap().masks_required);
    }

    #[test]
    fn faulty_prefix_wraps_any_backend() {
        let i = init();
        let be = create("faulty:qgemm", &i).unwrap();
        assert_eq!(be.name(), "faulty:qgemm");
        assert!(spec("faulty:float").is_ok());
        assert!(create("faulty:tpu", &i).is_err());
        // An explicit schedule on init wraps a plain name too.
        let i = BackendInit {
            fault: Some(FaultSpec { error_prob: 1.0, ..FaultSpec::default() }),
            ..init()
        };
        let be = create("qgemm", &i).unwrap();
        assert_eq!(be.name(), "faulty:qgemm");
        be.prepare().unwrap();
        let x = vec![0.25f32; 8 * 8 * 3];
        assert!(be.run_batch(&x, 1).is_err());
    }

    #[test]
    fn create_builds_working_cpu_backends() {
        let i = init();
        for name in ["qgemm", "float"] {
            let be = create(name, &i).unwrap();
            assert_eq!(be.name(), name);
            be.prepare().unwrap();
            let x = vec![0.25f32; 2 * 8 * 8 * 3];
            let out = be.run_batch(&x, 2).unwrap();
            assert_eq!(out.preds.len(), 2);
        }
    }
}
