//! Synthetic TinyResNet fixtures: a hand-built manifest plus random params
//! and masks, for artifact-free backend tests, the server smoke test, and
//! the model-level bench — no `make artifacts`, no PJRT, no disk.
//!
//! The geometry mirrors `python/compile/model.py::layer_defs` /
//! [`crate::model::zoo::tinyresnet`] exactly: params in layer-defs order
//! (stem, per-stage c1/c2[/proj], fc/w, fc/b) and `quantized_layers` in the
//! same network order — so a mask set built here zips correctly against the
//! zoo network inside the FPGA-sim overlay, just like the real manifest.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::quant::{assign, LayerMasks, MaskSet, Ratio, Scheme};
use crate::runtime::{DataSpec, HostTensor, Manifest};
use crate::util::Rng;

/// Hand-build a manifest for an `height x width x channels` TinyResNet with
/// the given stage widths and class count. Artifact/data tables are empty:
/// everything execution-related that reads them (PJRT artifacts, the test
/// split) is out of scope for synthetic fixtures.
pub fn tiny_manifest(
    height: usize,
    width: usize,
    channels: usize,
    widths: &[usize],
    classes: usize,
) -> Manifest {
    assert!(!widths.is_empty(), "need at least one stage width");
    // layer_defs order (python/compile/model.py): stem, s{i}/c1, s{i}/c2,
    // [s{i}/proj], ..., fc/w, fc/b.
    let mut params: Vec<(String, Vec<usize>)> = Vec::new();
    let w0 = widths[0];
    params.push(("stem/w".into(), vec![3, 3, channels, w0]));
    let mut prev = w0;
    for (si, &wch) in widths.iter().enumerate() {
        params.push((format!("s{si}/c1/w"), vec![3, 3, prev, wch]));
        params.push((format!("s{si}/c2/w"), vec![3, 3, wch, wch]));
        if prev != wch {
            params.push((format!("s{si}/proj/w"), vec![1, 1, prev, wch]));
        }
        prev = wch;
    }
    params.push(("fc/w".into(), vec![classes, prev]));
    params.push(("fc/b".into(), vec![classes]));
    finish_manifest("tiny-synth", height, width, channels, widths, classes, params)
}

/// Hand-build a manifest for the narrow VGG-style plain stack
/// ([`crate::model::zoo::vggnarrow`]): params `s{i}/conv/w` (3x3, HWIO),
/// then `fc/w`/`fc/b` — no stem, no residual projections. The second
/// geometry constructible end-to-end without artifacts.
pub fn vgg_manifest(
    height: usize,
    width: usize,
    channels: usize,
    widths: &[usize],
    classes: usize,
) -> Manifest {
    assert!(!widths.is_empty(), "need at least one stage width");
    let mut params: Vec<(String, Vec<usize>)> = Vec::new();
    let mut prev = channels;
    for (si, &wch) in widths.iter().enumerate() {
        params.push((format!("s{si}/conv/w"), vec![3, 3, prev, wch]));
        prev = wch;
    }
    params.push(("fc/w".into(), vec![classes, prev]));
    params.push(("fc/b".into(), vec![classes]));
    finish_manifest("vggnarrow-synth", height, width, channels, widths, classes, params)
}

/// Shared manifest tail: derive `quantized_layers` from the `/w` params
/// (2-D → (rows, fan-in); 4-D HWIO → (out_ch, kh*kw*in_ch)) and fill the
/// empty artifact/data tables.
fn finish_manifest(
    model_name: &str,
    height: usize,
    width: usize,
    channels: usize,
    widths: &[usize],
    classes: usize,
    params: Vec<(String, Vec<usize>)>,
) -> Manifest {
    let quantized_layers: Vec<(String, usize, usize)> = params
        .iter()
        .filter(|(n, _)| n.ends_with("/w"))
        .map(|(n, s)| {
            let (rows, fan) = if s.len() == 2 {
                (s[0], s[1])
            } else {
                // analyze:allow(non-matmul weights are rank-4 conv [kh,kw,cin,cout]; the slice is never empty)
                (*s.last().unwrap(), s[..3].iter().product())
            };
            (n.clone(), rows, fan)
        })
        .collect();

    Manifest {
        dir: PathBuf::from("/nonexistent"),
        model_name: model_name.into(),
        widths: widths.to_vec(),
        classes,
        height,
        width,
        channels,
        params,
        quantized_layers,
        data: DataSpec {
            height,
            width,
            channels,
            classes,
            n_train: 0,
            n_test: 0,
            dir: PathBuf::from("/nonexistent"),
        },
        train_batch: 1,
        eval_batch: 1,
        infer_batches: vec![1, 4],
        hvp_batch: 1,
        artifacts: BTreeMap::new(),
        eigs: BTreeMap::new(),
        default_masks: BTreeMap::new(),
    }
}

/// The serving fixture's canonical geometry — shared by
/// `loadgen`/`serve --synthetic` and `ilmpq plan derive --synthetic`, so a
/// plan derived artifact-free validates against the manifest the synthetic
/// server actually runs.
pub fn serving_manifest() -> Manifest {
    tiny_manifest(16, 16, 3, &[8, 16], 10)
}

/// The vggnarrow serving fixture at the same input geometry as
/// [`serving_manifest`] (16x16x3 → 768 image elems, 10 classes), so a
/// multi-model pool can mix both behind one load generator.
pub fn vgg_serving_manifest() -> Manifest {
    vgg_manifest(16, 16, 3, &[8, 16], 10)
}

/// Synthetic serving manifest by zoo geometry name — the pool-config
/// `"synthetic"` knob resolves through this.
pub fn serving_manifest_for(geometry: &str) -> anyhow::Result<Manifest> {
    match geometry {
        "tinyresnet" => Ok(serving_manifest()),
        "vggnarrow" => Ok(vgg_serving_manifest()),
        other => anyhow::bail!(
            "unknown synthetic geometry {other:?} (expected tinyresnet or vggnarrow)"
        ),
    }
}

/// Random normal(0, 0.3) params for every manifest tensor, in order.
pub fn random_params(m: &Manifest, rng: &mut Rng) -> Vec<HostTensor> {
    m.params
        .iter()
        .map(|(_, shape)| {
            let n: usize = shape.iter().product();
            HostTensor::f32(shape.clone(), (0..n).map(|_| rng.normal() * 0.3).collect())
        })
        .collect()
}

/// A mixed mask set at `ratio` over every quantized layer. Row
/// sensitivities and the variance proxy are random (assignment *policy* is
/// under test elsewhere; here only the per-row scheme mix matters).
pub fn random_masks(m: &Manifest, ratio: Ratio, rng: &mut Rng) -> MaskSet {
    let layers = m
        .quantized_layers
        .iter()
        .map(|(name, rows, _)| {
            let eigs: Vec<f64> = (0..*rows).map(|_| rng.f64()).collect();
            let w: Vec<Vec<f32>> = (0..*rows)
                .map(|_| (0..8).map(|_| rng.normal()).collect())
                .collect();
            assign::assign_layer(name, &w, &eigs, ratio)
        })
        .collect();
    MaskSet { name: format!("synth-{}", ratio.label()), layers }
}

/// A uniform single-scheme mask set (e.g. all-Fixed-8 for parity checks).
pub fn uniform_masks(m: &Manifest, scheme: Scheme) -> MaskSet {
    let layers: Vec<LayerMasks> = m
        .quantized_layers
        .iter()
        .map(|(n, rows, _)| assign::assign_uniform_layer(n, *rows, scheme))
        .collect();
    MaskSet { name: format!("uniform-{}", scheme.label()), layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn quantized_layers_match_zoo_network_order() {
        let m = tiny_manifest(16, 16, 3, &[16, 32, 64], 10);
        let net = zoo::tinyresnet(16, 16, 3, &[16, 32, 64], 10);
        let manifest_names: Vec<&str> =
            m.quantized_layers.iter().map(|(n, _, _)| n.as_str()).collect();
        let net_names: Vec<&str> = net.layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(manifest_names, net_names);
        for ((_, rows, _), l) in m.quantized_layers.iter().zip(&net.layers) {
            assert_eq!(*rows, l.rows(), "{}", l.name);
        }
    }

    #[test]
    fn vgg_quantized_layers_match_zoo_network_order() {
        let m = vgg_manifest(16, 16, 3, &[8, 16], 10);
        let net = zoo::vggnarrow(16, 16, 3, &[8, 16], 10);
        let manifest_names: Vec<&str> =
            m.quantized_layers.iter().map(|(n, _, _)| n.as_str()).collect();
        let net_names: Vec<&str> = net.layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(manifest_names, net_names);
        for ((_, rows, _), l) in m.quantized_layers.iter().zip(&net.layers) {
            assert_eq!(*rows, l.rows(), "{}", l.name);
        }
        assert_eq!(m.model_name, "vggnarrow-synth");
        // Same wire geometry as the tiny fixture: one loadgen image size
        // drives both pool models.
        let tiny = serving_manifest();
        assert_eq!(m.data.image_elems(), tiny.data.image_elems());
        assert_eq!(serving_manifest_for("vggnarrow").unwrap().model_name, "vggnarrow-synth");
        assert!(serving_manifest_for("resnet18").is_err());
    }

    #[test]
    fn masks_cover_every_quantized_layer() {
        let mut rng = Rng::new(1);
        let m = tiny_manifest(8, 8, 3, &[4, 8], 5);
        let ms = random_masks(&m, Ratio::new(65.0, 30.0, 5.0), &mut rng);
        for (name, rows, _) in &m.quantized_layers {
            let lm = ms.layer(name).unwrap();
            assert_eq!(lm.rows(), *rows, "{name}");
        }
        let u = uniform_masks(&m, Scheme::Fixed8);
        assert_eq!(u.layers.len(), m.quantized_layers.len());
    }

    #[test]
    fn params_match_declared_shapes() {
        let mut rng = Rng::new(2);
        let m = tiny_manifest(8, 8, 3, &[4, 8], 5);
        let ps = random_params(&m, &mut rng);
        assert_eq!(ps.len(), m.params.len());
        for (t, (_, shape)) in ps.iter().zip(&m.params) {
            assert_eq!(&t.shape, shape);
        }
    }
}
