//! Ablation assignment policies (paper §II-C design-choice validation).
//!
//! The paper picks (a) 8-bit rows by Hessian top-eigenvalue and (b) PoT rows
//! by low weight variance. The ablations replace each with a random pick so
//! the benches can show both choices matter:
//!
//! * `random_bits` — random 5% of rows get 8-bit;
//! * `random_schemes` — random PoT subset instead of variance-sorted;
//! * `inverse_schemes` — *highest*-variance rows get PoT (the adversarial
//!   assignment; should hurt the most, since PoT's resolution concentrates
//!   near zero).

use crate::quant::{assign, LayerMasks, Ratio};
use crate::util::stats::variance_f32;
use crate::util::Rng;

/// Random 8-bit row pick (same count as the paper's policy).
pub fn random_bits(rows: usize, frac8: f64, rng: &mut Rng) -> Vec<f32> {
    let n8 = if frac8 <= 0.0 {
        0
    } else {
        ((rows as f64 * frac8).round() as usize).max(1)
    };
    let mut idx: Vec<usize> = (0..rows).collect();
    rng.shuffle(&mut idx);
    let mut is8 = vec![0f32; rows];
    for &i in idx.iter().take(n8) {
        is8[i] = 1.0;
    }
    is8
}

/// Random PoT pick among 4-bit rows (same count as variance policy).
pub fn random_schemes(
    rows: usize,
    is8: &[f32],
    pot_share: f64,
    rng: &mut Rng,
) -> Vec<f32> {
    let four_bit: Vec<usize> = (0..rows).filter(|&i| is8[i] < 0.5).collect();
    let n_pot = (four_bit.len() as f64 * pot_share).round() as usize;
    let mut idx = four_bit;
    rng.shuffle(&mut idx);
    let mut is_pot = vec![0f32; rows];
    for &i in idx.iter().take(n_pot) {
        is_pot[i] = 1.0;
    }
    is_pot
}

/// Adversarial: highest-variance rows get PoT.
pub fn inverse_schemes(w_rows: &[Vec<f32>], is8: &[f32], pot_share: f64) -> Vec<f32> {
    let rows = w_rows.len();
    let four_bit: Vec<usize> = (0..rows).filter(|&i| is8[i] < 0.5).collect();
    let n_pot = (four_bit.len() as f64 * pot_share).round() as usize;
    let mut idx = four_bit;
    idx.sort_by(|&a, &b| {
        variance_f32(&w_rows[b])
            .partial_cmp(&variance_f32(&w_rows[a]))
            .unwrap()
            .then(a.cmp(&b))
    });
    let mut is_pot = vec![0f32; rows];
    for &i in idx.iter().take(n_pot) {
        is_pot[i] = 1.0;
    }
    is_pot
}

/// Assignment policy selector for the ablation benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Paper: Hessian eigs for bits, low variance for PoT.
    Paper,
    /// Random bits, variance schemes.
    RandomBits,
    /// Paper bits, random schemes.
    RandomSchemes,
    /// Paper bits, inverse (high-variance) schemes.
    InverseSchemes,
}

impl Policy {
    pub fn all() -> [Policy; 4] {
        [Policy::Paper, Policy::RandomBits, Policy::RandomSchemes, Policy::InverseSchemes]
    }

    pub fn label(&self) -> &'static str {
        match self {
            Policy::Paper => "paper (eig+variance)",
            Policy::RandomBits => "random 8-bit rows",
            Policy::RandomSchemes => "random PoT rows",
            Policy::InverseSchemes => "inverse-variance PoT",
        }
    }

    /// Build masks for one layer under this policy.
    pub fn assign(
        &self,
        layer: &str,
        w_rows: &[Vec<f32>],
        eigs: &[f64],
        ratio: Ratio,
        rng: &mut Rng,
    ) -> LayerMasks {
        let rows = w_rows.len();
        let is8 = match self {
            Policy::RandomBits => random_bits(rows, ratio.frac8(), rng),
            _ => assign::assign_bits(eigs, ratio.frac8()),
        };
        let is_pot = match self {
            Policy::RandomSchemes => {
                random_schemes(rows, &is8, ratio.pot_share_of_4bit(), rng)
            }
            Policy::InverseSchemes => {
                inverse_schemes(w_rows, &is8, ratio.pot_share_of_4bit())
            }
            _ => assign::assign_schemes(w_rows, &is8, ratio.pot_share_of_4bit()),
        };
        LayerMasks { layer: layer.to_string(), is8, is_pot }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn random_bits_count_matches_policy() {
        let mut rng = Rng::new(1);
        let is8 = random_bits(40, 0.05, &mut rng);
        assert_eq!(is8.iter().filter(|&&v| v > 0.5).count(), 2);
        assert_eq!(random_bits(40, 0.0, &mut rng).iter().sum::<f32>(), 0.0);
    }

    #[test]
    fn inverse_picks_high_variance() {
        let rows = vec![
            vec![0.0, 0.01],  // low var
            vec![-5.0, 5.0],  // high var
            vec![0.0, 0.02],  // low var
            vec![-4.0, 4.0],  // high var
        ];
        let is8 = vec![0.0; 4];
        let ip = inverse_schemes(&rows, &is8, 0.5);
        assert_eq!(ip, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn prop_all_policies_same_counts() {
        forall(
            91,
            48,
            |r| {
                let rows = r.range_usize(6, 48);
                let data: Vec<Vec<f32>> = (0..rows)
                    .map(|_| (0..8).map(|_| r.normal()).collect())
                    .collect();
                let eigs: Vec<f64> = (0..rows).map(|_| r.f64()).collect();
                (data, eigs, r.next_u64())
            },
            |(data, eigs, seed)| {
                let ratio = Ratio::new(60.0, 35.0, 5.0);
                let counts: Vec<(usize, usize, usize)> = Policy::all()
                    .iter()
                    .map(|p| {
                        let mut rng = Rng::new(*seed);
                        p.assign("t", data, eigs, ratio, &mut rng).counts()
                    })
                    .collect();
                for c in &counts[1..] {
                    ensure(c == &counts[0], || format!("{counts:?}"))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn policy_labels_unique() {
        let labels: Vec<&str> = Policy::all().iter().map(|p| p.label()).collect();
        let mut dedup = labels.clone();
        dedup.dedup();
        assert_eq!(labels.len(), 4);
        assert_eq!(dedup.len(), 4);
    }
}
