//! Baseline quantization configurations — every non-ILMPQ row of Table I,
//! plus the ablation policies (random bit assignment, random scheme
//! assignment) used to validate the paper's §II-C design choices.

pub mod ablation;
pub mod table1;

pub use table1::{accuracy_configs, hw_configs, AccuracyConfig, HwConfig};
