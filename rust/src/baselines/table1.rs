//! The Table-I configuration matrix: 8 prior-work rows + 2 ILMPQ rows.
//!
//! Two views of the same matrix:
//! * `hw_configs(device)` — `NetConfig`s over the ImageNet ResNet-18
//!   geometry for the performance simulator (Table I's right columns);
//! * `accuracy_configs()` — mask-building recipes for the QAT accuracy runs
//!   on the AOT TinyResNet (Table I's accuracy columns, ImageNet substitute).

use crate::fpga::sim::NetConfig;
use crate::fpga::Mode;
use crate::model::Network;
use crate::quant::{Ratio, Scheme};

/// One hardware row of Table I.
#[derive(Debug, Clone)]
pub struct HwConfig {
    /// Table row label, e.g. "(1) Fixed".
    pub label: String,
    pub ratio: Ratio,
    pub first_last_8bit: bool,
    /// Execution mode: prior-work rows with separate 8-bit first/last
    /// engines run inter-layer; fully-uniform rows run intra-layer.
    pub mode: Mode,
    /// Paper-reported (throughput GOP/s, latency ms), if the paper filled
    /// this cell for the device — used by EXPERIMENTS.md comparisons.
    pub paper: Option<(f64, f64)>,
    /// Paper-reported (lut%, dsp%) utilization for the device.
    pub paper_util: Option<(f64, f64)>,
}

fn hw(
    label: &str,
    ratio: &str,
    fl8: bool,
    paper: Option<(f64, f64)>,
    paper_util: Option<(f64, f64)>,
) -> HwConfig {
    HwConfig {
        label: label.to_string(),
        ratio: Ratio::parse(ratio).unwrap(),
        first_last_8bit: fl8,
        mode: if fl8 { Mode::InterLayer } else { Mode::IntraLayer },
        paper,
        paper_util,
    }
}

/// Hardware rows for one device ("xc7z020" | "xc7z045"), paper cells filled
/// from Table I.
pub fn hw_configs(device: &str) -> Vec<HwConfig> {
    match device {
        "xc7z020" => vec![
            hw("(1) Fixed fl8", "0:100:0", true, Some((29.6, 122.6)), Some((49.0, 100.0))),
            hw("(2) Fixed", "0:100:0", false, Some((36.5, 99.3)), Some((45.0, 100.0))),
            hw("(3) PoT fl8", "100:0:0", true, Some((62.4, 58.1)), Some((51.0, 100.0))),
            hw("(4) PoT", "100:0:0", false, Some((72.2, 50.2)), Some((57.0, 12.0))),
            hw("(5) PoT+Fixed fl8", "50:50:0", true, Some((50.3, 72.0)), Some((71.0, 100.0))),
            hw("(6) PoT+Fixed", "50:50:0", false, Some((75.8, 47.8)), Some((66.0, 100.0))),
            hw("(7) PoT+Fixed fl8", "60:40:0", true, Some((57.0, 63.6)), Some((80.0, 100.0))),
            hw("ILMPQ-1", "60:35:5", false, Some((89.0, 40.7)), Some((82.0, 100.0))),
        ],
        "xc7z045" => vec![
            hw("(1) Fixed fl8", "0:100:0", true, Some((115.6, 31.4)), Some((21.0, 100.0))),
            hw("(2) Fixed", "0:100:0", false, Some((142.7, 25.4)), Some((24.0, 100.0))),
            hw("(3) PoT fl8", "100:0:0", true, Some((290.5, 12.5)), Some((40.0, 100.0))),
            hw("(4) PoT", "100:0:0", false, Some((352.6, 10.3)), Some((44.0, 3.0))),
            hw("(5) PoT+Fixed fl8", "50:50:0", true, Some((196.8, 18.4)), Some((42.0, 100.0))),
            hw("(6) PoT+Fixed", "50:50:0", false, Some((296.3, 12.2)), Some((38.0, 100.0))),
            hw("(8) PoT+Fixed fl8", "67:33:0", true, Some((245.8, 14.8)), Some((61.0, 100.0))),
            hw("ILMPQ-2", "65:30:5", false, Some((421.1, 8.6)), Some((65.0, 100.0))),
        ],
        other => panic!("unknown device {other}"),
    }
}

impl HwConfig {
    pub fn net_config(&self, net: &Network) -> NetConfig {
        NetConfig::from_ratio(net, self.ratio, self.first_last_8bit, &self.label)
    }
}

/// One accuracy row of Table I (device-independent).
#[derive(Debug, Clone)]
pub struct AccuracyConfig {
    pub label: String,
    /// Ratio name in the manifest `default_masks` (None = build in Rust
    /// with `first_last_8bit`).
    pub ratio: Ratio,
    pub first_last_8bit: bool,
    /// Uniform scheme shortcut for the fl8 baselines' middle layers.
    pub uniform_middle: Option<Scheme>,
    /// Paper-reported (top-1 %, top-5 %).
    pub paper_top1: f64,
    pub paper_top5: f64,
}

fn acc(
    label: &str,
    ratio: &str,
    fl8: bool,
    top1: f64,
    top5: f64,
) -> AccuracyConfig {
    AccuracyConfig {
        label: label.to_string(),
        ratio: Ratio::parse(ratio).unwrap(),
        first_last_8bit: fl8,
        uniform_middle: None,
        paper_top1: top1,
        paper_top5: top5,
    }
}

/// All ten accuracy rows.
pub fn accuracy_configs() -> Vec<AccuracyConfig> {
    vec![
        acc("(1) Fixed fl8", "0:100:0", true, 69.72, 88.67),
        acc("(2) Fixed", "0:100:0", false, 68.66, 87.54),
        acc("(3) PoT fl8", "100:0:0", true, 68.20, 87.14),
        acc("(4) PoT", "100:0:0", false, 67.11, 85.93),
        acc("(5) PoT+Fixed fl8", "50:50:0", true, 68.94, 88.66),
        acc("(6) PoT+Fixed", "50:50:0", false, 67.98, 86.75),
        acc("(7) PoT+Fixed fl8", "60:40:0", true, 68.53, 88.47),
        acc("(8) PoT+Fixed fl8", "67:33:0", true, 68.46, 88.22),
        acc("ILMPQ-1", "60:35:5", false, 70.66, 89.53),
        acc("ILMPQ-2", "65:30:5", false, 70.73, 89.62),
    ]
}

/// Manifest ratio-name for a config (the aot.py default-mask key), when the
/// config's masks are the plain intra-layer assignment.
pub fn manifest_ratio_name(ratio: &Ratio) -> Option<&'static str> {
    let label = ratio.label();
    match label.as_str() {
        "0:100:0" => Some("fixed4"),
        "100:0:0" => Some("pot4"),
        "50:50:0" => Some("mixed_50_50"),
        "60:40:0" => Some("mixed_60_40"),
        "67:33:0" => Some("mixed_67_33"),
        "60:35:5" => Some("ilmpq1"),
        "65:30:5" => Some("ilmpq2"),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::resnet18;

    #[test]
    fn both_devices_have_eight_rows() {
        assert_eq!(hw_configs("xc7z020").len(), 8);
        assert_eq!(hw_configs("xc7z045").len(), 8);
    }

    #[test]
    fn ilmpq_rows_use_intra_layer_mode() {
        for d in ["xc7z020", "xc7z045"] {
            let rows = hw_configs(d);
            let ilmpq = rows.last().unwrap();
            assert!(ilmpq.label.starts_with("ILMPQ"));
            assert_eq!(ilmpq.mode, Mode::IntraLayer);
            assert!(!ilmpq.first_last_8bit);
            assert_eq!(ilmpq.ratio.fixed8, 5.0);
        }
    }

    #[test]
    fn fl8_rows_use_inter_layer_mode() {
        for row in hw_configs("xc7z020").iter().filter(|r| r.first_last_8bit) {
            assert_eq!(row.mode, Mode::InterLayer, "{}", row.label);
        }
    }

    #[test]
    fn net_configs_build_on_resnet18() {
        let net = resnet18();
        for row in hw_configs("xc7z045") {
            let cfg = row.net_config(&net);
            assert_eq!(cfg.masks.len(), net.layers.len(), "{}", row.label);
        }
    }

    #[test]
    fn accuracy_rows_match_paper_ordering() {
        let rows = accuracy_configs();
        assert_eq!(rows.len(), 10);
        // ILMPQ-2 has the best paper top-1.
        let best = rows.iter().map(|r| r.paper_top1).fold(0.0, f64::max);
        assert_eq!(best, 70.73);
        // Fully-4-bit PoT is the worst.
        let worst = rows.iter().map(|r| r.paper_top1).fold(100.0, f64::min);
        assert_eq!(worst, 67.11);
    }

    #[test]
    fn manifest_names_cover_all_plain_ratios() {
        for row in accuracy_configs().iter().filter(|r| !r.first_last_8bit) {
            assert!(manifest_ratio_name(&row.ratio).is_some(), "{}", row.label);
        }
    }
}
