//! Dynamic batcher: groups inference requests to match the AOT batch sizes.
//!
//! PJRT executables have static shapes, so the serving path ships several
//! `infer_b{N}` artifacts (N = 1, 8, 64 by default) and the batcher picks,
//! for each dispatch, the smallest artifact that covers the queue — padding
//! the tail slots when the deadline forces a partial batch. Policy:
//!
//! * dispatch immediately once `max_batch` requests are queued;
//! * otherwise dispatch whatever is queued when the *oldest* request has
//!   waited `max_wait` (the latency SLO knob);
//! * always use the smallest covering artifact to minimize padded work.
//!
//! The batcher trusts its inputs: requests reach it only through the
//! server's admission pipeline (`Server::submit`), which has already
//! validated every image's geometry and bounded the in-system count — so
//! batch assembly here is pure concatenation with no per-item error paths.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy parameters.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Available artifact batch sizes, ascending (from the manifest).
    pub sizes: Vec<usize>,
    /// Max time the oldest request may wait before a partial dispatch.
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(mut sizes: Vec<usize>, max_wait: Duration) -> BatchPolicy {
        assert!(!sizes.is_empty(), "need at least one batch size");
        sizes.sort_unstable();
        BatchPolicy { sizes, max_wait }
    }

    pub fn max_batch(&self) -> usize {
        // analyze:allow(BatchPolicy::new asserts sizes is non-empty)
        *self.sizes.last().unwrap()
    }

    /// Smallest artifact size covering `n` requests (or the max size).
    pub fn cover(&self, n: usize) -> usize {
        for &s in &self.sizes {
            if s >= n {
                return s;
            }
        }
        self.max_batch()
    }

    /// Largest artifact size not exceeding `n` (sizes always include the
    /// smallest, so this is well-defined for n >= 1).
    pub fn floor_cover(&self, n: usize) -> usize {
        let mut best = self.sizes[0];
        for &s in &self.sizes {
            if s <= n {
                best = s;
            }
        }
        best
    }

    /// Decide whether to dispatch now. `queue_len` pending requests, the
    /// oldest enqueued at `oldest`. Returns the number of requests to take
    /// (0 = keep waiting).
    ///
    /// Deadline dispatches take the *floor* artifact size when the queue is
    /// deep enough (padding a 64-slot batch to ship 9 requests wastes more
    /// compute than shipping a full 8 and re-arming the deadline for the
    /// remainder); shallow queues ship whole with padding.
    pub fn decide(&self, queue_len: usize, oldest: Option<Instant>, now: Instant) -> usize {
        if queue_len == 0 {
            return 0;
        }
        if queue_len >= self.max_batch() {
            return self.max_batch();
        }
        match oldest {
            Some(t) if now.duration_since(t) >= self.max_wait => {
                let floor = self.floor_cover(queue_len);
                if floor > self.sizes[0] {
                    floor
                } else {
                    queue_len
                }
            }
            _ => 0,
        }
    }
}

/// A queued request, generic in payload (the server instantiates with the
/// image + reply channel; tests use unit payloads).
#[derive(Debug)]
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// The batch assembled for one dispatch.
#[derive(Debug)]
pub struct Assembled<T> {
    pub items: Vec<Pending<T>>,
    /// Artifact batch size to run (>= items.len()); the difference is
    /// padding.
    pub exec_size: usize,
}

impl<T> Assembled<T> {
    pub fn padded_slots(&self) -> usize {
        self.exec_size - self.items.len()
    }
}

/// FIFO queue + policy = the batcher.
#[derive(Debug)]
pub struct Batcher<T> {
    pub policy: BatchPolicy,
    queue: VecDeque<Pending<T>>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, payload: T, now: Instant) {
        self.queue.push_back(Pending { payload, enqueued: now });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Time until the oldest request hits its deadline (None if empty).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|p| {
            self.policy
                .max_wait
                .saturating_sub(now.duration_since(p.enqueued))
        })
    }

    /// Try to assemble a batch under the policy.
    pub fn try_assemble(&mut self, now: Instant) -> Option<Assembled<T>> {
        let take = self
            .policy
            .decide(self.queue.len(), self.queue.front().map(|p| p.enqueued), now);
        if take == 0 {
            return None;
        }
        let items: Vec<Pending<T>> = self.queue.drain(..take).collect();
        let exec_size = self.policy.cover(items.len());
        Some(Assembled { items, exec_size })
    }

    /// Drain everything regardless of deadline (shutdown path).
    pub fn flush(&mut self) -> Option<Assembled<T>> {
        if self.queue.is_empty() {
            return None;
        }
        let take = self.queue.len().min(self.policy.max_batch());
        let items: Vec<Pending<T>> = self.queue.drain(..take).collect();
        let exec_size = self.policy.cover(items.len());
        Some(Assembled { items, exec_size })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};
    use crate::util::Rng;

    fn policy(ms: u64) -> BatchPolicy {
        BatchPolicy::new(vec![1, 8, 64], Duration::from_millis(ms))
    }

    #[test]
    fn cover_picks_smallest() {
        let p = policy(10);
        assert_eq!(p.cover(1), 1);
        assert_eq!(p.cover(2), 8);
        assert_eq!(p.cover(8), 8);
        assert_eq!(p.cover(9), 64);
        assert_eq!(p.cover(200), 64);
    }

    #[test]
    fn dispatch_on_full_batch() {
        let now = Instant::now();
        let mut b: Batcher<usize> = Batcher::new(policy(1_000));
        for i in 0..64 {
            b.push(i, now);
        }
        let a = b.try_assemble(now).expect("full batch dispatches immediately");
        assert_eq!(a.items.len(), 64);
        assert_eq!(a.exec_size, 64);
        assert_eq!(a.padded_slots(), 0);
        assert!(b.is_empty());
    }

    #[test]
    fn waits_below_deadline() {
        let now = Instant::now();
        let mut b: Batcher<usize> = Batcher::new(policy(1_000));
        b.push(1, now);
        assert!(b.try_assemble(now).is_none());
    }

    #[test]
    fn deadline_forces_partial_with_padding() {
        let start = Instant::now();
        let mut b: Batcher<usize> = Batcher::new(policy(5));
        b.push(1, start);
        b.push(2, start);
        b.push(3, start);
        let later = start + Duration::from_millis(6);
        let a = b.try_assemble(later).expect("deadline dispatch");
        assert_eq!(a.items.len(), 3);
        assert_eq!(a.exec_size, 8);
        assert_eq!(a.padded_slots(), 5);
    }

    #[test]
    fn deadline_takes_floor_when_deep() {
        // 9 queued at deadline: ship a full 8 (no padding), leave 1.
        let start = Instant::now();
        let mut b: Batcher<usize> = Batcher::new(policy(5));
        for i in 0..9 {
            b.push(i, start);
        }
        let later = start + Duration::from_millis(6);
        let a = b.try_assemble(later).expect("deadline dispatch");
        assert_eq!(a.items.len(), 8);
        assert_eq!(a.exec_size, 8);
        assert_eq!(a.padded_slots(), 0);
        assert_eq!(b.len(), 1);
        // The remainder ships immediately on the next poll (already late).
        let a2 = b.try_assemble(later).expect("remainder");
        assert_eq!(a2.items.len(), 1);
        assert_eq!(a2.exec_size, 1);
    }

    #[test]
    fn floor_cover_values() {
        let p = policy(10);
        assert_eq!(p.floor_cover(1), 1);
        assert_eq!(p.floor_cover(7), 1);
        assert_eq!(p.floor_cover(8), 8);
        assert_eq!(p.floor_cover(63), 8);
        assert_eq!(p.floor_cover(200), 64);
    }

    #[test]
    fn fifo_order_preserved() {
        let now = Instant::now();
        let mut b: Batcher<usize> = Batcher::new(policy(0));
        for i in 0..5 {
            b.push(i, now);
        }
        let a = b.try_assemble(now + Duration::from_millis(1)).unwrap();
        let got: Vec<usize> = a.items.iter().map(|p| p.payload).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn flush_drains_all() {
        let now = Instant::now();
        let mut b: Batcher<usize> = Batcher::new(policy(10_000));
        for i in 0..10 {
            b.push(i, now);
        }
        let a = b.flush().unwrap();
        assert_eq!(a.items.len(), 10);
        assert_eq!(a.exec_size, 64);
        assert!(b.flush().is_none());
    }

    #[test]
    fn prop_assembled_never_exceeds_max_and_covers() {
        forall(
            81,
            128,
            |r: &mut Rng| (r.range_usize(0, 200), r.bool(0.5)),
            |&(n, expired)| {
                let now = Instant::now();
                let mut b: Batcher<usize> = Batcher::new(policy(1_000));
                let enq = if expired {
                    now.checked_sub(Duration::from_secs(2)).unwrap_or(now)
                } else {
                    now
                };
                for i in 0..n {
                    b.push(i, enq);
                }
                if let Some(a) = b.try_assemble(now) {
                    ensure(a.items.len() <= 64, || "overfull batch".into())?;
                    ensure(a.exec_size >= a.items.len(), || "exec < items".into())?;
                    ensure(
                        a.exec_size == b.policy.cover(a.items.len()),
                        || "not smallest cover".into(),
                    )?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn time_to_deadline_counts_down() {
        let start = Instant::now();
        let mut b: Batcher<usize> = Batcher::new(policy(100));
        assert!(b.time_to_deadline(start).is_none());
        b.push(1, start);
        let d = b.time_to_deadline(start + Duration::from_millis(40)).unwrap();
        assert!(d <= Duration::from_millis(60));
    }
}
