//! The network front door: a minimal, dependency-free HTTP/1.1 JSON server
//! (plus the matching client) over the serving admission pipeline.
//!
//! The sandbox has no tokio/hyper, so this mirrors the thread architecture
//! of [`super::server`]: one accept thread feeds a small pool of connection
//! handler threads over a channel; each handler drives one keep-alive
//! connection at a time with blocking reads under a short poll timeout (so
//! a stalled or malicious client can never wedge a handler — it times out,
//! is answered, and the handler moves on).
//!
//! ```text
//!   TCP clients ──▶ accept thread ──TcpStream──▶ handler 0..N-1
//!                                                 │ parse HTTP/1.1
//!                                                 │ Server::submit
//!                                                 ▼
//!                                       admission pipeline (server.rs)
//! ```
//!
//! The front end serves a [`ServerPool`] — one or many named models. The
//! single-model `start` wraps its `Server` as a one-entry pool, so both
//! modes share one routing table.
//!
//! Routes:
//!
//! * `POST /v1/infer` → `200` with
//!   `{"pred", "logits", "queue_wait_s", "e2e_s", "sim_fpga_s"}`. The body
//!   encoding is negotiated via `Content-Type` (see [`Encoding`]):
//!   `application/json` (or no header) carries `{"image": [f32, ...]}`,
//!   decoded by the lazy field scanner
//!   ([`crate::util::json::extract_f32_field`]) without building the full
//!   value tree; `application/x-raw-f32` carries the image as little-endian
//!   f32 bytes in the manifest's flattened NHWC order (shape comes from the
//!   served model — a body whose byte length disagrees with
//!   `image_elems * 4` is `400` kind `bad_tensor_size`). Any other
//!   content type is `415` listing the supported encodings. Either way the
//!   image is decoded once into one owned buffer ([`crate::backend::ImageBuf`])
//!   that flows to batch assembly uncopied. The typed
//!   [`ServeError`] maps onto HTTP semantics:
//!   `InvalidInput → 400`, `QueueFull → 429`, `BackendFailed → 500`,
//!   `ShuttingDown → 503` (plus `504` when the reply outruns
//!   [`HttpConfig::reply_timeout`]). Admission still owns all request
//!   validation — the HTTP layer only decodes the wire encoding and lets
//!   `submit` reject bad geometry, so the two ingresses (in-process and
//!   network) can never drift.
//! * `GET /v1/healthz` → `200` with the model geometry
//!   (`image_elems`/`classes`) plus the active plan name, its content
//!   digest (`plan_digest`), and — for bundle-booted entries — the
//!   lockfile blob digests under `bundle`, which is how the remote load
//!   generator learns what to send and how a fleet operator asserts every
//!   replica serves identical bytes.
//! * `GET /v1/metrics` → `200` with [`Metrics::to_json`] (counters,
//!   occupancy, shed rate, latency summaries).
//! * `GET /v1/plan` → `200` with the active quantization plan's summary
//!   (name, provenance, per-layer and total scheme fractions — see
//!   [`crate::quant::QuantPlan::summary_json`]), so monitoring can see
//!   exactly which precision configuration is serving; `404` when the
//!   server runs unquantized.
//! * `GET /v1/models` → the pool registry listing (per-model plan name,
//!   provenance, breaker/readiness state, queue depth, `plan_digest`, and
//!   the bundle digests when serving from a store).
//! * `GET /v1/models/{name}/verify` — re-hash the entry's store blobs on
//!   demand (bundle-booted entries only; others answer `404` kind
//!   `no_bundle`). A corrupt blob maps through the pinned
//!   [`ArtifactError`] → status table (`digest_mismatch` → `500`,
//!   `missing_blob` → `404`).
//! * `POST /v1/models/{name}/infer`, `GET /v1/models/{name}/
//!   {healthz,metrics,plan}` — the per-model forms of the routes above. An
//!   unknown `{name}` answers `404` with kind `unknown_model` *and the list
//!   of served models* (the registry UX contract).
//! * `POST /v1/models/{name}/plan` — **live plan hot-swap**: the body is a
//!   [`QuantPlan`] JSON document; it is validated against the model's
//!   manifest (`400` kind `invalid_plan` on any mismatch, old plan keeps
//!   serving), re-packed off the serving path, and traffic is swung
//!   atomically with zero lost replies ([`PoolEntry::swap_plan`]).
//!
//! The bare `/v1/*` routes always map onto the pool's *default* model, so
//! single-model clients work unchanged against a pool.
//!
//! Protocol scope (documented, not accidental): HTTP/1.1 with
//! `Content-Length` bodies and keep-alive, `Expect: 100-continue`
//! honored; chunked transfer encoding is answered `501`. That is exactly
//! what the bundled client, curl, and every mainstream HTTP client emit
//! for JSON POSTs.
//!
//! [`HttpClient`]/[`HttpTarget`] are the client half used by
//! `loadgen --url`, the over-the-wire section of `benches/serving.rs`, and
//! the `http_smoke` integration tests.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::Metrics;
use super::pool::{PoolEntry, ServerPool};
use super::server::{ServeError, Server};
use crate::artifact::ArtifactError;
use crate::backend::ImageBuf;
use crate::quant::QuantPlan;
use crate::runtime::Manifest;
use crate::util::json::extract_f32_field;
use crate::util::sync::LockExt;
use crate::util::Json;

/// Read-poll granularity: handlers block at most this long per `read()`
/// before re-checking shutdown / idle budgets. This is the bound on how
/// long a garbage or stalled request can hold a handler, and on how stale
/// the shutdown flag can look to an idle keep-alive connection.
const READ_POLL: Duration = Duration::from_millis(250);

/// Cap on the request-line + header block; beyond this the request is
/// answered `431` and the connection closed.
const MAX_HEAD: usize = 16 * 1024;

/// Wire encoding of an infer request body, negotiated via `Content-Type`.
/// Adding a variant? `ilmpq analyze` rule R6 requires it handled in both
/// this file (decode + content-type mapping) and `loadgen.rs` (client
/// encode), so the two ends of the wire cannot drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Encoding {
    /// `application/json`: `{"image": [f32, ...]}` — self-describing and
    /// curl-able; decoded by the lazy field scanner, never a full tree.
    #[default]
    Json,
    /// `application/x-raw-f32`: the image as little-endian f32 bytes in the
    /// manifest's flattened NHWC order. No framing beyond `Content-Length`;
    /// the shape comes from the served model's manifest.
    Raw,
}

/// The raw-tensor media type — one string, shared by server, client,
/// tests, and CI.
pub const RAW_CONTENT_TYPE: &str = "application/x-raw-f32";

impl Encoding {
    /// The `Content-Type` this encoding sends and answers to.
    pub fn content_type(&self) -> &'static str {
        match self {
            Encoding::Json => "application/json",
            Encoding::Raw => RAW_CONTENT_TYPE,
        }
    }

    /// CLI spelling (`--encoding json|raw`).
    pub fn name(&self) -> &'static str {
        match self {
            Encoding::Json => "json",
            Encoding::Raw => "raw",
        }
    }

    /// Parse the CLI spelling.
    pub fn parse(s: &str) -> Result<Encoding> {
        match s {
            "json" => Ok(Encoding::Json),
            "raw" => Ok(Encoding::Raw),
            other => anyhow::bail!("unknown encoding {other:?} (expected \"json\" or \"raw\")"),
        }
    }

    /// Resolve a request's `Content-Type` header to an encoding. No header
    /// means JSON (the historic default). Parameters (`; charset=...`) are
    /// ignored; the media type is matched case-insensitively. An unknown
    /// media type (e.g. the `application/x-www-form-urlencoded` a bare
    /// `curl -d` sends) is the 415 path, with the supported list spelled
    /// out — the registry-style curated-error UX.
    fn from_content_type(header: Option<&str>) -> std::result::Result<Encoding, String> {
        let Some(raw) = header else { return Ok(Encoding::Json) };
        let media = match raw.split(';').next() {
            Some(m) => m.trim().to_ascii_lowercase(),
            None => String::new(),
        };
        match media.as_str() {
            "" | "application/json" | "text/json" => Ok(Encoding::Json),
            m if m == RAW_CONTENT_TYPE => Ok(Encoding::Raw),
            other => Err(format!(
                "unsupported content-type {other:?} on infer; supported encodings: \
                 {} (a JSON object with an \"image\" array) and {} (the image as \
                 little-endian f32 bytes, shape from the model manifest)",
                Encoding::Json.content_type(),
                Encoding::Raw.content_type()
            )),
        }
    }
}

/// HTTP front-end configuration.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address, e.g. `127.0.0.1:8080` (`:0` picks an ephemeral port —
    /// read it back from [`HttpServer::local_addr`]).
    pub addr: String,
    /// Connection handler threads. Each drives one connection at a time
    /// until it closes or idles out, so size this **at or above the number
    /// of concurrent keep-alive client connections** — excess connections
    /// queue unread until a handler frees, which shows up as tail latency,
    /// not errors. (Admission's `queue_depth` still bounds the pipeline
    /// behind the handlers.) Parked handlers are cheap OS threads.
    pub workers: usize,
    /// How long a handler waits for the admission pipeline's reply before
    /// answering `504`. The reply still arrives on the channel later and is
    /// dropped — the request itself was already admitted and counted.
    pub reply_timeout: Duration,
    /// How long a keep-alive connection may sit idle between requests
    /// before the handler closes it.
    pub idle_timeout: Duration,
    /// Total time allowed to receive one request (first byte → full body).
    /// This is the anti-wedging bound: a stalled or drip-feeding client is
    /// answered `408` and disconnected when it expires, while transient
    /// stalls longer than one read poll (routine on real links) are
    /// tolerated within it.
    pub request_timeout: Duration,
    /// Largest accepted request body; beyond it the request is answered
    /// `413` and the connection closed. `0` (the default) derives the
    /// limit from the served models' geometry at start: the largest
    /// `image_elems()` across the pool, costed at the JSON expansion rate
    /// (which dwarfs the raw-f32 rate), plus envelope slack — so a
    /// real-geometry model (ResNet-18 is a ~150k-element image) can never
    /// be silently 413'd by a flat cap tuned on the synthetic fixture,
    /// while tiny fixtures don't accept multi-megabyte garbage.
    pub max_body: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            addr: "127.0.0.1:8080".into(),
            workers: 8,
            reply_timeout: Duration::from_secs(60),
            idle_timeout: Duration::from_secs(15),
            request_timeout: Duration::from_secs(10),
            max_body: 0,
        }
    }
}

/// The derived `max_body` for a pool (the `max_body: 0` sentinel): the
/// largest image across the served models, costed per element at the JSON
/// rate — a shortest-roundtrip f32-as-f64 decimal runs to ~25 characters,
/// call it 32 with the comma — plus envelope slack, floored so header-ish
/// bodies (plan uploads, small fixtures) never get squeezed. Raw bodies
/// (4 bytes/element) fit inside the same bound by construction.
fn derived_max_body(pool: &ServerPool) -> usize {
    let elems = pool.entries().iter().map(|e| e.image_elems()).max().unwrap_or(0);
    (elems * 32 + 4096).max(64 * 1024)
}

/// Handle to a running HTTP front end. Owns the [`ServerPool`] behind it:
/// [`HttpServer::stop`] tears down the network side first (no new
/// submissions), then gracefully stops every admission pipeline.
pub struct HttpServer {
    pool: Option<Arc<ServerPool>>,
    /// Single-model mode only: the same `Arc<Server>` the pool's lone entry
    /// wraps, kept so [`HttpServer::server`] can hand out `&Server` for
    /// direct pipeline access. Dropped before the pool unwinds in teardown
    /// so the entry can unwrap and join it.
    single: Option<Arc<Server>>,
    local_addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    handlers: Vec<JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `cfg.addr` and start the accept + handler threads over one
    /// running `server` (wrapped as a single-entry pool). `manifest`
    /// supplies the geometry advertised on `/v1/healthz`.
    pub fn start(server: Server, manifest: &Manifest, cfg: HttpConfig) -> Result<HttpServer> {
        let server = Arc::new(server);
        let pool = Arc::new(ServerPool::single(server.clone(), manifest));
        Self::start_inner(pool, Some(server), cfg)
    }

    /// Bind `cfg.addr` and start the accept + handler threads over a
    /// multi-model pool (`ilmpq serve --pool`).
    pub fn start_pool(pool: Arc<ServerPool>, cfg: HttpConfig) -> Result<HttpServer> {
        Self::start_inner(pool, None, cfg)
    }

    fn start_inner(
        pool: Arc<ServerPool>,
        single: Option<Arc<Server>>,
        mut cfg: HttpConfig,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| anyhow::anyhow!("bind {}: {e}", cfg.addr))?;
        let local_addr = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        if cfg.max_body == 0 {
            cfg.max_body = derived_max_body(&pool);
        }
        let cfg = Arc::new(cfg);

        let (conn_tx, conn_rx) = channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut handlers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let pool = pool.clone();
            let shutdown = shutdown.clone();
            let conn_rx = conn_rx.clone();
            let cfg = cfg.clone();
            handlers.push(std::thread::spawn(move || loop {
                let stream = {
                    // analyze:allow(shared-receiver pool, same shape as the batch workers in server.rs: holding the mutex across recv IS the connection handoff)
                    let rx = conn_rx.plock();
                    rx.recv()
                };
                match stream {
                    Ok(s) => handle_connection(&pool, &cfg, &shutdown, s),
                    Err(_) => return, // accept thread gone: no more work
                }
            }));
        }

        let accept = {
            let shutdown = shutdown.clone();
            std::thread::spawn(move || loop {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        if shutdown.load(Ordering::SeqCst) {
                            // stop()'s wake connection (or a straggler
                            // racing it): drop it and exit, taking conn_tx
                            // down so the handlers drain out.
                            return;
                        }
                        if conn_tx.send(stream).is_err() {
                            return;
                        }
                    }
                    Err(_) => {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                        // Transient accept failure (EMFILE, aborted
                        // handshake): don't spin on it.
                        std::thread::sleep(Duration::from_millis(10));
                    }
                }
            })
        };

        Ok(HttpServer {
            pool: Some(pool),
            single,
            local_addr,
            shutdown,
            accept: Some(accept),
            handlers,
        })
    }

    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The admission pipeline behind a *single-model* front end (e.g. to
    /// [`Server::begin_shutdown`] it and watch 503s flow while the HTTP
    /// side stays up). Panics in pool mode, where no one `Server` is "the"
    /// pipeline — go through [`HttpServer::pool`] instead.
    pub fn server(&self) -> &Server {
        self.single
            .as_ref()
            // analyze:allow(documented contract: this accessor panics in pool mode by design — see doc comment)
            .expect("single-model front end (pool mode has no default &Server)")
    }

    /// The model pool behind this front end.
    pub fn pool(&self) -> &Arc<ServerPool> {
        // analyze:allow(construction invariant: pool is Some until stop()/Drop consumes the front end)
        self.pool.as_ref().expect("pool present until stop()")
    }

    /// Block until the front end exits — the `ilmpq serve --listen`
    /// foreground mode (the accept loop only exits on [`HttpServer::stop`]
    /// from another thread or a dead listener).
    pub fn wait(&mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
    }

    /// Tear down: stop accepting, drain the handler pool, then gracefully
    /// stop the admission pipeline (which answers everything in flight).
    /// Bounded by roughly [`READ_POLL`] + the longest in-flight request.
    pub fn stop(mut self) -> Arc<Metrics> {
        // analyze:allow(stop consumes self, so this is the first teardown and always yields metrics)
        self.teardown().expect("first teardown returns the metrics")
    }

    /// The shared teardown behind [`HttpServer::stop`] and `Drop`.
    /// Idempotent: returns `None` when already torn down. Returns the
    /// *default* model's metrics (the single-model contract; pool mode
    /// keeps it for the headline model).
    fn teardown(&mut self) -> Option<Arc<Metrics>> {
        let pool = self.pool.take()?;
        self.shutdown.store(true, Ordering::SeqCst);
        // The accept thread is parked in accept(): unblock it with a
        // throwaway connection to ourselves (it sees the flag and exits;
        // if the listener is already dead the error path exits too). A
        // wildcard bind (0.0.0.0 / ::) is not a connectable address — wake
        // through loopback on the same port instead.
        let mut wake = self.local_addr;
        if wake.ip().is_unspecified() {
            wake.set_ip(match wake.ip() {
                std::net::IpAddr::V4(_) => {
                    std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                }
                std::net::IpAddr::V6(_) => {
                    std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                }
            });
        }
        let _ = TcpStream::connect(wake);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // conn_tx died with the accept thread: handlers finish their
        // current connection (the flag caps that at one more response) and
        // drain out on the dead channel.
        for h in self.handlers.drain(..) {
            let _ = h.join();
        }
        // Drop the single-model alias *before* the pool shuts down, so the
        // lone entry holds the only `Arc<Server>` and can unwrap-and-join
        // it (graceful stop) rather than degrade to a drain.
        self.single = None;
        Some(pool.shutdown())
    }
}

impl Drop for HttpServer {
    /// An `HttpServer` dropped without [`HttpServer::stop`] (an error-path
    /// `?`, a panic unwind) must not leak the accept thread, the handler
    /// pool, the bound port, or a still-running admission pipeline.
    fn drop(&mut self) {
        let _ = self.teardown();
    }
}

// ---------------------------------------------------------------------------
// Connection handling (server side)
// ---------------------------------------------------------------------------

/// One parsed request.
struct HttpRequest {
    method: String,
    path: String,
    keep_alive: bool,
    /// The `Content-Type` header verbatim, when present — the infer route
    /// negotiates its body [`Encoding`] from it.
    content_type: Option<String>,
    body: Vec<u8>,
}

/// What one attempt to read a request produced.
enum ReadOutcome {
    Request(HttpRequest),
    /// Peer closed (or the socket errored) with no request in progress.
    Closed,
    /// Read poll expired with no request in progress (idle keep-alive).
    Idle,
    /// Protocol violation: answer `(status, message)` and close.
    Bad(u16, String),
}

enum ReadMore {
    Data,
    Eof,
    Timeout,
    Gone,
}

/// A connection with its accumulation buffer (bytes read past the end of
/// one request belong to the next — keep-alive pipelining).
struct Conn {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Conn {
    fn read_more(&mut self) -> ReadMore {
        let mut chunk = [0u8; 4096];
        match self.stream.read(&mut chunk) {
            Ok(0) => ReadMore::Eof,
            Ok(n) => {
                self.buf.extend_from_slice(&chunk[..n]);
                ReadMore::Data
            }
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                ReadMore::Timeout
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => ReadMore::Data,
            Err(_) => ReadMore::Gone,
        }
    }

    /// Read and parse one request off the connection. Blocking reads poll
    /// at `READ_POLL` granularity (so the caller's shutdown/idle checks
    /// stay fresh); `request_timeout` is the *cumulative* budget for
    /// receiving the whole request once its first byte is buffered — a
    /// single slow poll is tolerated (real links stall for >250ms
    /// routinely), while a stalled or drip-feeding request is answered
    /// `408` when the budget expires, so it can never wedge a handler.
    fn read_request(&mut self, max_body: usize, request_timeout: Duration) -> ReadOutcome {
        let mut deadline: Option<Instant> = None;
        // Accumulate the header block.
        let head_end = loop {
            if let Some(pos) = find_subsequence(&self.buf, b"\r\n\r\n") {
                break pos;
            }
            if self.buf.len() > MAX_HEAD {
                return ReadOutcome::Bad(431, "header block too large".into());
            }
            if deadline.is_none() && !self.buf.is_empty() {
                deadline = Some(Instant::now() + request_timeout);
            }
            if deadline.is_some_and(|d| Instant::now() >= d) {
                return ReadOutcome::Bad(
                    408,
                    "request not completed within the request timeout".into(),
                );
            }
            match self.read_more() {
                ReadMore::Data => {}
                ReadMore::Eof => {
                    return if self.buf.is_empty() {
                        ReadOutcome::Closed
                    } else {
                        ReadOutcome::Bad(400, "connection closed mid-request".into())
                    };
                }
                ReadMore::Timeout => {
                    if self.buf.is_empty() {
                        return ReadOutcome::Idle;
                    }
                    // In-request stall: keep polling, the deadline governs.
                }
                ReadMore::Gone => return ReadOutcome::Closed,
            }
        };
        let head = match std::str::from_utf8(&self.buf[..head_end]) {
            Ok(h) => h.to_string(),
            Err(_) => return ReadOutcome::Bad(400, "non-UTF-8 header block".into()),
        };
        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split(' ');
        let (method, path) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(p), Some(v), None)
                if !m.is_empty() && p.starts_with('/') && v.starts_with("HTTP/1.") =>
            {
                (m.to_string(), p.to_string())
            }
            _ => {
                return ReadOutcome::Bad(
                    400,
                    format!("malformed request line {request_line:?}"),
                )
            }
        };
        let http_11 = request_line.ends_with("HTTP/1.1");
        let mut content_length = 0usize;
        let mut content_type: Option<String> = None;
        let mut keep_alive = http_11;
        let mut expect_continue = false;
        for line in lines {
            let Some((name, value)) = line.split_once(':') else {
                return ReadOutcome::Bad(400, format!("malformed header line {line:?}"));
            };
            let value = value.trim();
            match name.trim().to_ascii_lowercase().as_str() {
                "content-length" => match value.parse::<usize>() {
                    Ok(n) => content_length = n,
                    Err(_) => {
                        return ReadOutcome::Bad(
                            400,
                            format!("bad content-length {value:?}"),
                        )
                    }
                },
                "content-type" => content_type = Some(value.to_string()),
                "connection" => {
                    let v = value.to_ascii_lowercase();
                    if v.split(',').any(|t| t.trim() == "close") {
                        keep_alive = false;
                    } else if v.split(',').any(|t| t.trim() == "keep-alive") {
                        keep_alive = true;
                    }
                }
                "expect" => {
                    if value.eq_ignore_ascii_case("100-continue") {
                        expect_continue = true;
                    }
                }
                "transfer-encoding" => {
                    return ReadOutcome::Bad(
                        501,
                        "chunked transfer encoding unsupported; send content-length".into(),
                    );
                }
                _ => {}
            }
        }
        if content_length > max_body {
            return ReadOutcome::Bad(
                413,
                format!("body of {content_length} bytes exceeds the {max_body}-byte limit"),
            );
        }
        let body_start = head_end + 4;
        if expect_continue
            && self.buf.len() < body_start + content_length
            && self.stream.write_all(b"HTTP/1.1 100 Continue\r\n\r\n").is_err()
        {
            return ReadOutcome::Closed;
        }
        // The header bytes armed the deadline already unless the whole
        // request arrived in one read — arm it for the body remainder.
        let deadline = deadline.unwrap_or_else(|| Instant::now() + request_timeout);
        while self.buf.len() < body_start + content_length {
            if Instant::now() >= deadline {
                return ReadOutcome::Bad(
                    408,
                    "body not completed within the request timeout".into(),
                );
            }
            match self.read_more() {
                ReadMore::Data => {}
                ReadMore::Eof => {
                    return ReadOutcome::Bad(400, "connection closed mid-body".into())
                }
                ReadMore::Timeout => {} // in-request stall: deadline governs
                ReadMore::Gone => return ReadOutcome::Closed,
            }
        }
        let body = self.buf[body_start..body_start + content_length].to_vec();
        self.buf.drain(..body_start + content_length);
        ReadOutcome::Request(HttpRequest { method, path, keep_alive, content_type, body })
    }
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn handle_connection(
    pool: &ServerPool,
    cfg: &HttpConfig,
    shutdown: &AtomicBool,
    stream: TcpStream,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let mut conn = Conn { stream, buf: Vec::new() };
    let mut idle_deadline = Instant::now() + cfg.idle_timeout;
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        match conn.read_request(cfg.max_body, cfg.request_timeout) {
            ReadOutcome::Request(req) => {
                let keep = req.keep_alive && !shutdown.load(Ordering::SeqCst);
                let (status, body) = route(pool, cfg, &req);
                if write_response(&mut conn.stream, status, &body, keep).is_err() || !keep {
                    return;
                }
                idle_deadline = Instant::now() + cfg.idle_timeout;
            }
            ReadOutcome::Closed => return,
            ReadOutcome::Idle => {
                if Instant::now() >= idle_deadline {
                    return;
                }
            }
            ReadOutcome::Bad(status, msg) => {
                // Best-effort answer; the connection closes either way, so
                // a half-broken peer can't wedge the handler.
                let body = err_body(&msg, protocol_kind(status));
                let _ = write_response(&mut conn.stream, status, &body, false);
                // Closing with unread bytes in the receive buffer can RST
                // the connection and destroy the response before the peer
                // reads it (classic for a 413 racing a large in-flight
                // body). Half-close the write side and briefly drain what
                // is still arriving so the rejection stays observable.
                let _ = conn.stream.shutdown(std::net::Shutdown::Write);
                let drain_deadline = Instant::now() + Duration::from_millis(500);
                let mut sink = [0u8; 4096];
                while Instant::now() < drain_deadline {
                    match conn.stream.read(&mut sink) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => {}
                    }
                }
                return;
            }
        }
    }
}

/// Machine-readable `kind` for protocol-level rejections, so consumers
/// switching on the field (as the smoke tests do for the pipeline kinds)
/// can tell a timeout from a size limit from a malformed request.
fn protocol_kind(status: u16) -> &'static str {
    match status {
        408 => "request_timeout",
        413 => "payload_too_large",
        415 => "unsupported_media_type",
        431 => "header_too_large",
        501 => "not_implemented",
        _ => "bad_request",
    }
}

fn err_body(msg: &str, kind: &str) -> String {
    Json::obj(vec![
        ("error", Json::Str(msg.to_string())),
        ("kind", Json::Str(kind.to_string())),
    ])
    .to_string_compact()
}

fn route(pool: &ServerPool, cfg: &HttpConfig, req: &HttpRequest) -> (u16, String) {
    // Per-model routes first; everything else falls through to the legacy
    // bare `/v1/*` routes against the pool's default model.
    if let Some(rest) = req.path.strip_prefix("/v1/models/") {
        return route_model(pool, cfg, req, rest);
    }
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/v1/models") => (200, pool.describe().to_string_compact()),
        ("GET", "/v1/healthz") => healthz(pool.default_entry()),
        ("GET", "/v1/metrics") => {
            (200, pool.default_entry().metrics_json().to_string_compact())
        }
        ("GET", "/v1/plan") => plan_endpoint(pool.default_entry()),
        ("POST", "/v1/infer") => entry_infer(pool.default_entry(), cfg, req),
        (_, "/v1/healthz" | "/v1/metrics" | "/v1/infer" | "/v1/plan" | "/v1/models") => (
            405,
            err_body(
                &format!("method {} not allowed on {}", req.method, req.path),
                "method_not_allowed",
            ),
        ),
        _ => (404, err_body(&format!("unknown path {}", req.path), "not_found")),
    }
}

/// Routes under `/v1/models/{name}[/endpoint]`. An unknown model name
/// answers `404` with the list of served models — the registry's UX
/// contract, pinned by `tests/pool_smoke.rs`.
fn route_model(
    pool: &ServerPool,
    cfg: &HttpConfig,
    req: &HttpRequest,
    rest: &str,
) -> (u16, String) {
    let (name, endpoint) = match rest.split_once('/') {
        Some((n, e)) => (n, Some(e)),
        None => (rest, None),
    };
    let Some(entry) = pool.entry(name) else {
        return (
            404,
            Json::obj(vec![
                ("error", Json::Str(format!("unknown model {name:?}"))),
                ("kind", Json::Str("unknown_model".into())),
                (
                    "models",
                    Json::Arr(pool.names().into_iter().map(Json::Str).collect()),
                ),
            ])
            .to_string_compact(),
        );
    };
    match (req.method.as_str(), endpoint) {
        ("POST", Some("infer")) => entry_infer(entry, cfg, req),
        ("POST", Some("plan")) => swap_plan_route(entry, &req.body),
        ("GET", Some("healthz")) => healthz(entry),
        ("GET", Some("metrics")) => (200, entry.metrics_json().to_string_compact()),
        ("GET", Some("plan")) => plan_endpoint(entry),
        ("GET", Some("verify")) => verify_route(entry),
        ("GET", None) => (200, entry.describe().to_string_compact()),
        (_, None | Some("infer" | "healthz" | "metrics" | "plan" | "verify")) => (
            405,
            err_body(
                &format!("method {} not allowed on {}", req.method, req.path),
                "method_not_allowed",
            ),
        ),
        (_, Some(e)) => (
            404,
            err_body(&format!("unknown model endpoint {e:?}"), "not_found"),
        ),
    }
}

fn healthz(entry: &PoolEntry) -> (u16, String) {
    // Liveness-vs-readiness split: this endpoint always answers
    // (liveness — the front end is up), but the status code tracks
    // *readiness* — 503 while the circuit breaker is open/half-open
    // or the server is draining, so load balancers stop routing
    // here while the body still explains why. A cold entry reads
    // ready: it lazily prepares on the first request.
    let h = entry.health();
    (
        if h.ready { 200 } else { 503 },
        Json::obj(vec![
            ("status", Json::Str(if h.ready { "ok" } else { "unavailable" }.into())),
            ("live", Json::Bool(true)),
            ("ready", Json::Bool(h.ready)),
            ("breaker", Json::Str(h.breaker.into())),
            ("degraded", Json::Bool(h.degraded)),
            ("draining", Json::Bool(h.draining)),
            ("model", Json::Str(entry.manifest().model_name.clone())),
            ("image_elems", Json::Num(entry.image_elems() as f64)),
            ("classes", Json::Num(entry.classes() as f64)),
            (
                "plan",
                match h.plan {
                    Some(p) => Json::Str(p),
                    None => Json::Null,
                },
            ),
            (
                "plan_digest",
                match entry.plan_digest() {
                    Some(d) => Json::Str(d.to_hex()),
                    None => Json::Null,
                },
            ),
            (
                "bundle",
                match entry.bundle_digests() {
                    Some((m, p, q)) => Json::obj(vec![
                        ("manifest", Json::Str(m.to_hex())),
                        ("params", Json::Str(p.to_hex())),
                        ("plan", Json::Str(q.to_hex())),
                    ]),
                    None => Json::Null,
                },
            ),
        ])
        .to_string_compact(),
    )
}

/// `GET /v1/models/{name}/verify` — re-hash the entry's three store blobs
/// on demand. Only meaningful for bundle-booted entries; a config-built
/// entry has no store provenance to verify.
fn verify_route(entry: &PoolEntry) -> (u16, String) {
    match entry.verify_bundle() {
        None => (
            404,
            err_body(
                "model is not bundle-backed (boot it with serve --bundle to verify)",
                "no_bundle",
            ),
        ),
        Some(Err(e)) => artifact_error_response(&e),
        Some(Ok(plan_matches)) => (
            200,
            Json::obj(vec![
                ("verified", Json::Bool(true)),
                ("model", Json::Str(entry.name().to_string())),
                ("blobs", Json::Num(3.0)),
                ("plan_matches_bundle", Json::Bool(plan_matches)),
            ])
            .to_string_compact(),
        ),
    }
}

/// The pinned [`ArtifactError`] → HTTP status mapping (analyzer rule R7's
/// HTTP consumer): a blob whose bytes no longer hash to their address is a
/// server-side integrity failure (`500`), an absent blob is `404`, and a
/// malformed digest string is the caller's fault (`400`).
fn artifact_error_response(e: &ArtifactError) -> (u16, String) {
    let (status, kind) = match e {
        ArtifactError::DigestMismatch { .. } => (500, "digest_mismatch"),
        ArtifactError::MissingBlob { .. } => (404, "missing_blob"),
        ArtifactError::BadDigest { .. } => (400, "bad_digest"),
        ArtifactError::Io { .. } => (500, "artifact_io"),
    };
    (status, err_body(&e.to_string(), kind))
}

fn plan_endpoint(entry: &PoolEntry) -> (u16, String) {
    match entry.plan_summary() {
        Some(s) => (200, s.to_string_compact()),
        None => (
            404,
            err_body("no quantization plan active (unquantized serving)", "no_plan"),
        ),
    }
}

/// `POST /v1/models/{name}/plan` — the live hot-swap endpoint. Any parse
/// or validation failure answers `400` with the old plan untouched and
/// still serving; only a validated plan reaches [`PoolEntry::swap_plan`].
fn swap_plan_route(entry: &PoolEntry, body: &[u8]) -> (u16, String) {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return (400, err_body("body is not UTF-8", "invalid_plan")),
    };
    let json = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => {
            return (400, err_body(&format!("body is not JSON: {e}"), "invalid_plan"))
        }
    };
    let plan = match QuantPlan::from_json(&json) {
        Ok(p) => p,
        Err(e) => {
            return (
                400,
                err_body(&format!("body is not a QuantPlan: {e:#}"), "invalid_plan"),
            )
        }
    };
    if let Err(e) = plan.validate(entry.manifest()) {
        return (
            400,
            err_body(
                &format!("plan does not fit model {:?}: {e:#}", entry.name()),
                "invalid_plan",
            ),
        );
    }
    let plan_name = plan.name.clone();
    // Recorded before the move: the content digest of the uploaded plan is
    // what the swap installs, and what healthz/describe will report.
    let plan_digest = plan.content_digest();
    match entry.swap_plan(plan) {
        Ok(()) => (
            200,
            Json::obj(vec![
                ("swapped", Json::Bool(true)),
                ("model", Json::Str(entry.name().to_string())),
                ("plan", Json::Str(plan_name)),
                ("plan_digest", Json::Str(plan_digest.to_hex())),
                ("swaps", Json::Num(entry.swaps() as f64)),
            ])
            .to_string_compact(),
        ),
        Err(e) => (
            500,
            err_body(
                &format!("swap failed ({e:#}); the previous plan keeps serving"),
                "swap_failed",
            ),
        ),
    }
}

/// Decode the request body into the one owned [`ImageBuf`] per the
/// negotiated encoding — the single write of the image's f32 data on the
/// ingress side. Errors come back as a ready-to-send `(status, body)`.
fn decode_image(
    entry: &PoolEntry,
    encoding: Encoding,
    body: &[u8],
) -> std::result::Result<ImageBuf, (u16, String)> {
    match encoding {
        Encoding::Json => {
            let text = std::str::from_utf8(body)
                .map_err(|_| (400, err_body("body is not UTF-8", "bad_request")))?;
            // Lazy scan: materializes only the "image" array (f64 -> f32
            // may overflow to ±inf for huge JSON numbers; the admission
            // finiteness scan rejects those as InvalidInput).
            match extract_f32_field(text, "image") {
                Ok(Some(v)) => Ok(ImageBuf::from(v)),
                Ok(None) => Err((
                    400,
                    err_body(
                        "body must be a JSON object with an \"image\" array of numbers",
                        "bad_request",
                    ),
                )),
                Err(e) => {
                    Err((400, err_body(&format!("body is not JSON: {e}"), "bad_request")))
                }
            }
        }
        Encoding::Raw => {
            // The one wire-geometry check the HTTP layer owns: a raw body
            // has no self-describing shape, so a byte count that disagrees
            // with the model's geometry is a framing error (kind
            // `bad_tensor_size`), distinct from admission's InvalidInput.
            let expected = entry.image_elems() * 4;
            if body.len() != expected {
                return Err((
                    400,
                    err_body(
                        &format!(
                            "raw tensor body is {} bytes; model {:?} expects {expected} \
                             ({} little-endian f32 elements)",
                            body.len(),
                            entry.name(),
                            entry.image_elems()
                        ),
                        "bad_tensor_size",
                    ),
                ));
            }
            ImageBuf::from_raw_le_bytes(body)
                .map_err(|e| (400, err_body(&e, "bad_tensor_size")))
        }
    }
}

fn entry_infer(entry: &PoolEntry, cfg: &HttpConfig, req: &HttpRequest) -> (u16, String) {
    let encoding = match Encoding::from_content_type(req.content_type.as_deref()) {
        Ok(e) => e,
        Err(msg) => return (415, err_body(&msg, "unsupported_media_type")),
    };
    let image = match decode_image(entry, encoding, &req.body) {
        Ok(img) => img,
        Err(resp) => return resp,
    };
    let rx = match entry.submit(image) {
        // Lazy prepare can fail (a backend that won't pack): that is the
        // entry failing to start, not a request-level ServeError.
        Ok(rx) => rx,
        Err(e) => {
            return (500, err_body(&format!("model failed to start: {e:#}"), "start_failed"))
        }
    };
    match rx.recv_timeout(cfg.reply_timeout) {
        Ok(Ok(resp)) => (
            200,
            Json::obj(vec![
                ("pred", Json::Num(resp.pred as f64)),
                (
                    "logits",
                    Json::Arr(resp.logits.iter().map(|&v| Json::Num(v as f64)).collect()),
                ),
                ("queue_wait_s", Json::Num(resp.queue_wait.as_secs_f64())),
                ("e2e_s", Json::Num(resp.e2e.as_secs_f64())),
                ("sim_fpga_s", Json::Num(resp.sim_fpga.as_secs_f64())),
            ])
            .to_string_compact(),
        ),
        Ok(Err(e)) => serve_error_response(&e),
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => (
            504,
            err_body("timed out waiting for the batch pipeline's reply", "reply_timeout"),
        ),
        // The pipeline promises this never happens (every admitted request
        // is answered); surface it as a 500 rather than hanging.
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => (
            500,
            err_body("reply channel closed without an answer", "reply_lost"),
        ),
    }
}

/// The pinned [`ServeError`] → HTTP status mapping.
fn serve_error_response(e: &ServeError) -> (u16, String) {
    let (status, kind) = match e {
        ServeError::InvalidInput(_) => (400, "invalid_input"),
        ServeError::QueueFull { .. } => (429, "queue_full"),
        ServeError::BackendFailed(_) => (500, "backend_failed"),
        ServeError::ShuttingDown => (503, "shutting_down"),
        ServeError::Timeout { .. } => (504, "execute_timeout"),
        ServeError::Unavailable => (503, "unavailable"),
    };
    (status, err_body(&e.to_string(), kind))
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        415 => "Unsupported Media Type",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "",
    }
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    body: &str,
    keep_alive: bool,
) -> io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\ncontent-type: application/json\r\n\
         content-length: {}\r\nconnection: {}\r\n\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

// ---------------------------------------------------------------------------
// Client (used by loadgen --url, the serving bench, and the smoke tests)
// ---------------------------------------------------------------------------

/// A parsed `http://host:port[/prefix]` base URL.
#[derive(Debug, Clone)]
pub struct HttpTarget {
    /// `host:port` — both the connect target and the `Host` header.
    pub authority: String,
    /// Path prefix prepended to every route (usually empty).
    pub base_path: String,
}

impl HttpTarget {
    pub fn parse(url: &str) -> Result<HttpTarget> {
        anyhow::ensure!(
            !url.starts_with("https://"),
            "https is not supported by the dependency-free client; use http://"
        );
        let rest = url.strip_prefix("http://").unwrap_or(url);
        let (authority, path) = match rest.split_once('/') {
            Some((a, p)) => (a, format!("/{p}")),
            None => (rest, String::new()),
        };
        anyhow::ensure!(!authority.is_empty(), "no host in URL {url:?}");
        let authority = if authority.contains(':') {
            authority.to_string()
        } else {
            format!("{authority}:80")
        };
        Ok(HttpTarget {
            authority,
            base_path: path.trim_end_matches('/').to_string(),
        })
    }
}

struct ClientConn {
    stream: TcpStream,
    buf: Vec<u8>,
}

/// Minimal keep-alive HTTP/1.1 client: one connection, sequential
/// requests, one transparent reconnect when a reused connection turns out
/// to have been closed by the server.
pub struct HttpClient {
    target: HttpTarget,
    timeout: Duration,
    conn: Option<ClientConn>,
}

impl HttpClient {
    /// Lazy: no I/O until the first request.
    pub fn connect(target: &HttpTarget, timeout: Duration) -> HttpClient {
        HttpClient { target: target.clone(), timeout, conn: None }
    }

    /// Issue one JSON request; returns `(status, body)`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> io::Result<(u16, String)> {
        self.request_bytes(
            method,
            path,
            body.unwrap_or("").as_bytes(),
            Encoding::Json.content_type(),
        )
    }

    /// Issue one request with an arbitrary payload and content type — the
    /// raw-f32 wire encoding's entry point (responses are always JSON).
    pub fn request_bytes(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
        content_type: &str,
    ) -> io::Result<(u16, String)> {
        let reused = self.conn.is_some();
        match self.request_once(method, path, body, content_type) {
            Ok(r) => Ok(r),
            Err((e, response_started)) => {
                // Retry exactly the stale-keep-alive race: a *reused*
                // connection the server closed under us, with *zero*
                // response bytes received. The server answers every request
                // it reads (including errors), so no response bytes means
                // the request was never processed — the retry cannot
                // double-submit an inference. Anything past that (timeout,
                // mid-response EOF) is surfaced to the caller instead.
                let stale = reused
                    && !response_started
                    && matches!(
                        e.kind(),
                        io::ErrorKind::UnexpectedEof
                            | io::ErrorKind::ConnectionReset
                            | io::ErrorKind::ConnectionAborted
                            | io::ErrorKind::BrokenPipe
                            | io::ErrorKind::WriteZero
                    );
                if stale {
                    self.request_once(method, path, body, content_type).map_err(|(e, _)| e)
                } else {
                    Err(e)
                }
            }
        }
    }

    fn ensure_conn(&mut self) -> io::Result<&mut ClientConn> {
        if self.conn.is_none() {
            let addr = self
                .target
                .authority
                .to_socket_addrs()?
                .next()
                .ok_or_else(|| {
                    io::Error::new(
                        io::ErrorKind::AddrNotAvailable,
                        format!("{} resolves to no address", self.target.authority),
                    )
                })?;
            let stream = TcpStream::connect_timeout(&addr, self.timeout.min(Duration::from_secs(5)))?;
            stream.set_nodelay(true)?;
            stream.set_read_timeout(Some(self.timeout))?;
            stream.set_write_timeout(Some(self.timeout))?;
            self.conn = Some(ClientConn { stream, buf: Vec::new() });
        }
        // analyze:allow(the branch above just installed the connection)
        Ok(self.conn.as_mut().expect("just ensured"))
    }

    /// One attempt on the current (or a fresh) connection. The error side
    /// carries whether any response bytes had arrived before the failure —
    /// the signal `request` uses to decide whether a retry is safe.
    fn request_once(
        &mut self,
        method: &str,
        path: &str,
        payload: &[u8],
        content_type: &str,
    ) -> Result<(u16, String), (io::Error, bool)> {
        let full_path = format!("{}{}", self.target.base_path, path);
        let authority = self.target.authority.clone();
        let timeout = self.timeout;
        let conn = match self.ensure_conn() {
            Ok(c) => c,
            Err(e) => return Err((e, false)),
        };
        let head = format!(
            "{method} {full_path} HTTP/1.1\r\nhost: {authority}\r\n\
             content-type: {content_type}\r\ncontent-length: {}\r\n\
             connection: keep-alive\r\n\r\n",
            payload.len()
        );
        let result = send_and_read(conn, &head, payload, timeout);
        match result {
            Ok((status, body, close)) => {
                if close {
                    self.conn = None;
                }
                Ok((status, body))
            }
            Err(e) => {
                // The buffer only ever holds bytes of the in-flight
                // response (each success drains exactly its own bytes), so
                // non-empty here means the server had started answering.
                let response_started =
                    self.conn.as_ref().is_some_and(|c| !c.buf.is_empty());
                self.conn = None;
                Err((e, response_started))
            }
        }
    }
}

/// Cap on a response body the client will buffer — a lying
/// `content-length` must not be able to grow the buffer without bound.
const MAX_CLIENT_BODY: usize = 16 * 1024 * 1024;

fn send_and_read(
    conn: &mut ClientConn,
    head: &str,
    payload: &[u8],
    timeout: Duration,
) -> io::Result<(u16, String, bool)> {
    let wrote = conn
        .stream
        .write_all(head.as_bytes())
        .and_then(|()| conn.stream.write_all(payload))
        .and_then(|()| conn.stream.flush());
    match wrote {
        Ok(()) => read_client_response(conn, Instant::now() + timeout),
        Err(e) => {
            // A mid-write failure often means the server rejected early
            // (413 on an oversized body) and closed its read side — the
            // response may already be buffered locally. Prefer it over the
            // raw transport error so the pinned status mapping stays
            // observable through this client.
            read_client_response(conn, Instant::now() + Duration::from_millis(500))
                .map_err(|_| e)
        }
    }
}

/// Read one response; returns `(status, body, server_wants_close)`.
/// `deadline` is the *cumulative* budget for the whole response — the
/// per-read socket timeout alone would let a drip-feeding server (one
/// byte per poll) hold the caller forever.
fn read_client_response(
    conn: &mut ClientConn,
    deadline: Instant,
) -> io::Result<(u16, String, bool)> {
    let overdue = || {
        io::Error::new(
            io::ErrorKind::TimedOut,
            "response not completed within the client timeout",
        )
    };
    let head_end = loop {
        if let Some(pos) = find_subsequence(&conn.buf, b"\r\n\r\n") {
            break pos;
        }
        if conn.buf.len() > MAX_HEAD {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "response header block too large",
            ));
        }
        if Instant::now() >= deadline {
            return Err(overdue());
        }
        let mut chunk = [0u8; 4096];
        match conn.stream.read(&mut chunk)? {
            0 => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed before a full response",
                ))
            }
            n => conn.buf.extend_from_slice(&chunk[..n]),
        }
    };
    let head = String::from_utf8_lossy(&conn.buf[..head_end]).to_string();
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status = status_line
        .split(' ')
        .nth(1)
        .and_then(|c| c.parse::<u16>().ok())
        .ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("malformed status line {status_line:?}"),
            )
        })?;
    let mut content_length: Option<usize> = None;
    let mut close = false;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let value = value.trim();
            match name.trim().to_ascii_lowercase().as_str() {
                "content-length" => content_length = value.parse().ok(),
                "connection" => close = value.eq_ignore_ascii_case("close"),
                _ => {}
            }
        }
    }
    let body_start = head_end + 4;
    let body = match content_length {
        Some(len) => {
            if len > MAX_CLIENT_BODY {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("response declares a {len}-byte body; refusing to buffer it"),
                ));
            }
            while conn.buf.len() < body_start + len {
                if Instant::now() >= deadline {
                    return Err(overdue());
                }
                let mut chunk = [0u8; 4096];
                match conn.stream.read(&mut chunk)? {
                    0 => {
                        return Err(io::Error::new(
                            io::ErrorKind::UnexpectedEof,
                            "connection closed mid-body",
                        ))
                    }
                    n => conn.buf.extend_from_slice(&chunk[..n]),
                }
            }
            let b = String::from_utf8_lossy(&conn.buf[body_start..body_start + len]).to_string();
            conn.buf.drain(..body_start + len);
            b
        }
        None => {
            // No content-length: legal only on a connection the server is
            // closing — read to EOF, bounded in size and time like the
            // length-delimited path.
            loop {
                if conn.buf.len() > body_start + MAX_CLIENT_BODY {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "unbounded close-delimited response body",
                    ));
                }
                if Instant::now() >= deadline {
                    return Err(overdue());
                }
                let mut chunk = [0u8; 4096];
                match conn.stream.read(&mut chunk)? {
                    0 => break,
                    n => conn.buf.extend_from_slice(&chunk[..n]),
                }
            }
            let b = String::from_utf8_lossy(&conn.buf[body_start..]).to_string();
            conn.buf.clear();
            close = true;
            b
        }
    };
    Ok((status, body, close))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_parses_bare_and_prefixed_urls() {
        let t = HttpTarget::parse("http://127.0.0.1:8731").unwrap();
        assert_eq!(t.authority, "127.0.0.1:8731");
        assert_eq!(t.base_path, "");
        let t = HttpTarget::parse("http://box:9000/api/").unwrap();
        assert_eq!(t.authority, "box:9000");
        assert_eq!(t.base_path, "/api");
        let t = HttpTarget::parse("localhost:80").unwrap();
        assert_eq!(t.authority, "localhost:80");
        let t = HttpTarget::parse("http://example.org").unwrap();
        assert_eq!(t.authority, "example.org:80");
    }

    #[test]
    fn target_rejects_https_and_empty() {
        assert!(HttpTarget::parse("https://x:1").is_err());
        assert!(HttpTarget::parse("http:///path").is_err());
    }

    #[test]
    fn serve_errors_map_to_pinned_statuses() {
        assert_eq!(serve_error_response(&ServeError::InvalidInput("x".into())).0, 400);
        assert_eq!(serve_error_response(&ServeError::QueueFull { depth: 4 }).0, 429);
        assert_eq!(serve_error_response(&ServeError::BackendFailed("x".into())).0, 500);
        assert_eq!(serve_error_response(&ServeError::ShuttingDown).0, 503);
        assert_eq!(serve_error_response(&ServeError::Timeout { deadline_ms: 50 }).0, 504);
        assert_eq!(serve_error_response(&ServeError::Unavailable).0, 503);
        let (_, body) = serve_error_response(&ServeError::QueueFull { depth: 4 });
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("queue_full"));
        // The two 503s and the two 504s are told apart by `kind` — loadgen's
        // wire classifier depends on this.
        let (_, body) = serve_error_response(&ServeError::Unavailable);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("unavailable"));
        let (_, body) = serve_error_response(&ServeError::Timeout { deadline_ms: 50 });
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some("execute_timeout"));
    }

    #[test]
    fn artifact_errors_map_to_pinned_statuses() {
        use crate::artifact::Digest;
        let a = Digest::of(b"a");
        let b = Digest::of(b"b");
        let cases: Vec<(ArtifactError, u16, &str)> = vec![
            (
                ArtifactError::DigestMismatch {
                    blob: "tiny/params".into(),
                    expected: a,
                    actual: b,
                },
                500,
                "digest_mismatch",
            ),
            (
                ArtifactError::MissingBlob { blob: "tiny/plan".into(), digest: a },
                404,
                "missing_blob",
            ),
            (
                ArtifactError::BadDigest { input: "zz".into(), reason: "short".into() },
                400,
                "bad_digest",
            ),
            (
                ArtifactError::Io {
                    blob: "tiny/manifest".into(),
                    op: "read blob",
                    source: std::io::Error::new(std::io::ErrorKind::Other, "disk"),
                },
                500,
                "artifact_io",
            ),
        ];
        for (e, status, kind) in cases {
            let (got, body) = artifact_error_response(&e);
            assert_eq!(got, status, "{e}");
            let j = Json::parse(&body).unwrap();
            assert_eq!(j.get("kind").and_then(|v| v.as_str()), Some(kind));
        }
        // The mismatch body names both digests — the operator-facing half
        // of the integrity contract.
        let (_, body) = artifact_error_response(&ArtifactError::DigestMismatch {
            blob: "tiny/params".into(),
            expected: a,
            actual: b,
        });
        assert!(body.contains(&a.to_hex()) && body.contains(&b.to_hex()), "{body}");
    }

    #[test]
    fn find_subsequence_locates_terminator() {
        assert_eq!(find_subsequence(b"ab\r\n\r\ncd", b"\r\n\r\n"), Some(2));
        assert_eq!(find_subsequence(b"abcd", b"\r\n\r\n"), None);
    }

    #[test]
    fn content_type_negotiation_maps_every_encoding() {
        // No header and JSON spellings (parameters, case) resolve to Json.
        assert_eq!(Encoding::from_content_type(None), Ok(Encoding::Json));
        assert_eq!(
            Encoding::from_content_type(Some("application/json")),
            Ok(Encoding::Json)
        );
        assert_eq!(
            Encoding::from_content_type(Some("Application/JSON; charset=utf-8")),
            Ok(Encoding::Json)
        );
        assert_eq!(
            Encoding::from_content_type(Some(RAW_CONTENT_TYPE)),
            Ok(Encoding::Raw)
        );
        assert_eq!(
            Encoding::from_content_type(Some("APPLICATION/X-RAW-F32")),
            Ok(Encoding::Raw)
        );
        // Unknown types name both supported encodings — the 415 body's UX.
        let err = Encoding::from_content_type(Some("application/x-www-form-urlencoded"))
            .unwrap_err();
        assert!(err.contains("application/json") && err.contains(RAW_CONTENT_TYPE), "{err}");
    }

    #[test]
    fn encoding_cli_spellings_roundtrip() {
        for e in [Encoding::Json, Encoding::Raw] {
            assert_eq!(Encoding::parse(e.name()).unwrap(), e);
        }
        assert!(Encoding::parse("protobuf").is_err());
    }

    #[test]
    fn derived_max_body_scales_with_geometry() {
        // ResNet-18 geometry (~150k elements) must clear the historic flat
        // 4 MiB cap at the JSON expansion rate; a tiny fixture floors out.
        assert!(150_528 * 32 + 4096 > 4 * 1024 * 1024);
        assert_eq!(0usize * 32 + 4096, 4096); // floor applies below 64 KiB
    }
}
