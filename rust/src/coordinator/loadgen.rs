//! Open-loop load generator for the serving admission pipeline.
//!
//! Drives a running [`Server`] with Poisson arrivals at a configured
//! offered load, collects every typed reply, and folds the server metrics
//! into one [`LoadReport`] (p50/p99 end-to-end latency, batch occupancy,
//! shed rate, goodput). Shared by the `ilmpq loadgen` subcommand and
//! `benches/serving.rs` so both report identical numbers for identical
//! workloads.
//!
//! The generator is *open-loop*: arrivals do not wait for replies, so an
//! offered load beyond the backend's capacity exercises the queue bound —
//! the shed rate is the interesting output, not an error. A configurable
//! fraction of deliberately malformed requests exercises the admission
//! validator the same way.
//!
//! [`synth_fixture`] builds an artifact-free serving stack (synthetic
//! TinyResNet manifest + registry backend), so the whole pipeline runs
//! end-to-end on a toolchain-only machine: no `make artifacts`, no PJRT,
//! `--no-default-features` is enough.
//!
//! Beyond the steady Poisson shape, [`Scenario`] selects adversarial
//! workloads for the resilience machinery: `burst` offers a square-wave
//! overload (the admission bound and breaker see alternating saturation
//! and silence), `chaos` blends valid, malformed, and poison
//! (fault-triggering) requests — pair it with `ilmpq serve --fault` to
//! drive the full supervised-execution state machine. Both emit the same
//! [`LoadReport`], so resilience runs chart on the same axes as clean ones.
//!
//! [`run_remote`] is the same workload spoken over real sockets against an
//! `ilmpq serve --listen` front end (`ilmpq loadgen --url`): the HTTP
//! statuses fold back into the same [`LoadReport`] outcome classes
//! (200→done, 400→invalid, 429→shed, 500→failed, 503→shutdown or
//! unavailable by body kind, 504→timeout or slow by body kind, transport
//! failure→lost), and `e2e`/`queue_wait` carry
//! the *server-reported* per-request timings from each reply body, so
//! those columns stay directly comparable with in-process runs. Caveat:
//! arrivals are open-loop (Poisson-paced into a bounded client-side
//! queue) but *delivery* is bounded by the `conns` synchronous
//! connections — once the offered rate exceeds `conns / round-trip`, the
//! server sees at most `conns` in-flight requests (so it sheds less than
//! the in-process run at the same nominal rate), the backlog shows up in
//! `client_rtt` (the client-observed round-trip including connection
//! queueing), and arrivals overflowing the bounded queue are counted as
//! `slow` instead of buffering request bodies without bound.

use std::sync::mpsc::{sync_channel, RecvTimeoutError, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::http::{Encoding, HttpClient, HttpTarget};
use super::metrics::Metrics;
use super::server::{ServeError, Server};
use crate::backend::{self, synth, BackendInit, InferenceBackend};
use crate::quant::{ratio_by_name, MaskSet, Provenance, QuantPlan, QuantSource, Ratio};
use crate::runtime::{HostTensor, Manifest};
use crate::util::sync::LockExt;
use crate::util::stats::Summary;
use crate::util::{Json, Rng};

/// Arrival/content shape of a load-generation run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Scenario {
    /// Steady Poisson arrivals at the configured rate (the default).
    #[default]
    Steady,
    /// Square-wave overload: 500ms periods, all arrivals compressed into
    /// the first half at double the instantaneous rate, silence in the
    /// second — same mean offered load, but the admission bound and
    /// breaker see alternating saturation and recovery.
    Burst,
    /// Steady arrivals, adversarial content: the valid/malformed/poison
    /// blend for resilience runs (pair with `ilmpq serve --fault`). The
    /// CLI defaults `malformed_frac`/`poison_frac` up when this scenario
    /// is chosen without explicit fractions.
    Chaos,
    /// Steady arrivals fanned across the models of a pool front end
    /// (remote runs only): each request picks a model by weight — from
    /// [`LoadSpec::model_weights`], or the default 80/20 skew toward the
    /// pool's default model — and posts to its per-model route. Per-model
    /// outcomes land in [`LoadReport::models`].
    Multi,
}

impl Scenario {
    /// Parse a `--scenario` argument.
    pub fn parse(s: &str) -> Result<Scenario> {
        match s {
            "steady" => Ok(Scenario::Steady),
            "burst" => Ok(Scenario::Burst),
            "chaos" => Ok(Scenario::Chaos),
            "multi" => Ok(Scenario::Multi),
            other => anyhow::bail!(
                "unknown scenario {other:?} (expected steady, burst, chaos, or multi)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Steady => "steady",
            Scenario::Burst => "burst",
            Scenario::Chaos => "chaos",
            Scenario::Multi => "multi",
        }
    }
}

/// Workload knobs for one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Total requests to offer.
    pub requests: usize,
    /// Offered load in requests/second (Poisson inter-arrivals). Zero or
    /// non-finite disables pacing (submit as fast as possible).
    pub rate: f64,
    /// Fraction of requests submitted with a deliberately malformed length,
    /// to exercise admission rejection (0.0 for a clean run).
    pub malformed_frac: f64,
    /// Fraction of well-formed requests carrying the
    /// [`backend::POISON_MAGIC`] sentinel a [`backend::FaultyBackend`]
    /// deterministically fails on — exercises singleton-retry quarantine
    /// (0.0 for a clean run; inert against a non-faulty backend, the
    /// sentinel is an ordinary finite float).
    pub poison_frac: f64,
    /// Arrival/content shape.
    pub scenario: Scenario,
    /// RNG seed for arrivals + images.
    pub seed: u64,
    /// [`Scenario::Multi`] only: explicit `(model, weight)` traffic mix.
    /// Empty means "discover the pool and skew 80/20 toward its default
    /// model". Weights are relative (they need not sum to 1).
    pub model_weights: Vec<(String, f64)>,
    /// Remote runs only: how request bodies go on the wire — `json` (the
    /// default; an `{"image": [...]}` object) or `raw` (the image as
    /// little-endian f32 bytes under `application/x-raw-f32`). Both fold
    /// into the same [`LoadReport`] outcome classes, so the encodings
    /// chart on the same axes. In-process runs ignore this (there is no
    /// wire).
    pub encoding: Encoding,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            requests: 512,
            rate: 2000.0,
            malformed_frac: 0.0,
            poison_frac: 0.0,
            scenario: Scenario::Steady,
            seed: 42,
            model_weights: Vec::new(),
            encoding: Encoding::Json,
        }
    }
}

/// Parse a `--models name:weight,name:weight` traffic-mix argument.
pub fn parse_model_weights(s: &str) -> Result<Vec<(String, f64)>> {
    let mut out: Vec<(String, f64)> = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, w) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("model weight {part:?} is not name:weight"))?;
        let name = name.trim();
        anyhow::ensure!(!name.is_empty(), "model weight {part:?} has an empty name");
        let w: f64 = w
            .trim()
            .parse()
            .map_err(|_| anyhow::anyhow!("model weight {part:?}: non-numeric weight"))?;
        anyhow::ensure!(
            w.is_finite() && w > 0.0,
            "model weight {part:?} must be positive and finite"
        );
        anyhow::ensure!(
            out.iter().all(|(n, _)| n != name),
            "model {name:?} appears twice in the weights"
        );
        out.push((name.to_string(), w));
    }
    anyhow::ensure!(!out.is_empty(), "--models got no name:weight entries");
    Ok(out)
}

/// Outcome of one run: client-observed reply counts + server-side
/// latency/occupancy summaries.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// The nominal rate the spec asked for.
    pub offered_rate: f64,
    /// The rate actually achieved during the submission phase (requests /
    /// submission elapsed). Sleep overshoot and per-request generation cost
    /// make this fall short of nominal at high rates — plot against this
    /// axis, not the nominal one.
    pub achieved_rate: f64,
    pub requests: usize,
    /// Replies answered with logits.
    pub done: usize,
    /// `InvalidInput` rejections (admission validation).
    pub invalid: usize,
    /// `QueueFull` sheds (admission bound).
    pub shed: usize,
    /// `BackendFailed` replies.
    pub failed: usize,
    /// `ShuttingDown` replies.
    pub shutdown: usize,
    /// `Timeout` replies: the execution watchdog abandoned the batch.
    pub timeout: usize,
    /// `Unavailable` replies: shed at admission while the circuit breaker
    /// was open.
    pub unavailable: usize,
    /// Replies not collected within the run-wide 60s drain deadline (they
    /// may still arrive later): a saturated or very slow backend, not a
    /// protocol regression.
    pub slow: usize,
    /// Reply channels closed without an answer — always 0 with the
    /// typed-error pipeline; counted so a dropped-reply regression is
    /// visible.
    pub lost: usize,
    pub wall_s: f64,
    /// Completed requests per wall-clock second.
    pub goodput_rps: f64,
    /// Server-side end-to-end latency (submit → reply inside the server).
    /// Identical definition for in-process and remote runs — for remote
    /// runs it is collected from the `e2e_s` field of each reply body —
    /// so this column is directly comparable across transports.
    pub e2e: Summary,
    pub queue_wait: Summary,
    /// Remote runs only (empty in-process): client-observed round-trip
    /// from job dispatch to parsed response, *including* time queued for
    /// one of the `conns` client connections. When this diverges from
    /// `e2e`, the client's connection pool — not the server — is the
    /// bottleneck (the remote driver is open-loop in its arrivals but
    /// delivery is concurrency-bounded by `conns`).
    pub client_rtt: Summary,
    pub occupancy: f64,
    pub shed_rate: f64,
    /// [`Scenario::Multi`] remote runs only (empty otherwise): per-model
    /// outcome rows, in pool-listing order.
    pub models: Vec<ModelOutcome>,
}

/// Per-model slice of a multi-model run: what the mixer offered this model
/// and how it answered. `offered == done + failed` (failed folds in every
/// non-200 outcome, client-side overflow, and deadline skips), so lost
/// traffic can never hide between models.
#[derive(Debug, Clone)]
pub struct ModelOutcome {
    pub model: String,
    pub offered: usize,
    pub done: usize,
    pub failed: usize,
    /// Server-reported e2e latency for this model's 200s.
    pub e2e: Summary,
}

impl ModelOutcome {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::Str(self.model.clone())),
            ("offered", Json::Num(self.offered as f64)),
            ("done", Json::Num(self.done as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("e2e", self.e2e.to_json()),
        ])
    }
}

/// One workload image for the next request — the *single* generator shared
/// by [`run`] and [`run_remote`], so the in-process and over-the-wire
/// workloads are identical (image values, malformed positions, RNG stream)
/// for the same spec/seed. A wrong-length image must bounce off admission,
/// never a batch; `img + 1` is malformed for every geometry (a halved
/// length would collide with `img` itself when image_elems <= 2).
fn gen_image(rng: &mut Rng, spec: &LoadSpec, img: usize) -> Vec<f32> {
    let malformed = spec.malformed_frac > 0.0 && rng.bool(spec.malformed_frac);
    let len = if malformed { img + 1 } else { img };
    let mut image = vec![0f32; len];
    rng.fill_normal(&mut image, 1.0);
    // Poison only well-formed images (a malformed one bounces at admission
    // before any backend could see the sentinel). The sentinel is a plain
    // finite float, so it sails through admission and only a FaultyBackend
    // with poison detection treats it specially.
    if !malformed && spec.poison_frac > 0.0 && rng.bool(spec.poison_frac) {
        image[0] = backend::POISON_MAGIC;
    }
    image
}

/// Inter-arrival sleep before the *next* request, or `None` when pacing is
/// disabled. Exactly one RNG draw per call on every path, so the image
/// stream stays deterministic per seed regardless of wall-clock phase.
fn inter_arrival(rng: &mut Rng, spec: &LoadSpec, t0: Instant) -> Option<Duration> {
    if !(spec.rate.is_finite() && spec.rate > 0.0) {
        return None;
    }
    match spec.scenario {
        Scenario::Steady | Scenario::Chaos | Scenario::Multi => {
            Some(Duration::from_secs_f64(rng.exp(spec.rate)))
        }
        Scenario::Burst => {
            // Square wave: the whole offered load arrives in the first half
            // of each 500ms period (at 2x the nominal instantaneous rate),
            // the second half is silent.
            const PERIOD_S: f64 = 0.5;
            let gap = rng.exp(spec.rate * 2.0);
            let into = t0.elapsed().as_secs_f64() % PERIOD_S;
            if into < PERIOD_S / 2.0 {
                Some(Duration::from_secs_f64(gap))
            } else {
                // Off-phase: wait out the rest of the period, then resume
                // the on-phase arrival process.
                Some(Duration::from_secs_f64(PERIOD_S - into + gap))
            }
        }
    }
}

/// Drive `server` with `spec` and stop it when the run drains. `manifest`
/// supplies the image geometry for the generated workload. Returns the
/// client-side report plus the server's metrics handle (for consumers that
/// also want the full `Metrics::report()`).
pub fn run(
    server: Server,
    manifest: &Manifest,
    spec: &LoadSpec,
) -> (LoadReport, Arc<Metrics>) {
    let img = manifest.data.image_elems();
    let mut rng = Rng::new(spec.seed);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(spec.requests);
    for _ in 0..spec.requests {
        pending.push(server.submit(gen_image(&mut rng, spec, img)));
        if let Some(gap) = inter_arrival(&mut rng, spec, t0) {
            std::thread::sleep(gap);
        }
    }
    let submit_s = t0.elapsed().as_secs_f64();
    let (mut done, mut invalid, mut shed, mut failed, mut shutdown) =
        (0usize, 0usize, 0usize, 0usize, 0usize);
    let (mut timeout, mut unavailable) = (0usize, 0usize);
    let (mut slow, mut lost) = (0usize, 0usize);
    // One run-wide drain deadline (not per-request): a wedged server costs
    // ~60s total instead of 60s x requests, and the slow/lost counts still
    // get reported rather than an opaque external kill.
    let deadline = Instant::now() + Duration::from_secs(60);
    for rx in pending {
        let remaining = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(remaining) {
            Ok(Ok(_)) => done += 1,
            Ok(Err(ServeError::InvalidInput(_))) => invalid += 1,
            Ok(Err(ServeError::QueueFull { .. })) => shed += 1,
            Ok(Err(ServeError::BackendFailed(_))) => failed += 1,
            Ok(Err(ServeError::ShuttingDown)) => shutdown += 1,
            Ok(Err(ServeError::Timeout { .. })) => timeout += 1,
            Ok(Err(ServeError::Unavailable)) => unavailable += 1,
            // Slow is a capacity symptom; only a *closed* channel is the
            // dropped-reply regression the pipeline promises never happens.
            Err(RecvTimeoutError::Timeout) => slow += 1,
            Err(RecvTimeoutError::Disconnected) => lost += 1,
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let metrics = server.stop();
    let report = LoadReport {
        offered_rate: spec.rate,
        achieved_rate: spec.requests as f64 / submit_s.max(1e-9),
        requests: spec.requests,
        done,
        invalid,
        shed,
        failed,
        shutdown,
        timeout,
        unavailable,
        slow,
        lost,
        wall_s,
        goodput_rps: done as f64 / wall_s.max(1e-9),
        e2e: metrics.e2e.summary(),
        queue_wait: metrics.queue_wait.summary(),
        client_rtt: Summary::of(&[]),
        occupancy: metrics.batch_occupancy(),
        shed_rate: metrics.shed_rate(),
        models: Vec::new(),
    };
    (report, metrics)
}

impl LoadReport {
    /// Human-readable multi-line report for the CLI.
    pub fn render(&self) -> String {
        let rtt = if self.client_rtt.n > 0 {
            format!("\nclient_rtt: {} (incl. client-side connection queueing)", self.client_rtt)
        } else {
            String::new()
        };
        let mut per_model = String::new();
        for m in &self.models {
            per_model.push_str(&format!(
                "\nmodel {}: offered={} done={} failed={}, e2e {}",
                m.model, m.offered, m.done, m.failed, m.e2e
            ));
        }
        format!(
            "offered {:.0} req/s (achieved {:.0}), {} requests in {:.2}s\n\
             outcomes: done={} invalid={} shed={} failed={} shutdown={} \
             timeout={} unavailable={} slow={} lost={}\n\
             goodput {:.0} req/s, occupancy {:.1}%, shed rate {:.1}%\n\
             e2e:        {}\nqueue_wait: {}{}{}",
            self.offered_rate,
            self.achieved_rate,
            self.requests,
            self.wall_s,
            self.done,
            self.invalid,
            self.shed,
            self.failed,
            self.shutdown,
            self.timeout,
            self.unavailable,
            self.slow,
            self.lost,
            self.goodput_rps,
            self.occupancy * 100.0,
            self.shed_rate * 100.0,
            self.e2e,
            self.queue_wait,
            rtt,
            per_model,
        )
    }

    /// Machine-readable form, one point of `BENCH_serving.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offered_rate_rps", Json::Num(self.offered_rate)),
            ("achieved_rate_rps", Json::Num(self.achieved_rate)),
            ("requests", Json::Num(self.requests as f64)),
            ("done", Json::Num(self.done as f64)),
            ("invalid", Json::Num(self.invalid as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("shutdown", Json::Num(self.shutdown as f64)),
            ("timeout", Json::Num(self.timeout as f64)),
            ("unavailable", Json::Num(self.unavailable as f64)),
            ("slow", Json::Num(self.slow as f64)),
            ("lost", Json::Num(self.lost as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("occupancy", Json::Num(self.occupancy)),
            ("shed_rate", Json::Num(self.shed_rate)),
            ("e2e", self.e2e.to_json()),
            ("queue_wait", self.queue_wait.to_json()),
            ("client_rtt", self.client_rtt.to_json()),
            (
                "models",
                Json::Arr(self.models.iter().map(ModelOutcome::to_json).collect()),
            ),
        ])
    }
}

/// One generated request on its way to a client-connection worker.
struct WireJob {
    /// Serialized request body in the run's [`LoadSpec::encoding`]: UTF-8
    /// JSON bytes, or the image's little-endian f32 bytes.
    body: Vec<u8>,
    queued: Instant,
    /// Route to POST to (`/v1/infer`, or a per-model pool route).
    path: String,
    /// Index into the run's model-target list (0 for single-model runs).
    model: usize,
}

/// Serialize one generated image in the run's wire encoding — the client
/// half of the `Encoding` contract (`ilmpq analyze` rule R6 requires every
/// variant handled here and in `http.rs`). `Json` is the classic
/// `{"image": [...]}` object; `Raw` is the image verbatim as little-endian
/// f32 bytes, bit-exact with what `ImageBuf::from_raw_le_bytes` decodes
/// server-side. Outcome folding needs no per-encoding arm: `classify_wire`
/// is status-based, and a malformed raw image (wrong length ⇒ wrong byte
/// count) draws the same 400 as its JSON twin.
fn encode_image(encoding: Encoding, image: &[f32]) -> Vec<u8> {
    match encoding {
        Encoding::Json => Json::obj(vec![(
            "image",
            Json::Arr(image.iter().map(|&v| Json::Num(v as f64)).collect()),
        )])
        .to_string_compact()
        .into_bytes(),
        Encoding::Raw => {
            let mut body = Vec::with_capacity(image.len() * 4);
            for v in image {
                body.extend_from_slice(&v.to_le_bytes());
            }
            body
        }
    }
}

/// One model a remote run routes traffic to. Single-model runs have
/// exactly one (the bare `/v1/infer` route at weight 1); `multi` runs
/// discover the pool's registry.
struct ModelTarget {
    name: String,
    path: String,
    img: usize,
    weight: f64,
}

/// Per-model slice of a [`WireTally`].
#[derive(Default, Clone)]
struct ModelAgg {
    done: usize,
    failed: usize,
    e2e: Vec<f64>,
}

/// Per-connection tallies, merged into the final [`LoadReport`].
#[derive(Default)]
struct WireTally {
    done: usize,
    invalid: usize,
    shed: usize,
    failed: usize,
    shutdown: usize,
    timeout: usize,
    unavailable: usize,
    slow: usize,
    lost: usize,
    /// Server-reported `e2e_s` per reply (comparable with in-process runs).
    e2e: Vec<f64>,
    /// Server-reported `queue_wait_s` per reply.
    queue_wait: Vec<f64>,
    /// Client-observed dispatch→response round-trip (includes client-side
    /// connection queueing).
    rtt: Vec<f64>,
    /// Per-model outcome slices, indexed like the run's target list.
    models: Vec<ModelAgg>,
}

/// The `kind` discriminator from a typed-error reply body (the wire form
/// of [`ServeError`]'s variant name).
fn body_kind(body: &str) -> Option<String> {
    Json::parse(body)
        .ok()?
        .get("kind")
        .and_then(Json::as_str)
        .map(str::to_string)
}

fn classify_wire(tally: &mut WireTally, job: &WireJob, result: std::io::Result<(u16, String)>) {
    // Per-model ledger first: a 200 is this model's `done`, everything
    // else (any other status, any transport failure) its `failed` — so
    // each model's offered count reconciles exactly.
    {
        let agg = &mut tally.models[job.model];
        match &result {
            Ok((200, body)) => {
                agg.done += 1;
                if let Ok(j) = Json::parse(body) {
                    if let Some(e) = j.get("e2e_s").and_then(Json::as_f64) {
                        agg.e2e.push(e);
                    }
                }
            }
            _ => agg.failed += 1,
        }
    }
    match result {
        Ok((200, body)) => {
            tally.done += 1;
            tally.rtt.push(job.queued.elapsed().as_secs_f64());
            // The server reports its own per-request timings in the reply
            // body — the same quantities the in-process report measures, so
            // e2e/queue_wait stay comparable across transports.
            if let Ok(j) = Json::parse(&body) {
                if let Some(qw) = j.get("queue_wait_s").and_then(Json::as_f64) {
                    tally.queue_wait.push(qw);
                }
                if let Some(e) = j.get("e2e_s").and_then(Json::as_f64) {
                    tally.e2e.push(e);
                }
            }
        }
        Ok((400, _)) => tally.invalid += 1,
        Ok((429, _)) => tally.shed += 1,
        // Two distinct 503s, told apart by the body's error kind: the
        // breaker shedding (`unavailable`) vs. the drain path
        // (`shutting_down`). Same for 504: the server-side execution
        // watchdog (`execute_timeout`) vs. the front end's reply-timeout,
        // which is the wire twin of `slow`.
        Ok((503, body)) => {
            if body_kind(&body).as_deref() == Some("unavailable") {
                tally.unavailable += 1;
            } else {
                tally.shutdown += 1;
            }
        }
        Ok((504, body)) => {
            if body_kind(&body).as_deref() == Some("execute_timeout") {
                tally.timeout += 1;
            } else {
                tally.slow += 1;
            }
        }
        // 500 (BackendFailed / reply_lost) and anything unexpected.
        Ok((_, _)) => tally.failed += 1,
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            ) =>
        {
            tally.slow += 1
        }
        Err(_) => tally.lost += 1,
    }
}

/// `multi`-scenario target discovery: `GET /v1/models`, then weight the
/// listed models from `spec.model_weights` (every named model must exist;
/// unnamed models get no traffic) or, with no explicit weights, skew 80%
/// onto the pool's default model and split the rest evenly.
fn discover_models(target: &HttpTarget, url: &str, spec: &LoadSpec) -> Result<Vec<ModelTarget>> {
    let (code, body) = {
        let mut probe = HttpClient::connect(target, Duration::from_secs(10));
        probe
            .request("GET", "/v1/models", None)
            .map_err(|e| anyhow::anyhow!("model discovery at {url} failed: {e}"))?
    };
    anyhow::ensure!(code == 200, "/v1/models at {url} returned {code}: {body}");
    let j = Json::parse(&body)
        .map_err(|e| anyhow::anyhow!("/v1/models at {url} returned non-JSON: {e}"))?;
    let default = j.get("default").and_then(Json::as_str).unwrap_or("").to_string();
    let listed = j
        .get("models")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("/v1/models response lacks a models array: {body}"))?;
    let mut targets = Vec::new();
    for m in listed {
        let name = m
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("model row lacks a name: {body}"))?
            .to_string();
        let img = m
            .get("image_elems")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("model {name:?} row lacks image_elems"))?;
        targets.push(ModelTarget {
            path: format!("/v1/models/{name}/infer"),
            name,
            img,
            weight: 0.0,
        });
    }
    anyhow::ensure!(!targets.is_empty(), "the pool at {url} serves no models");
    if spec.model_weights.is_empty() {
        let di = targets.iter().position(|t| t.name == default).unwrap_or(0);
        let rest = (targets.len() - 1) as f64;
        for (i, t) in targets.iter_mut().enumerate() {
            t.weight = if i == di {
                if rest > 0.0 { 0.8 } else { 1.0 }
            } else {
                0.2 / rest
            };
        }
    } else {
        for (name, w) in &spec.model_weights {
            let t = targets.iter_mut().find(|t| &t.name == name).ok_or_else(|| {
                anyhow::anyhow!(
                    "--models names {name:?}, which the pool does not serve \
                     (it serves: {})",
                    targets.iter().map(|t| t.name.as_str()).collect::<Vec<_>>().join(", ")
                )
            })?;
            t.weight = *w;
        }
        targets.retain(|t| t.weight > 0.0);
    }
    Ok(targets)
}

/// Drive a remote `ilmpq serve --listen` front end at `url` with the same
/// open-loop Poisson workload as [`run`], over `conns` keep-alive client
/// connections. Returns the client-side report plus the server's final
/// `/v1/metrics` snapshot (`Json::Null` when unavailable) — occupancy and
/// shed rate in the report come from that snapshot, so they are cumulative
/// over the *server's* lifetime, not just this run.
///
/// Under [`Scenario::Multi`] the run discovers the pool's registry, fans
/// requests across per-model routes by weight, and reports per-model
/// outcome rows in [`LoadReport::models`].
pub fn run_remote(url: &str, spec: &LoadSpec, conns: usize) -> Result<(LoadReport, Json)> {
    let target = HttpTarget::parse(url)?;
    let targets: Vec<ModelTarget> = if spec.scenario == Scenario::Multi {
        discover_models(&target, url, spec)?
    } else {
        // Probe the front end: liveness + the model geometry to generate
        // for. Scoped so the probe's keep-alive connection closes before
        // the run — an idle connection pins one of the server's handler
        // threads.
        let (code, body) = {
            let mut probe = HttpClient::connect(&target, Duration::from_secs(10));
            probe
                .request("GET", "/v1/healthz", None)
                .map_err(|e| anyhow::anyhow!("healthz probe of {url} failed: {e}"))?
        };
        anyhow::ensure!(code == 200, "healthz at {url} returned {code}: {body}");
        let health = Json::parse(&body)
            .map_err(|e| anyhow::anyhow!("healthz at {url} returned non-JSON: {e}"))?;
        let img = health
            .get("image_elems")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("healthz response lacks image_elems: {body}"))?;
        vec![ModelTarget {
            name: String::new(),
            path: "/v1/infer".into(),
            img,
            weight: 1.0,
        }]
    };
    let n_models = targets.len();

    // Run-wide give-up deadline, the wire twin of `run`'s 60s drain: the
    // paced submission phase plus 60 seconds of collection.
    let submit_budget = if spec.rate.is_finite() && spec.rate > 0.0 {
        Duration::from_secs_f64(spec.requests as f64 / spec.rate)
    } else {
        Duration::ZERO
    };
    let t0 = Instant::now();
    let deadline = t0 + submit_budget + Duration::from_secs(60);

    // Bounded dispatch queue: at full-size images a serialized body is
    // megabytes, so an unbounded backlog under a saturating rate would
    // buffer itself in client memory. The bound is denominated in *bytes*
    // (a job-count bound alone still admits gigabytes at real ResNet
    // geometry), with the channel capacity as a secondary count cap.
    // Overflowing jobs are counted like uncollected replies (`slow`) —
    // the server-side analogue is `queue_depth` shedding.
    const MAX_BACKLOG_BYTES: usize = 64 * 1024 * 1024;
    let (tx, rx) = sync_channel::<WireJob>(conns.max(1) * 64);
    let rx = Arc::new(Mutex::new(rx));
    let backlog_bytes = Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let mut overflow = 0usize;
    let encoding = spec.encoding;
    let workers: Vec<_> = (0..conns.max(1))
        .map(|_| {
            let rx = rx.clone();
            let target = target.clone();
            let backlog_bytes = backlog_bytes.clone();
            std::thread::spawn(move || {
                let mut client = HttpClient::connect(&target, Duration::from_secs(30));
                let mut tally = WireTally {
                    models: vec![ModelAgg::default(); n_models],
                    ..Default::default()
                };
                loop {
                    let job = {
                        let rx = rx.plock();
                        rx.recv()
                    };
                    let Ok(job) = job else { break };
                    backlog_bytes
                        .fetch_sub(job.body.len(), std::sync::atomic::Ordering::Relaxed);
                    if Instant::now() >= deadline {
                        // Wedged or saturated server: stop burning sockets,
                        // count the backlog the same way `run` counts
                        // uncollected replies.
                        tally.slow += 1;
                        tally.models[job.model].failed += 1;
                        continue;
                    }
                    let result = client.request_bytes(
                        "POST",
                        &job.path,
                        &job.body,
                        encoding.content_type(),
                    );
                    classify_wire(&mut tally, &job, result);
                }
                tally
            })
        })
        .collect();

    // Open-loop submission: Poisson arrivals, images from the same
    // generator (and RNG stream) as the in-process `run`. The model mixer
    // draws from its *own* RNG stream, so a multi run's image/arrival
    // sequence stays identical to a single-model run at the same seed.
    let mut rng = Rng::new(spec.seed);
    let mut pick_rng = Rng::new(spec.seed ^ 0x706f_6f6c);
    let total_weight: f64 = targets.iter().map(|t| t.weight).sum();
    let mut offered = vec![0usize; n_models];
    let mut overflow_by_model = vec![0usize; n_models];
    for _ in 0..spec.requests {
        let ti = if n_models == 1 {
            0
        } else {
            // Cumulative-weight pick; the final index catches the
            // floating-point remainder.
            let mut x = pick_rng.f64() * total_weight;
            let mut idx = n_models - 1;
            for (i, t) in targets.iter().enumerate() {
                if x < t.weight {
                    idx = i;
                    break;
                }
                x -= t.weight;
            }
            idx
        };
        offered[ti] += 1;
        let image = gen_image(&mut rng, spec, targets[ti].img);
        let body = encode_image(spec.encoding, &image);
        // Non-blocking so the arrival process stays open-loop: a full
        // queue (by bytes or count) means delivery (bounded by `conns`)
        // fell this far behind the offered rate; drop the job client-side
        // rather than stall the Poisson clock or buffer without bound.
        let len = body.len();
        if backlog_bytes.load(std::sync::atomic::Ordering::Relaxed) + len
            > MAX_BACKLOG_BYTES
        {
            overflow += 1;
            overflow_by_model[ti] += 1;
        } else {
            backlog_bytes.fetch_add(len, std::sync::atomic::Ordering::Relaxed);
            let job = WireJob {
                body,
                queued: Instant::now(),
                path: targets[ti].path.clone(),
                model: ti,
            };
            match tx.try_send(job) {
                Ok(()) => {}
                Err(TrySendError::Full(_) | TrySendError::Disconnected(_)) => {
                    backlog_bytes.fetch_sub(len, std::sync::atomic::Ordering::Relaxed);
                    overflow += 1;
                    overflow_by_model[ti] += 1;
                }
            }
        }
        if let Some(gap) = inter_arrival(&mut rng, spec, t0) {
            std::thread::sleep(gap);
        }
    }
    let submit_s = t0.elapsed().as_secs_f64();
    drop(tx); // workers drain the queue and exit
    // Client-side overflow folds into `slow` (requests offered but never
    // delivered inside the run's budget).
    let mut t = WireTally {
        slow: overflow,
        models: vec![ModelAgg::default(); n_models],
        ..Default::default()
    };
    for w in workers {
        if let Ok(wt) = w.join() {
            t.done += wt.done;
            t.invalid += wt.invalid;
            t.shed += wt.shed;
            t.failed += wt.failed;
            t.shutdown += wt.shutdown;
            t.timeout += wt.timeout;
            t.unavailable += wt.unavailable;
            t.slow += wt.slow;
            t.lost += wt.lost;
            t.e2e.extend(wt.e2e);
            t.queue_wait.extend(wt.queue_wait);
            t.rtt.extend(wt.rtt);
            for (dst, src) in t.models.iter_mut().zip(wt.models) {
                dst.done += src.done;
                dst.failed += src.failed;
                dst.e2e.extend(src.e2e);
            }
        }
    }
    // Airtight accounting: anything offered but not classified — a
    // panicked worker's whole tally, jobs stranded in a dead channel —
    // surfaces as `lost` (the regression class) instead of silently
    // shrinking the totals under the sum-to-requests invariant the tests
    // and CI assert on.
    let accounted = t.done
        + t.invalid
        + t.shed
        + t.failed
        + t.shutdown
        + t.timeout
        + t.unavailable
        + t.slow
        + t.lost;
    t.lost += spec.requests.saturating_sub(accounted);
    let wall_s = t0.elapsed().as_secs_f64();

    // Final server-side snapshot for the occupancy / shed-rate columns
    // (fresh connection: the probe's was dropped before the run).
    let mut probe = HttpClient::connect(&target, Duration::from_secs(10));
    let metrics_json = match probe.request("GET", "/v1/metrics", None) {
        Ok((200, body)) => Json::parse(&body).unwrap_or(Json::Null),
        _ => Json::Null,
    };
    let m_f64 = |key: &str| metrics_json.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    let report = LoadReport {
        offered_rate: spec.rate,
        achieved_rate: spec.requests as f64 / submit_s.max(1e-9),
        requests: spec.requests,
        done: t.done,
        invalid: t.invalid,
        shed: t.shed,
        failed: t.failed,
        shutdown: t.shutdown,
        timeout: t.timeout,
        unavailable: t.unavailable,
        slow: t.slow,
        lost: t.lost,
        wall_s,
        goodput_rps: t.done as f64 / wall_s.max(1e-9),
        e2e: Summary::of(&t.e2e),
        queue_wait: Summary::of(&t.queue_wait),
        client_rtt: Summary::of(&t.rtt),
        occupancy: m_f64("occupancy"),
        shed_rate: m_f64("shed_rate"),
        models: if spec.scenario == Scenario::Multi {
            targets
                .iter()
                .enumerate()
                .map(|(i, mt)| ModelOutcome {
                    model: mt.name.clone(),
                    offered: offered[i],
                    done: t.models[i].done,
                    failed: t.models[i].failed + overflow_by_model[i],
                    e2e: Summary::of(&t.models[i].e2e),
                })
                .collect()
        } else {
            Vec::new()
        },
    };
    Ok((report, metrics_json))
}

/// The shared serving-stack construction recipe behind `ilmpq serve` and
/// `ilmpq loadgen`: the real artifact manifest + `create_serving` backend
/// when artifacts exist, else (or when `force_synth`) the synthetic
/// TinyResNet fixture, with the fallback logged under `log_prefix`. The
/// quantization config comes from one [`QuantSource`] on both paths —
/// plan file, named ratio, fresh derivation, or unquantized — and the
/// resolved plan rides back for `ServeConfig::plan` / `GET /v1/plan`.
///
/// The fallback triggers only when the manifest file is *absent* (no
/// `make artifacts` on this machine — the toolchain-only case). A manifest
/// that exists but fails to load is a broken deployment and propagates as
/// an error: silently serving the 16x16 toy model from behind a healthy
/// `/v1/healthz` would be far worse than refusing to start.
pub fn fixture_or_artifacts(
    backend_name: &str,
    source: &QuantSource,
    frozen: bool,
    threads: Option<usize>,
    seed: u64,
    force_synth: bool,
    log_prefix: &str,
) -> Result<(Manifest, Arc<dyn InferenceBackend>, Option<QuantPlan>)> {
    if force_synth {
        return synth_fixture_source(backend_name, source, threads, seed, frozen);
    }
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "[{log_prefix}] no artifact manifest in {dir:?}; \
             using the synthetic TinyResNet fixture"
        );
        return synth_fixture_source(backend_name, source, threads, seed, frozen);
    }
    let manifest = Manifest::load(&dir)?;
    let (be, plan) =
        backend::create_serving(backend_name, &manifest, source, frozen, threads)?;
    Ok((manifest, be, plan))
}

/// The synthetic serving plan: deterministic §II-C-shaped masks for the
/// synthetic TinyResNet at `ratio`, drawn on the same RNG stream as the
/// fixture's params — so `ilmpq plan derive --synthetic --seed S` produces
/// exactly the masks that `--synthetic` serving generates at seed S.
/// Returns the matching manifest and params alongside the plan.
pub fn synth_plan(
    name: &str,
    ratio: Ratio,
    seed: u64,
) -> (Manifest, Vec<HostTensor>, QuantPlan) {
    let mut rng = Rng::new(seed);
    let m = synth::serving_manifest();
    let params = synth::random_params(&m, &mut rng);
    let plan = synth_plan_masks(&m, name, ratio, seed, &mut rng);
    (m, params, plan)
}

/// The mask-drawing tail of [`synth_plan`]: must be called with an `rng`
/// that has already drawn the fixture params, so the params-before-masks
/// stream order (the invariant behind "`plan derive --synthetic`
/// reproduces `serve --synthetic`'s masks") lives in exactly one place.
fn synth_plan_masks(
    m: &Manifest,
    name: &str,
    ratio: Ratio,
    seed: u64,
    rng: &mut Rng,
) -> QuantPlan {
    let masks = synth::random_masks(m, ratio, rng);
    QuantPlan::from_mask_set(
        MaskSet { name: name.to_string(), layers: masks.layers },
        Provenance::Synthetic { seed, ratio: ratio.label() },
    )
    .with_model(&m.model_name)
}

/// Artifact-free serving fixture at the default 65:30:5 mix, plan
/// registered under `plan_name`. This is what lets the serving bench and
/// the smoke tests run on a machine with nothing but a Rust toolchain.
pub fn synth_fixture(
    backend_name: &str,
    plan_name: &str,
    threads: Option<usize>,
    seed: u64,
) -> Result<(Manifest, Arc<dyn InferenceBackend>, QuantPlan)> {
    let (m, be, plan) = synth_fixture_source(
        backend_name,
        &QuantSource::NamedRatio(plan_name.to_string()),
        threads,
        seed,
        true,
    )?;
    let plan = plan.context("a named source always resolves to a plan")?;
    Ok((m, be, plan))
}

/// The synthetic twin of [`backend::create_serving`]: build the fixture
/// manifest + params, resolve `source` against it (a named ratio *creates*
/// the deterministic synthetic plan under that name; a plan file loads and
/// validates against the fixture geometry), and construct the backend.
/// `frozen` reaches the registry builder unchanged, so incoherent
/// combinations (e.g. `qgemm` with `frozen = false`) fail here exactly as
/// on the artifacts path — `--synthetic` must not make `--no-frozen`
/// silently mean something else.
pub fn synth_fixture_source(
    backend_name: &str,
    source: &QuantSource,
    threads: Option<usize>,
    seed: u64,
    frozen: bool,
) -> Result<(Manifest, Arc<dyn InferenceBackend>, Option<QuantPlan>)> {
    let default_ratio = Ratio::new(65.0, 30.0, 5.0);
    // One draw site for the fixture's RNG stream (params first, masks
    // second) — every source variant shares it, so the PlanFile path's
    // params cannot desynchronize from the derive path's.
    let mut rng = Rng::new(seed);
    let mut m = synth::serving_manifest();
    let params = synth::random_params(&m, &mut rng);
    let plan = match source {
        QuantSource::NamedRatio(name) => {
            // A Table-I name gets its actual mix (so `--synthetic --ratio
            // ilmpq1` really serves 60:35:5); ad-hoc fixture names fall
            // back to the paper's 65:30:5 default.
            let ratio = ratio_by_name(name).unwrap_or(default_ratio);
            Some(synth_plan_masks(&m, name, ratio, seed, &mut rng))
        }
        QuantSource::Derived { ratio } => Some(synth_plan_masks(
            &m,
            &crate::quant::plan::derived_plan_name(*ratio),
            *ratio,
            seed,
            &mut rng,
        )),
        QuantSource::PlanFile(path) => {
            let plan = QuantPlan::load(path)?;
            plan.validate(&m).with_context(|| {
                format!("plan {path:?} does not fit the synthetic fixture")
            })?;
            Some(plan)
        }
        QuantSource::Unquantized => None,
    };
    // Register the plan's masks in the manifest table too, so named
    // re-resolution against the fixture manifest stays possible (and the
    // legacy table can never disagree with the plan being served).
    if let Some(p) = &plan {
        m.default_masks.insert(p.name.clone(), p.masks.clone());
    }
    let init = BackendInit {
        plan: plan.clone(),
        threads,
        frozen,
        ..BackendInit::new(m.clone(), params)
    };
    let be: Arc<dyn InferenceBackend> = Arc::from(backend::create(backend_name, &init)?);
    Ok((m, be, plan))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServeConfig;

    #[test]
    fn synth_fixture_registers_plan_and_builds_backend() {
        let (m, be, plan) = synth_fixture("qgemm", "lg", Some(1), 3).unwrap();
        assert!(m.default_masks.contains_key("lg"));
        assert_eq!(plan.name, "lg");
        assert_eq!(be.name(), "qgemm");
        plan.validate(&m).unwrap();
    }

    #[test]
    fn synthetic_named_table1_ratio_gets_its_actual_mix() {
        // `--synthetic --ratio ilmpq1` must serve 60:35:5, not silently
        // the 65:30:5 default under the wrong name.
        let (_m, _be, plan) = synth_fixture("qgemm", "ilmpq1", Some(1), 9).unwrap();
        match &plan.provenance {
            Provenance::Synthetic { ratio, .. } => assert_eq!(ratio, "60:35:5"),
            other => panic!("expected synthetic provenance, got {other:?}"),
        }
        let (p, _f4, _f8) = plan.total_fractions();
        assert!((p - 0.60).abs() < 0.1, "pot fraction {p} should track 60%");
    }

    #[test]
    fn derived_source_builds_a_synthetic_plan_at_the_ratio() {
        let (m, be, plan) = synth_fixture_source(
            "qgemm",
            &QuantSource::Derived { ratio: Ratio::new(50.0, 45.0, 5.0) },
            Some(1),
            13,
            true,
        )
        .unwrap();
        let plan = plan.expect("derived source yields a plan");
        assert_eq!(be.name(), "qgemm");
        assert_eq!(plan.name, "derived-50:45:5");
        plan.validate(&m).unwrap();
        // The fixture's assignment policy honors the requested mix (rounded
        // per layer) and records it as synthetic provenance.
        let (p, _f4, f8) = plan.total_fractions();
        assert!((p - 0.5).abs() < 0.15, "pot fraction {p}");
        assert!(f8 > 0.0, "fixed8 rescue rows present");
        assert!(matches!(plan.provenance, Provenance::Synthetic { seed: 13, .. }));
    }

    #[test]
    fn loadgen_drains_and_classifies_every_reply() {
        let (m, be, plan) = synth_fixture("qgemm", "lg", Some(2), 7).unwrap();
        let cfg = ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            plan: Some(plan),
            ..Default::default()
        };
        let server = Server::start(&m, be, cfg).unwrap();
        let spec = LoadSpec {
            requests: 24,
            rate: 0.0, // unpaced
            malformed_frac: 0.5,
            seed: 11,
            ..Default::default()
        };
        let (r, metrics) = run(server, &m, &spec);
        assert_eq!(r.lost, 0, "typed pipeline must answer every request");
        assert_eq!(r.slow, 0, "tiny run must drain inside the deadline");
        assert_eq!(
            r.done + r.invalid + r.shed + r.failed + r.shutdown + r.timeout + r.unavailable,
            r.requests
        );
        assert_eq!(Metrics::get(&metrics.requests_done), r.done as u64);
        assert!(r.done > 0);
        assert!(r.invalid > 0, "malformed_frac must produce rejections");
        assert!(r.goodput_rps > 0.0);
    }

    #[test]
    fn report_renders_and_serializes() {
        let r = LoadReport {
            offered_rate: 100.0,
            achieved_rate: 92.0,
            requests: 10,
            done: 8,
            invalid: 1,
            shed: 1,
            failed: 0,
            shutdown: 0,
            timeout: 0,
            unavailable: 0,
            slow: 0,
            lost: 0,
            wall_s: 0.5,
            goodput_rps: 16.0,
            e2e: Summary::of(&[0.001, 0.002]),
            queue_wait: Summary::of(&[0.0005]),
            client_rtt: Summary::of(&[]),
            occupancy: 0.75,
            shed_rate: 0.1,
            models: vec![],
        };
        let text = r.render();
        assert!(text.contains("done=8") && text.contains("shed rate"));
        assert!(text.contains("timeout=0") && text.contains("unavailable=0"));
        // Empty client_rtt (in-process run) stays out of the render...
        assert!(!text.contains("client_rtt"));
        let j = r.to_json();
        assert!(j.get("e2e").is_some() && j.get("shed_rate").is_some());
        // ...but is always present (as zeros) in the JSON schema.
        assert!(j.get("client_rtt").is_some());
        assert_eq!(j.get("done").and_then(|v| v.as_f64()), Some(8.0));
        assert_eq!(j.get("timeout").and_then(|v| v.as_f64()), Some(0.0));
        assert_eq!(j.get("unavailable").and_then(|v| v.as_f64()), Some(0.0));
    }

    #[test]
    fn scenario_parses_and_rejects_unknown() {
        assert_eq!(Scenario::parse("steady").unwrap(), Scenario::Steady);
        assert_eq!(Scenario::parse("burst").unwrap(), Scenario::Burst);
        assert_eq!(Scenario::parse("chaos").unwrap(), Scenario::Chaos);
        assert_eq!(Scenario::parse("multi").unwrap(), Scenario::Multi);
        assert_eq!(Scenario::parse("chaos").unwrap().name(), "chaos");
        assert_eq!(Scenario::parse("multi").unwrap().name(), "multi");
        assert!(Scenario::parse("storm").is_err());
    }

    #[test]
    fn model_weights_parse_and_reject_garbage() {
        let w = parse_model_weights("tiny:4, narrow:1").unwrap();
        assert_eq!(w.len(), 2);
        assert_eq!(w[0], ("tiny".to_string(), 4.0));
        assert_eq!(w[1], ("narrow".to_string(), 1.0));
        assert!(parse_model_weights("").is_err(), "empty spec");
        assert!(parse_model_weights("tiny").is_err(), "no weight");
        assert!(parse_model_weights("tiny:x").is_err(), "non-numeric");
        assert!(parse_model_weights("tiny:0").is_err(), "zero weight");
        assert!(parse_model_weights("tiny:-1").is_err(), "negative weight");
        assert!(parse_model_weights(":1").is_err(), "empty name");
        assert!(parse_model_weights("a:1,a:2").is_err(), "duplicate name");
    }

    #[test]
    fn multi_report_carries_per_model_rows() {
        let base = LoadReport {
            offered_rate: 100.0,
            achieved_rate: 92.0,
            requests: 10,
            done: 8,
            invalid: 0,
            shed: 0,
            failed: 2,
            shutdown: 0,
            timeout: 0,
            unavailable: 0,
            slow: 0,
            lost: 0,
            wall_s: 0.5,
            goodput_rps: 16.0,
            e2e: Summary::of(&[0.001, 0.002]),
            queue_wait: Summary::of(&[0.0005]),
            client_rtt: Summary::of(&[0.003]),
            occupancy: 0.75,
            shed_rate: 0.0,
            models: vec![
                ModelOutcome {
                    model: "tiny".into(),
                    offered: 8,
                    done: 7,
                    failed: 1,
                    e2e: Summary::of(&[0.001]),
                },
                ModelOutcome {
                    model: "narrow".into(),
                    offered: 2,
                    done: 1,
                    failed: 1,
                    e2e: Summary::of(&[0.002]),
                },
            ],
        };
        let text = base.render();
        assert!(text.contains("model tiny: offered=8 done=7 failed=1"));
        assert!(text.contains("model narrow: offered=2"));
        let j = base.to_json();
        let rows = j.get("models").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("model").and_then(|v| v.as_str()), Some("tiny"));
        assert_eq!(rows[0].get("offered").and_then(|v| v.as_f64()), Some(8.0));
        // The per-model ledger reconciles: offered == done + failed.
        for r in rows {
            let offered = r.get("offered").and_then(|v| v.as_f64()).unwrap();
            let done = r.get("done").and_then(|v| v.as_f64()).unwrap();
            let failed = r.get("failed").and_then(|v| v.as_f64()).unwrap();
            assert_eq!(offered, done + failed);
        }
    }

    #[test]
    fn encode_image_covers_both_wire_encodings() {
        let image = [1.5f32, -0.25, f32::MIN_POSITIVE, 3.0e7];
        // JSON: the classic object, parseable back to the same values
        // (shortest-decimal f32→f64 round-trips are bit-exact).
        let json = encode_image(Encoding::Json, &image);
        let j = Json::parse(std::str::from_utf8(&json).unwrap()).unwrap();
        let arr = j.get("image").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), image.len());
        for (v, x) in arr.iter().zip(image) {
            assert_eq!(v.as_f64().map(|f| f as f32), Some(x));
        }
        // Raw: 4 bytes per element, decoding back bit-exactly.
        let raw = encode_image(Encoding::Raw, &image);
        assert_eq!(raw.len(), image.len() * 4);
        let back: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(back, image);
    }

    #[test]
    fn poison_frac_plants_the_sentinel_in_well_formed_images_only() {
        let spec = LoadSpec { poison_frac: 1.0, ..Default::default() };
        let mut rng = Rng::new(5);
        let image = gen_image(&mut rng, &spec, 16);
        assert_eq!(image.len(), 16, "poisoned images stay well-formed");
        assert_eq!(image[0], backend::POISON_MAGIC);
        assert!(image[0].is_finite(), "the sentinel must pass admission");
        // Malformed wins over poison: a wrong-length image never carries
        // the sentinel (it bounces at admission before any backend).
        let spec = LoadSpec { poison_frac: 1.0, malformed_frac: 1.0, ..Default::default() };
        let image = gen_image(&mut rng, &spec, 16);
        assert_eq!(image.len(), 17);
        assert_ne!(image[0], backend::POISON_MAGIC);
    }

    #[test]
    fn burst_pacing_draws_one_rng_value_per_request() {
        // The burst clock must not desynchronize the image stream: for the
        // same seed, steady and burst specs generate identical images.
        let steady = LoadSpec { scenario: Scenario::Steady, ..Default::default() };
        let burst = LoadSpec { scenario: Scenario::Burst, ..Default::default() };
        let t0 = Instant::now();
        let (mut r1, mut r2) = (Rng::new(9), Rng::new(9));
        for _ in 0..8 {
            let a = gen_image(&mut r1, &steady, 12);
            let _ = inter_arrival(&mut r1, &steady, t0);
            let b = gen_image(&mut r2, &burst, 12);
            let _ = inter_arrival(&mut r2, &burst, t0);
            assert_eq!(a, b);
        }
        // An off-phase burst gap waits at least to the next period edge.
        let spec = LoadSpec { rate: 1000.0, scenario: Scenario::Burst, ..Default::default() };
        let mut rng = Rng::new(1);
        let shifted = t0 - Duration::from_millis(300); // 300ms into a period
        let gap = inter_arrival(&mut rng, &spec, shifted).unwrap();
        assert!(gap >= Duration::from_millis(150), "off-phase gap {gap:?}");
    }
}
