//! Open-loop load generator for the serving admission pipeline.
//!
//! Drives a running [`Server`] with Poisson arrivals at a configured
//! offered load, collects every typed reply, and folds the server metrics
//! into one [`LoadReport`] (p50/p99 end-to-end latency, batch occupancy,
//! shed rate, goodput). Shared by the `ilmpq loadgen` subcommand and
//! `benches/serving.rs` so both report identical numbers for identical
//! workloads.
//!
//! The generator is *open-loop*: arrivals do not wait for replies, so an
//! offered load beyond the backend's capacity exercises the queue bound —
//! the shed rate is the interesting output, not an error. A configurable
//! fraction of deliberately malformed requests exercises the admission
//! validator the same way.
//!
//! [`synth_fixture`] builds an artifact-free serving stack (synthetic
//! TinyResNet manifest + registry backend), so the whole pipeline runs
//! end-to-end on a toolchain-only machine: no `make artifacts`, no PJRT,
//! `--no-default-features` is enough.

use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::metrics::Metrics;
use super::server::{ServeError, Server};
use crate::backend::{self, synth, BackendInit, InferenceBackend};
use crate::quant::Ratio;
use crate::runtime::Manifest;
use crate::util::stats::Summary;
use crate::util::{Json, Rng};

/// Workload knobs for one load-generation run.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Total requests to offer.
    pub requests: usize,
    /// Offered load in requests/second (Poisson inter-arrivals). Zero or
    /// non-finite disables pacing (submit as fast as possible).
    pub rate: f64,
    /// Fraction of requests submitted with a deliberately malformed length,
    /// to exercise admission rejection (0.0 for a clean run).
    pub malformed_frac: f64,
    /// RNG seed for arrivals + images.
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec { requests: 512, rate: 2000.0, malformed_frac: 0.0, seed: 42 }
    }
}

/// Outcome of one run: client-observed reply counts + server-side
/// latency/occupancy summaries.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// The nominal rate the spec asked for.
    pub offered_rate: f64,
    /// The rate actually achieved during the submission phase (requests /
    /// submission elapsed). Sleep overshoot and per-request generation cost
    /// make this fall short of nominal at high rates — plot against this
    /// axis, not the nominal one.
    pub achieved_rate: f64,
    pub requests: usize,
    /// Replies answered with logits.
    pub done: usize,
    /// `InvalidInput` rejections (admission validation).
    pub invalid: usize,
    /// `QueueFull` sheds (admission bound).
    pub shed: usize,
    /// `BackendFailed` replies.
    pub failed: usize,
    /// `ShuttingDown` replies.
    pub shutdown: usize,
    /// Replies not collected within the run-wide 60s drain deadline (they
    /// may still arrive later): a saturated or very slow backend, not a
    /// protocol regression.
    pub slow: usize,
    /// Reply channels closed without an answer — always 0 with the
    /// typed-error pipeline; counted so a dropped-reply regression is
    /// visible.
    pub lost: usize,
    pub wall_s: f64,
    /// Completed requests per wall-clock second.
    pub goodput_rps: f64,
    pub e2e: Summary,
    pub queue_wait: Summary,
    pub occupancy: f64,
    pub shed_rate: f64,
}

/// Drive `server` with `spec` and stop it when the run drains. `manifest`
/// supplies the image geometry for the generated workload. Returns the
/// client-side report plus the server's metrics handle (for consumers that
/// also want the full `Metrics::report()`).
pub fn run(
    server: Server,
    manifest: &Manifest,
    spec: &LoadSpec,
) -> (LoadReport, Arc<Metrics>) {
    let img = manifest.data.image_elems();
    let mut rng = Rng::new(spec.seed);
    let pace = spec.rate.is_finite() && spec.rate > 0.0;
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(spec.requests);
    for _ in 0..spec.requests {
        let malformed = spec.malformed_frac > 0.0 && rng.bool(spec.malformed_frac);
        // A wrong-length image must bounce off admission, never a batch;
        // `img + 1` is malformed for every geometry (a halved length would
        // collide with `img` itself when image_elems <= 2).
        let len = if malformed { img + 1 } else { img };
        let mut image = vec![0f32; len];
        rng.fill_normal(&mut image, 1.0);
        pending.push(server.submit(image));
        if pace {
            std::thread::sleep(Duration::from_secs_f64(rng.exp(spec.rate)));
        }
    }
    let submit_s = t0.elapsed().as_secs_f64();
    let (mut done, mut invalid, mut shed, mut failed, mut shutdown) =
        (0usize, 0usize, 0usize, 0usize, 0usize);
    let (mut slow, mut lost) = (0usize, 0usize);
    // One run-wide drain deadline (not per-request): a wedged server costs
    // ~60s total instead of 60s x requests, and the slow/lost counts still
    // get reported rather than an opaque external kill.
    let deadline = Instant::now() + Duration::from_secs(60);
    for rx in pending {
        let remaining = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(remaining) {
            Ok(Ok(_)) => done += 1,
            Ok(Err(ServeError::InvalidInput(_))) => invalid += 1,
            Ok(Err(ServeError::QueueFull { .. })) => shed += 1,
            Ok(Err(ServeError::BackendFailed(_))) => failed += 1,
            Ok(Err(ServeError::ShuttingDown)) => shutdown += 1,
            // Slow is a capacity symptom; only a *closed* channel is the
            // dropped-reply regression the pipeline promises never happens.
            Err(RecvTimeoutError::Timeout) => slow += 1,
            Err(RecvTimeoutError::Disconnected) => lost += 1,
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let metrics = server.stop();
    let report = LoadReport {
        offered_rate: spec.rate,
        achieved_rate: spec.requests as f64 / submit_s.max(1e-9),
        requests: spec.requests,
        done,
        invalid,
        shed,
        failed,
        shutdown,
        slow,
        lost,
        wall_s,
        goodput_rps: done as f64 / wall_s.max(1e-9),
        e2e: metrics.e2e.summary(),
        queue_wait: metrics.queue_wait.summary(),
        occupancy: metrics.batch_occupancy(),
        shed_rate: metrics.shed_rate(),
    };
    (report, metrics)
}

fn summary_json(s: &Summary) -> Json {
    Json::obj(vec![
        ("n", Json::Num(s.n as f64)),
        ("mean_s", Json::Num(s.mean)),
        ("p50_s", Json::Num(s.p50)),
        ("p95_s", Json::Num(s.p95)),
        ("p99_s", Json::Num(s.p99)),
    ])
}

impl LoadReport {
    /// Human-readable multi-line report for the CLI.
    pub fn render(&self) -> String {
        format!(
            "offered {:.0} req/s (achieved {:.0}), {} requests in {:.2}s\n\
             outcomes: done={} invalid={} shed={} failed={} shutdown={} slow={} lost={}\n\
             goodput {:.0} req/s, occupancy {:.1}%, shed rate {:.1}%\n\
             e2e:        {}\nqueue_wait: {}",
            self.offered_rate,
            self.achieved_rate,
            self.requests,
            self.wall_s,
            self.done,
            self.invalid,
            self.shed,
            self.failed,
            self.shutdown,
            self.slow,
            self.lost,
            self.goodput_rps,
            self.occupancy * 100.0,
            self.shed_rate * 100.0,
            self.e2e,
            self.queue_wait,
        )
    }

    /// Machine-readable form, one point of `BENCH_serving.json`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offered_rate_rps", Json::Num(self.offered_rate)),
            ("achieved_rate_rps", Json::Num(self.achieved_rate)),
            ("requests", Json::Num(self.requests as f64)),
            ("done", Json::Num(self.done as f64)),
            ("invalid", Json::Num(self.invalid as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("shutdown", Json::Num(self.shutdown as f64)),
            ("slow", Json::Num(self.slow as f64)),
            ("lost", Json::Num(self.lost as f64)),
            ("wall_s", Json::Num(self.wall_s)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("occupancy", Json::Num(self.occupancy)),
            ("shed_rate", Json::Num(self.shed_rate)),
            ("e2e", summary_json(&self.e2e)),
            ("queue_wait", summary_json(&self.queue_wait)),
        ])
    }
}

/// Artifact-free serving fixture: the synthetic TinyResNet manifest with a
/// mixed mask set registered under `ratio_name`, plus a registry-built
/// backend over it. This is what lets `ilmpq loadgen` and the serving bench
/// run on a machine with nothing but a Rust toolchain.
pub fn synth_fixture(
    backend_name: &str,
    ratio_name: &str,
    threads: Option<usize>,
    seed: u64,
) -> Result<(Manifest, Arc<dyn InferenceBackend>)> {
    let mut rng = Rng::new(seed);
    let mut m = synth::tiny_manifest(16, 16, 3, &[8, 16], 10);
    let params = synth::random_params(&m, &mut rng);
    let masks = synth::random_masks(&m, Ratio::new(65.0, 30.0, 5.0), &mut rng);
    m.default_masks.insert(ratio_name.to_string(), masks.clone());
    let init = BackendInit {
        masks: Some(masks),
        threads,
        ..BackendInit::new(m.clone(), params)
    };
    let be: Arc<dyn InferenceBackend> = Arc::from(backend::create(backend_name, &init)?);
    Ok((m, be))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServeConfig;

    #[test]
    fn synth_fixture_registers_ratio_and_builds_backend() {
        let (m, be) = synth_fixture("qgemm", "lg", Some(1), 3).unwrap();
        assert!(m.default_masks.contains_key("lg"));
        assert_eq!(be.name(), "qgemm");
    }

    #[test]
    fn loadgen_drains_and_classifies_every_reply() {
        let (m, be) = synth_fixture("qgemm", "lg", Some(2), 7).unwrap();
        let cfg = ServeConfig {
            workers: 1,
            max_wait: Duration::from_millis(1),
            ratio_name: "lg".into(),
            ..Default::default()
        };
        let server = Server::start(&m, be, cfg).unwrap();
        let spec = LoadSpec {
            requests: 24,
            rate: 0.0, // unpaced
            malformed_frac: 0.5,
            seed: 11,
        };
        let (r, metrics) = run(server, &m, &spec);
        assert_eq!(r.lost, 0, "typed pipeline must answer every request");
        assert_eq!(r.slow, 0, "tiny run must drain inside the deadline");
        assert_eq!(
            r.done + r.invalid + r.shed + r.failed + r.shutdown,
            r.requests
        );
        assert_eq!(Metrics::get(&metrics.requests_done), r.done as u64);
        assert!(r.done > 0);
        assert!(r.invalid > 0, "malformed_frac must produce rejections");
        assert!(r.goodput_rps > 0.0);
    }

    #[test]
    fn report_renders_and_serializes() {
        let r = LoadReport {
            offered_rate: 100.0,
            achieved_rate: 92.0,
            requests: 10,
            done: 8,
            invalid: 1,
            shed: 1,
            failed: 0,
            shutdown: 0,
            slow: 0,
            lost: 0,
            wall_s: 0.5,
            goodput_rps: 16.0,
            e2e: Summary::of(&[0.001, 0.002]),
            queue_wait: Summary::of(&[0.0005]),
            occupancy: 0.75,
            shed_rate: 0.1,
        };
        let text = r.render();
        assert!(text.contains("done=8") && text.contains("shed rate"));
        let j = r.to_json();
        assert!(j.get("e2e").is_some() && j.get("shed_rate").is_some());
        assert_eq!(j.get("done").and_then(|v| v.as_f64()), Some(8.0));
    }
}
