//! Serving metrics: counters + latency recorder with percentile snapshots.
//!
//! Thread-safe (shared via `Arc`); the server threads record, the metrics
//! endpoint/bench snapshots. Latencies are kept as raw samples (bounded
//! ring) — with the request volumes here that is cheaper and more exact
//! than HDR buckets.
//!
//! Counters mirror the admission + execution pipeline's outcomes
//! one-to-one: every submission lands in exactly one of `done`, `invalid`,
//! `shed`, `failed`, `shutdown`, `timeout`, `unavailable`, or `quarantined`
//! (the typed [`crate::coordinator::ServeError`] variants), so
//! `in == done + invalid + shed + failed + shutdown + timeout + unavailable
//! + quarantined` once a run drains. `recovered` is informational — a
//! subset of `done` (requests answered by a singleton retry after their
//! batch failed), never part of the sum.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Summary;
use crate::util::sync::LockExt;
use crate::util::Json;

const MAX_SAMPLES: usize = 65_536;

/// One named latency track (e.g. queue wait, execute, end-to-end).
///
/// Bounded window: once `MAX_SAMPLES` samples accumulate, the oldest half
/// is dropped, so a long-running server's percentiles describe *recent*
/// behaviour, not all-time. The drops are counted (`samples_dropped`) and
/// surfaced in [`LatencyTrack::to_json`] so a snapshot can't silently pose
/// as an all-time summary.
#[derive(Default)]
pub struct LatencyTrack {
    samples: Mutex<Vec<f64>>,
    dropped: AtomicU64,
}

impl LatencyTrack {
    pub fn record(&self, seconds: f64) {
        let mut s = self.samples.plock();
        if s.len() >= MAX_SAMPLES {
            // Drop oldest half — keeps recent behaviour without unbounded RAM.
            let keep = s.split_off(MAX_SAMPLES / 2);
            self.dropped.fetch_add((MAX_SAMPLES / 2) as u64, Ordering::Relaxed);
            *s = keep;
        }
        s.push(seconds);
    }

    pub fn summary(&self) -> Summary {
        // Snapshot under the lock (one memcpy), summarize outside it: the
        // sort in `Summary::of` must not block the request-path `record`.
        let snap = self.samples.plock().clone();
        Summary::of(&snap)
    }

    pub fn count(&self) -> usize {
        self.samples.plock().len()
    }

    /// Samples discarded by the bounded window since startup. Zero until a
    /// track has seen more than `MAX_SAMPLES` recordings.
    pub fn samples_dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Summary JSON plus the window semantics: `window` is the sample bound
    /// and `samples_dropped` how many older samples fell out of it, so
    /// consumers can tell a true all-time summary (`samples_dropped == 0`)
    /// from a recent-window one.
    pub fn to_json(&self) -> Json {
        match self.summary().to_json() {
            Json::Obj(mut fields) => {
                fields.insert("window".into(), Json::Num(MAX_SAMPLES as f64));
                fields.insert(
                    "samples_dropped".into(),
                    Json::Num(self.samples_dropped() as f64),
                );
                Json::Obj(fields)
            }
            other => other,
        }
    }
}

/// All serving-side metrics.
#[derive(Default)]
pub struct Metrics {
    /// Submission attempts (admitted or not).
    pub requests_in: AtomicU64,
    /// Requests answered with logits.
    pub requests_done: AtomicU64,
    /// Rejected at admission: malformed image (wrong length / non-finite).
    pub requests_invalid: AtomicU64,
    /// Shed at admission: the queue bound was hit (reject-newest).
    pub requests_shed: AtomicU64,
    /// Answered with `BackendFailed`: their batch errored on the backend
    /// (and, when retries are enabled, so did their isolated re-runs — but
    /// those land in `requests_quarantined` instead).
    pub requests_failed: AtomicU64,
    /// Answered with `ShuttingDown` at/after the stop cutoff.
    pub requests_shutdown: AtomicU64,
    /// Answered with `Timeout`: the execution watchdog abandoned their
    /// batch (and any singleton retries also ran out of deadline).
    pub requests_timeout: AtomicU64,
    /// Shed at admission with `Unavailable`: the circuit breaker was open
    /// and no fallback backend was configured.
    pub requests_unavailable: AtomicU64,
    /// Quarantined: the request's batch failed, and its isolated singleton
    /// retries failed too — the poison-request outcome class.
    pub requests_quarantined: AtomicU64,
    /// Subset of `requests_done`: answered by a singleton retry after the
    /// original batch failed (batch-mates of a poison/transient fault).
    pub requests_recovered: AtomicU64,
    /// Replies whose receiver was already gone when the server answered
    /// (client stopped waiting — loadgen drain deadline, HTTP reply
    /// timeout). Informational: the request is still counted in its outcome
    /// class; this makes the dropped delivery observable instead of silent.
    pub replies_unclaimed: AtomicU64,
    pub batches: AtomicU64,
    /// Batches whose backend execution errored (every member answered).
    pub batches_failed: AtomicU64,
    /// Batches abandoned by the execution watchdog (every member answered).
    pub batches_timeout: AtomicU64,
    /// Singleton retry executions after a failed batch.
    pub batch_retries: AtomicU64,
    /// Batches executed on the fallback backend (degraded mode).
    pub fallback_batches: AtomicU64,
    pub batched_requests: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Circuit-breaker state gauge: 0 = closed, 1 = open, 2 = half-open.
    pub breaker_state: AtomicU64,
    /// Closed → open transitions (including failed half-open probes).
    pub breaker_opened: AtomicU64,
    /// Open → half-open probe admissions.
    pub breaker_half_open: AtomicU64,
    /// Half-open → closed recoveries (successful probes).
    pub breaker_closed: AtomicU64,
    /// Router loop iterations — the idle-wakeup regression signal. A parked
    /// router (blocking on the submit channel, bounded by the batch
    /// deadline) registers ~0 while idle; the historic busy-poll loop
    /// registered thousands per second on an empty queue.
    pub router_wakeups: AtomicU64,
    pub queue_wait: LatencyTrack,
    /// Backend-measured execution time of *successful* batches only.
    pub execute: LatencyTrack,
    /// Host-observed time lost to failed batch executions — kept out of
    /// `execute` so its percentiles describe successes only.
    pub failed: LatencyTrack,
    pub e2e: LatencyTrack,
    /// Simulated FPGA time attached to each batch (codesign view).
    pub sim_fpga: LatencyTrack,
}

impl Metrics {
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(counter: &AtomicU64, v: u64) {
        counter.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(counter: &AtomicU64) -> u64 {
        counter.load(Ordering::Relaxed)
    }

    /// Mean occupancy of executed batches (useful slots / total slots).
    pub fn batch_occupancy(&self) -> f64 {
        let reqs = Self::get(&self.batched_requests) as f64;
        let padded = Self::get(&self.padded_slots) as f64;
        if reqs + padded == 0.0 {
            return 0.0;
        }
        reqs / (reqs + padded)
    }

    /// Fraction of submissions shed by the queue bound.
    pub fn shed_rate(&self) -> f64 {
        let total = Self::get(&self.requests_in) as f64;
        if total == 0.0 {
            return 0.0;
        }
        Self::get(&self.requests_shed) as f64 / total
    }

    /// Human-readable name of the breaker-state gauge.
    pub fn breaker_state_name(&self) -> &'static str {
        match Self::get(&self.breaker_state) {
            1 => "open",
            2 => "half-open",
            _ => "closed",
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests: in={} done={} invalid={} shed={} failed={} shutdown={} \
             timeout={} unavailable={} quarantined={} \
             (recovered={} replies_unclaimed={})\n\
             batches: {} ({} failed, {} timed out, {} retries, {} on fallback, \
             slots {}+{} pad = occupancy {:.1}%, shed rate {:.1}%, \
             {} router wakeups)\n\
             breaker: {} (opened={} half_open={} closed={})\n\
             queue_wait: {}\nexecute:    {}\nfailed:     {}\n\
             e2e:        {}\nsim_fpga:   {}",
            Self::get(&self.requests_in),
            Self::get(&self.requests_done),
            Self::get(&self.requests_invalid),
            Self::get(&self.requests_shed),
            Self::get(&self.requests_failed),
            Self::get(&self.requests_shutdown),
            Self::get(&self.requests_timeout),
            Self::get(&self.requests_unavailable),
            Self::get(&self.requests_quarantined),
            Self::get(&self.requests_recovered),
            Self::get(&self.replies_unclaimed),
            Self::get(&self.batches),
            Self::get(&self.batches_failed),
            Self::get(&self.batches_timeout),
            Self::get(&self.batch_retries),
            Self::get(&self.fallback_batches),
            Self::get(&self.batched_requests),
            Self::get(&self.padded_slots),
            self.batch_occupancy() * 100.0,
            self.shed_rate() * 100.0,
            Self::get(&self.router_wakeups),
            self.breaker_state_name(),
            Self::get(&self.breaker_opened),
            Self::get(&self.breaker_half_open),
            Self::get(&self.breaker_closed),
            self.queue_wait.summary(),
            self.execute.summary(),
            self.failed.summary(),
            self.e2e.summary(),
            self.sim_fpga.summary(),
        )
    }

    /// Machine-readable snapshot: every counter, the derived rates, and the
    /// latency summaries. This is the body of the HTTP `GET /v1/metrics`
    /// endpoint, so the remote load generator folds the same numbers into
    /// its report as the in-process one. Latency tracks carry their window
    /// semantics (`window`, `samples_dropped`) alongside the summary.
    pub fn to_json(&self) -> Json {
        let num = |c: &AtomicU64| Json::Num(Self::get(c) as f64);
        Json::obj(vec![
            ("requests_in", num(&self.requests_in)),
            ("requests_done", num(&self.requests_done)),
            ("requests_invalid", num(&self.requests_invalid)),
            ("requests_shed", num(&self.requests_shed)),
            ("requests_failed", num(&self.requests_failed)),
            ("requests_shutdown", num(&self.requests_shutdown)),
            ("requests_timeout", num(&self.requests_timeout)),
            ("requests_unavailable", num(&self.requests_unavailable)),
            ("requests_quarantined", num(&self.requests_quarantined)),
            ("requests_recovered", num(&self.requests_recovered)),
            ("replies_unclaimed", num(&self.replies_unclaimed)),
            ("batches", num(&self.batches)),
            ("batches_failed", num(&self.batches_failed)),
            ("batches_timeout", num(&self.batches_timeout)),
            ("batch_retries", num(&self.batch_retries)),
            ("fallback_batches", num(&self.fallback_batches)),
            ("batched_requests", num(&self.batched_requests)),
            ("padded_slots", num(&self.padded_slots)),
            ("breaker_state", Json::Str(self.breaker_state_name().into())),
            ("breaker_opened", num(&self.breaker_opened)),
            ("breaker_half_open", num(&self.breaker_half_open)),
            ("breaker_closed", num(&self.breaker_closed)),
            ("router_wakeups", num(&self.router_wakeups)),
            ("occupancy", Json::Num(self.batch_occupancy())),
            ("shed_rate", Json::Num(self.shed_rate())),
            ("queue_wait", self.queue_wait.to_json()),
            ("execute", self.execute.to_json()),
            ("failed", self.failed.to_json()),
            ("e2e", self.e2e.to_json()),
            ("sim_fpga", self.sim_fpga.to_json()),
        ])
    }

    /// Ledger invariant audit — the runtime twin of the `ilmpq analyze`
    /// static rules. Valid at any *drained* boundary (a stopped server, a
    /// shut-down pool): every admitted request must have landed in exactly
    /// one outcome class, and derived/transition counters must balance.
    ///
    /// Checks:
    /// - outcome classes sum to `requests_in` (answer-exactly-once ledger);
    /// - `requests_recovered ⊆ requests_done`;
    /// - per-batch failure classes don't exceed `batches`;
    /// - breaker transitions balance: probes need a prior open
    ///   (`half_open ≤ opened`) and recoveries a prior probe
    ///   (`closed ≤ half_open`).
    ///
    /// [`super::Server::stop`] runs this under `debug_assertions` on every
    /// drained stop, so each `cargo test` run audits every server it
    /// stops; tests also call it explicitly so release-mode CI checks too.
    pub fn audit(&self) -> Result<(), String> {
        let g = Self::get;
        let outcomes = [
            ("requests_done", g(&self.requests_done)),
            ("requests_invalid", g(&self.requests_invalid)),
            ("requests_shed", g(&self.requests_shed)),
            ("requests_failed", g(&self.requests_failed)),
            ("requests_shutdown", g(&self.requests_shutdown)),
            ("requests_timeout", g(&self.requests_timeout)),
            ("requests_unavailable", g(&self.requests_unavailable)),
            ("requests_quarantined", g(&self.requests_quarantined)),
        ];
        let answered: u64 = outcomes.iter().map(|(_, v)| v).sum();
        let admitted = g(&self.requests_in);
        if answered != admitted {
            let detail: Vec<String> =
                outcomes.iter().map(|(k, v)| format!("{k}={v}")).collect();
            return Err(format!(
                "outcome classes sum to {answered} but requests_in={admitted} \
                 ({}) — a request was dropped or double-answered",
                detail.join(" ")
            ));
        }
        if g(&self.requests_recovered) > g(&self.requests_done) {
            return Err(format!(
                "requests_recovered={} exceeds requests_done={} — recovered is \
                 a subset of done by definition",
                g(&self.requests_recovered),
                g(&self.requests_done)
            ));
        }
        if g(&self.batches_failed) + g(&self.batches_timeout) > g(&self.batches) {
            return Err(format!(
                "batches_failed={} + batches_timeout={} exceeds batches={} — \
                 each batch fails in at most one way",
                g(&self.batches_failed),
                g(&self.batches_timeout),
                g(&self.batches)
            ));
        }
        if g(&self.breaker_half_open) > g(&self.breaker_opened) {
            return Err(format!(
                "breaker_half_open={} exceeds breaker_opened={} — every probe \
                 admission needs a prior open transition",
                g(&self.breaker_half_open),
                g(&self.breaker_opened)
            ));
        }
        if g(&self.breaker_closed) > g(&self.breaker_half_open) {
            return Err(format!(
                "breaker_closed={} exceeds breaker_half_open={} — every \
                 recovery needs a prior half-open probe",
                g(&self.breaker_closed),
                g(&self.breaker_half_open)
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_occupancy() {
        let m = Metrics::default();
        Metrics::inc(&m.requests_in);
        Metrics::add(&m.batched_requests, 6);
        Metrics::add(&m.padded_slots, 2);
        assert_eq!(Metrics::get(&m.requests_in), 1);
        assert!((m.batch_occupancy() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn occupancy_empty_is_zero() {
        assert_eq!(Metrics::default().batch_occupancy(), 0.0);
    }

    #[test]
    fn shed_rate_tracks_shed_over_in() {
        let m = Metrics::default();
        assert_eq!(m.shed_rate(), 0.0);
        Metrics::add(&m.requests_in, 8);
        Metrics::add(&m.requests_shed, 2);
        assert!((m.shed_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn latency_track_summary() {
        let t = LatencyTrack::default();
        for i in 1..=100 {
            t.record(i as f64 / 1000.0);
        }
        let s = t.summary();
        assert_eq!(s.n, 100);
        assert!((s.p50 - 0.0505).abs() < 1e-3);
        assert_eq!(t.count(), 100);
        assert_eq!(t.samples_dropped(), 0);
    }

    #[test]
    fn latency_track_bounds_memory_and_counts_drops() {
        let t = LatencyTrack::default();
        for i in 0..(MAX_SAMPLES + 10) {
            t.record(i as f64);
        }
        assert!(t.count() <= MAX_SAMPLES / 2 + 11);
        // One halving fired: exactly half the window was discarded, and the
        // snapshot says so instead of posing as an all-time summary.
        assert_eq!(t.samples_dropped(), (MAX_SAMPLES / 2) as u64);
        let j = t.to_json();
        assert_eq!(
            j.get("samples_dropped").and_then(|v| v.as_f64()),
            Some((MAX_SAMPLES / 2) as f64)
        );
        assert_eq!(j.get("window").and_then(|v| v.as_f64()), Some(MAX_SAMPLES as f64));
        assert!(j.get("n").is_some(), "summary fields must survive the merge");
    }

    #[test]
    fn report_renders() {
        let m = Metrics::default();
        m.e2e.record(0.001);
        let r = m.report();
        assert!(r.contains("requests:") && r.contains("e2e:"));
        assert!(r.contains("invalid=") && r.contains("shed rate"));
        assert!(r.contains("failed:"), "failed track must be visible: {r}");
        assert!(r.contains("router wakeups"), "wakeup signal must be visible: {r}");
        assert!(r.contains("quarantined="), "new outcome classes visible: {r}");
        assert!(r.contains("breaker: closed"), "breaker state visible: {r}");
    }

    #[test]
    fn to_json_snapshots_counters_rates_and_tracks() {
        let m = Metrics::default();
        Metrics::add(&m.requests_in, 4);
        Metrics::inc(&m.requests_done);
        Metrics::inc(&m.requests_shed);
        Metrics::add(&m.batched_requests, 3);
        Metrics::add(&m.padded_slots, 1);
        m.e2e.record(0.002);
        let j = m.to_json();
        assert_eq!(j.get("requests_in").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(j.get("requests_shed").and_then(|v| v.as_f64()), Some(1.0));
        assert!((j.get("occupancy").and_then(|v| v.as_f64()).unwrap() - 0.75).abs() < 1e-12);
        assert!((j.get("shed_rate").and_then(|v| v.as_f64()).unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(
            j.get("e2e").and_then(|e| e.get("n")).and_then(|v| v.as_f64()),
            Some(1.0)
        );
        assert_eq!(j.get("breaker_state").and_then(|v| v.as_str()), Some("closed"));
        assert_eq!(j.get("requests_quarantined").and_then(|v| v.as_f64()), Some(0.0));
        // Empty tracks must serialize to parseable JSON (no inf tokens).
        let text = j.to_string_compact();
        assert!(!text.contains("inf"), "non-JSON token in {text}");
        Json::parse(&text).expect("metrics snapshot must be valid JSON");
    }

    #[test]
    fn audit_passes_on_balanced_ledger() {
        let m = Metrics::default();
        assert!(m.audit().is_ok(), "an untouched ledger balances");
        Metrics::add(&m.requests_in, 5);
        Metrics::add(&m.requests_done, 3);
        Metrics::inc(&m.requests_shed);
        Metrics::inc(&m.requests_timeout);
        Metrics::inc(&m.requests_recovered);
        Metrics::inc(&m.batches);
        Metrics::inc(&m.batches_failed);
        Metrics::inc(&m.breaker_opened);
        Metrics::inc(&m.breaker_half_open);
        Metrics::inc(&m.breaker_closed);
        assert!(m.audit().is_ok(), "{:?}", m.audit());
    }

    #[test]
    fn audit_catches_imbalanced_outcomes() {
        let m = Metrics::default();
        Metrics::add(&m.requests_in, 3);
        Metrics::add(&m.requests_done, 2);
        // One admitted request never answered: the ledger must not balance.
        let err = m.audit().unwrap_err();
        assert!(err.contains("requests_in=3"), "{err}");
        assert!(err.contains("dropped or double-answered"), "{err}");
    }

    #[test]
    fn audit_catches_recovered_exceeding_done() {
        let m = Metrics::default();
        Metrics::inc(&m.requests_in);
        Metrics::inc(&m.requests_done);
        Metrics::add(&m.requests_recovered, 2);
        assert!(m.audit().unwrap_err().contains("requests_recovered"));
    }

    #[test]
    fn audit_catches_unbalanced_breaker_transitions() {
        let m = Metrics::default();
        Metrics::inc(&m.breaker_half_open);
        assert!(m.audit().unwrap_err().contains("breaker_half_open"));
        let m = Metrics::default();
        Metrics::inc(&m.breaker_opened);
        Metrics::inc(&m.breaker_half_open);
        Metrics::add(&m.breaker_closed, 2);
        assert!(m.audit().unwrap_err().contains("breaker_closed"));
    }

    #[test]
    fn report_names_raw_slot_counts() {
        let m = Metrics::default();
        Metrics::add(&m.batched_requests, 6);
        Metrics::add(&m.padded_slots, 2);
        let r = m.report();
        assert!(r.contains("slots 6+2 pad"), "raw slot counts visible: {r}");
        assert!(r.contains("replies_unclaimed=0"), "{r}");
    }

    #[test]
    fn breaker_gauge_names_states() {
        let m = Metrics::default();
        assert_eq!(m.breaker_state_name(), "closed");
        m.breaker_state.store(1, Ordering::Relaxed);
        assert_eq!(m.breaker_state_name(), "open");
        m.breaker_state.store(2, Ordering::Relaxed);
        assert_eq!(m.breaker_state_name(), "half-open");
    }
}
