//! Layer-3 coordinator: the paper's system contribution.
//!
//! * `ratio_search` — the offline PoT:Fixed mixing-ratio sweep (§II-B);
//! * `sensitivity` — on-device per-filter Hessian power iteration (§II-C);
//! * `trainer` — the QAT loop over the AOT `train_step` artifact;
//! * `batcher`/`server` — inference serving with dynamic batching over any
//!   [`crate::backend::InferenceBackend`] (PJRT artifacts, native qgemm, or
//!   the f32 reference), behind a validating, bounded, typed-error
//!   admission pipeline, with the FPGA-sim timing overlay and supervised
//!   execution (watchdog deadlines, poison-quarantining retry, a
//!   consecutive-failure circuit breaker, and degraded-mode fallback — see
//!   ROADMAP "Architecture: execution resilience");
//! * `pool` — multi-model, multi-plan serving: a [`ServerPool`] of named
//!   `(manifest, QuantPlan, backend)` entries, each behind its own admission
//!   pipeline, with lazy prepare and live plan hot-swap;
//! * `http` — the pure-std HTTP/1.1 front end over that pipeline
//!   (`ilmpq serve --listen`, single-model or `--pool`), plus the matching
//!   client;
//! * `loadgen` — the open-loop Poisson load driver behind `ilmpq loadgen`
//!   and `benches/serving.rs`, in-process or over the wire (`--url`),
//!   including the multi-model `--scenario multi` skew;
//! * `metrics` — counters + latency percentiles.

pub mod batcher;
pub mod http;
pub mod loadgen;
pub mod metrics;
pub mod pool;
pub mod ratio_search;
pub mod sensitivity;
pub mod server;
pub mod trainer;

pub use batcher::{BatchPolicy, Batcher};
pub use http::{Encoding, HttpClient, HttpConfig, HttpServer, HttpTarget, RAW_CONTENT_TYPE};
pub use metrics::Metrics;
pub use pool::{PoolEntry, ServerPool};
pub use server::{Request, Response, ServeConfig, ServeError, ServeResult, Server};
pub use trainer::Trainer;
