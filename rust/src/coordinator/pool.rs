//! Multi-model, multi-plan serving: a [`ServerPool`] hosts N named
//! `(manifest, QuantPlan, backend)` tuples in one process, each behind its
//! own admission pipeline ([`Server`]) with its own queue depth, circuit
//! breaker, and [`Metrics`]. This is the ILMPQ multi-tenant story made
//! concrete: intra-layer multi-precision means one hardware configuration
//! serves *any* (network, plan) pair, so one process can route many of them
//! through one uniform execution path.
//!
//! Three properties carry the design:
//!
//! * **Lazy prepare.** An entry packs its backend and starts its `Server`
//!   on the *first* request (double-checked under the entry's state lock),
//!   so a pool of many models pays startup cost only for the ones traffic
//!   actually reaches. `prepares()` counts builds, making prepare-once
//!   observable.
//! * **Live plan hot-swap with zero lost replies.** [`PoolEntry::swap_plan`]
//!   validates the uploaded [`QuantPlan`] against the entry's manifest,
//!   re-packs a whole new backend + `Server` off the serving path (on a
//!   joined helper thread, so a panicking pack surfaces as an error while
//!   the old stack keeps serving), then swings traffic under the state
//!   write lock. The infer path submits while *holding the read lock*
//!   without cloning the `Arc<Server>`, so after the swing (a) no new
//!   request can reach the old server and (b) the swap holds the only
//!   `Arc`. It then waits for the old server's [`Server::in_flight`] to
//!   drain to zero before stopping it — `stop()` answers still-queued
//!   requests `ShuttingDown`, which a zero-loss swap must never allow.
//! * **Bit-reproducible swaps.** Every pool-built entry retains its
//!   `(manifest, params)`; backend construction is deterministic in
//!   `(manifest, params, plan)` and the packed forward pass is bit-stable
//!   across thread counts, so post-swap logits equal a cold start on the
//!   uploaded plan bit for bit (pinned by `tests/pool_smoke.rs`).
//!
//! Each swap installs a fresh `Server` and therefore a fresh `Metrics` —
//! per-model counters describe the *current* plan's tenure. Zero-loss
//! assertions live client-side (the loadgen ledger), which is the contract
//! that matters over the wire.
//!
//! A pool can also boot from a **bundle** ([`ServerPool::from_bundle`]):
//! every entry resolves its manifest descriptor, params blob, and plan
//! JSON from a content-addressed [`Store`] by the digests a lockfile
//! pins, so the pool serves exactly the bytes that were packed — any
//! missing or mismatched blob is a startup error, never a fallback. The
//! inverse direction is [`pack_pool`].

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::metrics::Metrics;
use super::server::{ServeConfig, ServeResult, Server};
use crate::artifact::{ArtifactError, Bundle, BundleModel, Digest, Store, BUNDLE_VERSION};
use crate::backend::{self, synth, BackendInit, FaultSpec, ImageBuf, InferenceBackend};
use crate::quant::{plan::parse_ratio_arg, MaskSet, Provenance, QuantPlan};
use crate::runtime::{HostTensor, Manifest};
use crate::util::sync::{LockExt, RwLockExt};
use crate::util::{Json, Rng};

/// How long a swap waits for the replaced server to answer its in-flight
/// requests before falling back to `begin_shutdown` (which would surface
/// `ShuttingDown` to any stragglers — bounded badness over a hang).
const SWAP_DRAIN_DEADLINE: Duration = Duration::from_secs(60);

struct EntryState {
    /// The serving stack for the entry's current plan. `None` = cold (not
    /// yet prepared) or shut down.
    server: Option<Arc<Server>>,
}

/// One named model in the pool: its manifest + retained init params (the
/// hot-swap rebuild inputs), the backend recipe, and the per-model serving
/// configuration (whose `plan` field is the entry's *initial* plan).
pub struct PoolEntry {
    name: String,
    manifest: Manifest,
    /// Init params retained for re-packing on hot-swap. Empty for entries
    /// attached pre-built ([`ServerPool::single`]), which cannot swap.
    params: Vec<HostTensor>,
    /// Registry backend name; `None` marks a pre-built entry the pool
    /// cannot rebuild (no swap support).
    backend_name: Option<String>,
    /// Synthetic zoo geometry this entry was built from (empty for
    /// pre-built entries) — what `pack_pool` writes into the manifest
    /// descriptor blob.
    geometry: String,
    /// Set when the entry was booted from a bundle: the store plus the
    /// lockfile digests, retained so `/v1/models` can report them and
    /// `GET .../verify` can re-hash the blobs on demand.
    bundle: Option<BundleRef>,
    threads: Option<usize>,
    fault: Option<FaultSpec>,
    base_cfg: ServeConfig,
    state: RwLock<EntryState>,
    /// Serializes swaps so two concurrent uploads can't both re-pack and
    /// race the swing. The state lock alone can't give that: the pack runs
    /// *outside* it by design.
    swap_gate: Mutex<()>,
    prepares: AtomicU64,
    swaps: AtomicU64,
    /// Set by [`ServerPool::shutdown`]; checked inside the swing's critical
    /// section so a swap racing teardown can't install a server into a dead
    /// pool.
    closed: AtomicBool,
}

/// The provenance record of a bundle-booted entry (see
/// [`PoolEntry::bundle`]).
struct BundleRef {
    store: Store,
    manifest: Digest,
    params: Digest,
    plan: Digest,
}

/// Point-in-time health view for one entry (the `/v1/healthz` inputs). A
/// cold entry reads ready: it will lazily prepare on the first request.
pub struct EntryHealth {
    pub ready: bool,
    pub breaker: &'static str,
    pub degraded: bool,
    pub draining: bool,
    pub plan: Option<String>,
}

impl PoolEntry {
    /// Parse one `"models"` array element of a pool config. Knobs (all but
    /// `name` optional): `backend` (registry name, default `qgemm`),
    /// `synthetic` (zoo geometry, default `tinyresnet`), `ratio` (Table-I
    /// name or `P:F4:F8` split) *or* `plan` (a QuantPlan JSON path), `seed`,
    /// `workers`, `queue-depth`, `max-wait-ms`, `threads`, `device`, `fault`
    /// (`"chaos"` or a FaultSpec path), `breaker-threshold`,
    /// `breaker-cooldown-ms`, `execute-deadline-ms`, `retries`.
    fn from_json(j: &Json) -> Result<PoolEntry> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("pool model entry needs a \"name\""))?
            .to_string();
        let backend_name =
            j.get("backend").and_then(Json::as_str).unwrap_or("qgemm").to_string();
        // Typo'd backend names must fail at config time, not on the first
        // (lazy) request.
        backend::spec(&backend_name)
            .with_context(|| format!("pool model {name:?}"))?;
        let geometry =
            j.get("synthetic").and_then(Json::as_str).unwrap_or("tinyresnet");
        let seed = j.get("seed").and_then(Json::as_f64).unwrap_or(7.0) as u64;
        let get_u64 =
            |k: &str, d: u64| j.get(k).and_then(Json::as_f64).map_or(d, |v| v as u64);
        let threads = j.get("threads").and_then(Json::as_usize);
        let fault = match j.get("fault").and_then(Json::as_str) {
            None => None,
            Some("chaos") => Some(FaultSpec::chaos(seed)),
            Some(path) => Some(
                FaultSpec::load(Path::new(path))
                    .with_context(|| format!("pool model {name:?} fault schedule"))?,
            ),
        };

        // Synthetic fixture, single RNG stream per entry: params first,
        // masks second. Both arms build through the shared fixture
        // functions (`synth_parts` / `synth_entry_fixture`) that
        // bit-identity tests and `pack_pool` re-derive, so config boot and
        // bundle pack can never drift.
        let (mut manifest, params, plan) = match (
            j.get("plan").and_then(Json::as_str),
            j.get("ratio").and_then(Json::as_str),
        ) {
            (Some(_), Some(_)) => {
                anyhow::bail!("pool model {name:?}: give \"plan\" or \"ratio\", not both")
            }
            (Some(path), None) => {
                let (manifest, params) = synth_parts(geometry, seed)
                    .with_context(|| format!("pool model {name:?}"))?;
                let p = QuantPlan::load(Path::new(path))?;
                p.validate(&manifest).with_context(|| {
                    format!("plan {path:?} does not fit pool model {name:?}")
                })?;
                (manifest, params, p)
            }
            (None, ratio_arg) => {
                let label = ratio_arg.unwrap_or("65:30:5");
                synth_entry_fixture(geometry, seed, label)
                    .with_context(|| format!("pool model {name:?}"))?
            }
        };
        manifest.default_masks.insert(plan.name.clone(), plan.masks.clone());

        let base_cfg = ServeConfig {
            workers: j.get("workers").and_then(Json::as_usize).unwrap_or(2),
            max_wait: Duration::from_millis(get_u64("max-wait-ms", 5)),
            queue_depth: j.get("queue-depth").and_then(Json::as_usize).unwrap_or(1024),
            plan: Some(plan),
            device: j
                .get("device")
                .and_then(Json::as_str)
                .unwrap_or("xc7z045")
                .to_string(),
            execute_deadline: match get_u64("execute-deadline-ms", 0) {
                0 => None,
                ms => Some(Duration::from_millis(ms)),
            },
            retries: j.get("retries").and_then(Json::as_usize).unwrap_or(0),
            breaker_threshold: j
                .get("breaker-threshold")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            breaker_cooldown: Duration::from_millis(get_u64("breaker-cooldown-ms", 1000)),
            ..Default::default()
        };

        Ok(PoolEntry {
            name,
            manifest,
            params,
            backend_name: Some(backend_name),
            geometry: geometry.to_string(),
            bundle: None,
            threads,
            fault,
            base_cfg,
            state: RwLock::new(EntryState { server: None }),
            swap_gate: Mutex::new(()),
            prepares: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            closed: AtomicBool::new(false),
        })
    }

    /// Wrap an already-running server (the single-model HTTP front end).
    /// Such an entry serves immediately but cannot hot-swap: the pool holds
    /// no init params to re-pack from.
    fn from_running(server: Arc<Server>, manifest: &Manifest) -> PoolEntry {
        let base_cfg =
            ServeConfig { plan: server.plan.as_deref().cloned(), ..Default::default() };
        PoolEntry {
            name: manifest.model_name.clone(),
            manifest: manifest.clone(),
            params: Vec::new(),
            backend_name: None,
            geometry: String::new(),
            bundle: None,
            threads: None,
            fault: None,
            base_cfg,
            state: RwLock::new(EntryState { server: Some(server) }),
            swap_gate: Mutex::new(()),
            prepares: AtomicU64::new(1),
            swaps: AtomicU64::new(0),
            closed: AtomicBool::new(false),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn image_elems(&self) -> usize {
        self.manifest.data.image_elems()
    }

    pub fn classes(&self) -> usize {
        self.manifest.classes
    }

    /// Backend builds this entry has performed (lazy starts + swaps).
    pub fn prepares(&self) -> u64 {
        self.prepares.load(Ordering::SeqCst)
    }

    /// Completed hot-swaps.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::SeqCst)
    }

    /// Build backend + `Server` for `plan` on a joined helper thread: a
    /// panicking pack (poisoned weights, a buggy backend) must come back as
    /// an error on this call, never unwind through a pool that is serving.
    fn build_server(&self, plan: Option<QuantPlan>) -> Result<Server> {
        let backend_name = self.backend_name.clone().ok_or_else(|| {
            anyhow!(
                "model {:?} was attached pre-built; the pool holds no init \
                 params to re-pack it from",
                self.name
            )
        })?;
        let cfg = ServeConfig { plan, ..self.base_cfg.clone() };
        let manifest = self.manifest.clone();
        let params = self.params.clone();
        let threads = self.threads;
        let fault = self.fault.clone();
        let label = self.name.clone();
        let handle = std::thread::Builder::new()
            .name(format!("ilmpq-pack-{label}"))
            .spawn(move || -> Result<Server> {
                let init = BackendInit {
                    plan: cfg.plan.clone(),
                    threads,
                    frozen: cfg.frozen,
                    fault,
                    ..BackendInit::new(manifest.clone(), params)
                };
                let be: Arc<dyn InferenceBackend> =
                    Arc::from(backend::create(&backend_name, &init)?);
                Server::start(&manifest, be, cfg)
            })
            .context("spawn pack thread")?;
        handle
            .join()
            .map_err(|_| anyhow!("packing model {:?} panicked", self.name))?
            .with_context(|| format!("start pool model {:?}", self.name))
    }

    /// Lazy start: pack + start the entry's server if it is still cold.
    /// Double-checked under the state lock, so concurrent first requests
    /// build exactly once.
    fn ensure_started(&self) -> Result<()> {
        if self.state.pread().server.is_some() {
            return Ok(());
        }
        // analyze:allow(lazy init holds the write lock across the pack on purpose: concurrent first requests must wait for the one build, not error)
        let mut st = self.state.pwrite();
        if st.server.is_some() {
            return Ok(());
        }
        anyhow::ensure!(
            !self.closed.load(Ordering::SeqCst),
            "pool is shut down"
        );
        let server = self.build_server(self.base_cfg.plan.clone())?;
        self.prepares.fetch_add(1, Ordering::SeqCst);
        st.server = Some(Arc::new(server));
        Ok(())
    }

    /// Submit one image to this entry (starting it lazily on first use).
    /// Like [`Server::submit`], takes the image as an owned [`ImageBuf`]
    /// (a `Vec<f32>` converts for free) and moves it down the pipeline.
    ///
    /// The submit happens while *holding the state read lock*, without
    /// cloning the `Arc<Server>` — load-bearing for the swap: after the
    /// swap's write lock swings the pointer, no submit can still be routing
    /// into the old server, and the swap holds that server's only `Arc`.
    pub fn submit(&self, image: impl Into<ImageBuf>) -> Result<Receiver<ServeResult>> {
        self.ensure_started()?;
        let st = self.state.pread();
        let server = st
            .server
            .as_ref()
            .ok_or_else(|| anyhow!("model {:?} is shut down", self.name))?;
        Ok(server.submit(image))
    }

    /// Live plan hot-swap. Validates, re-packs off the serving path,
    /// atomically swings traffic, then drains and stops the old server —
    /// zero lost replies (see the module docs for why each step is where
    /// it is). On any error the old stack keeps serving untouched.
    pub fn swap_plan(&self, plan: QuantPlan) -> Result<()> {
        plan.validate(&self.manifest)
            .with_context(|| format!("uploaded plan rejected for model {:?}", self.name))?;
        // analyze:allow(the swap gate must span the off-path pack so two uploads cannot both re-pack and race the swing)
        let _gate = self.swap_gate.plock();
        anyhow::ensure!(!self.closed.load(Ordering::SeqCst), "pool is shut down");
        // The expensive part — pack the new backend, warm it up — runs
        // before any lock the serving path contends on.
        let new_server = Arc::new(self.build_server(Some(plan))?);
        self.prepares.fetch_add(1, Ordering::SeqCst);
        let old = {
            let mut st = self.state.pwrite();
            if self.closed.load(Ordering::SeqCst) {
                // Raced a pool shutdown between the gate check and here:
                // don't install into a dead pool.
                drop(st);
                if let Ok(s) = Arc::try_unwrap(new_server) {
                    s.stop();
                }
                anyhow::bail!("pool shut down during the swap");
            }
            std::mem::replace(&mut st.server, Some(new_server))
        };
        if let Some(old) = old {
            // After the swing the old server's in-flight count only falls
            // (the write lock waited out every in-progress submit). Drain
            // it to zero before stop(): stop answers still-queued requests
            // ShuttingDown, and a swap must lose nothing.
            let deadline = Instant::now() + SWAP_DRAIN_DEADLINE;
            while old.in_flight() > 0 && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
            }
            match Arc::try_unwrap(old) {
                Ok(s) => {
                    s.stop();
                }
                // Unreachable by construction (submit never clones the
                // Arc), but never hang a swap on it: drain-stop
                // best-effort.
                Err(s) => s.begin_shutdown(),
            }
        }
        self.swaps.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// The plan currently advertised: the active server's plan, or the
    /// configured initial plan while the entry is cold.
    pub fn current_plan(&self) -> Option<Arc<QuantPlan>> {
        let st = self.state.pread();
        match &st.server {
            Some(s) => s.plan.clone(),
            None => self.base_cfg.plan.clone().map(Arc::new),
        }
    }

    /// The `GET .../plan` body for this entry.
    pub fn plan_summary(&self) -> Option<Json> {
        self.current_plan().map(|p| p.summary_json())
    }

    /// The `GET .../metrics` body: the active server's counters, or a
    /// zeroed set while cold (a cold model has served nothing — that *is*
    /// its metrics).
    pub fn metrics_json(&self) -> Json {
        let st = self.state.pread();
        match &st.server {
            Some(s) => s.metrics.to_json(),
            None => Metrics::default().to_json(),
        }
    }

    /// Health view (see [`EntryHealth`]).
    pub fn health(&self) -> EntryHealth {
        let st = self.state.pread();
        let plan = match &st.server {
            Some(s) => s.plan.as_ref().map(|p| p.name.clone()),
            None => self.base_cfg.plan.as_ref().map(|p| p.name.clone()),
        };
        match st.server.as_deref() {
            Some(s) => EntryHealth {
                ready: s.is_ready(),
                breaker: s.breaker_state(),
                degraded: s.is_degraded(),
                draining: s.is_shutting_down(),
                plan,
            },
            None => EntryHealth {
                ready: !self.closed.load(Ordering::SeqCst),
                breaker: "closed",
                degraded: false,
                draining: false,
                plan,
            },
        }
    }

    /// One registry row of the `GET /v1/models` listing.
    pub fn describe(&self) -> Json {
        let st = self.state.pread();
        let (state, breaker, degraded) = match st.server.as_deref() {
            Some(s) => (
                if s.is_shutting_down() {
                    "draining"
                } else if s.is_ready() {
                    "ready"
                } else {
                    "unready"
                },
                s.breaker_state(),
                s.is_degraded(),
            ),
            None => ("cold", "closed", false),
        };
        let plan = match &st.server {
            Some(s) => s.plan.clone(),
            None => self.base_cfg.plan.clone().map(Arc::new),
        };
        drop(st);
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("model", Json::Str(self.manifest.model_name.clone())),
            (
                "backend",
                match &self.backend_name {
                    Some(b) => Json::Str(b.clone()),
                    None => Json::Null,
                },
            ),
            ("image_elems", Json::Num(self.image_elems() as f64)),
            ("classes", Json::Num(self.classes() as f64)),
            ("state", Json::Str(state.into())),
            ("breaker", Json::Str(breaker.into())),
            ("degraded", Json::Bool(degraded)),
            ("queue_depth", Json::Num(self.base_cfg.queue_depth as f64)),
            (
                "plan",
                match &plan {
                    Some(p) => Json::Str(p.name.clone()),
                    None => Json::Null,
                },
            ),
            (
                "provenance",
                match &plan {
                    Some(p) => Json::Str(p.provenance.kind().into()),
                    None => Json::Null,
                },
            ),
            ("swaps", Json::Num(self.swaps() as f64)),
            ("prepares", Json::Num(self.prepares() as f64)),
            (
                "plan_digest",
                match &plan {
                    Some(p) => Json::Str(p.content_digest().to_hex()),
                    None => Json::Null,
                },
            ),
            (
                "bundle",
                match &self.bundle {
                    Some(b) => Json::obj(vec![
                        ("manifest", Json::Str(b.manifest.to_hex())),
                        ("params", Json::Str(b.params.to_hex())),
                        ("plan", Json::Str(b.plan.to_hex())),
                    ]),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// One human line for the serve CLI banner.
    pub fn summary_line(&self) -> String {
        let plan = self
            .current_plan()
            .map_or_else(|| "unquantized".to_string(), |p| p.name.clone());
        format!(
            "{}: model {} ({} elems, {} classes), backend {}, plan {}",
            self.name,
            self.manifest.model_name,
            self.image_elems(),
            self.classes(),
            self.backend_name.as_deref().unwrap_or("(pre-built)"),
            plan
        )
    }

    /// Stop this entry's server (if running), returning its metrics.
    fn close(&self) -> Option<Arc<Metrics>> {
        self.closed.store(true, Ordering::SeqCst);
        let server = self.state.pwrite().server.take();
        server.map(|s| match Arc::try_unwrap(s) {
            Ok(s) => s.stop(),
            Err(s) => {
                s.begin_shutdown();
                s.metrics.clone()
            }
        })
    }

    // ---- bundle integration ----------------------------------------------

    /// Boot one entry from a bundle model: resolve all three blobs from
    /// the store by digest (each fully re-hashed on read), cross-check the
    /// manifest descriptor against the lockfile row, and refuse anything
    /// that does not match — a bad byte is a startup error, never a
    /// silent fallback.
    fn from_bundle_model(bm: &BundleModel, store: &Store) -> Result<PoolEntry> {
        backend::spec(&bm.backend)
            .with_context(|| format!("bundle model {:?}", bm.name))?;
        let manifest_bytes = store.get(&bm.manifest, &format!("{}/manifest", bm.name))?;
        let params_bytes = store.get(&bm.params, &format!("{}/params", bm.name))?;
        let plan_bytes = store.get(&bm.plan, &format!("{}/plan", bm.name))?;

        let (mut manifest, geometry) = manifest_from_descriptor(&manifest_bytes)
            .with_context(|| format!("bundle model {:?} manifest blob", bm.name))?;
        anyhow::ensure!(
            geometry == bm.geometry,
            "bundle model {:?}: lockfile says geometry {:?} but the manifest blob says {:?}",
            bm.name,
            bm.geometry,
            geometry
        );
        anyhow::ensure!(
            manifest.model_name == bm.model,
            "bundle model {:?}: lockfile says model {:?} but the manifest blob resolves to {:?}",
            bm.name,
            bm.model,
            manifest.model_name
        );
        let params = params_from_bytes(&manifest, &params_bytes)
            .with_context(|| format!("bundle model {:?} params blob", bm.name))?;
        let plan_text = String::from_utf8(plan_bytes)
            .map_err(|_| anyhow!("bundle model {:?}: plan blob is not UTF-8", bm.name))?;
        let plan_json = Json::parse(&plan_text)
            .map_err(|e| anyhow!("bundle model {:?}: plan blob: {e}", bm.name))?;
        let plan = QuantPlan::from_json(&plan_json)
            .with_context(|| format!("bundle model {:?} plan blob", bm.name))?;
        plan.validate(&manifest)
            .with_context(|| format!("bundle model {:?}", bm.name))?;
        manifest.default_masks.insert(plan.name.clone(), plan.masks.clone());

        // Serving knobs are deliberately not part of a bundle (they don't
        // change logits); a bundle-booted entry runs the same defaults a
        // knobless pool-config entry gets.
        let base_cfg = ServeConfig {
            workers: 2,
            max_wait: Duration::from_millis(5),
            queue_depth: 1024,
            plan: Some(plan),
            device: "xc7z045".to_string(),
            breaker_cooldown: Duration::from_millis(1000),
            ..Default::default()
        };
        Ok(PoolEntry {
            name: bm.name.clone(),
            manifest,
            params,
            backend_name: Some(bm.backend.clone()),
            geometry,
            bundle: Some(BundleRef {
                store: store.clone(),
                manifest: bm.manifest,
                params: bm.params,
                plan: bm.plan,
            }),
            threads: None,
            fault: None,
            base_cfg,
            state: RwLock::new(EntryState { server: None }),
            swap_gate: Mutex::new(()),
            prepares: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            closed: AtomicBool::new(false),
        })
    }

    /// Content digest ([`QuantPlan::content_digest`]) of the plan this
    /// entry currently advertises — swap-aware, identity-blind.
    pub fn plan_digest(&self) -> Option<Digest> {
        self.current_plan().map(|p| p.content_digest())
    }

    /// The lockfile blob digests `(manifest, params, plan)` this entry was
    /// booted from; `None` for entries not booted from a bundle.
    pub fn bundle_digests(&self) -> Option<(Digest, Digest, Digest)> {
        self.bundle.as_ref().map(|b| (b.manifest, b.params, b.plan))
    }

    /// Re-hash the entry's three store blobs on demand (`GET .../verify`).
    /// `None` for entries not booted from a bundle. On success, reports
    /// whether the *currently executing* plan still byte-equals the
    /// bundled one (false after a hot-swap).
    pub fn verify_bundle(&self) -> Option<Result<bool, ArtifactError>> {
        let b = self.bundle.as_ref()?;
        for (digest, what) in
            [(&b.manifest, "manifest"), (&b.params, "params"), (&b.plan, "plan")]
        {
            if let Err(e) = b.store.verify(digest, &format!("{}/{what}", self.name)) {
                return Some(Err(e));
            }
        }
        let plan_matches = self.current_plan().map_or(false, |p| {
            Digest::of(p.to_json().to_string_compact().as_bytes()) == b.plan
        });
        Some(Ok(plan_matches))
    }
}

/// A named registry of [`PoolEntry`]s behind one process. See module docs.
pub struct ServerPool {
    entries: Vec<Arc<PoolEntry>>,
    default: String,
}

impl ServerPool {
    /// Parse a pool config: `{"default": "name", "models": [ ... ]}` (see
    /// [`PoolEntry::from_json`] for the per-model knobs). `default` falls
    /// back to the first model.
    pub fn from_json(j: &Json) -> Result<ServerPool> {
        let models = j
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("pool config needs a \"models\" array"))?;
        anyhow::ensure!(!models.is_empty(), "pool config has no models");
        let mut entries: Vec<Arc<PoolEntry>> = Vec::new();
        for mj in models {
            let e = PoolEntry::from_json(mj)?;
            anyhow::ensure!(
                entries.iter().all(|x| x.name != e.name),
                "duplicate model name {:?} in pool config",
                e.name
            );
            entries.push(Arc::new(e));
        }
        let default = match j.get("default").and_then(Json::as_str) {
            Some(d) => {
                anyhow::ensure!(
                    entries.iter().any(|e| e.name == d),
                    "default model {d:?} is not in the pool"
                );
                d.to_string()
            }
            None => entries[0].name.clone(),
        };
        Ok(ServerPool { entries, default })
    }

    /// Load a pool config from a JSON file (`ilmpq serve --pool pool.json`).
    pub fn from_file(path: &Path) -> Result<ServerPool> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read pool config {path:?}"))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("pool config {path:?} is not JSON: {e}"))?;
        Self::from_json(&j).with_context(|| format!("pool config {path:?}"))
    }

    /// The built-in two-model synthetic pool for toolchain-only machines:
    /// `tiny` (TinyResNet geometry, the ilmpq2 Table-I ratio) and `narrow`
    /// (the plain vggnarrow stack, a 65:30:5 split), both on the qgemm
    /// backend — two genuinely different topologies behind one listener.
    pub fn synthetic_pair(seed: u64) -> Result<ServerPool> {
        let entry = |name: &str, geometry: &str, ratio: &str, seed: u64| {
            Json::obj(vec![
                ("name", Json::Str(name.into())),
                ("backend", Json::Str("qgemm".into())),
                ("synthetic", Json::Str(geometry.into())),
                ("ratio", Json::Str(ratio.into())),
                ("seed", Json::Num(seed as f64)),
            ])
        };
        let cfg = Json::obj(vec![
            ("default", Json::Str("tiny".into())),
            (
                "models",
                Json::Arr(vec![
                    entry("tiny", "tinyresnet", "ilmpq2", seed),
                    entry("narrow", "vggnarrow", "65:30:5", seed ^ 0x9e37),
                ]),
            ),
        ]);
        Self::from_json(&cfg)
    }

    /// Boot a pool from a bundle lockfile + store: every entry resolves
    /// its bytes from the store by the digests the lockfile pins (see
    /// [`PoolEntry::from_bundle_model`]), so the pool serves exactly what
    /// was packed or refuses to start.
    pub fn from_bundle(bundle: &Bundle, store: &Store) -> Result<ServerPool> {
        let mut entries: Vec<Arc<PoolEntry>> = Vec::new();
        for bm in &bundle.models {
            let e = PoolEntry::from_bundle_model(bm, store)?;
            anyhow::ensure!(
                entries.iter().all(|x| x.name != e.name),
                "duplicate model name {:?} in bundle",
                e.name
            );
            entries.push(Arc::new(e));
        }
        anyhow::ensure!(
            entries.iter().any(|e| e.name == bundle.default),
            "bundle default {:?} is not among its models",
            bundle.default
        );
        Ok(ServerPool { entries, default: bundle.default.clone() })
    }

    /// Wrap one already-running server as a single-entry pool (the legacy
    /// single-model HTTP front end). The caller may keep its own clone of
    /// the `Arc<Server>` for direct access, but must drop it before
    /// [`ServerPool::shutdown`] so the entry can unwrap and join it.
    pub fn single(server: Arc<Server>, manifest: &Manifest) -> ServerPool {
        let entry = Arc::new(PoolEntry::from_running(server, manifest));
        let default = entry.name.clone();
        ServerPool { entries: vec![entry], default }
    }

    pub fn entries(&self) -> &[Arc<PoolEntry>] {
        &self.entries
    }

    pub fn entry(&self, name: &str) -> Option<&Arc<PoolEntry>> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn default_name(&self) -> &str {
        &self.default
    }

    /// The entry legacy `/v1/*` routes map onto.
    pub fn default_entry(&self) -> &Arc<PoolEntry> {
        // analyze:allow(from_json/single/synthetic_pair all verify the default names an existing entry)
        self.entry(&self.default).expect("default entry exists by construction")
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    /// The `GET /v1/models` body.
    pub fn describe(&self) -> Json {
        Json::obj(vec![
            ("default", Json::Str(self.default.clone())),
            (
                "models",
                Json::Arr(self.entries.iter().map(|e| e.describe()).collect()),
            ),
        ])
    }

    /// Stop every entry's server; returns the default entry's metrics (the
    /// single-model front end's historic teardown contract) — zeroed if the
    /// default never started.
    pub fn shutdown(&self) -> Arc<Metrics> {
        let mut default_metrics: Option<Arc<Metrics>> = None;
        for e in &self.entries {
            let m = e.close();
            if e.name == self.default {
                default_metrics = m;
            }
        }
        default_metrics.unwrap_or_default()
    }
}

/// The synthetic fixture parts a pool-built entry at `(geometry, seed)` is
/// constructed from — exposed so tests can rebuild a bit-identical
/// reference backend (same params, any plan) and pin post-swap logits to a
/// cold start.
pub fn synth_parts(geometry: &str, seed: u64) -> Result<(Manifest, Vec<HostTensor>)> {
    let mut rng = Rng::new(seed);
    let m = synth::serving_manifest_for(geometry)?;
    let params = synth::random_params(&m, &mut rng);
    Ok((m, params))
}

/// The full synthetic fixture a ratio-configured pool entry at
/// `(geometry, seed, ratio label)` is built from — one RNG stream, params
/// first, masks second. [`PoolEntry`] config parsing builds through this
/// and bit-identity tests re-derive it, so the two can never drift.
pub fn synth_entry_fixture(
    geometry: &str,
    seed: u64,
    ratio_label: &str,
) -> Result<(Manifest, Vec<HostTensor>, QuantPlan)> {
    let mut rng = Rng::new(seed);
    let manifest = synth::serving_manifest_for(geometry)?;
    let params = synth::random_params(&manifest, &mut rng);
    let ratio = parse_ratio_arg(ratio_label)?;
    let masks = synth::random_masks(&manifest, ratio, &mut rng);
    let plan = QuantPlan::from_mask_set(
        MaskSet { name: ratio_label.to_string(), layers: masks.layers },
        Provenance::Synthetic { seed, ratio: ratio.label() },
    )
    .with_model(&manifest.model_name);
    Ok((manifest, params, plan))
}

// ---- artifact packing -----------------------------------------------------

/// Schema version of the manifest descriptor blob a bundle stores.
const MANIFEST_DESCRIPTOR_VERSION: u64 = 1;

/// The manifest blob `pack_pool` stores. Synthetic serving manifests are
/// fully reconstructible from their zoo geometry, so the blob is a small
/// strict descriptor rather than a serialized tensor table — the digest
/// still pins the identity (geometry + model name) the entry must resolve
/// to at boot.
pub fn manifest_descriptor_bytes(geometry: &str, model: &str) -> Vec<u8> {
    Json::obj(vec![
        ("ilmpq_manifest", Json::Num(MANIFEST_DESCRIPTOR_VERSION as f64)),
        ("geometry", Json::Str(geometry.to_string())),
        ("model", Json::Str(model.to_string())),
    ])
    .to_string_compact()
    .into_bytes()
}

/// Parse and resolve a manifest descriptor blob. Strict in the lockfile
/// style: unknown keys are an error, the version must match, and the
/// geometry must resolve to a manifest whose model name equals the
/// descriptor's. Returns the manifest plus the geometry it came from.
pub fn manifest_from_descriptor(bytes: &[u8]) -> Result<(Manifest, String)> {
    let text = std::str::from_utf8(bytes).context("manifest descriptor is not UTF-8")?;
    let j = Json::parse(text).map_err(|e| anyhow!("manifest descriptor: {e}"))?;
    let obj = j
        .as_obj()
        .ok_or_else(|| anyhow!("manifest descriptor must be a JSON object"))?;
    let mut version = None;
    let mut geometry = None;
    let mut model = None;
    for (key, val) in obj {
        match key.as_str() {
            "ilmpq_manifest" => version = val.as_f64(),
            "geometry" => geometry = val.as_str().map(str::to_string),
            "model" => model = val.as_str().map(str::to_string),
            _ => anyhow::bail!(
                "manifest descriptor: unknown key {key:?} (known: ilmpq_manifest, \
                 geometry, model)"
            ),
        }
    }
    let version =
        version.ok_or_else(|| anyhow!("manifest descriptor lacks \"ilmpq_manifest\""))?;
    anyhow::ensure!(
        version == MANIFEST_DESCRIPTOR_VERSION as f64,
        "manifest descriptor version {version} unsupported (this build reads \
         {MANIFEST_DESCRIPTOR_VERSION})"
    );
    let geometry = geometry.ok_or_else(|| anyhow!("manifest descriptor lacks \"geometry\""))?;
    let model = model.ok_or_else(|| anyhow!("manifest descriptor lacks \"model\""))?;
    let manifest = synth::serving_manifest_for(&geometry)?;
    anyhow::ensure!(
        manifest.model_name == model,
        "manifest descriptor names model {model:?} but geometry {geometry:?} \
         resolves to {:?}",
        manifest.model_name
    );
    Ok((manifest, geometry))
}

/// Params blob encoding: flat little-endian f32 concatenation in manifest
/// params order — the same layout as `params_init.bin`.
pub fn params_to_bytes(params: &[HostTensor]) -> Vec<u8> {
    let total: usize = params.iter().map(HostTensor::len).sum();
    let mut out = Vec::with_capacity(total * 4);
    for t in params {
        for v in t.as_f32() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    out
}

/// Split a flat params blob back into tensors by the manifest's shapes
/// (mirrors `Manifest::load_init_params`).
pub fn params_from_bytes(m: &Manifest, bytes: &[u8]) -> Result<Vec<HostTensor>> {
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "params blob is {} bytes, not a multiple of 4",
        bytes.len()
    );
    let flat: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let mut out = Vec::with_capacity(m.params.len());
    let mut off = 0usize;
    for (name, shape) in &m.params {
        let n: usize = shape.iter().product();
        if off + n > flat.len() {
            anyhow::bail!("params blob too short at {name}");
        }
        out.push(HostTensor::f32(shape.clone(), flat[off..off + n].to_vec()));
        off += n;
    }
    if off != flat.len() {
        anyhow::bail!("params blob has {} trailing floats", flat.len() - off);
    }
    Ok(out)
}

/// Walk a pool's entries into the store and emit the lockfile that pins
/// them. Only pool-built entries can pack (a pre-built entry carries no
/// params to serialize). The plan blob is each entry's *current* plan, so
/// packing after a hot-swap pins the swapped-in assignment.
pub fn pack_pool(pool: &ServerPool, store: &Store) -> Result<Bundle> {
    let mut models = Vec::with_capacity(pool.entries.len());
    for e in &pool.entries {
        let backend = e.backend_name.clone().ok_or_else(|| {
            anyhow!(
                "model {:?} was attached pre-built; only pool-built entries can pack",
                e.name
            )
        })?;
        let plan = e
            .current_plan()
            .ok_or_else(|| anyhow!("model {:?} has no plan to pack", e.name))?;
        let manifest = store
            .put(&manifest_descriptor_bytes(&e.geometry, &e.manifest.model_name))
            .with_context(|| format!("store manifest for model {:?}", e.name))?;
        let params = store
            .put(&params_to_bytes(&e.params))
            .with_context(|| format!("store params for model {:?}", e.name))?;
        let plan_digest = store
            .put(plan.to_json().to_string_compact().as_bytes())
            .with_context(|| format!("store plan for model {:?}", e.name))?;
        models.push(BundleModel {
            name: e.name.clone(),
            backend,
            geometry: e.geometry.clone(),
            model: e.manifest.model_name.clone(),
            manifest,
            params,
            plan: plan_digest,
        });
    }
    Ok(Bundle { version: BUNDLE_VERSION, default: pool.default.clone(), models })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_rejects_bad_pools() {
        let parse = |s: &str| ServerPool::from_json(&Json::parse(s).unwrap());
        assert!(parse("{}").is_err(), "no models array");
        assert!(parse(r#"{"models": []}"#).is_err(), "empty pool");
        assert!(
            parse(r#"{"models": [{"backend": "qgemm"}]}"#).is_err(),
            "nameless model"
        );
        assert!(
            parse(r#"{"models": [{"name": "a"}, {"name": "a"}]}"#).is_err(),
            "duplicate names"
        );
        assert!(
            parse(r#"{"models": [{"name": "a"}], "default": "b"}"#).is_err(),
            "default not in pool"
        );
        assert!(
            parse(r#"{"models": [{"name": "a", "backend": "no-such"}]}"#).is_err(),
            "unknown backend"
        );
        assert!(
            parse(r#"{"models": [{"name": "a", "synthetic": "resnet18"}]}"#).is_err(),
            "unserveable geometry"
        );
        assert!(
            parse(r#"{"models": [{"name": "a", "ratio": "x", "plan": "y"}]}"#).is_err(),
            "plan and ratio together"
        );
    }

    #[test]
    fn pool_parses_and_defaults() {
        let j = Json::parse(
            r#"{"models": [
                {"name": "a", "synthetic": "tinyresnet", "ratio": "30:60:10"},
                {"name": "b", "synthetic": "vggnarrow", "queue-depth": 4}
            ]}"#,
        )
        .unwrap();
        let pool = ServerPool::from_json(&j).unwrap();
        assert_eq!(pool.default_name(), "a");
        assert_eq!(pool.names(), vec!["a".to_string(), "b".to_string()]);
        let a = pool.entry("a").unwrap();
        assert_eq!(a.manifest().model_name, "tiny-synth");
        assert_eq!(a.current_plan().unwrap().name, "30:60:10");
        let b = pool.entry("b").unwrap();
        assert_eq!(b.manifest().model_name, "vggnarrow-synth");
        // Default ratio when none is given.
        assert_eq!(b.current_plan().unwrap().name, "65:30:5");
        assert!(pool.entry("c").is_none());
    }

    #[test]
    fn synthetic_pair_shape_and_describe() {
        let pool = ServerPool::synthetic_pair(7).unwrap();
        assert_eq!(pool.default_name(), "tiny");
        assert_eq!(pool.names(), vec!["tiny".to_string(), "narrow".to_string()]);
        let d = pool.describe();
        assert_eq!(d.get("default").and_then(Json::as_str), Some("tiny"));
        let models = d.get("models").and_then(Json::as_arr).unwrap();
        assert_eq!(models.len(), 2);
        for m in models {
            // Cold until first traffic: lazy prepare.
            assert_eq!(m.get("state").and_then(Json::as_str), Some("cold"));
            assert_eq!(m.get("breaker").and_then(Json::as_str), Some("closed"));
            assert_eq!(m.get("prepares").and_then(Json::as_usize), Some(0));
            assert!(m.get("plan").and_then(Json::as_str).is_some());
            assert_eq!(
                m.get("provenance").and_then(Json::as_str),
                Some("synthetic")
            );
        }
        // Both geometries share the wire image size.
        let tiny = pool.entry("tiny").unwrap();
        let narrow = pool.entry("narrow").unwrap();
        assert_eq!(tiny.image_elems(), narrow.image_elems());
        assert_ne!(
            tiny.manifest().model_name,
            narrow.manifest().model_name
        );
    }

    #[test]
    fn synth_parts_reproduce_entry_params() {
        // The bit-identity contract: `synth_parts` must draw exactly the
        // params a pool entry at the same (geometry, seed) was built with.
        let pool = ServerPool::synthetic_pair(21).unwrap();
        let tiny = pool.entry("tiny").unwrap();
        let (m, params) = synth_parts("tinyresnet", 21).unwrap();
        assert_eq!(m.model_name, tiny.manifest().model_name);
        assert_eq!(params, tiny.params);
    }

    #[test]
    fn synth_entry_fixture_matches_pool_construction() {
        let pool = ServerPool::synthetic_pair(21).unwrap();
        let tiny = pool.entry("tiny").unwrap();
        let (m, params, plan) = synth_entry_fixture("tinyresnet", 21, "ilmpq2").unwrap();
        assert_eq!(m.model_name, tiny.manifest().model_name);
        assert_eq!(params, tiny.params);
        assert_eq!(plan, *tiny.current_plan().unwrap());
    }

    #[test]
    fn params_codec_roundtrip_and_errors() {
        let (m, params) = synth_parts("tinyresnet", 5).unwrap();
        let bytes = params_to_bytes(&params);
        let total: usize = params.iter().map(HostTensor::len).sum();
        assert_eq!(bytes.len(), total * 4);
        let back = params_from_bytes(&m, &bytes).unwrap();
        assert_eq!(back, params, "params blob round-trip must be bit-identical");

        let err = params_from_bytes(&m, &bytes[..bytes.len() - 4]).unwrap_err();
        assert!(format!("{err:#}").contains("too short"), "{err:#}");
        let mut long = bytes.clone();
        long.extend_from_slice(&1.0f32.to_le_bytes());
        let err = params_from_bytes(&m, &long).unwrap_err();
        assert!(format!("{err:#}").contains("trailing"), "{err:#}");
        let err = params_from_bytes(&m, &bytes[..bytes.len() - 1]).unwrap_err();
        assert!(format!("{err:#}").contains("multiple of 4"), "{err:#}");
    }

    #[test]
    fn manifest_descriptor_roundtrip_and_strictness() {
        let bytes = manifest_descriptor_bytes("tinyresnet", "tiny-synth");
        let (m, g) = manifest_from_descriptor(&bytes).unwrap();
        assert_eq!(m.model_name, "tiny-synth");
        assert_eq!(g, "tinyresnet");

        let err = manifest_from_descriptor(
            br#"{"ilmpq_manifest":1,"geometry":"tinyresnet","model":"tiny-synth","x":1}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("unknown key"), "{err:#}");
        let err = manifest_from_descriptor(
            br#"{"ilmpq_manifest":9,"geometry":"tinyresnet","model":"tiny-synth"}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("unsupported"), "{err:#}");
        // A lying model name must not resolve.
        let err = manifest_from_descriptor(
            br#"{"ilmpq_manifest":1,"geometry":"tinyresnet","model":"resnet-152"}"#,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("resolves to"), "{err:#}");
    }

    fn temp_store(tag: &str) -> Store {
        let dir = std::env::temp_dir()
            .join(format!("ilmpq-pool-bundle-{}-{}", std::process::id(), tag));
        let _ = std::fs::remove_dir_all(&dir);
        Store::open(&dir).unwrap()
    }

    #[test]
    fn pack_then_boot_from_bundle_is_identity() {
        let store = temp_store("identity");
        let pool = ServerPool::synthetic_pair(33).unwrap();
        let bundle = pack_pool(&pool, &store).unwrap();
        assert_eq!(bundle.default, "tiny");
        assert_eq!(bundle.models.len(), 2);

        let booted = ServerPool::from_bundle(&bundle, &store).unwrap();
        assert_eq!(booted.default_name(), "tiny");
        for name in ["tiny", "narrow"] {
            let a = pool.entry(name).unwrap();
            let b = booted.entry(name).unwrap();
            assert_eq!(a.manifest().model_name, b.manifest().model_name);
            assert_eq!(a.params, b.params, "{name}: params must round-trip bit-exactly");
            assert_eq!(*a.current_plan().unwrap(), *b.current_plan().unwrap());
            assert_eq!(a.plan_digest(), b.plan_digest());
            assert!(a.bundle_digests().is_none(), "config-built entries carry no bundle");
            let (md, pd, qd) = b.bundle_digests().unwrap();
            let row = bundle.model(name).unwrap();
            assert_eq!((md, pd, qd), (row.manifest, row.params, row.plan));
            // Fresh boot: blobs verify and the executing plan is the bundled one.
            assert_eq!(b.verify_bundle().unwrap().unwrap(), true);
            // The registry row advertises both digest views.
            let d = b.describe();
            assert_eq!(
                d.get("plan_digest").and_then(Json::as_str),
                Some(b.plan_digest().unwrap().to_hex().as_str())
            );
            let bj = d.get("bundle").unwrap();
            assert_eq!(bj.get("params").and_then(Json::as_str), Some(pd.to_hex().as_str()));
        }
    }

    #[test]
    fn tampered_blob_fails_bundle_boot_and_verify() {
        let store = temp_store("tamper");
        let pool = ServerPool::synthetic_pair(44).unwrap();
        let bundle = pack_pool(&pool, &store).unwrap();
        let row = bundle.model("tiny").unwrap();

        // Flip one byte in the stored params blob.
        let path = store.path_of(&row.params);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();

        let err = ServerPool::from_bundle(&bundle, &store).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("mismatch") && msg.contains("tiny/params"), "{msg}");
        match store.verify(&row.params, "tiny/params").unwrap_err() {
            ArtifactError::DigestMismatch { blob, .. } => assert_eq!(blob, "tiny/params"),
            other => panic!("expected DigestMismatch, got {other}"),
        }

        // A missing blob is just as loud.
        std::fs::remove_file(&path).unwrap();
        let err = ServerPool::from_bundle(&bundle, &store).unwrap_err();
        assert!(format!("{err:#}").contains("missing blob"), "{err:#}");
    }
}
