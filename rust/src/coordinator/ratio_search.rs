//! Offline mixing-ratio search (paper §II-B: "the actual mixing ratio ...
//! can be determined offline by examining FPGA throughput").
//!
//! Sweeps the PoT share of the 4-bit rows (the Fixed-8 share is pinned at
//! the paper's 5%) and simulates end-to-end throughput on the target device;
//! the optimum is where the DSP lane and the LUT lane finish together in
//! every layer. This is the procedure that produced 60:35:5 on XC7Z020 and
//! 65:30:5 on XC7Z045 in the paper.

use crate::fpga::{simulate, DeviceModel, Mode, NetConfig};
use crate::model::Network;
use crate::quant::{MaskSet, Provenance, QuantPlan, Ratio};

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub ratio: Ratio,
    pub throughput_gops: f64,
    pub latency_s: f64,
}

/// Search result: the optimum + the full sweep (for the bench output).
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub device: String,
    pub best: SweepPoint,
    pub sweep: Vec<SweepPoint>,
}

impl SearchResult {
    /// The winning assignment as a loadable [`QuantPlan`] over `net`'s
    /// layer geometry — exactly the masks the simulator scored for the
    /// optimum, with the sweep point recorded as provenance. This is how
    /// a `ratio-search` result survives the process: save it, `ilmpq plan
    /// show` it, or serve it against a matching manifest.
    pub fn winning_plan(&self, net: &Network) -> QuantPlan {
        let label = self.best.ratio.label();
        let cfg = NetConfig::from_ratio(net, self.best.ratio, false, &label);
        // A degenerate sweep ([`best_point`]'s all-non-finite fallback)
        // must not poison the artifact: JSON has no NaN token, so a
        // non-finite sweep number would serialize as `null` and make the
        // saved plan unloadable. Record 0.0 — "no measured throughput" —
        // and keep the file valid.
        let fin = |v: f64| if v.is_finite() { v } else { 0.0 };
        QuantPlan::from_mask_set(
            MaskSet {
                name: format!("ratio-search-{}-{}", self.device, label),
                layers: cfg.masks,
            },
            Provenance::RatioSearch {
                device: self.device.clone(),
                ratio: label,
                throughput_gops: fin(self.best.throughput_gops),
                latency_ms: fin(self.best.latency_s * 1e3),
            },
        )
        .with_model(&net.name)
    }
}

/// The throughput-optimal sweep point. Non-finite throughputs (a degenerate
/// simulation) are excluded from the comparison — `f64::total_cmp` would
/// otherwise rank NaN *above* every real number and crown a poisoned point,
/// and the historic `partial_cmp().unwrap()` panicked outright. If every
/// point is non-finite the first one is returned so the caller still gets
/// the sweep back (its numbers make the problem visible).
fn best_point(sweep: &[SweepPoint]) -> Option<SweepPoint> {
    sweep
        .iter()
        .filter(|p| p.throughput_gops.is_finite())
        .max_by(|a, b| a.throughput_gops.total_cmp(&b.throughput_gops))
        .or_else(|| sweep.first())
        .cloned()
}

/// Sweep PoT percentage `0..=max_pot` (step `step`) with Fixed-8 fixed at
/// `fixed8_pct`, simulating `net` on `device` in intra-layer mode.
pub fn search(
    net: &Network,
    device: &DeviceModel,
    fixed8_pct: f64,
    step: f64,
    max_pot: f64,
) -> SearchResult {
    assert!(step > 0.0);
    assert!(max_pot >= 0.0, "max_pot must be non-negative so the sweep has a point");
    let mut sweep = Vec::new();
    let mut pot = 0.0;
    while pot <= max_pot + 1e-9 {
        let ratio = Ratio::new(pot, 100.0 - fixed8_pct - pot, fixed8_pct);
        let cfg = NetConfig::from_ratio(net, ratio, false, &ratio.label());
        let r = simulate(net, &cfg, device, Mode::IntraLayer);
        sweep.push(SweepPoint {
            ratio,
            throughput_gops: r.throughput_gops,
            latency_s: r.latency_s,
        });
        pot += step;
    }
    // analyze:allow(the pot=0 iteration always runs, and best_point falls back to sweep.first())
    let best = best_point(&sweep).expect("non-empty sweep");
    SearchResult { device: device.name.to_string(), best, sweep }
}

/// The paper's search: 5% Fixed-8, PoT swept at 1% granularity.
pub fn search_default(net: &Network, device: &DeviceModel) -> SearchResult {
    search(net, device, 5.0, 1.0, 95.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::resnet18;

    #[test]
    fn optimum_is_interior_and_pot_heavy() {
        // The LUT lane out-muscles the DSP lane on both devices, so the
        // optimum must be PoT-heavy (paper: 60% and 65%) — but not 0 or 95.
        for d in DeviceModel::all() {
            let r = search(&resnet18(), &d, 5.0, 5.0, 95.0);
            let pot = r.best.ratio.pot4;
            assert!(
                (40.0..90.0).contains(&pot),
                "{}: optimum at {pot}%",
                d.name
            );
        }
    }

    #[test]
    fn z045_optimum_at_least_z020() {
        // Z045 has more LUT bandwidth relative to its DSP count
        // (paper: 65% vs 60%).
        let z20 = search(&resnet18(), &DeviceModel::xc7z020(), 5.0, 1.0, 95.0);
        let z45 = search(&resnet18(), &DeviceModel::xc7z045(), 5.0, 1.0, 95.0);
        assert!(
            z45.best.ratio.pot4 >= z20.best.ratio.pot4 - 2.0,
            "z45 {} vs z20 {}",
            z45.best.ratio.pot4,
            z20.best.ratio.pot4
        );
    }

    #[test]
    fn sweep_is_unimodalish_around_best() {
        // Throughput should fall off on both sides of the optimum (balance
        // argument) — check the endpoints are strictly worse.
        let r = search(&resnet18(), &DeviceModel::xc7z045(), 5.0, 5.0, 95.0);
        let first = r.sweep.first().unwrap().throughput_gops;
        let last = r.sweep.last().unwrap().throughput_gops;
        assert!(r.best.throughput_gops > first * 1.05);
        assert!(r.best.throughput_gops > last * 1.05);
    }

    #[test]
    fn best_is_max_of_sweep() {
        let r = search(&resnet18(), &DeviceModel::xc7z020(), 5.0, 10.0, 90.0);
        for p in &r.sweep {
            assert!(p.throughput_gops <= r.best.throughput_gops + 1e-9);
        }
    }

    fn point(pot: f64, gops: f64) -> SweepPoint {
        SweepPoint {
            ratio: Ratio::new(pot, 95.0 - pot, 5.0),
            throughput_gops: gops,
            latency_s: 1.0 / gops.max(1e-9),
        }
    }

    #[test]
    fn nan_sweep_point_neither_panics_nor_wins() {
        // The PR-4 `percentile` bug class: max_by(partial_cmp().unwrap())
        // panicked on a NaN sample. A degenerate simulated throughput must
        // neither kill the sweep nor be crowned the optimum.
        let sweep = vec![
            point(0.0, 50.0),
            point(5.0, f64::NAN),
            point(10.0, 80.0),
            point(15.0, f64::INFINITY),
            point(20.0, 60.0),
        ];
        let best = best_point(&sweep).expect("non-empty sweep");
        assert_eq!(best.ratio.pot4, 10.0, "finite maximum must win, got {best:?}");
        assert!(best.throughput_gops.is_finite());
        // All-NaN degenerates to the first point rather than panicking.
        let poisoned = vec![point(0.0, f64::NAN), point(5.0, f64::NAN)];
        assert_eq!(best_point(&poisoned).unwrap().ratio.pot4, 0.0);
        assert!(best_point(&[]).is_none());
    }

    #[test]
    fn degenerate_winning_plan_still_serializes_loadably() {
        // A NaN best (all-non-finite fallback) must yield a plan whose
        // provenance round-trips — non-finite numbers would serialize as
        // JSON null and make the saved artifact unloadable.
        let net = resnet18();
        let best = SweepPoint {
            ratio: Ratio::new(10.0, 85.0, 5.0),
            throughput_gops: f64::NAN,
            latency_s: f64::NAN,
        };
        let r = SearchResult {
            device: "xc7z045".into(),
            best: best.clone(),
            sweep: vec![best],
        };
        let plan = r.winning_plan(&net);
        let text = plan.to_json().to_string_compact();
        let back = QuantPlan::from_json(&crate::util::Json::parse(&text).unwrap())
            .expect("degenerate plan must stay loadable");
        assert_eq!(back, plan);
        match back.provenance {
            crate::quant::Provenance::RatioSearch { throughput_gops, latency_ms, .. } => {
                assert_eq!(throughput_gops, 0.0);
                assert_eq!(latency_ms, 0.0);
            }
            other => panic!("expected RatioSearch, got {other:?}"),
        }
    }

    #[test]
    fn winning_plan_carries_sweep_provenance_and_geometry() {
        use crate::quant::Provenance;
        let net = resnet18();
        let r = search(&net, &DeviceModel::xc7z045(), 5.0, 5.0, 95.0);
        let plan = r.winning_plan(&net);
        assert_eq!(plan.masks.layers.len(), net.layers.len());
        assert_eq!(plan.model, net.name);
        match &plan.provenance {
            Provenance::RatioSearch { device, throughput_gops, .. } => {
                assert_eq!(device, "xc7z045");
                assert_eq!(*throughput_gops, r.best.throughput_gops);
            }
            other => panic!("expected RatioSearch provenance, got {other:?}"),
        }
        // The plan's row mix reflects the winning ratio (rounded per layer).
        let (p, _, f8) = plan.total_fractions();
        assert!((p * 100.0 - r.best.ratio.pot4).abs() < 5.0, "pot {p}");
        assert!((f8 * 100.0 - 5.0).abs() < 3.0, "f8 {f8}");
        // And it survives serialization.
        let text = plan.to_json().to_string_compact();
        let back = QuantPlan::from_json(&crate::util::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, plan);
    }
}
