//! Offline mixing-ratio search (paper §II-B: "the actual mixing ratio ...
//! can be determined offline by examining FPGA throughput").
//!
//! Sweeps the PoT share of the 4-bit rows (the Fixed-8 share is pinned at
//! the paper's 5%) and simulates end-to-end throughput on the target device;
//! the optimum is where the DSP lane and the LUT lane finish together in
//! every layer. This is the procedure that produced 60:35:5 on XC7Z020 and
//! 65:30:5 on XC7Z045 in the paper.

use crate::fpga::{simulate, DeviceModel, Mode, NetConfig};
use crate::model::Network;
use crate::quant::Ratio;

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub ratio: Ratio,
    pub throughput_gops: f64,
    pub latency_s: f64,
}

/// Search result: the optimum + the full sweep (for the bench output).
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub device: String,
    pub best: SweepPoint,
    pub sweep: Vec<SweepPoint>,
}

/// Sweep PoT percentage `0..=max_pot` (step `step`) with Fixed-8 fixed at
/// `fixed8_pct`, simulating `net` on `device` in intra-layer mode.
pub fn search(
    net: &Network,
    device: &DeviceModel,
    fixed8_pct: f64,
    step: f64,
    max_pot: f64,
) -> SearchResult {
    assert!(step > 0.0);
    let mut sweep = Vec::new();
    let mut pot = 0.0;
    while pot <= max_pot + 1e-9 {
        let ratio = Ratio::new(pot, 100.0 - fixed8_pct - pot, fixed8_pct);
        let cfg = NetConfig::from_ratio(net, ratio, false, &ratio.label());
        let r = simulate(net, &cfg, device, Mode::IntraLayer);
        sweep.push(SweepPoint {
            ratio,
            throughput_gops: r.throughput_gops,
            latency_s: r.latency_s,
        });
        pot += step;
    }
    let best = sweep
        .iter()
        .cloned()
        .max_by(|a, b| a.throughput_gops.partial_cmp(&b.throughput_gops).unwrap())
        .expect("non-empty sweep");
    SearchResult { device: device.name.to_string(), best, sweep }
}

/// The paper's search: 5% Fixed-8, PoT swept at 1% granularity.
pub fn search_default(net: &Network, device: &DeviceModel) -> SearchResult {
    search(net, device, 5.0, 1.0, 95.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::resnet18;

    #[test]
    fn optimum_is_interior_and_pot_heavy() {
        // The LUT lane out-muscles the DSP lane on both devices, so the
        // optimum must be PoT-heavy (paper: 60% and 65%) — but not 0 or 95.
        for d in DeviceModel::all() {
            let r = search(&resnet18(), &d, 5.0, 5.0, 95.0);
            let pot = r.best.ratio.pot4;
            assert!(
                (40.0..90.0).contains(&pot),
                "{}: optimum at {pot}%",
                d.name
            );
        }
    }

    #[test]
    fn z045_optimum_at_least_z020() {
        // Z045 has more LUT bandwidth relative to its DSP count
        // (paper: 65% vs 60%).
        let z20 = search(&resnet18(), &DeviceModel::xc7z020(), 5.0, 1.0, 95.0);
        let z45 = search(&resnet18(), &DeviceModel::xc7z045(), 5.0, 1.0, 95.0);
        assert!(
            z45.best.ratio.pot4 >= z20.best.ratio.pot4 - 2.0,
            "z45 {} vs z20 {}",
            z45.best.ratio.pot4,
            z20.best.ratio.pot4
        );
    }

    #[test]
    fn sweep_is_unimodalish_around_best() {
        // Throughput should fall off on both sides of the optimum (balance
        // argument) — check the endpoints are strictly worse.
        let r = search(&resnet18(), &DeviceModel::xc7z045(), 5.0, 5.0, 95.0);
        let first = r.sweep.first().unwrap().throughput_gops;
        let last = r.sweep.last().unwrap().throughput_gops;
        assert!(r.best.throughput_gops > first * 1.05);
        assert!(r.best.throughput_gops > last * 1.05);
    }

    #[test]
    fn best_is_max_of_sweep() {
        let r = search(&resnet18(), &DeviceModel::xc7z020(), 5.0, 10.0, 90.0);
        for p in &r.sweep {
            assert!(p.throughput_gops <= r.best.throughput_gops + 1e-9);
        }
    }
}
