//! On-device per-filter Hessian sensitivity (paper §II-C step 1) from Rust.
//!
//! Blockwise power iteration on the AOT `hessian_hvp` artifact — the same
//! algorithm as `python/compile/hessian.py` (one HVP per iteration covers
//! every filter; per-row renormalization between iterations; per-row
//! Rayleigh quotient at the end) so the coordinator can re-derive precision
//! assignments without Python, e.g. after on-device fine-tuning.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::quant::gemmview::{from_gemm_rows, gemm_rows};
use crate::runtime::{HostTensor, Runtime};
use crate::util::Rng;

/// Per-layer eigenvalue estimates keyed by layer name.
pub type Eigs = BTreeMap<String, Vec<f64>>;

fn renorm_rows(t: &HostTensor) -> HostTensor {
    let mut rows = gemm_rows(t);
    for row in rows.iter_mut() {
        let norm = row.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let norm = norm.max(1e-12) as f32;
        for v in row.iter_mut() {
            *v /= norm;
        }
    }
    from_gemm_rows(&rows, &t.shape)
}

fn rayleigh_rows(v: &HostTensor, hv: &HostTensor) -> Vec<f64> {
    let vr = gemm_rows(v);
    let hr = gemm_rows(hv);
    vr.iter()
        .zip(&hr)
        .map(|(a, b)| a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum())
        .collect()
}

/// Estimate the top eigenvalue of each filter's Hessian block.
///
/// `params` must be in AOT order; `iters` power iterations (6-8 suffice —
/// the assignment only needs the *ranking*). Data comes from the manifest's
/// train split (first `hvp_batch` samples, matching aot.py's default-mask
/// computation).
pub fn filter_eigs(
    rt: &Runtime,
    params: &[HostTensor],
    iters: usize,
    seed: u64,
) -> Result<Eigs> {
    let m = &rt.manifest;
    let qnames: Vec<&str> =
        m.quantized_layers.iter().map(|(n, _, _)| n.as_str()).collect();
    let (x_train, y_train) = m.data.load_train()?;
    let b = m.hvp_batch;
    let img = m.data.image_elems();
    let x = HostTensor::f32(
        vec![b, m.data.height, m.data.width, m.data.channels],
        x_train[..b * img].to_vec(),
    );
    let y = HostTensor::i32(vec![b], y_train[..b].to_vec());

    let mut rng = Rng::new(seed);
    // Init: per-row-normalized gaussian on quantized layers, zeros elsewhere.
    let mut v: Vec<HostTensor> = m
        .params
        .iter()
        .zip(params)
        .map(|((name, shape), _)| {
            if qnames.contains(&name.as_str()) {
                let n: usize = shape.iter().product();
                let mut data = vec![0f32; n];
                rng.fill_normal(&mut data, 1.0);
                renorm_rows(&HostTensor::f32(shape.clone(), data))
            } else {
                HostTensor::zeros(shape.clone())
            }
        })
        .collect();

    let run_hvp = |v: &[HostTensor]| -> Result<Vec<HostTensor>> {
        let mut inputs = Vec::with_capacity(2 * params.len() + 2);
        inputs.extend(params.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(x.clone());
        inputs.push(y.clone());
        rt.run("hessian_hvp", &inputs)
    };

    for _ in 0..iters {
        let hv = run_hvp(&v)?;
        v = m
            .params
            .iter()
            .zip(hv)
            .map(|((name, shape), h)| {
                if qnames.contains(&name.as_str()) {
                    renorm_rows(&h)
                } else {
                    HostTensor::zeros(shape.clone())
                }
            })
            .collect();
    }
    let hv = run_hvp(&v)?;

    let mut eigs = Eigs::new();
    for (i, (name, _)) in m.params.iter().enumerate() {
        if qnames.contains(&name.as_str()) {
            eigs.insert(name.clone(), rayleigh_rows(&v[i], &hv[i]));
        }
    }
    Ok(eigs)
}

/// Spearman-style rank agreement between two eigenvalue vectors — used by
/// tests to compare the Rust power iteration against the Python one stored
/// in the manifest (exact values differ by probe randomness; ranking of the
/// top filters is what the assignment consumes).
pub fn top_k_overlap(a: &[f64], b: &[f64], k: usize) -> f64 {
    if a.len() != b.len() || a.is_empty() {
        return 0.0;
    }
    let top = |v: &[f64]| -> Vec<usize> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&p, &q| v[q].total_cmp(&v[p]).then(p.cmp(&q)));
        idx.truncate(k);
        idx
    };
    let (ta, tb) = (top(a), top(b));
    let hits = ta.iter().filter(|i| tb.contains(i)).count();
    hits as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renorm_makes_unit_rows() {
        let t = HostTensor::f32(vec![2, 3], vec![3., 0., 4., 0., 5., 12.]);
        let n = renorm_rows(&t);
        let rows = gemm_rows(&n);
        for row in rows {
            let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn rayleigh_on_diagonal_matrix() {
        // v = e1 per row, hv = 2*v  ->  eigenvalue 2 per row.
        let v = HostTensor::f32(vec![2, 2], vec![1., 0., 0., 1.]);
        let hv = HostTensor::f32(vec![2, 2], vec![2., 0., 0., 2.]);
        assert_eq!(rayleigh_rows(&v, &hv), vec![2.0, 2.0]);
    }

    #[test]
    fn top_k_overlap_metrics() {
        let a = vec![5.0, 1.0, 4.0, 0.1];
        let b = vec![4.9, 0.9, 4.2, 0.2];
        assert_eq!(top_k_overlap(&a, &b, 2), 1.0);
        let c = vec![0.0, 9.0, 0.0, 9.1];
        assert_eq!(top_k_overlap(&a, &c, 2), 0.0);
        assert_eq!(top_k_overlap(&a, &b[..2].to_vec(), 2), 0.0); // len mismatch
    }
}
