//! The serving loop: router + dynamic batcher + worker pool over PJRT.
//!
//! Architecture (threads + channels; the sandbox has no tokio, and the
//! workload — CPU-bound PJRT executions — wants a small fixed pool anyway):
//!
//! ```text
//!   clients ──submit──▶ router/batcher thread ──Batch──▶ worker 0..N-1
//!                        (Batcher<Request>)               │  PJRT execute
//!   clients ◀──reply channel per request──────────────────┘  + FPGA-sim
//! ```
//!
//! Every executed batch also gets a *simulated FPGA latency* from the
//! performance model (the codesign view: numerics from XLA-CPU, timing from
//! the Zynq model) so the serving benches can report both.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Assembled, BatchPolicy, Batcher};
use super::metrics::Metrics;
use crate::fpga::{simulate, DeviceModel, Mode, NetConfig, SimReport};
use crate::model::zoo;
use crate::quant::MaskSet;
use crate::runtime::{HostTensor, Runtime};

/// One inference request: a flattened image.
pub struct Request {
    pub image: Vec<f32>,
    pub reply: Sender<Response>,
    pub submitted: Instant,
}

/// The reply: logits + argmax + timing breakdown.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub queue_wait: Duration,
    pub e2e: Duration,
    /// What this request would have cost on the simulated FPGA.
    pub sim_fpga: Duration,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_wait: Duration,
    /// Ratio name for the quantization masks (manifest `default_masks`).
    pub ratio_name: String,
    /// Device for the FPGA-sim timing overlay.
    pub device: String,
    /// Serve pre-quantized ("frozen") weights through the
    /// `infer_frozen_b{N}` artifacts — the FPGA-faithful fast path (weights
    /// live pre-quantized in BRAM; no fake-quant ops per request). ~3x
    /// lower execute cost; numerically identical (quantizers idempotent).
    pub frozen: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_wait: Duration::from_millis(5),
            ratio_name: "ilmpq2".into(),
            device: "xc7z045".into(),
            frozen: true,
        }
    }
}

enum WorkerMsg {
    Batch(Assembled<Request>),
    Shutdown,
}

/// Handle to a running server.
pub struct Server {
    submit_tx: Sender<Request>,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// The FPGA-sim report for the configured (model, ratio, device).
    pub sim: SimReport,
}

impl Server {
    /// Start router + workers. `params` are the (trained) model parameters
    /// in AOT order; `masks` the quantization config.
    pub fn start(
        rt: Arc<Runtime>,
        params: Vec<HostTensor>,
        masks: &MaskSet,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let m = &rt.manifest;
        let policy = BatchPolicy::new(m.infer_batches.clone(), cfg.max_wait);
        let metrics = Arc::new(Metrics::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        // Frozen path: quantize the weights once here (BRAM-image
        // analogue), serve mask-free artifacts; otherwise pass masks along
        // and let the graph fake-quant per request.
        let frozen = cfg.frozen;
        let (params, mask_tensors) = if frozen {
            let names: Vec<String> =
                m.params.iter().map(|(n, _)| n.clone()).collect();
            (
                Arc::new(crate::quant::freeze::freeze_params(&params, &names, masks)),
                Arc::new(Vec::new()),
            )
        } else {
            (Arc::new(params), Arc::new(m.mask_tensors(masks)))
        };
        let artifact_prefix = if frozen { "infer_frozen_b" } else { "infer_b" };

        // Pre-compile every infer artifact (no compile stalls on the path).
        for &b in &m.infer_batches {
            rt.engine.load(m.artifact(&format!("{artifact_prefix}{b}"))?)?;
        }

        // FPGA-sim overlay: per-image latency of this config on the device.
        let device = DeviceModel::by_name(&cfg.device)
            .ok_or_else(|| anyhow::anyhow!("unknown device {}", cfg.device))?;
        let net = zoo::tinyresnet(
            m.height,
            m.width,
            m.channels,
            &m.widths,
            m.classes,
        );
        let mask_set = m
            .default_masks
            .get(&cfg.ratio_name)
            .ok_or_else(|| anyhow::anyhow!("unknown ratio {}", cfg.ratio_name))?;
        let sim_cfg = NetConfig::from_masks(&cfg.ratio_name, mask_set.layers.clone());
        let sim = simulate(&net, &sim_cfg, &device, Mode::IntraLayer);
        let sim_per_image = sim.latency_s;

        let (submit_tx, submit_rx) = channel::<Request>();
        let (work_tx, work_rx) = channel::<WorkerMsg>();
        let work_rx = Arc::new(std::sync::Mutex::new(work_rx));

        // Worker pool.
        let inflight = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rt = rt.clone();
            let metrics = metrics.clone();
            let work_rx = work_rx.clone();
            let params = params.clone();
            let mask_tensors = mask_tensors.clone();
            let inflight = inflight.clone();
            let prefix = artifact_prefix.to_string();
            workers.push(std::thread::spawn(move || loop {
                let msg = {
                    let rx = work_rx.lock().unwrap();
                    rx.recv()
                };
                match msg {
                    Ok(WorkerMsg::Batch(batch)) => {
                        run_batch(
                            &rt,
                            &prefix,
                            &params,
                            &mask_tensors,
                            &metrics,
                            batch,
                            sim_per_image,
                        );
                        inflight.fetch_sub(1, Ordering::Relaxed);
                    }
                    Ok(WorkerMsg::Shutdown) | Err(_) => return,
                }
            }));
        }

        // Router/batcher thread.
        let router = {
            let metrics = metrics.clone();
            let shutdown = shutdown.clone();
            let inflight = inflight.clone();
            std::thread::spawn(move || {
                let mut batcher: Batcher<Request> = Batcher::new(policy);
                loop {
                    // Pull whatever is immediately available.
                    loop {
                        match submit_rx.try_recv() {
                            Ok(req) => {
                                Metrics::inc(&metrics.requests_in);
                                batcher.push(req, Instant::now());
                            }
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => {
                                // Drain and stop.
                                while let Some(b) = batcher.flush() {
                                    inflight.fetch_add(1, Ordering::Relaxed);
                                    let _ = work_tx.send(WorkerMsg::Batch(b));
                                }
                                for _ in 0..64 {
                                    let _ = work_tx.send(WorkerMsg::Shutdown);
                                }
                                return;
                            }
                        }
                    }
                    if shutdown.load(Ordering::Relaxed) {
                        while let Some(b) = batcher.flush() {
                            inflight.fetch_add(1, Ordering::Relaxed);
                            let _ = work_tx.send(WorkerMsg::Batch(b));
                        }
                        for _ in 0..64 {
                            let _ = work_tx.send(WorkerMsg::Shutdown);
                        }
                        return;
                    }
                    let now = Instant::now();
                    if let Some(batch) = batcher.try_assemble(now) {
                        Metrics::inc(&metrics.batches);
                        Metrics::add(&metrics.batched_requests, batch.items.len() as u64);
                        Metrics::add(&metrics.padded_slots, batch.padded_slots() as u64);
                        inflight.fetch_add(1, Ordering::Relaxed);
                        let _ = work_tx.send(WorkerMsg::Batch(batch));
                        continue;
                    }
                    // Sleep until the next deadline (or a short poll tick).
                    let nap = batcher
                        .time_to_deadline(now)
                        .unwrap_or(Duration::from_micros(200))
                        .min(Duration::from_micros(500));
                    std::thread::sleep(nap.max(Duration::from_micros(50)));
                }
            })
        };

        Ok(Server {
            submit_tx,
            metrics,
            shutdown,
            router: Some(router),
            workers,
            sim,
        })
    }

    /// Submit one image; returns the channel the response arrives on.
    pub fn submit(&self, image: Vec<f32>) -> Receiver<Response> {
        let (tx, rx) = channel();
        let req = Request { image, reply: tx, submitted: Instant::now() };
        // A send error means shutdown already started; the caller sees a
        // closed reply channel.
        let _ = self.submit_tx.send(req);
        rx
    }

    /// Graceful stop: flush queues, join threads.
    pub fn stop(mut self) -> Arc<Metrics> {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.clone()
    }
}

fn run_batch(
    rt: &Runtime,
    artifact_prefix: &str,
    params: &[HostTensor],
    mask_tensors: &[HostTensor],
    metrics: &Metrics,
    batch: Assembled<Request>,
    sim_per_image: f64,
) {
    let m = &rt.manifest;
    let exec_size = batch.exec_size;
    let img = m.data.image_elems();
    let mut x = Vec::with_capacity(exec_size * img);
    for p in &batch.items {
        x.extend_from_slice(&p.payload.image);
    }
    x.resize(exec_size * img, 0.0); // padded slots
    let mut inputs = Vec::with_capacity(params.len() + mask_tensors.len() + 1);
    inputs.extend(params.iter().cloned());
    inputs.extend(mask_tensors.iter().cloned());
    inputs.push(HostTensor::f32(
        vec![exec_size, m.data.height, m.data.width, m.data.channels],
        x,
    ));
    let t_exec = Instant::now();
    let result = rt.run(&format!("{artifact_prefix}{exec_size}"), &inputs);
    let exec_elapsed = t_exec.elapsed();
    metrics.execute.record(exec_elapsed.as_secs_f64());
    // Simulated FPGA time: per-layer pipeline over the batch.
    let sim_batch = Duration::from_secs_f64(sim_per_image * batch.items.len() as f64);
    metrics.sim_fpga.record(sim_batch.as_secs_f64());

    match result {
        Ok(out) => {
            let logits = out[0].as_f32();
            let classes = m.classes;
            let done = Instant::now();
            for (i, p) in batch.items.iter().enumerate() {
                let row = &logits[i * classes..(i + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(k, _)| k)
                    .unwrap_or(0);
                let queue_wait = t_exec.duration_since(p.enqueued);
                let e2e = done.duration_since(p.payload.submitted);
                metrics.queue_wait.record(queue_wait.as_secs_f64());
                metrics.e2e.record(e2e.as_secs_f64());
                Metrics::inc(&metrics.requests_done);
                let _ = p.payload.reply.send(Response {
                    logits: row.to_vec(),
                    pred,
                    queue_wait,
                    e2e,
                    sim_fpga: sim_batch,
                });
            }
        }
        Err(err) => {
            eprintln!("[server] batch failed: {err:#}");
            for _p in &batch.items {
                // Dropping the batch (and with it each reply Sender) closes
                // the per-request channels — the client sees RecvError.
                Metrics::inc(&metrics.requests_rejected);
            }
        }
    }
}
