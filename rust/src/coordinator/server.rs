//! The serving loop: a validating admission pipeline + dynamic batcher + a
//! backend-generic worker pool with supervised, self-healing execution.
//!
//! Architecture (threads + channels; the sandbox has no tokio, and the
//! workload — CPU-bound batch executions — wants a small fixed pool anyway):
//!
//! ```text
//!   clients ──submit──▶ [admission] ──▶ router/batcher ──Batch──▶ worker 0..N-1
//!             validate + bounded queue   (Batcher<Request>)        │  InferenceBackend
//!             + breaker shed                                       │  (watchdog, retry,
//!   clients ◀──reply channel per request: Result<Response, ServeError>──┘  fallback)
//! ```
//!
//! **Admission pipeline.** `submit` is the front door and enforces the batch
//! contract *before* a request can touch batch assembly:
//!
//! * geometry + finiteness validation ([`crate::backend::validate_image`]) —
//!   a malformed request is rejected alone with
//!   [`ServeError::InvalidInput`]. This is load-bearing: batch assembly
//!   concatenates images back to back into one statically-shaped backend
//!   buffer, so a short/long image admitted into a batch would shift every
//!   subsequent image's offset and hand neighbors each other's logits
//!   (the FINN-R dataflow contract: fixed per-image geometry feeding
//!   statically-shaped accelerator batches);
//! * a bounded in-system count ([`ServeConfig::queue_depth`]) — once that
//!   many requests are admitted but unanswered, new submissions are shed
//!   newest-first with [`ServeError::QueueFull`] instead of growing the
//!   router's memory without bound;
//! * circuit-breaker shed — while the breaker is open (and no fallback
//!   backend is configured), submissions are answered
//!   [`ServeError::Unavailable`] immediately instead of queueing doomed
//!   work;
//! * every admitted request is *always* answered exactly once — no dropped
//!   reply channels.
//!
//! **Supervised execution.** A dispatched batch runs under the failure
//! state machine (see ROADMAP "Architecture: execution resilience"):
//!
//! 1. *Watchdog deadline* ([`ServeConfig::execute_deadline`]): the backend
//!    call runs on a helper thread and is abandoned when it exceeds the
//!    deadline; members are answered [`ServeError::Timeout`] (or retried)
//!    and their `queue_depth` slots recover — a wedged backend cannot hold
//!    requests hostage.
//! 2. *Output validation*: shape, class range, and logits finiteness — a
//!    backend handing back NaN or truncated logits is a failed batch, never
//!    an `Ok` served to clients.
//! 3. *Bounded retry with quarantine* ([`ServeConfig::retries`]): a failed
//!    batch is re-split into singletons so one poison request cannot fail
//!    its batch-mates; members that succeed in isolation are answered `Ok`
//!    (counted `requests_recovered`), members that keep failing are
//!    *quarantined* (their own metrics class).
//! 4. *Circuit breaker* ([`ServeConfig::breaker_threshold`]): consecutive
//!    primary-backend failures open it (closed → open → half-open probe →
//!    closed), shedding at admission while open and surfacing live-vs-ready
//!    on `GET /v1/healthz`.
//! 5. *Fallback chain* ([`Server::start_with_fallback`]): while the breaker
//!    is not closed, batches execute on the fallback backend (e.g. qgemm →
//!    float) — degraded, visible in `/v1/healthz` and `Metrics`, but
//!    serving.
//!
//! Workers execute through the unified [`InferenceBackend`] trait, so the
//! same dynamic-batching loop serves the PJRT engine, the native
//! packed-code `qgemm` path (which runs on toolchain-only machines under
//! `--no-default-features`), or the f32 reference — pick with
//! `backend::create` and hand the result to [`Server::start`].
//!
//! Every executed batch also gets a *simulated FPGA latency* from the
//! performance model (the codesign view: numerics from the backend, timing
//! from the Zynq model) so the serving benches can report both.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{Assembled, BatchPolicy, Batcher, Pending};
use super::metrics::Metrics;
use crate::backend::{self, BackendInit, BatchOutput, ImageBuf, InferenceBackend};
use crate::util::sync::LockExt;
use crate::fpga::{simulate, DeviceModel, Mode, NetConfig, SimReport};
use crate::model::zoo;
use crate::quant::{assign, MaskSet, Provenance, QuantPlan, Scheme};
use crate::runtime::{HostTensor, Manifest, Runtime};

/// One inference request: a flattened image (already admission-validated).
///
/// The image is the single owned buffer from ingress decode onward — it
/// *moves* through admission, the router, and the batcher untouched, and is
/// read in place by batch assembly and the singleton-retry path. See
/// ROADMAP "Architecture: wire encodings & ingestion".
pub struct Request {
    pub image: ImageBuf,
    pub reply: Sender<ServeResult>,
    pub submitted: Instant,
}

/// The reply: logits + argmax + timing breakdown.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub queue_wait: Duration,
    pub e2e: Duration,
    /// What *this request alone* would have cost on the simulated FPGA (one
    /// image through the per-layer pipeline). The accelerator model runs
    /// images sequentially — cross-image pipeline amortization is not
    /// modeled — so the batch-level figure in `Metrics::sim_fpga` is this
    /// value times the batch's occupied slots.
    pub sim_fpga: Duration,
}

/// Typed serving error: why a request was not answered with logits. Every
/// submitted request receives exactly one `Result<Response, ServeError>` on
/// its reply channel — the error variants replace the historic behaviour of
/// silently dropping the channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Rejected at admission (wrong image length or non-finite values);
    /// the request never entered batch assembly, so its batch-mates are
    /// unaffected.
    InvalidInput(String),
    /// The admission queue is at its configured depth; this request was
    /// shed (reject-newest) without being enqueued.
    QueueFull { depth: usize },
    /// The backend failed executing the batch this request was assembled
    /// into (and any isolated retries failed too).
    BackendFailed(String),
    /// The server stopped before this request could be dispatched.
    ShuttingDown,
    /// The execution watchdog abandoned this request's batch: the backend
    /// call exceeded [`ServeConfig::execute_deadline`] (and any isolated
    /// retries did too). The stalled call is left to finish on its helper
    /// thread; its late result is discarded.
    Timeout { deadline_ms: u64 },
    /// Shed at admission: the circuit breaker is open (the backend is
    /// failing consecutively) and no fallback backend is configured, so
    /// queueing the request would only feed it doomed work.
    Unavailable,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::InvalidInput(reason) => write!(f, "invalid input: {reason}"),
            ServeError::QueueFull { depth } => {
                write!(f, "admission queue full (depth {depth}); request shed")
            }
            ServeError::BackendFailed(reason) => {
                write!(f, "backend failed executing this request's batch: {reason}")
            }
            ServeError::ShuttingDown => {
                write!(f, "server shutting down before the request was dispatched")
            }
            ServeError::Timeout { deadline_ms } => write!(
                f,
                "batch execution exceeded the {deadline_ms}ms deadline and was \
                 abandoned by the watchdog"
            ),
            ServeError::Unavailable => write!(
                f,
                "service unavailable: circuit breaker open (backend failing); \
                 request shed at admission"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// What every reply channel carries.
pub type ServeResult = std::result::Result<Response, ServeError>;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_wait: Duration,
    /// Bound on requests admitted but not yet answered (submit channel +
    /// batcher queue + in-flight batches combined). Submissions beyond this
    /// are shed newest-first with [`ServeError::QueueFull`], so an overload
    /// can't grow the router's memory without bound. Values below 1 are
    /// clamped to 1. Default: 1024.
    pub queue_depth: usize,
    /// The active quantization plan: validated against the manifest at
    /// start, drives the FPGA-sim timing overlay, and is advertised on
    /// `GET /v1/plan`. `None` serves unquantized weights — the overlay then
    /// falls back to uniform Fixed-8 timing (the nearest hardware config;
    /// the simulator has no float mode).
    pub plan: Option<QuantPlan>,
    /// Device for the FPGA-sim timing overlay.
    pub device: String,
    /// Serve pre-quantized ("frozen") weights where the backend has a
    /// native frozen path (see `InferenceBackend::supports_frozen`).
    /// Construction-time only: consumed by [`Server::start_pjrt`] and the
    /// CLI/example when they build the backend — the generic
    /// [`Server::start`] never reads it (the backend already owns its
    /// weight policy).
    pub frozen: bool,
    /// Per-batch execution watchdog: a backend call exceeding this is
    /// abandoned (the helper thread keeps running; its late result is
    /// dropped), its members answered [`ServeError::Timeout`] or retried,
    /// and their queue slots recovered. `None` (the default) runs the
    /// backend call inline with no deadline.
    pub execute_deadline: Option<Duration>,
    /// Isolated retry attempts for each member of a failed batch (the batch
    /// is re-split into singletons so one poison request cannot fail its
    /// batch-mates). `0` (the default) disables retry: a failed batch
    /// answers every member with the typed error, as before.
    pub retries: usize,
    /// Base backoff slept before each retry attempt; doubles per attempt.
    pub retry_backoff: Duration,
    /// Consecutive primary-backend batch failures that open the circuit
    /// breaker. `0` (the default) disables the breaker.
    pub breaker_threshold: usize,
    /// How long an open breaker sheds before admitting a half-open probe.
    pub breaker_cooldown: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_wait: Duration::from_millis(5),
            queue_depth: 1024,
            plan: None,
            device: "xc7z045".into(),
            frozen: true,
            execute_deadline: None,
            retries: 0,
            retry_backoff: Duration::from_millis(20),
            breaker_threshold: 0,
            breaker_cooldown: Duration::from_secs(1),
        }
    }
}

// ---------------------------------------------------------------------------
// Circuit breaker

/// Consecutive-failure circuit breaker over the *primary* backend.
///
/// Closed → (threshold consecutive failures) → Open → (cooldown elapses,
/// one probe batch runs on the primary) → Half-open → Closed on probe
/// success / back to Open on probe failure. Fallback-backend outcomes never
/// drive the state — the breaker describes the primary's health only.
/// State transitions mirror into the shared [`Metrics`] gauges/counters so
/// `/v1/metrics` shows them.
struct Breaker {
    threshold: usize,
    cooldown: Duration,
    metrics: Arc<Metrics>,
    inner: Mutex<BreakerInner>,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

struct BreakerInner {
    state: BreakerState,
    consecutive: usize,
    opened_at: Option<Instant>,
    /// A half-open probe batch is in flight; further batches keep routing
    /// to the fallback (or shedding) until it reports back.
    probing: bool,
}

/// Where a batch executes, as decided by [`Breaker::route`].
struct ExecRoute {
    /// Prefer the fallback backend (breaker not closed).
    use_fallback: bool,
    /// This execution is the half-open probe; its outcome closes or
    /// re-opens the breaker.
    probe: bool,
}

impl Breaker {
    fn new(threshold: usize, cooldown: Duration, metrics: Arc<Metrics>) -> Breaker {
        Breaker {
            threshold,
            cooldown,
            metrics,
            inner: Mutex::new(BreakerInner {
                state: BreakerState::Closed,
                consecutive: 0,
                opened_at: None,
                probing: false,
            }),
        }
    }

    fn enabled(&self) -> bool {
        self.threshold > 0
    }

    fn state(&self) -> BreakerState {
        if !self.enabled() {
            return BreakerState::Closed;
        }
        self.inner.plock().state
    }

    fn state_name(&self) -> &'static str {
        match self.state() {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }

    /// Admission-time check: shed new work only while open *and* still in
    /// cooldown — once the cooldown elapses, submissions are admitted so
    /// the half-open probe has traffic to probe with.
    fn shedding(&self) -> bool {
        if !self.enabled() {
            return false;
        }
        let inner = self.inner.plock();
        inner.state == BreakerState::Open
            && inner.opened_at.is_some_and(|t| t.elapsed() < self.cooldown)
    }

    /// Worker-side routing decision for one execution attempt.
    fn route(&self) -> ExecRoute {
        if !self.enabled() {
            return ExecRoute { use_fallback: false, probe: false };
        }
        let mut inner = self.inner.plock();
        match inner.state {
            BreakerState::Closed => ExecRoute { use_fallback: false, probe: false },
            BreakerState::Open
                if !inner.probing
                    && inner.opened_at.is_some_and(|t| t.elapsed() >= self.cooldown) =>
            {
                inner.state = BreakerState::HalfOpen;
                inner.probing = true;
                self.metrics.breaker_state.store(2, Ordering::Relaxed);
                Metrics::inc(&self.metrics.breaker_half_open);
                ExecRoute { use_fallback: false, probe: true }
            }
            BreakerState::Open | BreakerState::HalfOpen => {
                ExecRoute { use_fallback: true, probe: false }
            }
        }
    }

    /// Feed one execution outcome back. Only primary-backend outcomes move
    /// the state; `route.probe` marks the half-open probe.
    fn on_result(&self, route: &ExecRoute, on_fallback: bool, success: bool) {
        if !self.enabled() || on_fallback {
            return;
        }
        let mut inner = self.inner.plock();
        if success {
            if route.probe {
                inner.state = BreakerState::Closed;
                inner.probing = false;
                inner.consecutive = 0;
                inner.opened_at = None;
                self.metrics.breaker_state.store(0, Ordering::Relaxed);
                Metrics::inc(&self.metrics.breaker_closed);
            } else if inner.state == BreakerState::Closed {
                inner.consecutive = 0;
            }
        } else if route.probe {
            // Failed probe: back to open with a fresh cooldown.
            inner.state = BreakerState::Open;
            inner.probing = false;
            inner.opened_at = Some(Instant::now());
            self.metrics.breaker_state.store(1, Ordering::Relaxed);
            Metrics::inc(&self.metrics.breaker_opened);
        } else {
            inner.consecutive += 1;
            if inner.state == BreakerState::Closed && inner.consecutive >= self.threshold {
                inner.state = BreakerState::Open;
                inner.opened_at = Some(Instant::now());
                self.metrics.breaker_state.store(1, Ordering::Relaxed);
                Metrics::inc(&self.metrics.breaker_opened);
            }
        }
    }
}

enum WorkerMsg {
    Batch(Assembled<Request>),
    Shutdown,
}

/// What the submit channel carries. `Wake` exists because the router
/// *blocks* on this channel when it has nothing to do (no busy-polling an
/// empty queue): `begin_shutdown` sends one so a parked router notices the
/// shutdown flag immediately instead of on the next request.
enum RouterMsg {
    Req(QueuedRequest),
    Wake,
}

/// An admitted request in flight to the router, armed to answer on drop.
///
/// This closes the submit/shutdown race airtight: `begin_shutdown` can be
/// called from any thread (it takes `&self`), so a request that passed
/// submit's shutdown-flag check can land in the channel *after* the
/// router's final drain. Such a request is never popped — it is dropped
/// when the channel's receiver drops — and the `Drop` impl below turns
/// exactly that into a typed `ShuttingDown` answer (plus the counter
/// bookkeeping), so "every admitted request is answered exactly once"
/// holds with no drain-ordering subtleties. The router *disarms* the guard
/// with [`QueuedRequest::take`] when it pops a request for real.
struct QueuedRequest {
    req: Option<Request>,
    in_system: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
}

impl QueuedRequest {
    /// Disarm and hand out the request (the popped-by-router path).
    fn take(mut self) -> Request {
        // analyze:allow(armed-guard invariant: the router calls take exactly once per pop)
        self.req.take().expect("take called once")
    }
}

impl Drop for QueuedRequest {
    fn drop(&mut self) {
        if let Some(req) = self.req.take() {
            Metrics::inc(&self.metrics.requests_shutdown);
            self.in_system.fetch_sub(1, Ordering::SeqCst);
            deliver(&self.metrics, &req.reply, Err(ServeError::ShuttingDown));
        }
    }
}

/// Deliver one reply. The send's only failure mode is a receiver that is
/// already gone — a client that stopped waiting (loadgen's drain deadline,
/// an HTTP handler's reply timeout). The request is counted in its outcome
/// class either way; the dead receiver is made observable in
/// `Metrics::replies_unclaimed` instead of being silently discarded
/// (`ilmpq analyze` rule R2: no dropped reply results).
fn deliver(metrics: &Metrics, reply: &Sender<ServeResult>, result: ServeResult) {
    if reply.send(result).is_err() {
        Metrics::inc(&metrics.replies_unclaimed);
    }
}

/// Handle to a running server.
pub struct Server {
    submit_tx: Sender<RouterMsg>,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    /// Requests admitted but not yet answered; the admission bound.
    in_system: Arc<AtomicU64>,
    img_elems: usize,
    queue_depth: usize,
    breaker: Arc<Breaker>,
    has_fallback: bool,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// The FPGA-sim report for the configured (model, plan, device).
    pub sim: SimReport,
    /// The quantization plan this server runs (`None` = unquantized) —
    /// what `GET /v1/plan` advertises.
    pub plan: Option<Arc<QuantPlan>>,
}

impl Server {
    /// Start router + workers over any execution backend. The backend owns
    /// the weights; `manifest` supplies the batching geometry
    /// (`infer_batches`, image dims) and the FPGA-sim overlay inputs.
    pub fn start(
        manifest: &Manifest,
        backend: Arc<dyn InferenceBackend>,
        cfg: ServeConfig,
    ) -> Result<Server> {
        Self::start_with_fallback(manifest, backend, None, cfg)
    }

    /// [`Server::start`] with an optional degraded-mode fallback backend:
    /// while the circuit breaker is not closed, batches execute on
    /// `fallback` instead of the failing primary (e.g. qgemm → float). The
    /// fallback must serve the same manifest geometry; it is warmed up at
    /// start like the primary. Without a breaker
    /// ([`ServeConfig::breaker_threshold`] = 0) the fallback is never used.
    pub fn start_with_fallback(
        manifest: &Manifest,
        backend: Arc<dyn InferenceBackend>,
        fallback: Option<Arc<dyn InferenceBackend>>,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let policy = BatchPolicy::new(manifest.infer_batches.clone(), cfg.max_wait);
        let metrics = Arc::new(Metrics::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let in_system = Arc::new(AtomicU64::new(0));
        let queue_depth = cfg.queue_depth.max(1);

        // FPGA-sim overlay: per-image latency of this config on the device.
        let device = DeviceModel::by_name(&cfg.device)
            .ok_or_else(|| anyhow::anyhow!("unknown device {}", cfg.device))?;
        let net = zoo::serving_network(
            &manifest.model_name,
            manifest.height,
            manifest.width,
            manifest.channels,
            &manifest.widths,
            manifest.classes,
        );
        // The plan is the serving contract: validate it against the
        // manifest before anything packs or simulates with it, so a stale
        // or mismatched plan file fails at startup, not mid-traffic.
        let plan = cfg.plan.clone().map(Arc::new);
        if let Some(p) = &plan {
            p.validate(manifest).context("serving plan rejected")?;
        }
        // Executed-vs-advertised cross-check: a backend that retains its
        // mask set must agree with the plan this server advertises on
        // `GET /v1/plan` — the config carrying one assignment while the
        // backend executes another is exactly the silent misreport the
        // plan API exists to prevent.
        match (&plan, backend.active_masks()) {
            (Some(p), Some(masks)) => anyhow::ensure!(
                p.masks.layers == masks.layers,
                "ServeConfig.plan {:?} does not match the mask set the backend executes",
                p.name
            ),
            (None, Some(_)) => anyhow::bail!(
                "the backend executes a quantization mask set but ServeConfig.plan \
                 is unset; pass the plan the backend was built with so /v1/plan \
                 cannot misreport"
            ),
            _ => {}
        }
        let sim_cfg = match &plan {
            Some(p) => NetConfig::from_masks(&p.name, p.masks.layers.clone()),
            // Unquantized serving: the simulator has no float mode, so
            // overlay the nearest hardware config (uniform Fixed-8).
            None => NetConfig::from_masks(
                "unquantized (Fixed-8 overlay)",
                net.layers
                    .iter()
                    .map(|l| assign::assign_uniform_layer(&l.name, l.rows(), Scheme::Fixed8))
                    .collect(),
            ),
        };
        let sim = simulate(&net, &sim_cfg, &device, Mode::IntraLayer);
        let sim_per_image = sim.latency_s;

        // Warm up before accepting traffic: compile/pack everything so no
        // request pays a one-time cost — the fallback too, so engaging it
        // under an already-failing primary never adds a pack stall.
        backend.prepare()?;
        if let Some(fb) = &fallback {
            fb.prepare().context("prepare fallback backend")?;
        }

        let img_elems = manifest.data.image_elems();
        let classes = manifest.classes;
        let (submit_tx, submit_rx) = channel::<RouterMsg>();
        let (work_tx, work_rx) = channel::<WorkerMsg>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        let breaker =
            Arc::new(Breaker::new(cfg.breaker_threshold, cfg.breaker_cooldown, metrics.clone()));
        let has_fallback = fallback.is_some();
        let ctx = Arc::new(ExecCtx {
            backend: backend.clone(),
            fallback,
            img_elems,
            classes,
            metrics: metrics.clone(),
            in_system: in_system.clone(),
            breaker: breaker.clone(),
            deadline: cfg.execute_deadline,
            retries: cfg.retries,
            retry_backoff: cfg.retry_backoff,
            sim_per_image,
        });

        // Worker pool.
        let n_workers = cfg.workers.max(1);
        let mut workers = Vec::new();
        for _ in 0..n_workers {
            let ctx = ctx.clone();
            let work_rx = work_rx.clone();
            workers.push(std::thread::spawn(move || loop {
                let msg = {
                    // analyze:allow(shared-receiver pool: holding the mutex across recv IS the work handoff)
                    let rx = work_rx.plock();
                    rx.recv()
                };
                match msg {
                    Ok(WorkerMsg::Batch(batch)) => run_batch(&ctx, batch),
                    Ok(WorkerMsg::Shutdown) | Err(_) => return,
                }
            }));
        }

        // Router/batcher thread. The loop *blocks* on the submit channel
        // when there is nothing to do — bounded by the batch deadline when
        // requests are pending, unbounded when the batcher is empty — so an
        // idle server parks instead of waking every few hundred µs (the
        // historic `try_recv` + capped-sleep loop woke ~2–5k times/s on an
        // empty queue). `metrics.router_wakeups` counts loop iterations as
        // the regression signal; `begin_shutdown` sends `RouterMsg::Wake`
        // so a parked router still notices stop immediately.
        let router = {
            let metrics = metrics.clone();
            let shutdown = shutdown.clone();
            let in_system = in_system.clone();
            std::thread::spawn(move || {
                let mut batcher: Batcher<Request> = Batcher::new(policy);
                loop {
                    Metrics::inc(&metrics.router_wakeups);
                    // Pull whatever is immediately available.
                    let mut disc = false;
                    loop {
                        match submit_rx.try_recv() {
                            Ok(RouterMsg::Req(q)) => batcher.push(q.take(), Instant::now()),
                            Ok(RouterMsg::Wake) => {}
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => {
                                disc = true;
                                break;
                            }
                        }
                    }
                    if disc || shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let now = Instant::now();
                    if let Some(batch) = batcher.try_assemble(now) {
                        dispatch(&metrics, &in_system, &work_tx, batch);
                        continue;
                    }
                    // Park. With requests pending the wait is capped by the
                    // oldest request's deadline (so the partial-batch
                    // dispatch still fires on time); with an empty batcher
                    // the recv blocks until the next submission or Wake —
                    // zero idle wakeups. A `Some(0)` deadline is impossible
                    // here: an expired oldest request makes `try_assemble`
                    // dispatch above.
                    let msg = match batcher.time_to_deadline(Instant::now()) {
                        Some(d) => submit_rx.recv_timeout(d),
                        None => submit_rx
                            .recv()
                            .map_err(|_| RecvTimeoutError::Disconnected),
                    };
                    match msg {
                        Ok(RouterMsg::Req(q)) => batcher.push(q.take(), Instant::now()),
                        Ok(RouterMsg::Wake) | Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                // Stop cutoff (or dropped Server). Everything already
                // admitted to the batcher ships and gets real answers from
                // the workers. Requests still buffered in the submit
                // channel — including any that race in *after* this point,
                // which `begin_shutdown(&self)` makes possible — are
                // answered `ShuttingDown` by `QueuedRequest`'s drop guard
                // the moment `submit_rx` drops with this thread; no drain
                // loop can miss them.
                while let Some(b) = batcher.flush() {
                    dispatch(&metrics, &in_system, &work_tx, b);
                }
                for _ in 0..n_workers {
                    // analyze:allow(Shutdown carries no reply channel; a dead worker pool needs no nudge)
                    let _ = work_tx.send(WorkerMsg::Shutdown);
                }
            })
        };

        Ok(Server {
            submit_tx,
            metrics,
            shutdown,
            in_system,
            img_elems,
            queue_depth,
            breaker,
            has_fallback,
            router: Some(router),
            workers,
            sim,
            plan,
        })
    }

    /// Historic PJRT entry point: build the `"pjrt"` registry backend from
    /// a loaded runtime (honoring `cfg.frozen`) and serve it. `params` are
    /// the (trained) model parameters in AOT order; `masks` the
    /// quantization config, wrapped into a [`QuantPlan`] when `cfg.plan` is
    /// unset. When the caller *did* set `cfg.plan`, that plan is what the
    /// backend executes — the advertised plan and the executed masks are
    /// one value by construction, never two that can drift — so it is
    /// validated here, before the (expensive, possibly panicky) backend
    /// build can see its masks.
    pub fn start_pjrt(
        rt: Arc<Runtime>,
        params: Vec<HostTensor>,
        masks: &MaskSet,
        mut cfg: ServeConfig,
    ) -> Result<Server> {
        let plan = match cfg.plan.clone() {
            Some(p) => p,
            None => {
                let p = QuantPlan::from_mask_set(
                    masks.clone(),
                    Provenance::NamedRatio { ratio: masks.name.clone() },
                );
                cfg.plan = Some(p.clone());
                p
            }
        };
        plan.validate(&rt.manifest).context("serving plan rejected")?;
        let init = BackendInit {
            plan: Some(plan),
            frozen: cfg.frozen,
            runtime: Some(rt.clone()),
            ..BackendInit::new(rt.manifest.clone(), params)
        };
        let backend: Arc<dyn InferenceBackend> =
            Arc::from(backend::create("pjrt", &init)?);
        Server::start(&rt.manifest, backend, cfg)
    }

    /// Submit one image; returns the channel the reply arrives on. Never
    /// blocks: admission decides immediately. A request that fails
    /// validation or hits the queue bound receives its typed error on the
    /// returned channel without ever entering batch assembly; every
    /// admitted request is answered exactly once.
    ///
    /// Takes the image by value as an owned [`ImageBuf`] (a `Vec<f32>`
    /// converts for free): admission validates it in place and the same
    /// buffer rides the pipeline to batch assembly — no copy at this hop.
    pub fn submit(&self, image: impl Into<ImageBuf>) -> Receiver<ServeResult> {
        let image: ImageBuf = image.into();
        let (tx, rx) = channel();
        let submitted = Instant::now();
        Metrics::inc(&self.metrics.requests_in);

        // Cheap geometry check first: a wrong-length image is the
        // corruption-dangerous class and is rejected alone regardless of
        // load, before it can touch batch assembly.
        if let Err(reason) = backend::validate_image_len(&image, self.img_elems) {
            Metrics::inc(&self.metrics.requests_invalid);
            deliver(&self.metrics, &tx, Err(ServeError::InvalidInput(reason)));
            return rx;
        }
        if self.shutdown.load(Ordering::SeqCst) {
            Metrics::inc(&self.metrics.requests_shutdown);
            deliver(&self.metrics, &tx, Err(ServeError::ShuttingDown));
            return rx;
        }
        // Breaker shed: while the breaker is open (and still cooling down)
        // with no fallback to serve on, queueing the request would only
        // hand it to a failing backend — answer Unavailable immediately.
        // With a fallback configured, admission proceeds and the workers
        // route to the fallback instead.
        if !self.has_fallback && self.breaker.shedding() {
            Metrics::inc(&self.metrics.requests_unavailable);
            deliver(&self.metrics, &tx, Err(ServeError::Unavailable));
            return rx;
        }
        // Bounded admission: shed newest-first once `queue_depth` requests
        // are in the system (queued or executing, not yet answered). This
        // runs before the O(image_elems) finiteness scan so an overloaded
        // ingress sheds in O(1).
        let prev = self.in_system.fetch_add(1, Ordering::SeqCst);
        if prev >= self.queue_depth as u64 {
            self.in_system.fetch_sub(1, Ordering::SeqCst);
            Metrics::inc(&self.metrics.requests_shed);
            deliver(&self.metrics, &tx, Err(ServeError::QueueFull { depth: self.queue_depth }));
            return rx;
        }
        // Full value scan only for requests that are actually admitted
        // (roll the slot back on rejection).
        if let Err(reason) = backend::validate_image_finite(&image) {
            self.in_system.fetch_sub(1, Ordering::SeqCst);
            Metrics::inc(&self.metrics.requests_invalid);
            deliver(&self.metrics, &tx, Err(ServeError::InvalidInput(reason)));
            return rx;
        }
        let queued = QueuedRequest {
            req: Some(Request { image, reply: tx, submitted }),
            in_system: self.in_system.clone(),
            metrics: self.metrics.clone(),
        };
        // Three ways this send can end, all answered exactly once: the
        // router pops it (pipeline answers), the send fails because the
        // router exited (the SendError drops the guard → ShuttingDown), or
        // it sits buffered past the router's exit (dropped with the
        // receiver → ShuttingDown via the same guard).
        // analyze:allow(a SendError drops the armed QueuedRequest guard, which answers ShuttingDown)
        let _ = self.submit_tx.send(RouterMsg::Req(queued));
        rx
    }

    /// Liveness-vs-readiness split for health endpoints: the server is
    /// *ready* when the breaker is closed and it is not draining. A
    /// not-ready server still answers `/v1/healthz` (liveness) — with a 503
    /// so load balancers stop routing to it.
    pub fn is_ready(&self) -> bool {
        !self.shutdown.load(Ordering::SeqCst) && self.breaker.state() == BreakerState::Closed
    }

    /// True after [`Server::begin_shutdown`]: draining, new work refused.
    pub fn is_shutting_down(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Circuit-breaker state: `"closed"`, `"open"`, or `"half-open"` (a
    /// disabled breaker reads closed).
    pub fn breaker_state(&self) -> &'static str {
        self.breaker.state_name()
    }

    /// Degraded mode: the breaker is not closed and batches are routing to
    /// the fallback backend.
    pub fn is_degraded(&self) -> bool {
        self.has_fallback && self.breaker.state() != BreakerState::Closed
    }

    /// Requests admitted but not yet answered. The pool's hot-swap drains a
    /// replaced server by polling this to zero before stopping it —
    /// [`Server::stop`] answers still-queued requests `ShuttingDown`, which
    /// a zero-lost-replies swap must never let happen.
    pub fn in_flight(&self) -> u64 {
        self.in_system.load(Ordering::SeqCst)
    }

    /// Front half of graceful stop: raise the shutdown flag and wake the
    /// router. From this point every *new* submission is answered
    /// `ShuttingDown` at the front door while already-admitted requests
    /// drain through the workers — this is what lets a network front end
    /// keep answering (with 503s) while the pipeline behind it drains.
    /// Idempotent; [`Server::stop`] calls it and then joins the threads.
    pub fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // A parked router (blocking recv on an empty batcher) only sees the
        // flag when a message arrives: nudge it.
        // analyze:allow(Wake carries no reply channel; an already-exited router needs no nudge)
        let _ = self.submit_tx.send(RouterMsg::Wake);
    }

    /// Graceful stop: flush queues, join threads. In-flight requests are
    /// answered (executed where already batched, `ShuttingDown` otherwise);
    /// no reply channel is left to dangle.
    ///
    /// A joined stop is a *drained* boundary, so the [`Metrics::audit`]
    /// ledger invariants are exact here and debug builds verify them on
    /// every server the tests stop (the runtime twin of the `ilmpq analyze`
    /// static rules). Release builds skip the assert but the audit stays
    /// callable on the returned metrics.
    pub fn stop(mut self) -> Arc<Metrics> {
        self.begin_shutdown();
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let audit = self.metrics.audit();
        debug_assert!(audit.is_ok(), "metrics ledger audit failed at stop(): {audit:?}");
        debug_assert_eq!(
            self.in_flight(),
            0,
            "admission slots leaked across a drained stop()"
        );
        self.metrics.clone()
    }
}

/// Hand one assembled batch to the worker pool, recording assembly metrics
/// (shared by the deadline path and the shutdown/disconnect flush).
fn dispatch(
    metrics: &Metrics,
    in_system: &AtomicU64,
    work_tx: &Sender<WorkerMsg>,
    batch: Assembled<Request>,
) {
    Metrics::inc(&metrics.batches);
    Metrics::add(&metrics.batched_requests, batch.items.len() as u64);
    Metrics::add(&metrics.padded_slots, batch.padded_slots() as u64);
    if let Err(rejected) = work_tx.send(WorkerMsg::Batch(batch)) {
        // The worker pool is gone (every worker exited or died by panic
        // before this batch arrived). Dropping the batch here would drop
        // every member's reply channel — instead recover it from the
        // SendError and answer each member ShuttingDown, releasing their
        // admission slots, so answer-exactly-once holds on this path too.
        if let WorkerMsg::Batch(batch) = rejected.0 {
            for p in &batch.items {
                Metrics::inc(&metrics.requests_shutdown);
                in_system.fetch_sub(1, Ordering::SeqCst);
                deliver(metrics, &p.payload.reply, Err(ServeError::ShuttingDown));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Supervised execution (worker side)

/// Everything a worker needs to execute, supervise, and answer batches.
struct ExecCtx {
    backend: Arc<dyn InferenceBackend>,
    fallback: Option<Arc<dyn InferenceBackend>>,
    img_elems: usize,
    classes: usize,
    metrics: Arc<Metrics>,
    in_system: Arc<AtomicU64>,
    breaker: Arc<Breaker>,
    deadline: Option<Duration>,
    retries: usize,
    retry_backoff: Duration,
    sim_per_image: f64,
}

impl ExecCtx {
    /// Resolve a routing decision to an actual backend. A fallback route
    /// without a configured fallback executes on the primary — the requests
    /// were already admitted, so answering via the ordinary failure path is
    /// still better than dropping them.
    fn select_backend(&self, route: &ExecRoute) -> (&Arc<dyn InferenceBackend>, bool) {
        match (&self.fallback, route.use_fallback) {
            (Some(fb), true) => (fb, true),
            _ => (&self.backend, false),
        }
    }
}

/// Why a supervised execution attempt produced no usable output.
#[derive(Debug, Clone)]
enum ExecFailure {
    /// The watchdog abandoned the call at the configured deadline.
    Timeout(Duration),
    /// The backend errored, panicked, or returned malformed output.
    Failed(String),
}

impl ExecFailure {
    fn to_serve_error(&self) -> ServeError {
        match self {
            ExecFailure::Timeout(d) => {
                ServeError::Timeout { deadline_ms: d.as_millis() as u64 }
            }
            ExecFailure::Failed(msg) => ServeError::BackendFailed(msg.clone()),
        }
    }

    fn describe(&self) -> String {
        match self {
            ExecFailure::Timeout(d) => {
                format!("execution exceeded the {}ms watchdog deadline", d.as_millis())
            }
            ExecFailure::Failed(msg) => msg.clone(),
        }
    }
}

/// Run the backend with panics contained: under the admission bound, a
/// batch that died without answering would leak its `queue_depth` slots
/// forever (and drop reply channels) — so a panic becomes an ordinary
/// failed execution, which answers and decrements for every member.
fn run_contained(
    backend: &dyn InferenceBackend,
    x: &[f32],
    exec_size: usize,
) -> Result<BatchOutput> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        backend.run_batch(x, exec_size)
    }))
    .unwrap_or_else(|payload| {
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "non-string panic payload".into());
        Err(anyhow::anyhow!("backend panicked executing the batch: {msg}"))
    })
}

/// Validate a backend's output against the *manifest's* geometry, not the
/// backend's self-reported one — a degenerate output (wrong shape, class
/// index out of range, NaN/Inf logits) must become a failed execution here,
/// never an `Ok` served to clients.
fn validate_output(out: BatchOutput, exec_size: usize, classes: usize) -> Result<BatchOutput> {
    anyhow::ensure!(
        out.classes == classes
            && out.preds.len() == exec_size
            && out.logits.len() == exec_size * classes
            && out.preds.iter().all(|&p| p < classes),
        "backend returned malformed output: {} logits / {} preds / {} classes \
         for batch {exec_size} x {classes} classes",
        out.logits.len(),
        out.preds.len(),
        out.classes
    );
    anyhow::ensure!(
        out.logits.iter().all(|v| v.is_finite()),
        "backend returned non-finite logits for batch {exec_size} x {classes} classes"
    );
    Ok(out)
}

/// One supervised execution attempt: contained, validated, and — when a
/// deadline is configured — abandoned by the watchdog if it stalls.
fn execute_once(
    backend: &Arc<dyn InferenceBackend>,
    x: &[f32],
    exec_size: usize,
    classes: usize,
    deadline: Option<Duration>,
) -> std::result::Result<BatchOutput, ExecFailure> {
    let raw: Result<BatchOutput> = match deadline {
        None => run_contained(backend.as_ref(), x, exec_size),
        Some(limit) => {
            // The backend call runs on a detached helper thread; on expiry
            // the helper is *abandoned* — it keeps running, but its
            // eventual result is dropped with the channel, so the worker
            // can answer the members and release their slots now. The
            // input is cloned because the abandoned helper may still read
            // it after this frame returns — the documented deadline-path
            // exception to the one-owned-buffer "at most two writes"
            // invariant (no deadline configured ⇒ no clone).
            let (tx, rx) = channel();
            let be = backend.clone();
            let input = x.to_vec();
            let spawned = std::thread::Builder::new()
                .name("ilmpq-exec".into())
                .spawn(move || {
                    // analyze:allow(the watchdog may have abandoned this helper; a dead receiver is that signal)
                    let _ = tx.send(run_contained(be.as_ref(), &input, exec_size));
                });
            match spawned {
                Err(e) => Err(anyhow::anyhow!("spawn execution helper thread: {e}")),
                Ok(_detached) => match rx.recv_timeout(limit) {
                    Ok(result) => result,
                    Err(RecvTimeoutError::Timeout) => {
                        return Err(ExecFailure::Timeout(limit));
                    }
                    Err(RecvTimeoutError::Disconnected) => Err(anyhow::anyhow!(
                        "execution helper thread died without reporting a result"
                    )),
                },
            }
        }
    };
    raw.and_then(|out| validate_output(out, exec_size, classes))
        .map_err(|e| ExecFailure::Failed(format!("{e:#}")))
}

/// Answer a set of members with a successful output: record latencies,
/// count them `done` (plus `recovered` for singleton-retry successes),
/// release their slots, reply.
fn answer_ok(
    ctx: &ExecCtx,
    items: &[Pending<Request>],
    out: &BatchOutput,
    t_exec: Instant,
    recovered: bool,
) {
    // The backend's own measurement excludes the input-copy work, so
    // `execute` tracks pure backend cost.
    ctx.metrics.execute.record(out.elapsed.as_secs_f64());
    // Simulated FPGA time: the sequential per-image model, summed over the
    // batch's occupied slots for the batch-level metric.
    let sim_batch = Duration::from_secs_f64(ctx.sim_per_image * items.len() as f64);
    ctx.metrics.sim_fpga.record(sim_batch.as_secs_f64());
    let sim_request = Duration::from_secs_f64(ctx.sim_per_image);
    let classes = out.classes;
    let done = Instant::now();
    for (i, p) in items.iter().enumerate() {
        let row = &out.logits[i * classes..(i + 1) * classes];
        // Measured from *submit* time, not router-push time: the historic
        // `p.enqueued` anchor silently excluded time spent in the submit
        // channel, so a congested ingress reported rosy queue waits (and
        // queue_wait ≤ e2e only held by luck). Both anchors share
        // `submitted`, so the invariant holds by construction.
        let queue_wait = t_exec.duration_since(p.payload.submitted);
        let e2e = done.duration_since(p.payload.submitted);
        ctx.metrics.queue_wait.record(queue_wait.as_secs_f64());
        ctx.metrics.e2e.record(e2e.as_secs_f64());
        Metrics::inc(&ctx.metrics.requests_done);
        if recovered {
            Metrics::inc(&ctx.metrics.requests_recovered);
        }
        ctx.in_system.fetch_sub(1, Ordering::SeqCst);
        deliver(
            &ctx.metrics,
            &p.payload.reply,
            Ok(Response {
                logits: row.to_vec(),
                pred: out.preds[i],
                queue_wait,
                e2e,
                sim_fpga: sim_request,
            }),
        );
    }
}

/// Answer a set of members with the typed error for `fail`, counting each
/// in `class` (exactly one outcome class per request — the metrics sum
/// invariant) and releasing their slots.
fn answer_failed(
    ctx: &ExecCtx,
    items: &[Pending<Request>],
    fail: &ExecFailure,
    class: &AtomicU64,
) {
    let err = fail.to_serve_error();
    for p in items {
        // Degrade per-request, not per-batch-silently: every member of the
        // failed batch gets the typed error on its channel.
        Metrics::inc(class);
        ctx.in_system.fetch_sub(1, Ordering::SeqCst);
        deliver(&ctx.metrics, &p.payload.reply, Err(err.clone()));
    }
}

/// The outcome class a *final* (unretried) failure counts toward.
fn failure_class<'m>(metrics: &'m Metrics, fail: &ExecFailure) -> &'m AtomicU64 {
    match fail {
        ExecFailure::Timeout(_) => &metrics.requests_timeout,
        ExecFailure::Failed(_) => &metrics.requests_failed,
    }
}

/// Bounded retry with poison quarantine: re-split a failed batch into
/// singleton executions so one poison request cannot fail its batch-mates.
/// Each member gets up to `ctx.retries` isolated attempts with doubling
/// backoff; a member that succeeds is answered `Ok` (and counted
/// `recovered`), a member that keeps failing is *quarantined* — answered
/// with the typed error but counted in its own metrics class, since its
/// isolated failure is evidence the request itself is the poison.
fn retry_singletons(ctx: &ExecCtx, items: Vec<Pending<Request>>, first: ExecFailure) {
    for p in items {
        let mut last = first.clone();
        let mut answered = false;
        for attempt in 0..ctx.retries {
            let backoff = ctx
                .retry_backoff
                .saturating_mul(1u32 << (attempt.min(16) as u32));
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
            Metrics::inc(&ctx.metrics.batch_retries);
            let route = ctx.breaker.route();
            let (be, on_fallback) = ctx.select_backend(&route);
            if on_fallback {
                Metrics::inc(&ctx.metrics.fallback_batches);
            }
            let t_exec = Instant::now();
            let result = execute_once(be, &p.payload.image, 1, ctx.classes, ctx.deadline);
            ctx.breaker.on_result(&route, on_fallback, result.is_ok());
            match result {
                Ok(out) => {
                    answer_ok(ctx, std::slice::from_ref(&p), &out, t_exec, true);
                    answered = true;
                    break;
                }
                Err(f) => last = f,
            }
        }
        if !answered {
            eprintln!(
                "[server] request quarantined after {} isolated retries: {}",
                ctx.retries,
                last.describe()
            );
            answer_failed(
                ctx,
                std::slice::from_ref(&p),
                &last,
                &ctx.metrics.requests_quarantined,
            );
        }
    }
}

/// Execute one assembled batch under the full supervision state machine:
/// breaker routing → watchdog-bounded execution → output validation →
/// (on failure) singleton retry with quarantine. Every member is answered
/// exactly once and releases exactly one `in_system` slot on every path.
fn run_batch(ctx: &ExecCtx, batch: Assembled<Request>) {
    let exec_size = batch.exec_size;
    let mut x = Vec::with_capacity(exec_size * ctx.img_elems);
    for p in &batch.items {
        // Admission validated every image's geometry, so this concatenation
        // cannot shift a neighbour's offset. This is each image's second
        // and final write (the first was its decode into the ImageBuf) —
        // the one-owned-buffer invariant the counting-backend test pins.
        debug_assert_eq!(p.payload.image.len(), ctx.img_elems);
        x.extend_from_slice(&p.payload.image);
    }
    x.resize(exec_size * ctx.img_elems, 0.0); // padded slots

    let route = ctx.breaker.route();
    let (be, on_fallback) = ctx.select_backend(&route);
    if on_fallback {
        Metrics::inc(&ctx.metrics.fallback_batches);
    }
    let t_exec = Instant::now();
    let result = execute_once(be, &x, exec_size, ctx.classes, ctx.deadline);
    ctx.breaker.on_result(&route, on_fallback, result.is_ok());

    match result {
        Ok(out) => answer_ok(ctx, &batch.items, &out, t_exec, false),
        Err(fail) => {
            // Host-observed elapsed goes to the dedicated failure track so
            // the `execute` percentiles only ever describe successful runs.
            ctx.metrics.failed.record(t_exec.elapsed().as_secs_f64());
            match &fail {
                ExecFailure::Timeout(_) => Metrics::inc(&ctx.metrics.batches_timeout),
                ExecFailure::Failed(_) => Metrics::inc(&ctx.metrics.batches_failed),
            }
            eprintln!("[server] batch failed: {}", fail.describe());
            if ctx.retries == 0 {
                answer_failed(ctx, &batch.items, &fail, failure_class(&ctx.metrics, &fail));
            } else {
                retry_singletons(ctx, batch.items, fail);
            }
        }
    }
}
