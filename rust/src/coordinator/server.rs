//! The serving loop: router + dynamic batcher + a backend-generic worker
//! pool.
//!
//! Architecture (threads + channels; the sandbox has no tokio, and the
//! workload — CPU-bound batch executions — wants a small fixed pool anyway):
//!
//! ```text
//!   clients ──submit──▶ router/batcher thread ──Batch──▶ worker 0..N-1
//!                        (Batcher<Request>)               │  InferenceBackend
//!   clients ◀──reply channel per request──────────────────┘  + FPGA-sim
//! ```
//!
//! Workers execute through the unified [`InferenceBackend`] trait, so the
//! same dynamic-batching loop serves the PJRT engine, the native
//! packed-code `qgemm` path (which runs on toolchain-only machines under
//! `--no-default-features`), or the f32 reference — pick with
//! `backend::create` and hand the result to [`Server::start`].
//!
//! Every executed batch also gets a *simulated FPGA latency* from the
//! performance model (the codesign view: numerics from the backend, timing
//! from the Zynq model) so the serving benches can report both.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::{Assembled, BatchPolicy, Batcher};
use super::metrics::Metrics;
use crate::backend::{self, BackendInit, InferenceBackend};
use crate::fpga::{simulate, DeviceModel, Mode, NetConfig, SimReport};
use crate::model::zoo;
use crate::quant::MaskSet;
use crate::runtime::{HostTensor, Manifest, Runtime};

/// One inference request: a flattened image.
pub struct Request {
    pub image: Vec<f32>,
    pub reply: Sender<Response>,
    pub submitted: Instant,
}

/// The reply: logits + argmax + timing breakdown.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub queue_wait: Duration,
    pub e2e: Duration,
    /// What this request would have cost on the simulated FPGA.
    pub sim_fpga: Duration,
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_wait: Duration,
    /// Ratio name for the quantization masks (manifest `default_masks`),
    /// used by the FPGA-sim timing overlay.
    pub ratio_name: String,
    /// Device for the FPGA-sim timing overlay.
    pub device: String,
    /// Serve pre-quantized ("frozen") weights where the backend has a
    /// native frozen path (see `InferenceBackend::supports_frozen`).
    /// Construction-time only: consumed by [`Server::start_pjrt`] and the
    /// CLI/example when they build the backend — the generic
    /// [`Server::start`] never reads it (the backend already owns its
    /// weight policy).
    pub frozen: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_wait: Duration::from_millis(5),
            ratio_name: "ilmpq2".into(),
            device: "xc7z045".into(),
            frozen: true,
        }
    }
}

enum WorkerMsg {
    Batch(Assembled<Request>),
    Shutdown,
}

/// Handle to a running server.
pub struct Server {
    submit_tx: Sender<Request>,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// The FPGA-sim report for the configured (model, ratio, device).
    pub sim: SimReport,
}

impl Server {
    /// Start router + workers over any execution backend. The backend owns
    /// the weights; `manifest` supplies the batching geometry
    /// (`infer_batches`, image dims) and the FPGA-sim overlay inputs.
    pub fn start(
        manifest: &Manifest,
        backend: Arc<dyn InferenceBackend>,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let policy = BatchPolicy::new(manifest.infer_batches.clone(), cfg.max_wait);
        let metrics = Arc::new(Metrics::default());
        let shutdown = Arc::new(AtomicBool::new(false));

        // FPGA-sim overlay: per-image latency of this config on the device.
        let device = DeviceModel::by_name(&cfg.device)
            .ok_or_else(|| anyhow::anyhow!("unknown device {}", cfg.device))?;
        let net = zoo::tinyresnet(
            manifest.height,
            manifest.width,
            manifest.channels,
            &manifest.widths,
            manifest.classes,
        );
        let mask_set = manifest
            .default_masks
            .get(&cfg.ratio_name)
            .ok_or_else(|| anyhow::anyhow!("unknown ratio {}", cfg.ratio_name))?;
        let sim_cfg = NetConfig::from_masks(&cfg.ratio_name, mask_set.layers.clone());
        let sim = simulate(&net, &sim_cfg, &device, Mode::IntraLayer);
        let sim_per_image = sim.latency_s;

        // Warm up before accepting traffic: compile/pack everything so no
        // request pays a one-time cost.
        backend.prepare()?;

        let img_elems = manifest.data.image_elems();
        let (submit_tx, submit_rx) = channel::<Request>();
        let (work_tx, work_rx) = channel::<WorkerMsg>();
        let work_rx = Arc::new(std::sync::Mutex::new(work_rx));

        // Worker pool.
        let inflight = Arc::new(AtomicU64::new(0));
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let backend = backend.clone();
            let metrics = metrics.clone();
            let work_rx = work_rx.clone();
            let inflight = inflight.clone();
            workers.push(std::thread::spawn(move || loop {
                let msg = {
                    let rx = work_rx.lock().unwrap();
                    rx.recv()
                };
                match msg {
                    Ok(WorkerMsg::Batch(batch)) => {
                        run_batch(
                            backend.as_ref(),
                            img_elems,
                            &metrics,
                            batch,
                            sim_per_image,
                        );
                        inflight.fetch_sub(1, Ordering::Relaxed);
                    }
                    Ok(WorkerMsg::Shutdown) | Err(_) => return,
                }
            }));
        }

        // Router/batcher thread.
        let router = {
            let metrics = metrics.clone();
            let shutdown = shutdown.clone();
            let inflight = inflight.clone();
            std::thread::spawn(move || {
                let mut batcher: Batcher<Request> = Batcher::new(policy);
                loop {
                    // Pull whatever is immediately available.
                    loop {
                        match submit_rx.try_recv() {
                            Ok(req) => {
                                Metrics::inc(&metrics.requests_in);
                                batcher.push(req, Instant::now());
                            }
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => {
                                // Drain and stop.
                                while let Some(b) = batcher.flush() {
                                    inflight.fetch_add(1, Ordering::Relaxed);
                                    let _ = work_tx.send(WorkerMsg::Batch(b));
                                }
                                for _ in 0..64 {
                                    let _ = work_tx.send(WorkerMsg::Shutdown);
                                }
                                return;
                            }
                        }
                    }
                    if shutdown.load(Ordering::Relaxed) {
                        while let Some(b) = batcher.flush() {
                            inflight.fetch_add(1, Ordering::Relaxed);
                            let _ = work_tx.send(WorkerMsg::Batch(b));
                        }
                        for _ in 0..64 {
                            let _ = work_tx.send(WorkerMsg::Shutdown);
                        }
                        return;
                    }
                    let now = Instant::now();
                    if let Some(batch) = batcher.try_assemble(now) {
                        Metrics::inc(&metrics.batches);
                        Metrics::add(&metrics.batched_requests, batch.items.len() as u64);
                        Metrics::add(&metrics.padded_slots, batch.padded_slots() as u64);
                        inflight.fetch_add(1, Ordering::Relaxed);
                        let _ = work_tx.send(WorkerMsg::Batch(batch));
                        continue;
                    }
                    // Sleep until the next deadline (or a short poll tick).
                    let nap = batcher
                        .time_to_deadline(now)
                        .unwrap_or(Duration::from_micros(200))
                        .min(Duration::from_micros(500));
                    std::thread::sleep(nap.max(Duration::from_micros(50)));
                }
            })
        };

        Ok(Server {
            submit_tx,
            metrics,
            shutdown,
            router: Some(router),
            workers,
            sim,
        })
    }

    /// Historic PJRT entry point: build the `"pjrt"` registry backend from
    /// a loaded runtime (honoring `cfg.frozen`) and serve it. `params` are
    /// the (trained) model parameters in AOT order; `masks` the
    /// quantization config.
    pub fn start_pjrt(
        rt: Arc<Runtime>,
        params: Vec<HostTensor>,
        masks: &MaskSet,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let init = BackendInit {
            masks: Some(masks.clone()),
            frozen: cfg.frozen,
            runtime: Some(rt.clone()),
            ..BackendInit::new(rt.manifest.clone(), params)
        };
        let backend: Arc<dyn InferenceBackend> =
            Arc::from(backend::create("pjrt", &init)?);
        Server::start(&rt.manifest, backend, cfg)
    }

    /// Submit one image; returns the channel the response arrives on.
    pub fn submit(&self, image: Vec<f32>) -> Receiver<Response> {
        let (tx, rx) = channel();
        let req = Request { image, reply: tx, submitted: Instant::now() };
        // A send error means shutdown already started; the caller sees a
        // closed reply channel.
        let _ = self.submit_tx.send(req);
        rx
    }

    /// Graceful stop: flush queues, join threads.
    pub fn stop(mut self) -> Arc<Metrics> {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.clone()
    }
}

fn run_batch(
    backend: &dyn InferenceBackend,
    img_elems: usize,
    metrics: &Metrics,
    batch: Assembled<Request>,
    sim_per_image: f64,
) {
    let exec_size = batch.exec_size;
    let mut x = Vec::with_capacity(exec_size * img_elems);
    for p in &batch.items {
        x.extend_from_slice(&p.payload.image);
    }
    x.resize(exec_size * img_elems, 0.0); // padded slots
    let t_exec = Instant::now();
    let result = backend.run_batch(&x, exec_size);
    // Simulated FPGA time: per-layer pipeline over the batch.
    let sim_batch = Duration::from_secs_f64(sim_per_image * batch.items.len() as f64);
    metrics.sim_fpga.record(sim_batch.as_secs_f64());

    match result {
        Ok(out) => {
            // The backend's own measurement excludes the input-copy work
            // above, so `execute` tracks pure backend cost.
            metrics.execute.record(out.elapsed.as_secs_f64());
            let classes = out.classes;
            let done = Instant::now();
            for (i, p) in batch.items.iter().enumerate() {
                let row = &out.logits[i * classes..(i + 1) * classes];
                let queue_wait = t_exec.duration_since(p.enqueued);
                let e2e = done.duration_since(p.payload.submitted);
                metrics.queue_wait.record(queue_wait.as_secs_f64());
                metrics.e2e.record(e2e.as_secs_f64());
                Metrics::inc(&metrics.requests_done);
                let _ = p.payload.reply.send(Response {
                    logits: row.to_vec(),
                    pred: out.preds[i],
                    queue_wait,
                    e2e,
                    sim_fpga: sim_batch,
                });
            }
        }
        Err(err) => {
            metrics.execute.record(t_exec.elapsed().as_secs_f64());
            eprintln!("[server] batch failed: {err:#}");
            for _p in &batch.items {
                // Dropping the batch (and with it each reply Sender) closes
                // the per-request channels — the client sees RecvError.
                Metrics::inc(&metrics.requests_rejected);
            }
        }
    }
}
