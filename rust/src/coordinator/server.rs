//! The serving loop: a validating admission pipeline + dynamic batcher + a
//! backend-generic worker pool.
//!
//! Architecture (threads + channels; the sandbox has no tokio, and the
//! workload — CPU-bound batch executions — wants a small fixed pool anyway):
//!
//! ```text
//!   clients ──submit──▶ [admission] ──▶ router/batcher ──Batch──▶ worker 0..N-1
//!             validate + bounded queue   (Batcher<Request>)        │  InferenceBackend
//!   clients ◀──reply channel per request: Result<Response, ServeError>──┘
//! ```
//!
//! **Admission pipeline.** `submit` is the front door and enforces the batch
//! contract *before* a request can touch batch assembly:
//!
//! * geometry + finiteness validation ([`crate::backend::validate_image`]) —
//!   a malformed request is rejected alone with
//!   [`ServeError::InvalidInput`]. This is load-bearing: batch assembly
//!   concatenates images back to back into one statically-shaped backend
//!   buffer, so a short/long image admitted into a batch would shift every
//!   subsequent image's offset and hand neighbors each other's logits
//!   (the FINN-R dataflow contract: fixed per-image geometry feeding
//!   statically-shaped accelerator batches);
//! * a bounded in-system count ([`ServeConfig::queue_depth`]) — once that
//!   many requests are admitted but unanswered, new submissions are shed
//!   newest-first with [`ServeError::QueueFull`] instead of growing the
//!   router's memory without bound;
//! * every admitted request is *always* answered: success is
//!   `Ok(Response)`, a failed batch answers each member with
//!   [`ServeError::BackendFailed`] (one corrupt dispatch degrades
//!   per-request, never per-batch-silently), and stop answers stragglers
//!   with [`ServeError::ShuttingDown`] — no dropped reply channels.
//!
//! Workers execute through the unified [`InferenceBackend`] trait, so the
//! same dynamic-batching loop serves the PJRT engine, the native
//! packed-code `qgemm` path (which runs on toolchain-only machines under
//! `--no-default-features`), or the f32 reference — pick with
//! `backend::create` and hand the result to [`Server::start`].
//!
//! Every executed batch also gets a *simulated FPGA latency* from the
//! performance model (the codesign view: numerics from the backend, timing
//! from the Zynq model) so the serving benches can report both.

use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{Assembled, BatchPolicy, Batcher};
use super::metrics::Metrics;
use crate::backend::{self, BackendInit, InferenceBackend};
use crate::fpga::{simulate, DeviceModel, Mode, NetConfig, SimReport};
use crate::model::zoo;
use crate::quant::{assign, MaskSet, Provenance, QuantPlan, Scheme};
use crate::runtime::{HostTensor, Manifest, Runtime};

/// One inference request: a flattened image (already admission-validated).
pub struct Request {
    pub image: Vec<f32>,
    pub reply: Sender<ServeResult>,
    pub submitted: Instant,
}

/// The reply: logits + argmax + timing breakdown.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<f32>,
    pub pred: usize,
    pub queue_wait: Duration,
    pub e2e: Duration,
    /// What *this request alone* would have cost on the simulated FPGA (one
    /// image through the per-layer pipeline). The accelerator model runs
    /// images sequentially — cross-image pipeline amortization is not
    /// modeled — so the batch-level figure in `Metrics::sim_fpga` is this
    /// value times the batch's occupied slots.
    pub sim_fpga: Duration,
}

/// Typed serving error: why a request was not answered with logits. Every
/// submitted request receives exactly one `Result<Response, ServeError>` on
/// its reply channel — the error variants replace the historic behaviour of
/// silently dropping the channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Rejected at admission (wrong image length or non-finite values);
    /// the request never entered batch assembly, so its batch-mates are
    /// unaffected.
    InvalidInput(String),
    /// The admission queue is at its configured depth; this request was
    /// shed (reject-newest) without being enqueued.
    QueueFull { depth: usize },
    /// The backend failed executing the batch this request was assembled
    /// into; every member of that batch receives this error.
    BackendFailed(String),
    /// The server stopped before this request could be dispatched.
    ShuttingDown,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::InvalidInput(reason) => write!(f, "invalid input: {reason}"),
            ServeError::QueueFull { depth } => {
                write!(f, "admission queue full (depth {depth}); request shed")
            }
            ServeError::BackendFailed(reason) => {
                write!(f, "backend failed executing this request's batch: {reason}")
            }
            ServeError::ShuttingDown => {
                write!(f, "server shutting down before the request was dispatched")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// What every reply channel carries.
pub type ServeResult = std::result::Result<Response, ServeError>;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_wait: Duration,
    /// Bound on requests admitted but not yet answered (submit channel +
    /// batcher queue + in-flight batches combined). Submissions beyond this
    /// are shed newest-first with [`ServeError::QueueFull`], so an overload
    /// can't grow the router's memory without bound. Values below 1 are
    /// clamped to 1. Default: 1024.
    pub queue_depth: usize,
    /// The active quantization plan: validated against the manifest at
    /// start, drives the FPGA-sim timing overlay, and is advertised on
    /// `GET /v1/plan`. `None` serves unquantized weights — the overlay then
    /// falls back to uniform Fixed-8 timing (the nearest hardware config;
    /// the simulator has no float mode).
    pub plan: Option<QuantPlan>,
    /// Device for the FPGA-sim timing overlay.
    pub device: String,
    /// Serve pre-quantized ("frozen") weights where the backend has a
    /// native frozen path (see `InferenceBackend::supports_frozen`).
    /// Construction-time only: consumed by [`Server::start_pjrt`] and the
    /// CLI/example when they build the backend — the generic
    /// [`Server::start`] never reads it (the backend already owns its
    /// weight policy).
    pub frozen: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 2,
            max_wait: Duration::from_millis(5),
            queue_depth: 1024,
            plan: None,
            device: "xc7z045".into(),
            frozen: true,
        }
    }
}

enum WorkerMsg {
    Batch(Assembled<Request>),
    Shutdown,
}

/// What the submit channel carries. `Wake` exists because the router
/// *blocks* on this channel when it has nothing to do (no busy-polling an
/// empty queue): `begin_shutdown` sends one so a parked router notices the
/// shutdown flag immediately instead of on the next request.
enum RouterMsg {
    Req(QueuedRequest),
    Wake,
}

/// An admitted request in flight to the router, armed to answer on drop.
///
/// This closes the submit/shutdown race airtight: `begin_shutdown` can be
/// called from any thread (it takes `&self`), so a request that passed
/// submit's shutdown-flag check can land in the channel *after* the
/// router's final drain. Such a request is never popped — it is dropped
/// when the channel's receiver drops — and the `Drop` impl below turns
/// exactly that into a typed `ShuttingDown` answer (plus the counter
/// bookkeeping), so "every admitted request is answered exactly once"
/// holds with no drain-ordering subtleties. The router *disarms* the guard
/// with [`QueuedRequest::take`] when it pops a request for real.
struct QueuedRequest {
    req: Option<Request>,
    in_system: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
}

impl QueuedRequest {
    /// Disarm and hand out the request (the popped-by-router path).
    fn take(mut self) -> Request {
        self.req.take().expect("take called once")
    }
}

impl Drop for QueuedRequest {
    fn drop(&mut self) {
        if let Some(req) = self.req.take() {
            Metrics::inc(&self.metrics.requests_shutdown);
            self.in_system.fetch_sub(1, Ordering::SeqCst);
            let _ = req.reply.send(Err(ServeError::ShuttingDown));
        }
    }
}

/// Handle to a running server.
pub struct Server {
    submit_tx: Sender<RouterMsg>,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    /// Requests admitted but not yet answered; the admission bound.
    in_system: Arc<AtomicU64>,
    img_elems: usize,
    queue_depth: usize,
    router: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// The FPGA-sim report for the configured (model, plan, device).
    pub sim: SimReport,
    /// The quantization plan this server runs (`None` = unquantized) —
    /// what `GET /v1/plan` advertises.
    pub plan: Option<Arc<QuantPlan>>,
}

impl Server {
    /// Start router + workers over any execution backend. The backend owns
    /// the weights; `manifest` supplies the batching geometry
    /// (`infer_batches`, image dims) and the FPGA-sim overlay inputs.
    pub fn start(
        manifest: &Manifest,
        backend: Arc<dyn InferenceBackend>,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let policy = BatchPolicy::new(manifest.infer_batches.clone(), cfg.max_wait);
        let metrics = Arc::new(Metrics::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let in_system = Arc::new(AtomicU64::new(0));
        let queue_depth = cfg.queue_depth.max(1);

        // FPGA-sim overlay: per-image latency of this config on the device.
        let device = DeviceModel::by_name(&cfg.device)
            .ok_or_else(|| anyhow::anyhow!("unknown device {}", cfg.device))?;
        let net = zoo::tinyresnet(
            manifest.height,
            manifest.width,
            manifest.channels,
            &manifest.widths,
            manifest.classes,
        );
        // The plan is the serving contract: validate it against the
        // manifest before anything packs or simulates with it, so a stale
        // or mismatched plan file fails at startup, not mid-traffic.
        let plan = cfg.plan.clone().map(Arc::new);
        if let Some(p) = &plan {
            p.validate(manifest).context("serving plan rejected")?;
        }
        // Executed-vs-advertised cross-check: a backend that retains its
        // mask set must agree with the plan this server advertises on
        // `GET /v1/plan` — the config carrying one assignment while the
        // backend executes another is exactly the silent misreport the
        // plan API exists to prevent.
        match (&plan, backend.active_masks()) {
            (Some(p), Some(masks)) => anyhow::ensure!(
                p.masks.layers == masks.layers,
                "ServeConfig.plan {:?} does not match the mask set the backend executes",
                p.name
            ),
            (None, Some(_)) => anyhow::bail!(
                "the backend executes a quantization mask set but ServeConfig.plan \
                 is unset; pass the plan the backend was built with so /v1/plan \
                 cannot misreport"
            ),
            _ => {}
        }
        let sim_cfg = match &plan {
            Some(p) => NetConfig::from_masks(&p.name, p.masks.layers.clone()),
            // Unquantized serving: the simulator has no float mode, so
            // overlay the nearest hardware config (uniform Fixed-8).
            None => NetConfig::from_masks(
                "unquantized (Fixed-8 overlay)",
                net.layers
                    .iter()
                    .map(|l| assign::assign_uniform_layer(&l.name, l.rows(), Scheme::Fixed8))
                    .collect(),
            ),
        };
        let sim = simulate(&net, &sim_cfg, &device, Mode::IntraLayer);
        let sim_per_image = sim.latency_s;

        // Warm up before accepting traffic: compile/pack everything so no
        // request pays a one-time cost.
        backend.prepare()?;

        let img_elems = manifest.data.image_elems();
        let classes = manifest.classes;
        let (submit_tx, submit_rx) = channel::<RouterMsg>();
        let (work_tx, work_rx) = channel::<WorkerMsg>();
        let work_rx = Arc::new(std::sync::Mutex::new(work_rx));

        // Worker pool.
        let n_workers = cfg.workers.max(1);
        let mut workers = Vec::new();
        for _ in 0..n_workers {
            let backend = backend.clone();
            let metrics = metrics.clone();
            let work_rx = work_rx.clone();
            let in_system = in_system.clone();
            workers.push(std::thread::spawn(move || loop {
                let msg = {
                    let rx = work_rx.lock().unwrap();
                    rx.recv()
                };
                match msg {
                    Ok(WorkerMsg::Batch(batch)) => {
                        run_batch(
                            backend.as_ref(),
                            img_elems,
                            classes,
                            &metrics,
                            &in_system,
                            batch,
                            sim_per_image,
                        );
                    }
                    Ok(WorkerMsg::Shutdown) | Err(_) => return,
                }
            }));
        }

        // Router/batcher thread. The loop *blocks* on the submit channel
        // when there is nothing to do — bounded by the batch deadline when
        // requests are pending, unbounded when the batcher is empty — so an
        // idle server parks instead of waking every few hundred µs (the
        // historic `try_recv` + capped-sleep loop woke ~2–5k times/s on an
        // empty queue). `metrics.router_wakeups` counts loop iterations as
        // the regression signal; `begin_shutdown` sends `RouterMsg::Wake`
        // so a parked router still notices stop immediately.
        let router = {
            let metrics = metrics.clone();
            let shutdown = shutdown.clone();
            std::thread::spawn(move || {
                let mut batcher: Batcher<Request> = Batcher::new(policy);
                loop {
                    Metrics::inc(&metrics.router_wakeups);
                    // Pull whatever is immediately available.
                    let mut disc = false;
                    loop {
                        match submit_rx.try_recv() {
                            Ok(RouterMsg::Req(q)) => batcher.push(q.take(), Instant::now()),
                            Ok(RouterMsg::Wake) => {}
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => {
                                disc = true;
                                break;
                            }
                        }
                    }
                    if disc || shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    let now = Instant::now();
                    if let Some(batch) = batcher.try_assemble(now) {
                        dispatch(&metrics, &work_tx, batch);
                        continue;
                    }
                    // Park. With requests pending the wait is capped by the
                    // oldest request's deadline (so the partial-batch
                    // dispatch still fires on time); with an empty batcher
                    // the recv blocks until the next submission or Wake —
                    // zero idle wakeups. A `Some(0)` deadline is impossible
                    // here: an expired oldest request makes `try_assemble`
                    // dispatch above.
                    let msg = match batcher.time_to_deadline(Instant::now()) {
                        Some(d) => submit_rx.recv_timeout(d),
                        None => submit_rx
                            .recv()
                            .map_err(|_| RecvTimeoutError::Disconnected),
                    };
                    match msg {
                        Ok(RouterMsg::Req(q)) => batcher.push(q.take(), Instant::now()),
                        Ok(RouterMsg::Wake) | Err(RecvTimeoutError::Timeout) => {}
                        Err(RecvTimeoutError::Disconnected) => break,
                    }
                }
                // Stop cutoff (or dropped Server). Everything already
                // admitted to the batcher ships and gets real answers from
                // the workers. Requests still buffered in the submit
                // channel — including any that race in *after* this point,
                // which `begin_shutdown(&self)` makes possible — are
                // answered `ShuttingDown` by `QueuedRequest`'s drop guard
                // the moment `submit_rx` drops with this thread; no drain
                // loop can miss them.
                while let Some(b) = batcher.flush() {
                    dispatch(&metrics, &work_tx, b);
                }
                for _ in 0..n_workers {
                    let _ = work_tx.send(WorkerMsg::Shutdown);
                }
            })
        };

        Ok(Server {
            submit_tx,
            metrics,
            shutdown,
            in_system,
            img_elems,
            queue_depth,
            router: Some(router),
            workers,
            sim,
            plan,
        })
    }

    /// Historic PJRT entry point: build the `"pjrt"` registry backend from
    /// a loaded runtime (honoring `cfg.frozen`) and serve it. `params` are
    /// the (trained) model parameters in AOT order; `masks` the
    /// quantization config, wrapped into a [`QuantPlan`] when `cfg.plan` is
    /// unset. When the caller *did* set `cfg.plan`, that plan is what the
    /// backend executes — the advertised plan and the executed masks are
    /// one value by construction, never two that can drift — so it is
    /// validated here, before the (expensive, possibly panicky) backend
    /// build can see its masks.
    pub fn start_pjrt(
        rt: Arc<Runtime>,
        params: Vec<HostTensor>,
        masks: &MaskSet,
        mut cfg: ServeConfig,
    ) -> Result<Server> {
        let plan = match cfg.plan.clone() {
            Some(p) => p,
            None => {
                let p = QuantPlan::from_mask_set(
                    masks.clone(),
                    Provenance::NamedRatio { ratio: masks.name.clone() },
                );
                cfg.plan = Some(p.clone());
                p
            }
        };
        plan.validate(&rt.manifest).context("serving plan rejected")?;
        let init = BackendInit {
            plan: Some(plan),
            frozen: cfg.frozen,
            runtime: Some(rt.clone()),
            ..BackendInit::new(rt.manifest.clone(), params)
        };
        let backend: Arc<dyn InferenceBackend> =
            Arc::from(backend::create("pjrt", &init)?);
        Server::start(&rt.manifest, backend, cfg)
    }

    /// Submit one image; returns the channel the reply arrives on. Never
    /// blocks: admission decides immediately. A request that fails
    /// validation or hits the queue bound receives its typed error on the
    /// returned channel without ever entering batch assembly; every
    /// admitted request is answered exactly once.
    pub fn submit(&self, image: Vec<f32>) -> Receiver<ServeResult> {
        let (tx, rx) = channel();
        let submitted = Instant::now();
        Metrics::inc(&self.metrics.requests_in);

        // Cheap geometry check first: a wrong-length image is the
        // corruption-dangerous class and is rejected alone regardless of
        // load, before it can touch batch assembly.
        if let Err(reason) = backend::validate_image_len(&image, self.img_elems) {
            Metrics::inc(&self.metrics.requests_invalid);
            let _ = tx.send(Err(ServeError::InvalidInput(reason)));
            return rx;
        }
        if self.shutdown.load(Ordering::SeqCst) {
            Metrics::inc(&self.metrics.requests_shutdown);
            let _ = tx.send(Err(ServeError::ShuttingDown));
            return rx;
        }
        // Bounded admission: shed newest-first once `queue_depth` requests
        // are in the system (queued or executing, not yet answered). This
        // runs before the O(image_elems) finiteness scan so an overloaded
        // ingress sheds in O(1).
        let prev = self.in_system.fetch_add(1, Ordering::SeqCst);
        if prev >= self.queue_depth as u64 {
            self.in_system.fetch_sub(1, Ordering::SeqCst);
            Metrics::inc(&self.metrics.requests_shed);
            let _ = tx.send(Err(ServeError::QueueFull { depth: self.queue_depth }));
            return rx;
        }
        // Full value scan only for requests that are actually admitted
        // (roll the slot back on rejection).
        if let Err(reason) = backend::validate_image_finite(&image) {
            self.in_system.fetch_sub(1, Ordering::SeqCst);
            Metrics::inc(&self.metrics.requests_invalid);
            let _ = tx.send(Err(ServeError::InvalidInput(reason)));
            return rx;
        }
        let queued = QueuedRequest {
            req: Some(Request { image, reply: tx, submitted }),
            in_system: self.in_system.clone(),
            metrics: self.metrics.clone(),
        };
        // Three ways this send can end, all answered exactly once: the
        // router pops it (pipeline answers), the send fails because the
        // router exited (the SendError drops the guard → ShuttingDown), or
        // it sits buffered past the router's exit (dropped with the
        // receiver → ShuttingDown via the same guard).
        let _ = self.submit_tx.send(RouterMsg::Req(queued));
        rx
    }

    /// Front half of graceful stop: raise the shutdown flag and wake the
    /// router. From this point every *new* submission is answered
    /// `ShuttingDown` at the front door while already-admitted requests
    /// drain through the workers — this is what lets a network front end
    /// keep answering (with 503s) while the pipeline behind it drains.
    /// Idempotent; [`Server::stop`] calls it and then joins the threads.
    pub fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // A parked router (blocking recv on an empty batcher) only sees the
        // flag when a message arrives: nudge it.
        let _ = self.submit_tx.send(RouterMsg::Wake);
    }

    /// Graceful stop: flush queues, join threads. In-flight requests are
    /// answered (executed where already batched, `ShuttingDown` otherwise);
    /// no reply channel is left to dangle.
    pub fn stop(mut self) -> Arc<Metrics> {
        self.begin_shutdown();
        if let Some(r) = self.router.take() {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.clone()
    }
}

/// Hand one assembled batch to the worker pool, recording assembly metrics
/// (shared by the deadline path and the shutdown/disconnect flush).
fn dispatch(metrics: &Metrics, work_tx: &Sender<WorkerMsg>, batch: Assembled<Request>) {
    Metrics::inc(&metrics.batches);
    Metrics::add(&metrics.batched_requests, batch.items.len() as u64);
    Metrics::add(&metrics.padded_slots, batch.padded_slots() as u64);
    let _ = work_tx.send(WorkerMsg::Batch(batch));
}

fn run_batch(
    backend: &dyn InferenceBackend,
    img_elems: usize,
    classes: usize,
    metrics: &Metrics,
    in_system: &AtomicU64,
    batch: Assembled<Request>,
    sim_per_image: f64,
) {
    let exec_size = batch.exec_size;
    let mut x = Vec::with_capacity(exec_size * img_elems);
    for p in &batch.items {
        // Admission validated every image's geometry, so this concatenation
        // cannot shift a neighbour's offset.
        debug_assert_eq!(p.payload.image.len(), img_elems);
        x.extend_from_slice(&p.payload.image);
    }
    x.resize(exec_size * img_elems, 0.0); // padded slots
    let t_exec = Instant::now();
    // Contain backend panics and malformed outputs: under the admission
    // bound, a batch that died without answering would leak its
    // `queue_depth` slots forever (and drop reply channels) — so both
    // become the ordinary failed-batch path below, which answers and
    // decrements for every member.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        backend.run_batch(&x, exec_size)
    }))
    .unwrap_or_else(|payload| {
        let msg = payload
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_else(|| "non-string panic payload".into());
        Err(anyhow::anyhow!("backend panicked executing the batch: {msg}"))
    })
    .and_then(|out| {
        // Validate against the *manifest's* class count, not the backend's
        // self-reported one — a degenerate output (e.g. classes == 0 with
        // empty logits) must fail here, not reach clients as Ok.
        anyhow::ensure!(
            out.classes == classes
                && out.preds.len() == exec_size
                && out.logits.len() == exec_size * classes
                && out.preds.iter().all(|&p| p < classes),
            "backend returned malformed output: {} logits / {} preds / {} classes \
             for batch {exec_size} x {classes} classes",
            out.logits.len(),
            out.preds.len(),
            out.classes
        );
        Ok(out)
    });

    match result {
        Ok(out) => {
            // The backend's own measurement excludes the input-copy work
            // above, so `execute` tracks pure backend cost.
            metrics.execute.record(out.elapsed.as_secs_f64());
            // Simulated FPGA time: the sequential per-image model, summed
            // over the batch's occupied slots for the batch-level metric.
            let sim_batch =
                Duration::from_secs_f64(sim_per_image * batch.items.len() as f64);
            metrics.sim_fpga.record(sim_batch.as_secs_f64());
            let sim_request = Duration::from_secs_f64(sim_per_image);
            let classes = out.classes;
            let done = Instant::now();
            for (i, p) in batch.items.iter().enumerate() {
                let row = &out.logits[i * classes..(i + 1) * classes];
                // Measured from *submit* time, not router-push time: the
                // historic `p.enqueued` anchor silently excluded time spent
                // in the submit channel, so a congested ingress reported
                // rosy queue waits (and queue_wait ≤ e2e only held by
                // luck). Both anchors now share `submitted`, so the
                // invariant holds by construction.
                let queue_wait = t_exec.duration_since(p.payload.submitted);
                let e2e = done.duration_since(p.payload.submitted);
                metrics.queue_wait.record(queue_wait.as_secs_f64());
                metrics.e2e.record(e2e.as_secs_f64());
                Metrics::inc(&metrics.requests_done);
                in_system.fetch_sub(1, Ordering::SeqCst);
                let _ = p.payload.reply.send(Ok(Response {
                    logits: row.to_vec(),
                    pred: out.preds[i],
                    queue_wait,
                    e2e,
                    sim_fpga: sim_request,
                }));
            }
        }
        Err(err) => {
            // Host-observed elapsed goes to the dedicated failure track so
            // the `execute` percentiles only ever describe successful runs.
            metrics.failed.record(t_exec.elapsed().as_secs_f64());
            Metrics::inc(&metrics.batches_failed);
            let reason = format!("{err:#}");
            eprintln!("[server] batch failed: {reason}");
            for p in &batch.items {
                // Degrade per-request, not per-batch-silently: every member
                // of the failed batch gets the typed error on its channel.
                Metrics::inc(&metrics.requests_failed);
                in_system.fetch_sub(1, Ordering::SeqCst);
                let _ = p
                    .payload
                    .reply
                    .send(Err(ServeError::BackendFailed(reason.clone())));
            }
        }
    }
}
