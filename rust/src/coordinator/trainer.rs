//! QAT training driver — runs the AOT `train_step` artifact from Rust.
//!
//! This is the paper's 50-epoch PyTorch QAT loop, re-hosted: the coordinator
//! owns the parameter state, streams data batches, applies the step-decay
//! learning-rate schedule, and books the loss curve. All math happens inside
//! the lowered XLA executable (which itself embeds the Pallas fake-quant
//! kernels); Python is not involved.

use anyhow::{bail, Context, Result};

use crate::quant::MaskSet;
use crate::runtime::{HostTensor, Runtime};
use crate::util::Rng;

/// Step-decay LR schedule (the paper trains with "step learning rate").
#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub base: f32,
    /// Multiply by `gamma` every `step_every` steps.
    pub gamma: f32,
    pub step_every: usize,
}

impl LrSchedule {
    pub fn lr_at(&self, step: usize) -> f32 {
        self.base * self.gamma.powi((step / self.step_every) as i32)
    }
}

impl Default for LrSchedule {
    fn default() -> Self {
        LrSchedule { base: 0.05, gamma: 0.5, step_every: 150 }
    }
}

/// One record of the training log.
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub lr: f32,
}

/// Final evaluation numbers.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub loss: f32,
    pub acc: f32,
}

/// The QAT driver: parameter state + data + schedule.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub params: Vec<HostTensor>,
    mask_tensors: Vec<HostTensor>,
    pub schedule: LrSchedule,
    x_train: Vec<f32>,
    y_train: Vec<i32>,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub log: Vec<StepLog>,
}

impl<'rt> Trainer<'rt> {
    /// Start from the He-init parameters in the artifacts dir.
    pub fn new(rt: &'rt Runtime, masks: &MaskSet, seed: u64) -> Result<Trainer<'rt>> {
        let params = rt.manifest.load_init_params()?;
        let (x_train, y_train) = rt.manifest.data.load_train()?;
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..rt.manifest.data.n_train).collect();
        rng.shuffle(&mut order);
        Ok(Trainer {
            rt,
            params,
            mask_tensors: rt.manifest.mask_tensors(masks),
            schedule: LrSchedule::default(),
            x_train,
            y_train,
            order,
            cursor: 0,
            rng,
            log: Vec::new(),
        })
    }

    /// Swap the quantization config mid-training (mask hot-swap: the ILMPQ
    /// artifact takes masks as inputs, so this costs nothing — the property
    /// the paper's inter-layer competitors lack).
    pub fn set_masks(&mut self, masks: &MaskSet) {
        self.mask_tensors = self.rt.manifest.mask_tensors(masks);
    }

    fn next_batch(&mut self) -> (HostTensor, HostTensor) {
        let m = &self.rt.manifest;
        let b = m.train_batch;
        let img = m.data.image_elems();
        let mut x = Vec::with_capacity(b * img);
        let mut y = Vec::with_capacity(b);
        for _ in 0..b {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                self.rng.shuffle(&mut self.order);
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            x.extend_from_slice(&self.x_train[idx * img..(idx + 1) * img]);
            y.push(self.y_train[idx]);
        }
        (
            HostTensor::f32(
                vec![b, m.data.height, m.data.width, m.data.channels],
                x,
            ),
            HostTensor::i32(vec![b], y),
        )
    }

    /// Run one SGD step; returns (loss, acc) on the training batch.
    pub fn step(&mut self) -> Result<(f32, f32)> {
        let step_no = self.log.len();
        let lr = self.schedule.lr_at(step_no);
        let (x, y) = self.next_batch();
        let mut inputs = Vec::with_capacity(
            self.params.len() + self.mask_tensors.len() + 3,
        );
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.mask_tensors.iter().cloned());
        inputs.push(x);
        inputs.push(y);
        inputs.push(HostTensor::scalar(lr));
        let mut out = self.rt.run("train_step", &inputs)?;
        if out.len() != self.params.len() + 2 {
            bail!("train_step returned {} outputs", out.len());
        }
        let acc = out.pop().context("train_step output vector ended early")?.item();
        let loss = out.pop().context("train_step output vector ended early")?.item();
        self.params = out;
        self.log.push(StepLog { step: step_no, loss, acc, lr });
        Ok((loss, acc))
    }

    /// Train for `steps` steps, logging every `log_every` to `sink`.
    pub fn train(
        &mut self,
        steps: usize,
        log_every: usize,
        mut sink: impl FnMut(&StepLog),
    ) -> Result<()> {
        for _ in 0..steps {
            self.step()?;
            if let Some(last) = self.log.last().copied() {
                if last.step % log_every == 0 {
                    sink(&last);
                }
            }
        }
        Ok(())
    }

    /// Evaluate on the held-out test split (all full eval batches).
    pub fn evaluate(&self) -> Result<EvalResult> {
        let m = &self.rt.manifest;
        let (x_test, y_test) = m.data.load_test()?;
        let b = m.eval_batch;
        let img = m.data.image_elems();
        let n_batches = m.data.n_test / b;
        if n_batches == 0 {
            bail!("test split smaller than eval batch");
        }
        let (mut loss_sum, mut acc_sum) = (0f64, 0f64);
        for bi in 0..n_batches {
            let xs = &x_test[bi * b * img..(bi + 1) * b * img];
            let ys = &y_test[bi * b..(bi + 1) * b];
            let mut inputs = Vec::new();
            inputs.extend(self.params.iter().cloned());
            inputs.extend(self.mask_tensors.iter().cloned());
            inputs.push(HostTensor::f32(
                vec![b, m.data.height, m.data.width, m.data.channels],
                xs.to_vec(),
            ));
            inputs.push(HostTensor::i32(vec![b], ys.to_vec()));
            let out = self.rt.run("eval_batch", &inputs)?;
            loss_sum += out[0].item() as f64;
            acc_sum += out[1].item() as f64;
        }
        Ok(EvalResult {
            loss: (loss_sum / n_batches as f64) as f32,
            acc: (acc_sum / n_batches as f64) as f32,
        })
    }

    /// Smoothed final training loss (mean of the last k entries).
    pub fn recent_loss(&self, k: usize) -> f32 {
        let tail = &self.log[self.log.len().saturating_sub(k)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|l| l.loss).sum::<f32>() / tail.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_decays_stepwise() {
        let s = LrSchedule { base: 0.1, gamma: 0.5, step_every: 100 };
        assert_eq!(s.lr_at(0), 0.1);
        assert_eq!(s.lr_at(99), 0.1);
        assert_eq!(s.lr_at(100), 0.05);
        assert_eq!(s.lr_at(250), 0.025);
    }
}
