//! Table I accuracy columns: QAT each quantization config on the AOT model
//! (the ImageNet/ResNet-18 substitute documented in DESIGN.md §5) and
//! report final test accuracy per row — the reproducible claim is the
//! *ordering* (ILMPQ >= Fixed-8-ish > mixed > uniform 4-bit > PoT-4, and
//! quantizing first/last without intra-layer rescue rows hurts).

use anyhow::Result;

use crate::backend::{InferenceBackend, QgemmBackend};
use crate::baselines::table1::{accuracy_configs, manifest_ratio_name, AccuracyConfig};
use crate::coordinator::trainer::Trainer;
use crate::experiments::ptq;
use crate::quant::{assign, gemm_rows, LayerMasks, MaskSet, Provenance, QuantPlan, Scheme};
use crate::runtime::Runtime;

/// One finished accuracy run.
#[derive(Debug, Clone)]
pub struct AccuracyRow {
    pub label: String,
    pub paper_top1: f64,
    pub test_acc: f64,
    pub final_loss: f64,
    /// Test accuracy of the same trained weights re-evaluated through the
    /// native packed-GEMM path (`--qgemm-check`): the cross-check that the
    /// integer execution model matches the PJRT fake-quant semantics.
    pub qgemm_acc: Option<f64>,
}

/// Build the quantization plan for one accuracy config.
///
/// Plain intra-layer configs resolve through [`crate::runtime::Manifest::plan`]
/// (the masks computed by `assign.py` — Hessian + variance at init).
/// First/last-8-bit baselines are assembled here: stem and fc uniform
/// Fixed-8, middle layers assigned in Rust with the same policy (using the
/// manifest's eigenvalues), exercising the Rust↔Python assignment parity on
/// the real artifacts.
pub fn plan_for(rt: &Runtime, cfg: &AccuracyConfig) -> Result<QuantPlan> {
    let m = &rt.manifest;
    if !cfg.first_last_8bit {
        let name = manifest_ratio_name(&cfg.ratio)
            .ok_or_else(|| anyhow::anyhow!("no manifest masks for {}", cfg.label))?;
        return m.plan(name);
    }
    let params = rt.manifest.load_init_params()?;
    let qnames: Vec<&String> = m.quantized_layers.iter().map(|(n, _, _)| n).collect();
    let first = qnames.first().unwrap().as_str();
    let last = qnames.last().unwrap().as_str();
    let mut layers = Vec::new();
    for ((name, rows, _), _) in m.quantized_layers.iter().zip(0..) {
        if name == first || name == last {
            layers.push(assign::assign_uniform_layer(name, *rows, Scheme::Fixed8));
            continue;
        }
        let idx = m
            .params
            .iter()
            .position(|(n, _)| n == name)
            .expect("param for quantized layer");
        let w_rows = gemm_rows(&params[idx]);
        // Middle layers of fl8 baselines carry no Fixed-8 rows: the ratio's
        // PoT share applies to all rows (eigs only matter when frac8 > 0).
        let is8 = vec![0f32; *rows];
        let is_pot =
            assign::assign_schemes(&w_rows, &is8, cfg.ratio.pot_share_of_4bit());
        layers.push(LayerMasks { layer: name.clone(), is8, is_pot });
    }
    Ok(QuantPlan::from_mask_set(
        MaskSet { name: cfg.label.clone(), layers },
        Provenance::Sensitivity { ratio: cfg.ratio.label() },
    )
    .with_model(&m.model_name))
}

/// Train + evaluate one config. With `qgemm_check`, the trained weights are
/// additionally re-evaluated through the [`QgemmBackend`] (integer codes
/// end to end — packing raw weights under the training masks reproduces the
/// frozen codes exactly) so the two execution models can be diffed.
pub fn run_one(
    rt: &Runtime,
    cfg: &AccuracyConfig,
    steps: usize,
    seed: u64,
    qgemm_check: bool,
    mut log: impl FnMut(&str),
) -> Result<AccuracyRow> {
    let plan = plan_for(rt, cfg)?;
    let masks = plan.masks;
    let mut tr = Trainer::new(rt, &masks, seed)?;
    tr.train(steps, (steps / 5).max(1), |s| {
        log(&format!(
            "  step {:>4}  loss {:.4}  acc {:.3}  lr {:.4}",
            s.step, s.loss, s.acc, s.lr
        ));
    })?;
    let eval = tr.evaluate()?;
    let qgemm_acc = if qgemm_check {
        let be =
            QgemmBackend::new(rt.manifest.clone(), tr.params.clone(), masks.clone());
        be.prepare()?; // pack once; reused for the whole evaluation
        let acc = ptq::eval_with(&be, &rt.manifest)? * 100.0;
        log(&format!(
            "  qgemm cross-check: {:.2}% (PJRT eval {:.2}%)",
            acc,
            eval.acc as f64 * 100.0
        ));
        Some(acc)
    } else {
        None
    };
    Ok(AccuracyRow {
        label: cfg.label.clone(),
        paper_top1: cfg.paper_top1,
        test_acc: eval.acc as f64 * 100.0,
        final_loss: eval.loss as f64,
        qgemm_acc,
    })
}

/// Run every Table-I accuracy row, averaging test accuracy over `seeds`
/// independent data orders (init weights stay fixed, like the paper's
/// shared pretrained checkpoint — only the SGD batch order varies).
pub fn run_all(
    rt: &Runtime,
    steps: usize,
    seeds: &[u64],
    mut log: impl FnMut(&str),
) -> Result<Vec<AccuracyRow>> {
    let mut out = Vec::new();
    for cfg in accuracy_configs() {
        log(&format!("[accuracy] {} (ratio {})", cfg.label, cfg.ratio.label()));
        let mut accs = Vec::new();
        let mut losses = Vec::new();
        for &seed in seeds {
            let row = run_one(rt, &cfg, steps, seed, false, &mut log)?;
            log(&format!("  seed {seed}: test acc {:.2}%", row.test_acc));
            accs.push(row.test_acc);
            losses.push(row.final_loss);
        }
        out.push(AccuracyRow {
            label: cfg.label.clone(),
            paper_top1: cfg.paper_top1,
            test_acc: accs.iter().sum::<f64>() / accs.len() as f64,
            final_loss: losses.iter().sum::<f64>() / losses.len() as f64,
            qgemm_acc: None,
        });
    }
    Ok(out)
}

/// Render the accuracy table (proxy task vs paper ImageNet numbers).
pub fn render(rows: &[AccuracyRow]) -> String {
    let mut s = String::from(
        "== Table I accuracy (proxy task; paper = ResNet-18/ImageNet top-1) ==\n",
    );
    s.push_str(&format!(
        "{:<20} {:>12} {:>14} {:>12}\n",
        "config", "paper top-1", "proxy test acc", "final loss"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:>11.2}% {:>13.2}% {:>12.4}",
            r.label, r.paper_top1, r.test_acc, r.final_loss
        ));
        if let Some(q) = r.qgemm_acc {
            s.push_str(&format!("  [qgemm {q:.2}%]"));
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_formats() {
        let rows = vec![AccuracyRow {
            label: "ILMPQ-2".into(),
            paper_top1: 70.73,
            test_acc: 91.2,
            final_loss: 0.31,
            qgemm_acc: None,
        }];
        let s = render(&rows);
        assert!(s.contains("ILMPQ-2") && s.contains("70.73"));
        assert!(!s.contains("qgemm"));
    }

    #[test]
    fn render_includes_qgemm_column_when_checked() {
        let rows = vec![AccuracyRow {
            label: "ILMPQ-1".into(),
            paper_top1: 70.66,
            test_acc: 90.0,
            final_loss: 0.4,
            qgemm_acc: Some(89.61),
        }];
        let s = render(&rows);
        assert!(s.contains("[qgemm 89.61%]"));
    }
}
