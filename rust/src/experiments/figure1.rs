//! Figure 1 reproduction: the per-row scheme/precision map of a weight
//! tensor, rendered as ASCII (the paper's figure is a diagram of exactly
//! this assignment).

use crate::quant::{LayerMasks, MaskSet, Scheme};

fn glyph(s: Scheme) -> char {
    match s {
        Scheme::Pot4 => 'p',
        Scheme::Fixed4 => '4',
        Scheme::Fixed8 => '8',
    }
}

/// One layer as a row-map line: e.g. `stem/w  [44p8pp44...]  (6xPoT 8xF4 2xF8)`.
pub fn render_layer(m: &LayerMasks) -> String {
    let map: String = (0..m.rows()).map(|r| glyph(m.scheme_of(r))).collect();
    let (p, f4, f8) = m.counts();
    format!("{:<12} [{map}]  ({p}xPoT-4 {f4}xFixed-4 {f8}xFixed-8)", m.layer)
}

/// The full figure: every layer's row map + the legend.
pub fn render(masks: &MaskSet) -> String {
    let mut s = format!(
        "== Figure 1 — intra-layer row assignment ({}) ==\n\
         legend: p = PoT-4 (LUT lane)  4 = Fixed-4 (DSP, packed)  8 = Fixed-8 (DSP)\n",
        masks.name
    );
    for l in &masks.layers {
        s.push_str(&render_layer(l));
        s.push('\n');
    }
    let (p, f4, f8) = masks.total_fractions();
    s.push_str(&format!(
        "total row mix: {:.0}:{:.0}:{:.0} (PoT-4 : Fixed-4 : Fixed-8)\n",
        p * 100.0,
        f4 * 100.0,
        f8 * 100.0
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn masks() -> MaskSet {
        MaskSet {
            name: "test".into(),
            layers: vec![LayerMasks {
                layer: "stem/w".into(),
                is8: vec![1.0, 0.0, 0.0, 0.0],
                is_pot: vec![0.0, 1.0, 1.0, 0.0],
            }],
        }
    }

    #[test]
    fn layer_map_glyphs() {
        let s = render_layer(&masks().layers[0]);
        assert!(s.contains("[8pp4]"), "{s}");
        assert!(s.contains("2xPoT-4 1xFixed-4 1xFixed-8"));
    }

    #[test]
    fn figure_includes_totals_and_legend() {
        let s = render(&masks());
        assert!(s.contains("legend"));
        assert!(s.contains("total row mix: 50:25:25"));
    }
}
