//! Experiment harness: one module per paper artifact (DESIGN.md §4 index).
//!
//! Shared by the `harness = false` benches, the CLI subcommands, and the
//! integration tests, so a table is regenerated identically everywhere.

pub mod accuracy;
pub mod figure1;
pub mod ptq;
pub mod table1;
