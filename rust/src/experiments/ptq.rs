//! PTQ probe — the seed-noise-free accuracy experiment.
//!
//! The QAT runs on the small proxy task carry ±4-7% data-order variance,
//! which swamps the 1-3% gaps Table I reports. This probe isolates the
//! *representational* quality of each quantization config deterministically:
//!
//! 1. train ONE reference model with all-rows-Fixed-8 masks (≈ float —
//!    8-bit error is negligible at this scale);
//! 2. for every Table-I config, freeze (post-training-quantize) the trained
//!    weights under that config's masks using the bit-exact Rust quantizers;
//! 3. evaluate each frozen model on the full test split via the
//!    `infer_frozen_b64` artifact.
//!
//! No randomness anywhere in steps 2-3, so config deltas are pure
//! quantization effect — exactly the quantity ILMPQ's 8-bit rescue rows and
//! variance-sorted PoT are supposed to protect.

use anyhow::Result;

use crate::baselines::table1::accuracy_configs;
use crate::coordinator::trainer::Trainer;
use crate::experiments::accuracy::masks_for;
use crate::quant::{assign, freeze, LayerMasks, MaskSet, Scheme};
use crate::runtime::{HostTensor, PackedModel, Runtime};

/// One PTQ row.
#[derive(Debug, Clone)]
pub struct PtqRow {
    pub label: String,
    pub paper_top1: f64,
    pub acc: f64,
    /// Accuracy drop vs the unquantized reference weights.
    pub drop_vs_float: f64,
}

/// Which executor evaluates the frozen model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalBackend {
    /// The `infer_frozen_b64` XLA artifact (f32 GEMMs on frozen weights).
    Pjrt,
    /// The native packed-code GEMM path (`quant::qgemm` over the BRAM
    /// image) — integer arithmetic end to end.
    Qgemm,
}

/// All-Fixed-8 mask set (the near-float training config).
pub fn fixed8_masks(rt: &Runtime) -> MaskSet {
    MaskSet {
        name: "fixed8-ref".into(),
        layers: rt
            .manifest
            .quantized_layers
            .iter()
            .map(|(n, rows, _)| assign::assign_uniform_layer(n, *rows, Scheme::Fixed8))
            .collect(),
    }
}

fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, c| a.1.partial_cmp(c.1).unwrap())
        .map(|(k, _)| k)
        .unwrap()
}

/// Fraction of predictions matching labels (over the predicted prefix).
fn score(preds: &[usize], labels: &[i32]) -> f64 {
    if preds.is_empty() {
        return 0.0;
    }
    let correct = preds.iter().zip(labels).filter(|(p, y)| **p as i32 == **y).count();
    correct as f64 / preds.len() as f64
}

/// Predictions over an already-loaded test split (one disk read serves
/// both the prediction and the scoring pass).
fn predict_frozen_on(
    rt: &Runtime,
    params: &[HostTensor],
    x_test: &[f32],
) -> Result<Vec<usize>> {
    let m = &rt.manifest;
    let img = m.data.image_elems();
    let b = 64usize;
    let n_batches = m.data.n_test / b;
    let mut preds = Vec::with_capacity(n_batches * b);
    for bi in 0..n_batches {
        let mut inputs = params.to_vec();
        inputs.push(HostTensor::f32(
            vec![b, m.data.height, m.data.width, m.data.channels],
            x_test[bi * b * img..(bi + 1) * b * img].to_vec(),
        ));
        let out = rt.run("infer_frozen_b64", &inputs)?;
        let logits = out[0].as_f32();
        for i in 0..b {
            preds.push(argmax(&logits[i * m.classes..(i + 1) * m.classes]));
        }
    }
    Ok(preds)
}

fn predict_frozen_qgemm_on(
    rt: &Runtime,
    params: &[HostTensor],
    masks: Option<&MaskSet>,
    x_test: &[f32],
) -> Result<Vec<usize>> {
    let m = &rt.manifest;
    let model = PackedModel::build(m, params, masks)?;
    let img = m.data.image_elems();
    let b = 64usize;
    let n_batches = m.data.n_test / b;
    let mut preds = Vec::with_capacity(n_batches * b);
    for bi in 0..n_batches {
        let logits = model.forward(&x_test[bi * b * img..(bi + 1) * b * img], b);
        for i in 0..b {
            preds.push(argmax(&logits[i * m.classes..(i + 1) * m.classes]));
        }
    }
    Ok(preds)
}

/// Argmax predictions for the full test split via the `infer_frozen_b64`
/// artifact (params as given — caller freezes).
pub fn predict_frozen(rt: &Runtime, params: &[HostTensor]) -> Result<Vec<usize>> {
    let (x_test, _) = rt.manifest.data.load_test()?;
    predict_frozen_on(rt, params, &x_test)
}

/// Argmax predictions for the full test split via the native packed-GEMM
/// path. `masks = Some` packs the weights (pass the freeze-time mask set —
/// the codes are identical whether params are frozen or raw, since
/// fake-quant is idempotent); `None` runs the f32 reference backend.
pub fn predict_frozen_qgemm(
    rt: &Runtime,
    params: &[HostTensor],
    masks: Option<&MaskSet>,
) -> Result<Vec<usize>> {
    let (x_test, _) = rt.manifest.data.load_test()?;
    predict_frozen_qgemm_on(rt, params, masks, &x_test)
}

/// Evaluate params (as given — caller freezes) on the full test split via
/// the frozen artifacts. Returns accuracy in [0, 1].
pub fn eval_frozen(rt: &Runtime, params: &[HostTensor]) -> Result<f64> {
    let (x_test, y_test) = rt.manifest.data.load_test()?;
    let preds = predict_frozen_on(rt, params, &x_test)?;
    Ok(score(&preds, &y_test))
}

/// Same split, native packed-GEMM execution. Returns accuracy in [0, 1].
pub fn eval_frozen_qgemm(
    rt: &Runtime,
    params: &[HostTensor],
    masks: Option<&MaskSet>,
) -> Result<f64> {
    let (x_test, y_test) = rt.manifest.data.load_test()?;
    let preds = predict_frozen_qgemm_on(rt, params, masks, &x_test)?;
    Ok(score(&preds, &y_test))
}

/// Train the near-float reference model.
pub fn train_reference(
    rt: &Runtime,
    steps: usize,
    seed: u64,
    mut log: impl FnMut(&str),
) -> Result<Vec<HostTensor>> {
    let masks = fixed8_masks(rt);
    let mut tr = Trainer::new(rt, &masks, seed)?;
    tr.train(steps, (steps / 4).max(1), |s| {
        log(&format!("  ref step {:>4} loss {:.4} acc {:.3}", s.step, s.loss, s.acc));
    })?;
    Ok(tr.params)
}

/// The full PTQ table: float reference + all ten Table-I configs.
pub fn run_all(
    rt: &Runtime,
    steps: usize,
    seed: u64,
    log: impl FnMut(&str),
) -> Result<(f64, Vec<PtqRow>)> {
    run_all_with(rt, steps, seed, EvalBackend::Pjrt, log)
}

/// The full PTQ table on a chosen evaluation backend. Training always runs
/// through PJRT (QAT needs the lowered train_step artifact); only the
/// frozen-model evaluations switch.
pub fn run_all_with(
    rt: &Runtime,
    steps: usize,
    seed: u64,
    backend: EvalBackend,
    mut log: impl FnMut(&str),
) -> Result<(f64, Vec<PtqRow>)> {
    log("[ptq] training near-float (all-Fixed-8) reference ...");
    let params = train_reference(rt, steps, seed, &mut log)?;
    let float_acc = match backend {
        EvalBackend::Pjrt => eval_frozen(rt, &params)?,
        // No masks: the float Rust backend (f32 GEMM over gemm-view rows).
        EvalBackend::Qgemm => eval_frozen_qgemm(rt, &params, None)?,
    } * 100.0;
    log(&format!(
        "[ptq] reference (unquantized weights, {backend:?}) test acc {float_acc:.2}%"
    ));
    let names: Vec<String> = rt.manifest.params.iter().map(|(n, _)| n.clone()).collect();
    let mut rows = Vec::new();
    for cfg in accuracy_configs() {
        let masks = masks_for(rt, &cfg)?;
        let frozen = freeze::freeze_params(&params, &names, &masks);
        let acc = match backend {
            EvalBackend::Pjrt => eval_frozen(rt, &frozen)?,
            EvalBackend::Qgemm => eval_frozen_qgemm(rt, &frozen, Some(&masks))?,
        } * 100.0;
        log(&format!("[ptq] {:<20} {:.2}%", cfg.label, acc));
        rows.push(PtqRow {
            label: cfg.label.clone(),
            paper_top1: cfg.paper_top1,
            acc,
            drop_vs_float: float_acc - acc,
        });
    }
    Ok((float_acc, rows))
}

/// PTQ over ablation policies at the ILMPQ-2 ratio (noise-free §II-C check).
pub fn run_policies(
    rt: &Runtime,
    params: &[HostTensor],
    mut log: impl FnMut(&str),
) -> Result<Vec<(String, f64)>> {
    use crate::baselines::ablation::Policy;
    use crate::quant::{gemm_rows, Ratio};
    use crate::util::Rng;

    let m = &rt.manifest;
    let names: Vec<String> = m.params.iter().map(|(n, _)| n.clone()).collect();
    let ratio = Ratio::parse("65:30:5").unwrap();
    let mut out = Vec::new();
    for policy in Policy::all() {
        let mut rng = Rng::new(7);
        let layers: Vec<LayerMasks> = m
            .quantized_layers
            .iter()
            .map(|(name, _rows, _)| {
                let idx = m.params.iter().position(|(n, _)| n == name).unwrap();
                let w_rows = gemm_rows(&params[idx]);
                let eigs = m.eigs.get(name).unwrap();
                policy.assign(name, &w_rows, eigs, ratio, &mut rng)
            })
            .collect();
        let masks = MaskSet { name: policy.label().into(), layers };
        let frozen = freeze::freeze_params(params, &names, &masks);
        let acc = eval_frozen(rt, &frozen)? * 100.0;
        log(&format!("[ptq-policy] {:<24} {:.2}%", policy.label(), acc));
        out.push((policy.label().to_string(), acc));
    }
    Ok(out)
}

/// Render the PTQ table.
pub fn render(float_acc: f64, rows: &[PtqRow]) -> String {
    let mut s = format!(
        "== PTQ probe (deterministic; reference float-weights acc {float_acc:.2}%) ==\n\
         {:<20} {:>12} {:>10} {:>12}\n",
        "config", "paper top-1", "PTQ acc", "drop vs f32"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:>11.2}% {:>9.2}% {:>11.2}pp\n",
            r.label, r.paper_top1, r.acc, r.drop_vs_float
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_formats() {
        let rows = vec![PtqRow {
            label: "ILMPQ-2".into(),
            paper_top1: 70.73,
            acc: 80.0,
            drop_vs_float: 1.5,
        }];
        let s = render(81.5, &rows);
        assert!(s.contains("ILMPQ-2") && s.contains("1.50pp"));
    }

    #[test]
    fn score_and_argmax_semantics() {
        assert_eq!(score(&[1, 2, 3], &[1, 0, 3]), 2.0 / 3.0);
        assert_eq!(score(&[], &[]), 0.0);
        // Labels may be longer than the predicted prefix (truncated batches).
        assert_eq!(score(&[0, 1], &[0, 1, 2, 3]), 1.0);
        // Ties resolve to the last maximal index (the PJRT path's historic
        // behavior via `max_by`), shared by both backends.
        assert_eq!(argmax(&[0.1, 0.9, 0.9]), 2);
        assert_eq!(argmax(&[3.0, 1.0]), 0);
    }
}
