//! PTQ probe — the seed-noise-free accuracy experiment.
//!
//! The QAT runs on the small proxy task carry ±4-7% data-order variance,
//! which swamps the 1-3% gaps Table I reports. This probe isolates the
//! *representational* quality of each quantization config deterministically:
//!
//! 1. train ONE reference model with all-rows-Fixed-8 masks (≈ float —
//!    8-bit error is negligible at this scale);
//! 2. for every Table-I config, freeze (post-training-quantize) the trained
//!    weights under that config's masks using the bit-exact Rust quantizers;
//! 3. evaluate each frozen model on the full test split.
//!
//! No randomness anywhere in steps 2-3, so config deltas are pure
//! quantization effect — exactly the quantity ILMPQ's 8-bit rescue rows and
//! variance-sorted PoT are supposed to protect.
//!
//! Evaluation goes through the unified [`crate::backend`] API: any
//! registered backend (`pjrt`, `qgemm`, `float`) evaluates the frozen
//! models; training always runs through PJRT (QAT needs the lowered
//! `train_step` artifact).

use std::sync::Arc;

use anyhow::Result;

use crate::backend::{self, BackendInit, InferenceBackend, PjrtBackend};
use crate::baselines::table1::accuracy_configs;
use crate::coordinator::trainer::Trainer;
use crate::experiments::accuracy::plan_for;
use crate::quant::{assign, freeze, LayerMasks, MaskSet, Scheme};
use crate::runtime::{HostTensor, Manifest, Runtime};

/// Test-split evaluation batch size. Every PJRT-class backend must ship an
/// `infer_frozen_b{EVAL_BATCH}` artifact; CPU backends take any size.
pub const EVAL_BATCH: usize = 64;

/// One PTQ row.
#[derive(Debug, Clone)]
pub struct PtqRow {
    pub label: String,
    pub paper_top1: f64,
    pub acc: f64,
    /// Accuracy drop vs the unquantized reference weights.
    pub drop_vs_float: f64,
}

/// All-Fixed-8 mask set (the near-float training config).
pub fn fixed8_masks(rt: &Runtime) -> MaskSet {
    MaskSet {
        name: "fixed8-ref".into(),
        layers: rt
            .manifest
            .quantized_layers
            .iter()
            .map(|(n, rows, _)| assign::assign_uniform_layer(n, *rows, Scheme::Fixed8))
            .collect(),
    }
}

/// Fraction of predictions matching labels (over the predicted prefix).
fn score(preds: &[usize], labels: &[i32]) -> f64 {
    if preds.is_empty() {
        return 0.0;
    }
    let correct = preds.iter().zip(labels).filter(|(p, y)| **p as i32 == **y).count();
    correct as f64 / preds.len() as f64
}

/// Predictions over an already-loaded test split (one disk read can serve
/// both the prediction and the scoring pass).
fn predict_on(
    be: &dyn InferenceBackend,
    m: &Manifest,
    x_test: &[f32],
) -> Result<Vec<usize>> {
    let img = m.data.image_elems();
    let n_batches = m.data.n_test / EVAL_BATCH;
    let mut preds = Vec::with_capacity(n_batches * EVAL_BATCH);
    for bi in 0..n_batches {
        let chunk = &x_test[bi * EVAL_BATCH * img..(bi + 1) * EVAL_BATCH * img];
        preds.extend(be.run_batch(chunk, EVAL_BATCH)?.preds);
    }
    Ok(preds)
}

/// Argmax predictions for the full test split through any backend. The
/// backend owns the weights (frozen, packed, or raw — construction policy).
pub fn predict_with(be: &dyn InferenceBackend, m: &Manifest) -> Result<Vec<usize>> {
    let (x_test, _) = m.data.load_test()?;
    predict_on(be, m, &x_test)
}

/// Accuracy in [0, 1] over the full test split through any backend.
pub fn eval_with(be: &dyn InferenceBackend, m: &Manifest) -> Result<f64> {
    let (x_test, y_test) = m.data.load_test()?;
    let preds = predict_on(be, m, &x_test)?;
    Ok(score(&preds, &y_test))
}

/// Train the near-float reference model.
pub fn train_reference(
    rt: &Runtime,
    steps: usize,
    seed: u64,
    mut log: impl FnMut(&str),
) -> Result<Vec<HostTensor>> {
    let masks = fixed8_masks(rt);
    let mut tr = Trainer::new(rt, &masks, seed)?;
    tr.train(steps, (steps / 4).max(1), |s| {
        log(&format!("  ref step {:>4} loss {:.4} acc {:.3}", s.step, s.loss, s.acc));
    })?;
    Ok(tr.params)
}

/// The full PTQ table on the default (PJRT) evaluation backend.
pub fn run_all(
    rt: &Arc<Runtime>,
    steps: usize,
    seed: u64,
    log: impl FnMut(&str),
) -> Result<(f64, Vec<PtqRow>)> {
    run_all_with(rt, steps, seed, "pjrt", log)
}

/// The full PTQ table on a named evaluation backend (resolved through
/// `backend::registry()`). Training always runs through PJRT; only the
/// frozen-model evaluations switch.
pub fn run_all_with(
    rt: &Arc<Runtime>,
    steps: usize,
    seed: u64,
    backend_name: &str,
    mut log: impl FnMut(&str),
) -> Result<(f64, Vec<PtqRow>)> {
    // Resolve before training so a typo'd name errors with the registry
    // listing instead of after the expensive reference train.
    let bspec = backend::spec(backend_name)?;
    log("[ptq] training near-float (all-Fixed-8) reference ...");
    let params = train_reference(rt.as_ref(), steps, seed, &mut log)?;
    // The reference row runs *unquantized* weights; backends that cannot
    // (mask-requiring ones, per the registry) substitute the f32 reference.
    let ref_name = if bspec.masks_required { "float" } else { backend_name };
    let ref_be = backend::create(
        ref_name,
        &BackendInit {
            plan: None,
            runtime: Some(rt.clone()),
            ..BackendInit::new(rt.manifest.clone(), params.clone())
        },
    )?;
    let float_acc = eval_with(ref_be.as_ref(), &rt.manifest)? * 100.0;
    log(&format!(
        "[ptq] reference (unquantized weights, {ref_name}) test acc {float_acc:.2}%"
    ));
    let mut rows = Vec::new();
    for cfg in accuracy_configs() {
        let plan = plan_for(rt.as_ref(), &cfg)?;
        // One backend per config, packed/frozen once and reused for the
        // whole evaluation (raw params: freezing is backend policy).
        let be = backend::create(
            backend_name,
            &BackendInit {
                plan: Some(plan),
                runtime: Some(rt.clone()),
                ..BackendInit::new(rt.manifest.clone(), params.clone())
            },
        )?;
        let acc = eval_with(be.as_ref(), &rt.manifest)? * 100.0;
        log(&format!("[ptq] {:<20} {:.2}%", cfg.label, acc));
        rows.push(PtqRow {
            label: cfg.label.clone(),
            paper_top1: cfg.paper_top1,
            acc,
            drop_vs_float: float_acc - acc,
        });
    }
    Ok((float_acc, rows))
}

/// PTQ over ablation policies at the ILMPQ-2 ratio (noise-free §II-C check).
pub fn run_policies(
    rt: &Arc<Runtime>,
    params: &[HostTensor],
    mut log: impl FnMut(&str),
) -> Result<Vec<(String, f64)>> {
    use crate::baselines::ablation::Policy;
    use crate::quant::{gemm_rows, Ratio};
    use crate::util::Rng;

    let m = &rt.manifest;
    let ratio = Ratio::parse("65:30:5").unwrap();
    let mut out = Vec::new();
    for policy in Policy::all() {
        let mut rng = Rng::new(7);
        let layers: Vec<LayerMasks> = m
            .quantized_layers
            .iter()
            .map(|(name, _rows, _)| {
                let idx = m.params.iter().position(|(n, _)| n == name).unwrap();
                let w_rows = gemm_rows(&params[idx]);
                let eigs = m.eigs.get(name).unwrap();
                policy.assign(name, &w_rows, eigs, ratio, &mut rng)
            })
            .collect();
        let masks = MaskSet { name: policy.label().into(), layers };
        let frozen = freeze::freeze_for_manifest(m, params, &masks);
        let be = PjrtBackend::frozen_as_given(rt.clone(), frozen);
        let acc = eval_with(&be, &rt.manifest)? * 100.0;
        log(&format!("[ptq-policy] {:<24} {:.2}%", policy.label(), acc));
        out.push((policy.label().to_string(), acc));
    }
    Ok(out)
}

/// Render the PTQ table.
pub fn render(float_acc: f64, rows: &[PtqRow]) -> String {
    let mut s = format!(
        "== PTQ probe (deterministic; reference float-weights acc {float_acc:.2}%) ==\n\
         {:<20} {:>12} {:>10} {:>12}\n",
        "config", "paper top-1", "PTQ acc", "drop vs f32"
    );
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:>11.2}% {:>9.2}% {:>11.2}pp\n",
            r.label, r.paper_top1, r.acc, r.drop_vs_float
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::argmax;

    #[test]
    fn render_formats() {
        let rows = vec![PtqRow {
            label: "ILMPQ-2".into(),
            paper_top1: 70.73,
            acc: 80.0,
            drop_vs_float: 1.5,
        }];
        let s = render(81.5, &rows);
        assert!(s.contains("ILMPQ-2") && s.contains("1.50pp"));
    }

    #[test]
    fn score_and_argmax_semantics() {
        assert_eq!(score(&[1, 2, 3], &[1, 0, 3]), 2.0 / 3.0);
        assert_eq!(score(&[], &[]), 0.0);
        // Labels may be longer than the predicted prefix (truncated batches).
        assert_eq!(score(&[0, 1], &[0, 1, 2, 3]), 1.0);
        // Ties resolve to the last maximal index (the PJRT path's historic
        // behavior via `max_by`), shared by every backend through
        // `backend::argmax`.
        assert_eq!(argmax(&[0.1, 0.9, 0.9]), 2);
        assert_eq!(argmax(&[3.0, 1.0]), 0);
    }
}
