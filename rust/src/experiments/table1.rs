//! Table I reproduction: run every row's config through the FPGA simulator
//! and print simulated vs paper cells. Shared by `benches/table1.rs`, the
//! CLI (`ilmpq table1`), and the integration tests.

use crate::baselines::{hw_configs, HwConfig};
use crate::fpga::{simulate, DeviceModel, SimReport};
use crate::model::{resnet18, Network};

/// One reproduced row: config + simulation + paper cells.
#[derive(Debug, Clone)]
pub struct Row {
    pub cfg: HwConfig,
    pub sim: SimReport,
}

impl Row {
    /// Relative error of simulated vs paper throughput (None if the paper
    /// left the cell empty).
    pub fn throughput_rel_err(&self) -> Option<f64> {
        self.cfg
            .paper
            .map(|(gops, _)| (self.sim.throughput_gops - gops).abs() / gops)
    }

    pub fn latency_rel_err(&self) -> Option<f64> {
        self.cfg
            .paper
            .map(|(_, ms)| (self.sim.latency_s * 1e3 - ms).abs() / ms)
    }
}

/// Simulate all rows of Table I for one device.
pub fn run_device(device: &DeviceModel, net: &Network) -> Vec<Row> {
    hw_configs(device.name)
        .into_iter()
        .map(|cfg| {
            let nc = cfg.net_config(net);
            let sim = simulate(net, &nc, device, cfg.mode);
            Row { cfg, sim }
        })
        .collect()
}

/// Full Table I (both devices) on ResNet-18.
pub fn run_all() -> Vec<(DeviceModel, Vec<Row>)> {
    let net = resnet18();
    DeviceModel::all()
        .into_iter()
        .map(|d| {
            let rows = run_device(&d, &net);
            (d, rows)
        })
        .collect()
}

/// Render one device's table, paper numbers in parentheses.
pub fn render(device: &DeviceModel, rows: &[Row]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "== Table I — {} (ResNet-18 / ImageNet geometry, simulated) ==\n",
        device.name
    ));
    s.push_str(&format!(
        "{:<20} {:>7} {:>12} {:>12} {:>20} {:>20}\n",
        "config", "ratio", "LUT% (paper)", "DSP% (paper)", "GOP/s (paper)", "ms (paper)"
    ));
    for r in rows {
        let (pl, pd) = r.cfg.paper_util.unwrap_or((f64::NAN, f64::NAN));
        let (pg, pm) = r.cfg.paper.unwrap_or((f64::NAN, f64::NAN));
        s.push_str(&format!(
            "{:<20} {:>7} {:>6.0} ({:>4.0}) {:>6.0} ({:>4.0}) {:>12.1} ({:>6.1}) {:>12.1} ({:>6.1})\n",
            r.cfg.label,
            r.cfg.ratio.label(),
            r.sim.lut_util * 100.0,
            pl,
            r.sim.dsp_util * 100.0,
            pd,
            r.sim.throughput_gops,
            pg,
            r.sim.latency_s * 1e3,
            pm,
        ));
    }
    s
}

/// The headline speedups (§III): ILMPQ row vs row (1).
pub fn speedup(rows: &[Row]) -> f64 {
    let base = rows
        .iter()
        .find(|r| r.cfg.label.starts_with("(1)"))
        .expect("row (1)");
    let ilmpq = rows
        .iter()
        .find(|r| r.cfg.label.starts_with("ILMPQ"))
        .expect("ILMPQ row");
    base.sim.latency_s / ilmpq.sim.latency_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_rows_simulate() {
        for (d, rows) in run_all() {
            assert_eq!(rows.len(), 8, "{}", d.name);
            for r in &rows {
                assert!(r.sim.latency_s > 0.0, "{}: {}", d.name, r.cfg.label);
                assert!(r.sim.lut_util <= 1.0 && r.sim.dsp_util <= 1.0);
            }
        }
    }

    #[test]
    fn ilmpq_wins_throughput_on_both_devices() {
        for (d, rows) in run_all() {
            let best = rows
                .iter()
                .max_by(|a, b| {
                    a.sim.throughput_gops.partial_cmp(&b.sim.throughput_gops).unwrap()
                })
                .unwrap();
            assert!(
                best.cfg.label.starts_with("ILMPQ"),
                "{}: best is {}",
                d.name,
                best.cfg.label
            );
        }
    }

    #[test]
    fn headline_speedups_in_band() {
        // Paper: 3.01x on XC7Z020, 3.65x on XC7Z045.
        for (d, rows) in run_all() {
            let s = speedup(&rows);
            let (lo, hi) = (2.3, 4.8);
            assert!((lo..hi).contains(&s), "{}: speedup {s}", d.name);
        }
    }

    #[test]
    fn ordering_matches_paper_shape() {
        // Within each device: PoT rows beat Fixed rows; ILMPQ beats all;
        // quantized-first/last beats the fl8 sibling.
        for (_, rows) in run_all() {
            let by = |label: &str| {
                rows.iter()
                    .find(|r| r.cfg.label.starts_with(label))
                    .unwrap()
                    .sim
                    .throughput_gops
            };
            assert!(by("(4) PoT") > by("(2) Fixed"));
            assert!(by("(2) Fixed") > by("(1) Fixed fl8"));
            assert!(by("(4) PoT") > by("(3) PoT fl8"));
            assert!(by("ILMPQ") > by("(6) PoT+Fixed"));
        }
    }

    #[test]
    fn render_contains_every_label() {
        let net = resnet18();
        let d = DeviceModel::xc7z045();
        let rows = run_device(&d, &net);
        let txt = render(&d, &rows);
        for r in &rows {
            assert!(txt.contains(&r.cfg.label));
        }
    }
}
