//! FPGA device models — the hardware the paper measured on, as data.
//!
//! Resource counts are the real Zynq-7000 datasheet numbers (XC7Z020:
//! 53,200 LUTs / 220 DSP48E1 / 4.9 Mb BRAM; XC7Z045: 218,600 LUTs /
//! 900 DSP48E1 / 19.2 Mb BRAM). Clock and DDR bandwidth are the design
//! points typical of the paper's generation of Zynq accelerators (100 MHz
//! fabric clock, PS-side DDR3 shared with the ARM cores); the calibration
//! constants in `pe.rs` are documented in EXPERIMENTS.md §T1.

/// Static description of one FPGA part + board design point.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceModel {
    pub name: &'static str,
    /// Logic LUTs available to the design.
    pub luts: u64,
    /// DSP48 slices.
    pub dsps: u64,
    /// On-chip BRAM bytes.
    pub bram_bytes: u64,
    /// Fabric clock (Hz).
    pub clock_hz: f64,
    /// Sustained DDR bandwidth available to the accelerator (bytes/s).
    pub ddr_bytes_per_sec: f64,
    /// LUTs consumed by control, AXI/DMA, and buffering regardless of the
    /// PE configuration (calibrated so the fixed-point-only rows of Table I
    /// reproduce the paper's LUT% column).
    pub lut_overhead: u64,
}

impl DeviceModel {
    /// Xilinx Zynq XC7Z020 (Zedboard / PYNQ-Z1 class).
    ///
    /// The Artix-class fabric of the -1 speed grade Z020 typically closes
    /// timing around 70 MHz for dense MAC arrays (vs 100 MHz on the
    /// Kintex-class Z045) — the clock below is that design point and is the
    /// main reason every Z020 column of Table I is ~3-4x the Z045 latency.
    pub fn xc7z020() -> DeviceModel {
        DeviceModel {
            name: "xc7z020",
            luts: 53_200,
            dsps: 220,
            bram_bytes: 4_900_000 / 8,
            clock_hz: 71e6,
            ddr_bytes_per_sec: 2.1e9,
            lut_overhead: 20_000,
        }
    }

    /// Xilinx Zynq XC7Z045 (ZC706 class).
    pub fn xc7z045() -> DeviceModel {
        DeviceModel {
            name: "xc7z045",
            luts: 218_600,
            dsps: 900,
            bram_bytes: 19_200_000 / 8,
            clock_hz: 100e6,
            ddr_bytes_per_sec: 4.2e9,
            lut_overhead: 40_000,
        }
    }

    pub fn by_name(name: &str) -> Option<DeviceModel> {
        match name {
            "xc7z020" => Some(DeviceModel::xc7z020()),
            "xc7z045" => Some(DeviceModel::xc7z045()),
            _ => None,
        }
    }

    pub fn all() -> Vec<DeviceModel> {
        vec![DeviceModel::xc7z020(), DeviceModel::xc7z045()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn datasheet_resource_counts() {
        let z20 = DeviceModel::xc7z020();
        assert_eq!((z20.luts, z20.dsps), (53_200, 220));
        let z45 = DeviceModel::xc7z045();
        assert_eq!((z45.luts, z45.dsps), (218_600, 900));
        assert!(z45.bram_bytes > z20.bram_bytes);
    }

    #[test]
    fn lookup() {
        assert_eq!(DeviceModel::by_name("xc7z020").unwrap().name, "xc7z020");
        assert_eq!(DeviceModel::by_name("xc7z045").unwrap().name, "xc7z045");
        assert!(DeviceModel::by_name("xc7z100").is_none());
        assert_eq!(DeviceModel::all().len(), 2);
    }

    #[test]
    fn overhead_fits_in_device() {
        for d in DeviceModel::all() {
            assert!(d.lut_overhead < d.luts / 2);
        }
    }
}
