//! Tiled-GEMM timing model: systolic-array tile quantization + pipeline
//! fill. This is where the "efficiency < 100%" of real accelerators comes
//! from — a 30x30 DSP array running a 64-row layer wastes (90-64)/90 of its
//! row slots, and every tile pays a fill/drain latency.

use crate::model::GemmDims;

/// Geometry of one systolic GEMM engine: `rows x cols` MAC lattice.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ArrayShape {
    pub rows: usize,
    pub cols: usize,
}

impl ArrayShape {
    /// Factor `n_macs` into a near-square array, capping rows at 64 (BRAM
    /// port fan-out limits row parallelism on real designs).
    pub fn near_square(n_macs: u64) -> ArrayShape {
        if n_macs == 0 {
            return ArrayShape { rows: 0, cols: 0 };
        }
        let mut rows = (n_macs as f64).sqrt().floor() as usize;
        rows = rows.clamp(1, 64);
        let cols = (n_macs as usize).div_ceil(rows);
        ArrayShape { rows, cols }
    }

    pub fn macs(&self) -> usize {
        self.rows * self.cols
    }
}

/// Fraction of MAC slots doing useful work for a layer on this array:
/// tile-quantization efficiency over the (M, N) dims.
pub fn tile_efficiency(g: GemmDims, array: ArrayShape) -> f64 {
    if array.rows == 0 || array.cols == 0 || g.m == 0 || g.n == 0 {
        return 0.0;
    }
    let em = g.m as f64 / (g.m.div_ceil(array.rows) * array.rows) as f64;
    let en = g.n as f64 / (g.n.div_ceil(array.cols) * array.cols) as f64;
    em * en
}

/// Cycles to run `macs_assigned` MACs of a layer with GEMM dims `g` on an
/// array sustaining `macs_per_cycle` (already including any DSP packing),
/// accounting tile quantization and per-tile pipeline fill.
pub fn layer_cycles(
    g: GemmDims,
    macs_assigned: u64,
    macs_per_cycle: f64,
    array: ArrayShape,
) -> f64 {
    if macs_assigned == 0 || macs_per_cycle <= 0.0 {
        return 0.0;
    }
    let eff = tile_efficiency(g, array).max(1e-3);
    let compute = macs_assigned as f64 / (macs_per_cycle * eff);
    // Pipeline fill/drain: K cycles per (M, N) tile wave.
    let tiles = (g.m.div_ceil(array.rows.max(1)) * g.n.div_ceil(array.cols.max(1))) as f64;
    // Only the fraction of tiles this engine actually owns.
    let total_macs = (g.m as u64 * g.k as u64 * g.n as u64).max(1);
    let share = macs_assigned as f64 / total_macs as f64;
    let fill = tiles * share * (array.rows as f64 + 32.0);
    compute + fill
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    fn g(m: usize, k: usize, n: usize) -> GemmDims {
        GemmDims { m, k, n }
    }

    #[test]
    fn near_square_shapes() {
        let a = ArrayShape::near_square(900);
        assert_eq!((a.rows, a.cols), (30, 30));
        let a = ArrayShape::near_square(220);
        assert_eq!(a.rows, 14);
        assert!(a.macs() >= 220);
        assert_eq!(ArrayShape::near_square(0).macs(), 0);
        // Cap at 64 rows.
        assert_eq!(ArrayShape::near_square(100_000).rows, 64);
    }

    #[test]
    fn tile_efficiency_exact_fit_is_one() {
        let a = ArrayShape { rows: 32, cols: 32 };
        assert_eq!(tile_efficiency(g(64, 100, 64), a), 1.0);
        // 64 rows on a 30-row array: 64/90.
        let a = ArrayShape { rows: 30, cols: 30 };
        let e = tile_efficiency(g(64, 100, 60), a);
        assert!((e - (64.0 / 90.0) * (60.0 / 60.0)).abs() < 1e-9);
    }

    #[test]
    fn prop_efficiency_in_unit_interval() {
        forall(
            61,
            128,
            |r| {
                (
                    g(r.range_usize(1, 1024), r.range_usize(1, 4096), r.range_usize(1, 12544)),
                    ArrayShape::near_square(r.range_usize(1, 4000) as u64),
                )
            },
            |&(dims, arr)| {
                let e = tile_efficiency(dims, arr);
                ensure((0.0..=1.0).contains(&e), || format!("eff {e}"))
            },
        );
    }

    #[test]
    fn cycles_scale_with_work() {
        let dims = g(64, 576, 3136);
        let arr = ArrayShape::near_square(900);
        let full = layer_cycles(dims, dims.m as u64 * dims.k as u64 * dims.n as u64, 900.0, arr);
        let half = layer_cycles(dims, (dims.m as u64 * dims.k as u64 * dims.n as u64) / 2, 900.0, arr);
        assert!(full > half && half > 0.0);
        assert!((full / half - 2.0).abs() < 0.1);
    }

    #[test]
    fn zero_work_zero_cycles() {
        let dims = g(64, 576, 3136);
        assert_eq!(layer_cycles(dims, 0, 900.0, ArrayShape::near_square(900)), 0.0);
    }

    #[test]
    fn small_layer_wastes_array() {
        // A 10-row fc layer on a 30-row array should show the quantization
        // penalty: cycles > ideal by ~3x.
        let dims = g(10, 512, 1);
        let arr = ArrayShape { rows: 30, cols: 30 };
        let macs = (10 * 512) as u64;
        let cycles = layer_cycles(dims, macs, 900.0, arr);
        let ideal = macs as f64 / 900.0;
        assert!(cycles > 2.5 * ideal, "cycles {cycles} ideal {ideal}");
    }
}
