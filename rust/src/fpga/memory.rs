//! Memory traffic model: DDR transfers + on-chip buffer passes.
//!
//! Zynq accelerators stream weights and activations from the PS-side DDR
//! (shared with the ARM cores) into BRAM double-buffers. Per layer we charge:
//!
//! * **DDR**: packed weight bytes (4-/8-bit + per-row scales) + input and
//!   output activations (8-bit fixed activations, the paper's setting);
//! * **buffer pass**: im2col/line-buffer reshaping at `BUFFER_ELEMS_PER_CYCLE`
//!   elements/cycle, overlappable with compute via double-buffering.
//!
//! The simulator takes `max(compute, ddr, buffer)` per layer — the standard
//! perfectly-overlapped pipeline bound (§EXPERIMENTS.md documents the
//! calibration).

use crate::model::LayerDesc;
use crate::quant::LayerMasks;

/// Activation bytes per element (8-bit fixed activations).
pub const ACT_BYTES: f64 = 1.0;
/// Elements the line-buffer/im2col stage moves per cycle.
pub const BUFFER_ELEMS_PER_CYCLE: f64 = 16.0;

/// Packed weight bytes for a layer under row masks (4-bit rows: nibble per
/// weight; 8-bit rows: byte) + 5 bytes/row for scale+tag.
pub fn weight_bytes(layer: &LayerDesc, masks: &LayerMasks) -> f64 {
    let g = layer.gemm();
    let (pot, f4, f8) = masks.op_fractions();
    let rows = g.m as f64;
    let per_row_4 = (g.k as f64 / 2.0).ceil();
    let per_row_8 = g.k as f64;
    rows * ((pot + f4) * per_row_4 + f8 * per_row_8) + rows * 5.0
}

/// Total DDR bytes for one inference of this layer (batch 1).
pub fn ddr_bytes(layer: &LayerDesc, masks: &LayerMasks) -> f64 {
    let (a_in, a_out) = layer.activations();
    weight_bytes(layer, masks) + (a_in + a_out) as f64 * ACT_BYTES
}

/// Seconds of DDR time for one layer.
pub fn ddr_seconds(layer: &LayerDesc, masks: &LayerMasks, ddr_bps: f64) -> f64 {
    ddr_bytes(layer, masks) / ddr_bps
}

/// Seconds of buffer-pass time (im2col + write-back) for one layer.
pub fn buffer_seconds(layer: &LayerDesc, clock_hz: f64) -> f64 {
    let (a_in, a_out) = layer.activations();
    // im2col reads each input element once per kernel overlap on average ~1
    // with line buffers; charge in + out element streams.
    (a_in + a_out) as f64 / (BUFFER_ELEMS_PER_CYCLE * clock_hz)
}

/// Does the working set (one layer's weights + IO tiles) fit BRAM? When it
/// doesn't, weights re-stream per output tile and DDR time multiplies.
pub fn bram_weight_refetch_factor(
    layer: &LayerDesc,
    masks: &LayerMasks,
    bram_bytes: u64,
) -> f64 {
    let wb = weight_bytes(layer, masks);
    let budget = bram_bytes as f64 * 0.5; // half for weights, half for act tiles
    if wb <= budget {
        1.0
    } else {
        (wb / budget).min(4.0) // tiling bounds the refetch blow-up
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LayerDesc;
    use crate::quant::assign::assign_uniform_layer;
    use crate::quant::Scheme;

    fn conv() -> LayerDesc {
        LayerDesc::conv("c", 3, 1, 64, 64, 56, 56)
    }

    #[test]
    fn eight_bit_weighs_double_minus_overhead() {
        let l = conv();
        let m4 = assign_uniform_layer("c", 64, Scheme::Fixed4);
        let m8 = assign_uniform_layer("c", 64, Scheme::Fixed8);
        let w4 = weight_bytes(&l, &m4);
        let w8 = weight_bytes(&l, &m8);
        // 4-bit ~ half the 8-bit weight stream (modulo the 5 B/row tags).
        assert!(w8 / w4 > 1.9 && w8 / w4 < 2.1, "{w4} {w8}");
    }

    #[test]
    fn pot_and_fixed4_same_footprint() {
        let l = conv();
        let mp = assign_uniform_layer("c", 64, Scheme::Pot4);
        let m4 = assign_uniform_layer("c", 64, Scheme::Fixed4);
        assert_eq!(weight_bytes(&l, &mp), weight_bytes(&l, &m4));
    }

    #[test]
    fn ddr_time_inversely_proportional_to_bw() {
        let l = conv();
        let m = assign_uniform_layer("c", 64, Scheme::Fixed8);
        let t1 = ddr_seconds(&l, &m, 2.1e9);
        let t2 = ddr_seconds(&l, &m, 4.2e9);
        assert!((t1 / t2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn buffer_pass_counts_elements() {
        let l = conv();
        let t = buffer_seconds(&l, 100e6);
        let (ai, ao) = l.activations();
        assert!((t - (ai + ao) as f64 / (16.0 * 100e6)).abs() < 1e-12);
    }

    #[test]
    fn refetch_kicks_in_for_big_layers() {
        // fc1 of VGG-11: 25M weights >> BRAM.
        let fc = LayerDesc::fc("fc1", 512 * 7 * 7, 4096);
        let m = assign_uniform_layer("fc1", 4096, Scheme::Fixed8);
        let f = bram_weight_refetch_factor(&fc, &m, 4_900_000 / 8);
        assert!(f > 1.0);
        // Small layer: no refetch.
        let m2 = assign_uniform_layer("c", 64, Scheme::Fixed4);
        assert_eq!(bram_weight_refetch_factor(&conv(), &m2, 19_200_000 / 8), 1.0);
    }
}
