//! FPGA accelerator performance simulator (Zynq XC7Z020 / XC7Z045).
//!
//! Substitute for the paper's physical boards (DESIGN.md §5): a
//! resource/arithmetic/memory model detailed enough that the Table-I
//! quantities — lane balance, PE idle waste, ratio optima, relative
//! speedups — emerge from the same mechanisms the paper argues from.

pub mod device;
pub mod gemm;
pub mod memory;
pub mod pe;
pub mod sim;

pub use device::DeviceModel;
pub use pe::EngineAlloc;
pub use sim::{simulate, Mode, NetConfig, SimReport};
