//! PE (processing element) cost & rate model — the heart of the codesign.
//!
//! The paper's FPGA design instantiates two GEMM engines per device:
//!
//! * **GEMM_Fixed** on DSP48 slices — one DSP does one 8x8 MAC/cycle, or
//!   *two* 4x4 MACs/cycle (the classic INT4 DSP packing), so Fixed-4 rows
//!   run at 2x the Fixed-8 rate on the same silicon;
//! * **GEMM_PoT** on LUT fabric — a PoT multiply is a barrel shift, so a
//!   MAC unit costs ~`LUTS_PER_POT_MAC` LUTs and no DSP.
//!
//! Because the intra-layer mix is the *same in every layer*, one static
//! allocation (all DSPs + all spare LUTs) serves the whole network — the
//! paper's central hardware argument. `EngineAlloc` captures an allocation
//! and reports the Vivado-style utilization columns of Table I.

use super::device::DeviceModel;

/// LUTs per PoT shift-add MAC unit (shift + CSA + pipeline regs).
pub const LUTS_PER_POT_MAC: u64 = 45;
/// Glue LUTs per instantiated DSP PE (operand muxing, partial-sum regs).
pub const LUTS_PER_DSP_PE: u64 = 25;
/// One DSP48 is borrowed as accumulator per this many PoT units.
pub const POT_UNITS_PER_ACC_DSP: u64 = 24;
/// MACs per DSP per cycle at 4-bit (packed) and 8-bit. INT4 packing puts
/// two multiplies in one DSP48 but needs correction cycles for the shared
/// partial products, sustaining ~1.75 rather than the ideal 2.0 (this is
/// the packing efficiency real INT4-on-DSP48 designs report).
pub const FIXED4_MACS_PER_DSP: f64 = 1.75;
pub const FIXED8_MACS_PER_DSP: f64 = 1.0;

/// A static engine allocation on one device.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineAlloc {
    pub device: DeviceModel,
    /// DSPs driving GEMM_Fixed.
    pub fixed_dsps: u64,
    /// PoT shift-add MAC units in LUT fabric.
    pub pot_units: u64,
    /// DSPs borrowed as PoT accumulators.
    pub pot_acc_dsps: u64,
}

impl EngineAlloc {
    /// The ILMPQ allocation: every DSP works for GEMM_Fixed, and all LUTs
    /// left after control overhead + DSP glue become PoT units (when the
    /// configuration has any PoT rows at all).
    pub fn ilmpq(device: &DeviceModel, wants_pot: bool) -> EngineAlloc {
        let glue = device.dsps * LUTS_PER_DSP_PE;
        let spare = device.luts.saturating_sub(device.lut_overhead + glue);
        let (pot_units, pot_acc) = if wants_pot {
            let mut units = spare / LUTS_PER_POT_MAC;
            let mut acc = units.div_ceil(POT_UNITS_PER_ACC_DSP);
            // Accumulator DSPs come out of the fixed pool; never exceed it.
            acc = acc.min(device.dsps / 4);
            units = units.min(acc * POT_UNITS_PER_ACC_DSP).max(if acc > 0 { 1 } else { 0 });
            (units, acc)
        } else {
            (0, 0)
        };
        EngineAlloc {
            device: device.clone(),
            fixed_dsps: device.dsps - pot_acc,
            pot_units,
            pot_acc_dsps: pot_acc,
        }
    }

    /// An allocation with an explicit PoT-unit budget (ratio-search sweeps).
    pub fn with_pot_units(device: &DeviceModel, pot_units: u64) -> EngineAlloc {
        let max = EngineAlloc::ilmpq(device, true).pot_units;
        let units = pot_units.min(max);
        let acc = units.div_ceil(POT_UNITS_PER_ACC_DSP.max(1)).min(device.dsps / 4);
        EngineAlloc {
            device: device.clone(),
            fixed_dsps: device.dsps - acc,
            pot_units: units,
            pot_acc_dsps: acc,
        }
    }

    // ---- rates (ops/sec; 1 MAC = 2 ops) -----------------------------------

    pub fn fixed4_ops_per_sec(&self) -> f64 {
        2.0 * FIXED4_MACS_PER_DSP * self.fixed_dsps as f64 * self.device.clock_hz
    }

    pub fn fixed8_ops_per_sec(&self) -> f64 {
        2.0 * FIXED8_MACS_PER_DSP * self.fixed_dsps as f64 * self.device.clock_hz
    }

    pub fn pot_ops_per_sec(&self) -> f64 {
        2.0 * self.pot_units as f64 * self.device.clock_hz
    }

    // ---- Vivado-style utilization columns ---------------------------------

    pub fn lut_used(&self) -> u64 {
        self.device.lut_overhead
            + self.fixed_dsps * LUTS_PER_DSP_PE
            + self.pot_units * LUTS_PER_POT_MAC
    }

    pub fn lut_util(&self) -> f64 {
        self.lut_used() as f64 / self.device.luts as f64
    }

    /// DSP utilization. Matches the paper's convention where a design that
    /// instantiates fixed PEs on every DSP reports 100%.
    pub fn dsp_util(&self, uses_fixed: bool) -> f64 {
        let used = if uses_fixed {
            self.fixed_dsps + self.pot_acc_dsps
        } else {
            self.pot_acc_dsps
        };
        used as f64 / self.device.dsps as f64
    }

    /// Sanity: the allocation must fit the device.
    pub fn fits(&self) -> bool {
        self.lut_used() <= self.device.luts
            && self.fixed_dsps + self.pot_acc_dsps <= self.device.dsps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn ilmpq_alloc_fits_both_devices() {
        for d in DeviceModel::all() {
            for wants_pot in [false, true] {
                let a = EngineAlloc::ilmpq(&d, wants_pot);
                assert!(a.fits(), "{d:?} wants_pot={wants_pot}: {a:?}");
            }
        }
    }

    #[test]
    fn no_pot_means_no_units_and_low_lut() {
        let a = EngineAlloc::ilmpq(&DeviceModel::xc7z020(), false);
        assert_eq!(a.pot_units, 0);
        assert_eq!(a.pot_acc_dsps, 0);
        // Fixed-only design: LUT% ~ overhead + DSP glue ~ 48% on Z020
        // (paper Table I row 1: 49%).
        assert!((0.40..0.55).contains(&a.lut_util()), "{}", a.lut_util());
    }

    #[test]
    fn z045_fixed_only_lut_util_near_paper() {
        let a = EngineAlloc::ilmpq(&DeviceModel::xc7z045(), false);
        // Paper row 1 on Z045: 21% LUT.
        assert!((0.15..0.35).contains(&a.lut_util()), "{}", a.lut_util());
    }

    #[test]
    fn fixed4_rate_is_packing_factor_times_fixed8() {
        let a = EngineAlloc::ilmpq(&DeviceModel::xc7z045(), true);
        let ratio = a.fixed4_ops_per_sec() / a.fixed8_ops_per_sec();
        assert!((ratio - FIXED4_MACS_PER_DSP).abs() < 1e-9);
        assert!(ratio > 1.5, "packing must still win: {ratio}");
    }

    #[test]
    fn pot_rate_beats_fixed4_on_both_devices() {
        // The LUT fabric provides more MAC bandwidth than the DSPs — the
        // reason the optimal ratio leans PoT-heavy (60-65%).
        for d in DeviceModel::all() {
            let a = EngineAlloc::ilmpq(&d, true);
            assert!(
                a.pot_ops_per_sec() > a.fixed4_ops_per_sec(),
                "{}: pot {} vs fixed4 {}",
                d.name,
                a.pot_ops_per_sec(),
                a.fixed4_ops_per_sec()
            );
        }
    }

    #[test]
    fn prop_with_pot_units_always_fits() {
        forall(
            51,
            64,
            |r| (r.below(2), r.below(10_000) as u64),
            |&(di, units)| {
                let d = if di == 0 { DeviceModel::xc7z020() } else { DeviceModel::xc7z045() };
                let a = EngineAlloc::with_pot_units(&d, units);
                ensure(a.fits(), || format!("{a:?}"))?;
                ensure(a.pot_units <= units.max(1), || "grew past request".into())
            },
        );
    }

    #[test]
    fn utilization_bounded() {
        for d in DeviceModel::all() {
            let a = EngineAlloc::ilmpq(&d, true);
            assert!(a.lut_util() <= 1.0);
            assert!(a.dsp_util(true) <= 1.0);
            assert!(a.dsp_util(false) < 0.3); // PoT-only: few accumulator DSPs
        }
    }
}
