//! End-to-end FPGA performance simulator (the paper's testbed substitute).
//!
//! Given a network, a per-layer scheme mix, a device, and an execution mode,
//! produces the Table-I columns: LUT/DSP utilization, GOP/s throughput, and
//! end-to-end latency. Two modes:
//!
//! * **IntraLayer** (ILMPQ): one uniform engine pair; within every layer the
//!   DSP lane (Fixed-4 + Fixed-8 rows, time-shared) and the LUT lane (PoT
//!   rows) run concurrently — the layer finishes when the slower lane does.
//! * **InterLayer** (prior work): DSPs statically split into a 4-bit pool
//!   and an 8-bit pool (split chosen *optimally* for the workload, the
//!   baseline's best case); a layer only uses the pool matching its
//!   precision, the other pool idles — the waste the paper's intra-layer
//!   uniformity eliminates.

use super::device::DeviceModel;
use super::gemm::{layer_cycles, ArrayShape};
use super::memory;
use super::pe::{EngineAlloc, FIXED4_MACS_PER_DSP, FIXED8_MACS_PER_DSP};
use crate::model::Network;
use crate::quant::{assign::assign_uniform_layer, LayerMasks, Ratio, Scheme};

/// Fixed per-layer control overhead (descriptor fetch, buffer swap, DMA
/// setup) — calibrated; see EXPERIMENTS.md §T1.
pub const LAYER_OVERHEAD_S: f64 = 60e-6;

/// Execution mode: the paper's contribution vs the prior-work foil.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    IntraLayer,
    InterLayer,
}

/// A fully specified hardware experiment: per-layer row masks.
#[derive(Debug, Clone)]
pub struct NetConfig {
    pub label: String,
    pub masks: Vec<LayerMasks>,
    /// True for the Table-I rows that keep first/last layers at Fixed-8
    /// (the "First/Last Layer Quantization" column *without* a check).
    pub first_last_8bit: bool,
}

impl NetConfig {
    /// Synthesize masks from a Table-I ratio: every (middle) layer gets
    /// `round(rows * fraction)` rows per scheme; first/last become uniform
    /// Fixed-8 when `first_last_8bit`.
    pub fn from_ratio(
        net: &Network,
        ratio: Ratio,
        first_last_8bit: bool,
        label: &str,
    ) -> NetConfig {
        let (first, last) = net.first_last();
        let masks = net
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let rows = l.rows();
                if first_last_8bit && (i == first || i == last) {
                    assign_uniform_layer(&l.name, rows, Scheme::Fixed8)
                } else {
                    synth_masks(&l.name, rows, ratio)
                }
            })
            .collect();
        NetConfig { label: label.to_string(), masks, first_last_8bit }
    }

    /// Wrap real (assignment-derived) masks.
    pub fn from_masks(label: &str, masks: Vec<LayerMasks>) -> NetConfig {
        NetConfig { label: label.to_string(), masks, first_last_8bit: false }
    }

    pub fn uses_pot(&self) -> bool {
        self.masks.iter().any(|m| m.counts().0 > 0)
    }

    pub fn uses_fixed(&self) -> bool {
        self.masks.iter().any(|m| {
            let (_, f4, f8) = m.counts();
            f4 + f8 > 0
        })
    }
}

/// Deterministic synthetic masks hitting the ratio's row counts.
pub fn synth_masks(layer: &str, rows: usize, ratio: Ratio) -> LayerMasks {
    let n8 = if ratio.fixed8 <= 0.0 {
        0
    } else {
        ((rows as f64 * ratio.frac8()).round() as usize).max(1)
    };
    let rest = rows - n8;
    let npot = (rest as f64 * ratio.pot_share_of_4bit()).round() as usize;
    let mut is8 = vec![0f32; rows];
    let mut is_pot = vec![0f32; rows];
    for v in is8.iter_mut().take(n8) {
        *v = 1.0;
    }
    for v in is_pot.iter_mut().skip(n8).take(npot) {
        *v = 1.0;
    }
    LayerMasks { layer: layer.to_string(), is8, is_pot }
}

/// What bound a layer's time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    FixedLane,
    PotLane,
    Ddr,
    Buffer,
}

/// Per-layer timing breakdown.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub name: String,
    pub fixed_s: f64,
    pub pot_s: f64,
    pub ddr_s: f64,
    pub buffer_s: f64,
    pub total_s: f64,
    pub bound: Bound,
}

/// The Table-I row this simulation produces.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub label: String,
    pub device: String,
    pub mode: Mode,
    pub latency_s: f64,
    pub throughput_gops: f64,
    pub lut_util: f64,
    pub dsp_util: f64,
    /// Fraction of DSP-seconds idle (inter-layer waste; ~0 for intra-layer).
    pub dsp_idle_frac: f64,
    pub per_layer: Vec<LayerTiming>,
}

impl SimReport {
    pub fn row(&self) -> String {
        format!(
            "{:<14} {:>9} LUT {:>4.0}% DSP {:>4.0}%  {:>7.1} GOP/s  {:>7.1} ms",
            self.label,
            self.device,
            self.lut_util * 100.0,
            self.dsp_util * 100.0,
            self.throughput_gops,
            self.latency_s * 1e3
        )
    }
}

/// Split a layer's `macs` across the three schemes from the mask's op
/// fractions. PoT and Fixed-4 round once and clamp to what remains;
/// Fixed-8 takes the exact remainder — so the three parts always sum to
/// `macs`, under any adversarial rounding of the fractions (independent
/// rounding of all three could previously over- or under-count by a few
/// MACs per layer).
pub fn partition_macs(macs: u64, frac_pot: f64, frac_f4: f64) -> (u64, u64, u64) {
    let pot = ((macs as f64 * frac_pot).round() as u64).min(macs);
    let f4 = ((macs as f64 * frac_f4).round() as u64).min(macs - pot);
    (pot, f4, macs - pot - f4)
}

fn lane_times(
    layer_idx: usize,
    net: &Network,
    masks: &LayerMasks,
    fixed_dsps: u64,
    pot_units: u64,
    clock_hz: f64,
) -> (f64, f64) {
    let l = &net.layers[layer_idx];
    let g = l.gemm();
    let macs = l.macs();
    let (fp, f4, _f8) = masks.op_fractions();
    let (pot_macs, f4_macs, f8_macs) = partition_macs(macs, fp, f4);

    let fixed_array = ArrayShape::near_square(
        (fixed_dsps as f64 * FIXED4_MACS_PER_DSP) as u64,
    );
    let fixed_cycles = layer_cycles(
        g,
        f4_macs,
        fixed_dsps as f64 * FIXED4_MACS_PER_DSP,
        fixed_array,
    ) + layer_cycles(
        g,
        f8_macs,
        fixed_dsps as f64 * FIXED8_MACS_PER_DSP,
        ArrayShape::near_square(fixed_dsps),
    );
    let pot_cycles = layer_cycles(
        g,
        pot_macs,
        pot_units as f64,
        ArrayShape::near_square(pot_units),
    );
    (fixed_cycles / clock_hz, pot_cycles / clock_hz)
}

/// Simulate one configuration on one device.
pub fn simulate(
    net: &Network,
    cfg: &NetConfig,
    device: &DeviceModel,
    mode: Mode,
) -> SimReport {
    assert_eq!(net.layers.len(), cfg.masks.len(), "config/net layer mismatch");
    match mode {
        Mode::IntraLayer => simulate_intra(net, cfg, device),
        Mode::InterLayer => simulate_inter(net, cfg, device),
    }
}

fn finish(
    net: &Network,
    cfg: &NetConfig,
    device: &DeviceModel,
    mode: Mode,
    alloc: &EngineAlloc,
    per_layer: Vec<LayerTiming>,
    dsp_idle_frac: f64,
) -> SimReport {
    let latency: f64 = per_layer.iter().map(|t| t.total_s).sum();
    SimReport {
        label: cfg.label.clone(),
        device: device.name.to_string(),
        mode,
        latency_s: latency,
        throughput_gops: net.total_gops() / latency,
        lut_util: alloc.lut_util(),
        dsp_util: alloc.dsp_util(cfg.uses_fixed()),
        dsp_idle_frac,
        per_layer,
    }
}

fn layer_timing(
    i: usize,
    net: &Network,
    masks: &LayerMasks,
    device: &DeviceModel,
    fixed_s: f64,
    pot_s: f64,
) -> LayerTiming {
    let l = &net.layers[i];
    let refetch = memory::bram_weight_refetch_factor(l, masks, device.bram_bytes);
    let ddr_s = memory::ddr_seconds(l, masks, device.ddr_bytes_per_sec) * refetch;
    let buffer_s = memory::buffer_seconds(l, device.clock_hz);
    let compute = fixed_s.max(pot_s);
    let total = compute.max(ddr_s).max(buffer_s) + LAYER_OVERHEAD_S;
    let bound = if compute >= ddr_s && compute >= buffer_s {
        if fixed_s >= pot_s {
            Bound::FixedLane
        } else {
            Bound::PotLane
        }
    } else if ddr_s >= buffer_s {
        Bound::Ddr
    } else {
        Bound::Buffer
    };
    LayerTiming {
        name: l.name.clone(),
        fixed_s,
        pot_s,
        ddr_s,
        buffer_s,
        total_s: total,
        bound,
    }
}

fn simulate_intra(net: &Network, cfg: &NetConfig, device: &DeviceModel) -> SimReport {
    let alloc = EngineAlloc::ilmpq(device, cfg.uses_pot());
    let per_layer: Vec<LayerTiming> = (0..net.layers.len())
        .map(|i| {
            let (fixed_s, pot_s) = lane_times(
                i,
                net,
                &cfg.masks[i],
                alloc.fixed_dsps,
                alloc.pot_units,
                device.clock_hz,
            );
            layer_timing(i, net, &cfg.masks[i], device, fixed_s, pot_s)
        })
        .collect();
    finish(net, cfg, device, Mode::IntraLayer, &alloc, per_layer, 0.0)
}

/// Inter-layer mode: DSPs split between an 8-bit pool and a 4-bit pool;
/// the split fraction is swept and the best (lowest latency) kept — prior
/// work at its best. Idle fraction is reported against that optimum.
fn simulate_inter(net: &Network, cfg: &NetConfig, device: &DeviceModel) -> SimReport {
    let alloc = EngineAlloc::ilmpq(device, cfg.uses_pot());
    let total_dsps = alloc.fixed_dsps;
    let mut best: Option<(f64, Vec<LayerTiming>, f64)> = None;
    for split_pct in (0..=100).step_by(2) {
        let dsps8 = total_dsps * split_pct as u64 / 100;
        let dsps4 = total_dsps - dsps8;
        let mut timings = Vec::with_capacity(net.layers.len());
        let mut busy_dsp_s = 0.0;
        for i in 0..net.layers.len() {
            let masks = &cfg.masks[i];
            let (fp, f4, _f8) = masks.op_fractions();
            // 8-bit rows only run on the 8-bit pool, 4-bit rows on the
            // 4-bit pool; a pool of zero size stalls the config (inf).
            let macs = net.layers[i].macs();
            let g = net.layers[i].gemm();
            // Same exact partition as the intra-layer lanes: per-pool MACs
            // must sum to the layer total.
            let (pot_macs, f4_macs, f8_macs) = partition_macs(macs, fp, f4);
            let c8 = layer_cycles(
                g,
                f8_macs,
                dsps8 as f64 * FIXED8_MACS_PER_DSP,
                ArrayShape::near_square(dsps8),
            );
            let c4 = layer_cycles(
                g,
                f4_macs,
                dsps4 as f64 * FIXED4_MACS_PER_DSP,
                ArrayShape::near_square(dsps4 * 2),
            );
            if (f8_macs > 0 && dsps8 == 0) || (f4_macs > 0 && dsps4 == 0) {
                timings.clear();
                break;
            }
            let cp = layer_cycles(
                g,
                pot_macs,
                alloc.pot_units as f64,
                ArrayShape::near_square(alloc.pot_units),
            );
            // Pools run concurrently with each other and the PoT lane.
            let fixed_s = (c8.max(c4)) / device.clock_hz;
            let pot_s = cp / device.clock_hz;
            let t = layer_timing(i, net, masks, device, fixed_s, pot_s);
            // DSP busy time: each pool busy only for its own work.
            busy_dsp_s += (c8 / device.clock_hz) * dsps8 as f64
                + (c4 / device.clock_hz) * dsps4 as f64;
            timings.push(t);
        }
        if timings.is_empty() {
            continue;
        }
        let latency: f64 = timings.iter().map(|t| t.total_s).sum();
        let idle = 1.0 - busy_dsp_s / (latency * total_dsps as f64).max(1e-12);
        if best.as_ref().map_or(true, |(b, _, _)| latency < *b) {
            best = Some((latency, timings, idle));
        }
    }
    let (_, per_layer, idle) = best.expect("no feasible inter-layer split");
    finish(net, cfg, device, Mode::InterLayer, &alloc, per_layer, idle.clamp(0.0, 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::resnet18;

    fn z45() -> DeviceModel {
        DeviceModel::xc7z045()
    }

    fn ratio(s: &str) -> Ratio {
        Ratio::parse(s).unwrap()
    }

    #[test]
    fn synth_masks_hit_counts() {
        let m = synth_masks("l", 64, ratio("60:35:5"));
        let (p, f4, f8) = m.counts();
        assert_eq!(f8, 3); // round(64*0.05)
        assert_eq!(p, 39); // round(61 * 60/95)
        assert_eq!(f4, 64 - 3 - 39);
    }

    #[test]
    fn mac_partition_is_exact_under_adversarial_rounding() {
        // Cases where rounding all three fractions independently over- or
        // under-counts (the pre-fix behaviour could drop MACs: e.g.
        // macs=10, fractions 0.33/0.33/0.34 summed to 9).
        for &(macs, fp, f4) in &[
            (10u64, 0.33, 0.33),
            (5, 0.5, 0.5),
            (3, 1.0 / 3.0, 1.0 / 3.0),
            (1, 0.999, 0.0009),
            (7, 0.0, 0.0),
            (7, 1.0, 0.0),
            (1_000_003, 0.65, 0.30),
        ] {
            let (p, a, b) = partition_macs(macs, fp, f4);
            assert_eq!(p + a + b, macs, "macs {macs} fp {fp} f4 {f4}");
        }
        // And from real mask op_fractions over ragged row counts.
        for rows in [1usize, 3, 5, 7, 13, 64] {
            let m = synth_masks("l", rows, ratio("60:35:5"));
            let (fp, f4, _) = m.op_fractions();
            for macs in [1u64, 97, 12_345] {
                let (p, a, b) = partition_macs(macs, fp, f4);
                assert_eq!(p + a + b, macs, "rows {rows} macs {macs}");
            }
        }
    }

    #[test]
    fn ilmpq_beats_fixed8_by_paper_factor() {
        let net = resnet18();
        let fixed8 = NetConfig::from_ratio(&net, ratio("0:100:0"), true, "fixed-fl8");
        let ilmpq = NetConfig::from_ratio(&net, ratio("65:30:5"), false, "ilmpq2");
        let r_base = simulate(&net, &fixed8, &z45(), Mode::InterLayer);
        let r_ilmpq = simulate(&net, &ilmpq, &z45(), Mode::IntraLayer);
        let speedup = r_base.latency_s / r_ilmpq.latency_s;
        // Paper: 3.65x on XC7Z045. Accept the band 2.5-4.5 here; the bench
        // reports the exact number.
        assert!(speedup > 2.5 && speedup < 4.8, "speedup {speedup}");
    }

    #[test]
    fn intra_layer_beats_inter_layer_on_fl8_configs() {
        // The paper's claim: when layers are precision-uniform (8-bit
        // first/last, 4-bit middles), the inter-layer baseline's 8-bit pool
        // idles through the middle of the network; the intra-layer engine
        // never idles. With a mix in *every* layer the two modes converge —
        // the advantage is specifically about uniform layers.
        let net = resnet18();
        let cfg = NetConfig::from_ratio(&net, ratio("0:100:0"), true, "fixed-fl8");
        let intra = simulate(&net, &cfg, &z45(), Mode::IntraLayer);
        let inter = simulate(&net, &cfg, &z45(), Mode::InterLayer);
        assert!(
            intra.latency_s < inter.latency_s,
            "intra {} inter {}",
            intra.latency_s,
            inter.latency_s
        );
        assert!(inter.dsp_idle_frac > 0.05, "idle {}", inter.dsp_idle_frac);
    }

    #[test]
    fn latency_positive_and_additive() {
        let net = resnet18();
        let cfg = NetConfig::from_ratio(&net, ratio("60:35:5"), false, "ilmpq1");
        let r = simulate(&net, &cfg, &DeviceModel::xc7z020(), Mode::IntraLayer);
        assert!(r.latency_s > 0.0);
        let sum: f64 = r.per_layer.iter().map(|t| t.total_s).sum();
        assert!((sum - r.latency_s).abs() < 1e-12);
        assert_eq!(r.per_layer.len(), net.layers.len());
    }

    #[test]
    fn throughput_is_gops_over_latency() {
        let net = resnet18();
        let cfg = NetConfig::from_ratio(&net, ratio("0:100:0"), false, "f4");
        let r = simulate(&net, &cfg, &z45(), Mode::IntraLayer);
        assert!((r.throughput_gops - net.total_gops() / r.latency_s).abs() < 1e-9);
    }

    #[test]
    fn pot_only_config_lowers_dsp_util() {
        let net = resnet18();
        let pot = NetConfig::from_ratio(&net, ratio("100:0:0"), false, "pot4");
        let r = simulate(&net, &pot, &z45(), Mode::IntraLayer);
        assert!(r.dsp_util < 0.3, "dsp util {}", r.dsp_util);
        assert!(r.lut_util > 0.5, "lut util {}", r.lut_util);
    }

    #[test]
    fn bigger_device_is_faster() {
        let net = resnet18();
        let cfg = NetConfig::from_ratio(&net, ratio("60:35:5"), false, "ilmpq1");
        let small = simulate(&net, &cfg, &DeviceModel::xc7z020(), Mode::IntraLayer);
        let big = simulate(&net, &cfg, &z45(), Mode::IntraLayer);
        assert!(big.latency_s < small.latency_s);
    }
}
