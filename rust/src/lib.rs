//! # ILMPQ — Intra-Layer Multi-Precision Quantization framework for FPGA
//!
//! Full-system reproduction of Chang et al., *"ILMPQ: An Intra-Layer
//! Multi-Precision Deep Neural Network Quantization framework for FPGA"*
//! (2021), as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 1/2** (build-time Python, `python/compile/`): Pallas
//!   mixed-scheme quantization kernels + the QAT model, AOT-lowered to HLO
//!   text artifacts.
//! * **Layer 3** (this crate): the coordinator — quantization assignment,
//!   bit-packing, the Zynq FPGA performance simulator, the offline ratio
//!   search, an inference server with dynamic batching, and the Table-I
//!   experiment harness — driving inference through the unified
//!   [`backend::InferenceBackend`] API (PJRT artifacts, the native
//!   packed-code qgemm path, or the f32 reference).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

// The only `unsafe` in the crate is the PJRT FFI surface (runtime/engine,
// runtime/tensor), all of it behind `feature = "pjrt"` — every other build
// proves the absence of unsafe at compile time.
#![cfg_attr(not(feature = "pjrt"), forbid(unsafe_code))]

pub mod analysis;
pub mod artifact;
pub mod backend;
pub mod baselines;
pub mod coordinator;
pub mod experiments;
pub mod fpga;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod util;
