//! `ilmpq` — the coordinator CLI (launcher for every experiment).
//!
//! ```text
//! ilmpq table1   [--device xc7z020|xc7z045|all]     Table I hardware columns
//! ilmpq speedup                                     §III headline speedups
//! ilmpq ratio-search [--device D] [--out p.json]    offline ratio sweep (§II-B)
//! ilmpq plan derive|show|validate                   quantization-plan artifacts
//! ilmpq assign --show [--ratio R|--plan F]          Figure 1 row map
//! ilmpq accuracy [--steps N] [--config LABEL]       Table I accuracy rows (QAT)
//! ilmpq train   [--steps N] [--ratio R|--plan F]    single QAT run + loss curve
//! ilmpq serve   [--listen ADDR] [--plan F]          serving (HTTP front end or demo loop)
//! ilmpq bundle pack|verify|show                     content-addressed artifact bundles
//! ilmpq loadgen [--rate R] [--url U] [--backend B]  offered-load driver (in-process or remote)
//! ilmpq backends                                    list execution backends
//! ilmpq analyze [--json] [DIR]                      project-specific static analysis (CI gate)
//! ilmpq info                                        artifacts + manifest summary
//! ```

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use ilmpq::analysis;
use ilmpq::artifact::{ArtifactError, Bundle, Store};
use ilmpq::backend::{self, synth, InferenceBackend};
use ilmpq::baselines::table1::accuracy_configs;
use ilmpq::coordinator::{
    loadgen, pool::pack_pool, ratio_search, trainer::Trainer, Encoding, HttpConfig,
    HttpServer, ServeConfig, Server, ServerPool,
};
use ilmpq::experiments::{accuracy, figure1, ptq, table1};
use ilmpq::fpga::DeviceModel;
use ilmpq::model::resnet18;
use ilmpq::quant::{plan, QuantPlan, QuantSource};
use ilmpq::runtime::{Manifest, Runtime};
use ilmpq::util::Args;

fn main() {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().unwrap_or_else(|| "help".to_string());
    let code = match run(&cmd) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {}", render_error(&e));
            1
        }
    };
    std::process::exit(code);
}

/// Render a top-level error, appending an actionable hint when an
/// [`ArtifactError`] sits anywhere in the chain — a digest mismatch at
/// startup should tell the operator what to run next, not only what broke.
fn render_error(e: &anyhow::Error) -> String {
    let hint = e
        .chain()
        .find_map(|c| c.downcast_ref::<ArtifactError>())
        .map(|ae| match ae {
            ArtifactError::DigestMismatch { .. } => {
                "the stored bytes no longer match their address; run `ilmpq \
                 bundle verify` to list every bad blob, then re-pack with \
                 `ilmpq bundle pack`"
            }
            ArtifactError::MissingBlob { .. } => {
                "the lockfile names a blob the store does not hold; re-run \
                 `ilmpq bundle pack`, or point --store at the directory the \
                 bundle was packed into"
            }
            ArtifactError::BadDigest { .. } => {
                "digests are exactly 64 hex chars; the lockfile or --store \
                 contents may be hand-edited or truncated"
            }
            ArtifactError::Io { .. } => {
                "check permissions and free space on the store directory"
            }
        });
    match hint {
        Some(h) => format!("{e:#}\n  hint: {h}"),
        None => format!("{e:#}"),
    }
}

/// `--store DIR` → the CAS root, defaulting to [`Store::default_root`]
/// ($ILMPQ_STORE, else ~/.ilmpq/store).
fn store_dir(a: &Args) -> PathBuf {
    a.get("store").map(PathBuf::from).unwrap_or_else(Store::default_root)
}

fn devices(arg: &str) -> Vec<DeviceModel> {
    match arg {
        "all" => DeviceModel::all(),
        name => vec![DeviceModel::by_name(name)
            .unwrap_or_else(|| panic!("unknown device {name:?} (xc7z020|xc7z045|all)"))],
    }
}

/// `--fault FILE|chaos [--seed S]` → an optional [`backend::FaultyBackend`]
/// wrap. `chaos` is the built-in mixed schedule seeded with the workload
/// seed; anything else is a fault-spec JSON path.
fn wrap_fault(
    a: &Args,
    seed: u64,
    be: Arc<dyn InferenceBackend>,
) -> Result<Arc<dyn InferenceBackend>> {
    Ok(match a.get("fault") {
        None => be,
        Some("chaos") => {
            Arc::new(backend::FaultyBackend::new(be, backend::FaultSpec::chaos(seed)))
        }
        Some(path) => {
            let spec = backend::FaultSpec::load(Path::new(path))?;
            Arc::new(backend::FaultyBackend::new(be, spec))
        }
    })
}

/// The shared resilience flags (`serve` and in-process `loadgen`) →
/// [`ServeConfig`] supervision fields. All default off, preserving the
/// historic fail-the-batch behaviour.
fn apply_resilience(a: &Args, cfg: &mut ServeConfig) {
    cfg.execute_deadline = match a.u64_or("execute-deadline-ms", 0) {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    cfg.retries = a.usize_or("retries", 0);
    cfg.retry_backoff = Duration::from_millis(a.u64_or("retry-backoff-ms", 20));
    cfg.breaker_threshold = a.usize_or("breaker-threshold", 0);
    cfg.breaker_cooldown = Duration::from_millis(a.u64_or("breaker-cooldown-ms", 1000));
}

/// The help rows for those shared resilience flags.
const RESILIENCE_FLAGS: [(&str, &str); 7] = [
    ("fault", "wrap the backend in fault injection: a spec JSON path, or `chaos`"),
    ("execute-deadline-ms", "per-batch watchdog deadline (default 0 = off)"),
    ("retries", "isolated singleton retries for failed batches (default 0)"),
    ("retry-backoff-ms", "base retry backoff, doubling per attempt (default 20)"),
    ("breaker-threshold", "consecutive failures opening the breaker (default 0 = off)"),
    ("breaker-cooldown-ms", "open-breaker shed window before a probe (default 1000)"),
    ("fallback", "degraded-mode backend while the breaker is open (e.g. float)"),
];

/// `--scenario`/`--malformed`/`--poison` → the workload content knobs.
/// The chaos scenario defaults the adversarial fractions up when they are
/// not given explicitly.
fn workload_content(
    a: &Args,
) -> Result<(loadgen::Scenario, f64, f64)> {
    let scenario = loadgen::Scenario::parse(a.str_or("scenario", "steady"))?;
    let chaos = scenario == loadgen::Scenario::Chaos;
    let malformed = a.f64_or("malformed", if chaos { 0.1 } else { 0.0 });
    let poison = a.f64_or("poison", if chaos { 0.05 } else { 0.0 });
    Ok((scenario, malformed, poison))
}

/// CLI flags → [`QuantSource`] via the shared [`QuantSource::from_cli`]
/// mapping (`--plan FILE` | `--ratio NAME` | `--derive RATIO`, mutually
/// exclusive). Every arm that used to re-plumb `str_or("ratio", ...)` →
/// `default_masks.get(name)` goes through this + `QuantSource::resolve`.
fn quant_source(a: &Args, default_ratio: &str) -> Result<QuantSource> {
    QuantSource::from_cli(a.get("plan"), a.get("ratio"), a.get("derive"), default_ratio)
}

fn run(cmd: &str) -> Result<()> {
    match cmd {
        "table1" => {
            let a = Args::parse_env("ilmpq table1", 2, &[("device", "xc7z020|xc7z045|all")]);
            let net = resnet18();
            for d in devices(a.str_or("device", "all")) {
                let rows = table1::run_device(&d, &net);
                println!("{}", table1::render(&d, &rows));
                println!(
                    "speedup vs (1): {:.2}x (paper: {})\n",
                    table1::speedup(&rows),
                    if d.name == "xc7z020" { "3.01x" } else { "3.65x" }
                );
            }
            Ok(())
        }
        "speedup" => {
            for (d, rows) in table1::run_all() {
                println!(
                    "{}: ILMPQ vs 8-bit-first/last fixed baseline: {:.2}x",
                    d.name,
                    table1::speedup(&rows)
                );
            }
            Ok(())
        }
        "ratio-search" => {
            let a = Args::parse_env(
                "ilmpq ratio-search",
                2,
                &[
                    ("device", "xc7z020|xc7z045|all"),
                    ("fixed8", "Fixed-8 percentage (default 5)"),
                    ("step", "sweep step in % (default 1)"),
                    (
                        "out",
                        "write the winning assignment as a loadable plan file \
                         (needs a single --device)",
                    ),
                ],
            );
            let net = resnet18();
            let ds = devices(a.str_or("device", "all"));
            if a.get("out").is_some() && ds.len() > 1 {
                anyhow::bail!(
                    "--out writes one device's winning plan; pass --device \
                     xc7z020 or xc7z045 with it"
                );
            }
            for d in ds {
                let r = ratio_search::search(
                    &net,
                    &d,
                    a.f64_or("fixed8", 5.0),
                    a.f64_or("step", 1.0),
                    95.0 - a.f64_or("fixed8", 5.0),
                );
                println!(
                    "{}: best ratio {} -> {:.1} GOP/s, {:.1} ms (paper optimum: {})",
                    d.name,
                    r.best.ratio.label(),
                    r.best.throughput_gops,
                    r.best.latency_s * 1e3,
                    if d.name == "xc7z020" { "60:35:5" } else { "65:30:5" }
                );
                for p in r.sweep.iter().step_by(10) {
                    println!(
                        "  pot {:>4.0}%  {:>7.1} GOP/s  {:>7.1} ms",
                        p.ratio.pot4,
                        p.throughput_gops,
                        p.latency_s * 1e3
                    );
                }
                if let Some(out) = a.get("out") {
                    // The winner no longer evaporates: save it as a plan
                    // (`ilmpq plan show --plan FILE` renders it later).
                    let plan = r.winning_plan(&net);
                    plan.save(Path::new(out))?;
                    println!("wrote winning plan to {out}\n{}", plan.report());
                }
            }
            Ok(())
        }
        "plan" => plan_cmd(),
        "bundle" => bundle_cmd(),
        "assign" => {
            let a = Args::parse_env(
                "ilmpq assign",
                2,
                &[
                    ("show!", "render the row map"),
                    ("ratio", "named plan from the manifest (default ilmpq2)"),
                    ("plan", "plan file (see `ilmpq plan derive`)"),
                    ("derive", "derive fresh at this ratio (name or P:F4:F8)"),
                ],
            );
            let source = quant_source(&a, "ilmpq2")?;
            // Only the manifest is needed (no PJRT engine): assign renders
            // a plan, it doesn't execute anything.
            let manifest = Manifest::load(&Manifest::default_dir())?;
            let plan = source.resolve_required(&manifest)?;
            println!("plan {:?}: {}", plan.name, plan.provenance.describe());
            println!("{}", figure1::render(&plan.masks));
            Ok(())
        }
        "accuracy" => {
            let a = Args::parse_env(
                "ilmpq accuracy",
                2,
                &[
                    ("steps", "QAT steps per config (default 300)"),
                    ("config", "run only rows whose label contains this"),
                    ("seed", "data order seed"),
                    ("qgemm-check!", "re-evaluate trained weights via the native packed GEMM"),
                ],
            );
            let rt = Runtime::load_default()?;
            let steps = a.usize_or("steps", 300);
            let seed = a.u64_or("seed", 2021);
            let qgemm_check = a.flag("qgemm-check");
            let filter = a.get("config").map(str::to_string);
            let mut rows = Vec::new();
            for cfg in accuracy_configs() {
                if let Some(f) = &filter {
                    if !cfg.label.contains(f.as_str()) {
                        continue;
                    }
                }
                println!("[accuracy] {} ({})", cfg.label, cfg.ratio.label());
                rows.push(accuracy::run_one(&rt, &cfg, steps, seed, qgemm_check, |s| {
                    println!("{s}")
                })?);
            }
            println!("{}", accuracy::render(&rows));
            Ok(())
        }
        "ptq" => {
            let a = Args::parse_env(
                "ilmpq ptq",
                2,
                &[
                    ("steps", "reference training steps (default 800)"),
                    ("seed", "reference training seed"),
                    ("policies!", "also run the §II-C policy ablation"),
                    ("backend", "frozen-model eval backend (see `ilmpq backends`)"),
                ],
            );
            // Resolve through the registry *before* loading the runtime so
            // a typo'd --backend errors with the list of names.
            let backend_name = a.str_or("backend", "pjrt").to_string();
            backend::spec(&backend_name)?;
            let rt = Arc::new(Runtime::load_default()?);
            let steps = a.usize_or("steps", 800);
            let (float_acc, rows) = ptq::run_all_with(
                &rt,
                steps,
                a.u64_or("seed", 2021),
                &backend_name,
                |s| println!("{s}"),
            )?;
            println!("{}", ptq::render(float_acc, &rows));
            if a.flag("policies") {
                let params =
                    ptq::train_reference(&rt, steps, a.u64_or("seed", 2021), |_| {})?;
                for (label, acc) in ptq::run_policies(&rt, &params, |s| println!("{s}"))? {
                    println!("{label:<24} {acc:.2}%");
                }
            }
            Ok(())
        }
        "train" => {
            let a = Args::parse_env(
                "ilmpq train",
                2,
                &[
                    ("steps", "QAT steps (default 400)"),
                    ("ratio", "named plan from the manifest (default ilmpq2)"),
                    ("plan", "plan file (see `ilmpq plan derive`)"),
                    ("derive", "derive fresh at this ratio (name or P:F4:F8)"),
                    ("seed", "data order seed"),
                ],
            );
            let source = quant_source(&a, "ilmpq2")?;
            let rt = Runtime::load_default()?;
            let plan = source.resolve_required(&rt.manifest)?;
            println!("plan {:?}: {}", plan.name, plan.provenance.describe());
            let mut tr = Trainer::new(&rt, &plan.masks, a.u64_or("seed", 2021))?;
            tr.train(a.usize_or("steps", 400), 20, |s| {
                println!(
                    "step {:>4}  loss {:.4}  acc {:.3}  lr {:.4}",
                    s.step, s.loss, s.acc, s.lr
                );
            })?;
            let ev = tr.evaluate()?;
            println!("final: test loss {:.4}  test acc {:.2}%", ev.loss, ev.acc * 100.0);
            Ok(())
        }
        "serve" => {
            let mut flags = vec![
                ("requests", "total requests (default 512; demo loop only)"),
                ("rate", "arrival rate req/s (default 2000; demo loop only)"),
                ("ratio", "named plan from the manifest (default ilmpq2)"),
                ("plan", "serve a saved plan file (see `ilmpq plan derive`)"),
                ("derive", "derive fresh at this ratio (name or P:F4:F8)"),
                ("device", "FPGA-sim overlay device"),
                ("workers", "worker threads"),
                ("queue-depth", "admission queue bound (default 1024)"),
                ("backend", "execution backend (see `ilmpq backends`)"),
                ("no-frozen!", "serve raw weights + per-request fake-quant"),
                (
                    "listen",
                    "serve over HTTP/1.1 on this address until killed \
                     (e.g. 127.0.0.1:8080) instead of the demo loop",
                ),
                (
                    "http-workers",
                    "HTTP connection handler threads (default 16); size at or \
                     above the expected concurrent keep-alive connections",
                ),
                ("synthetic!", "force the artifact-free synthetic TinyResNet"),
                ("seed", "fixture + fault-schedule seed (default 7)"),
                (
                    "pool",
                    "serve a multi-model pool over HTTP (requires --listen): a \
                     pool-config JSON path, or `synth` for the built-in \
                     two-model synthetic pair; routes under /v1/models/{name}/* \
                     with live plan hot-swap via POST /v1/models/{name}/plan",
                ),
                (
                    "bundle",
                    "boot the pool from a lockfile (requires --listen): every \
                     manifest/params/plan byte resolves from the \
                     content-addressed store by digest, and a mismatch is a \
                     startup error, never a silent fallback (see `ilmpq \
                     bundle pack`)",
                ),
                (
                    "store",
                    "content-addressed store directory for --bundle (default \
                     $ILMPQ_STORE, else ~/.ilmpq/store)",
                ),
            ];
            flags.extend(RESILIENCE_FLAGS);
            let a = Args::parse_env("ilmpq serve", 2, &flags);
            let backend_name = a.str_or("backend", "pjrt").to_string();
            backend::spec(&backend_name)?;
            let source = quant_source(&a, "ilmpq2")?;
            let frozen = !a.flag("no-frozen");
            let seed = a.u64_or("seed", 7);
            if let Some(lock_path) = a.get("bundle") {
                // Bundle mode: the pool is exactly what the lockfile pins.
                // Every blob re-hashes on read, so a boot that reaches
                // "listening" is a proof the fleet executes the packed bytes.
                if a.get("pool").is_some() {
                    anyhow::bail!("pass --bundle LOCKFILE or --pool CFG, not both");
                }
                let addr = a.get("listen").ok_or_else(|| {
                    anyhow::anyhow!(
                        "--bundle requires --listen ADDR (bundle serving is \
                         HTTP-only)"
                    )
                })?;
                let bundle = Bundle::load(Path::new(lock_path))?;
                let store = Store::open(&store_dir(&a))?;
                let pool = ServerPool::from_bundle(&bundle, &store)?;
                println!(
                    "bundle {lock_path}: {} models verified from store {}",
                    pool.entries().len(),
                    store.root().display()
                );
                for m in &bundle.models {
                    println!(
                        "  {:<12} manifest {} params {} plan {}",
                        m.name, m.manifest, m.params, m.plan
                    );
                }
                let http_cfg = HttpConfig {
                    addr: addr.to_string(),
                    workers: a.usize_or("http-workers", 16),
                    ..Default::default()
                };
                let mut front = HttpServer::start_pool(Arc::new(pool), http_cfg)?;
                println!(
                    "listening on http://{} — GET /v1/models reports the \
                     executing digests; GET /v1/models/{{name}}/verify \
                     re-checks the store live",
                    front.local_addr()
                );
                front.wait();
                return Ok(());
            }
            if let Some(pool_arg) = a.get("pool") {
                // Pool mode: N named (manifest, plan, backend) entries behind
                // one HTTP listener, each with its own admission pipeline.
                // Entries pack lazily on first traffic; plans hot-swap live.
                let addr = a.get("listen").ok_or_else(|| {
                    anyhow::anyhow!(
                        "--pool requires --listen ADDR (pool serving is HTTP-only)"
                    )
                })?;
                let pool = if pool_arg == "synth" {
                    ServerPool::synthetic_pair(seed)?
                } else {
                    ServerPool::from_file(Path::new(pool_arg))?
                };
                println!(
                    "pool: {} models, default {:?} (entries pack lazily on \
                     first request)",
                    pool.entries().len(),
                    pool.default_name()
                );
                for e in pool.entries() {
                    println!("  {}", e.summary_line());
                }
                let http_cfg = HttpConfig {
                    addr: addr.to_string(),
                    workers: a.usize_or("http-workers", 16),
                    ..Default::default()
                };
                let mut front = HttpServer::start_pool(Arc::new(pool), http_cfg)?;
                println!(
                    "listening on http://{} — GET /v1/models, POST \
                     /v1/models/{{name}}/infer, POST /v1/models/{{name}}/plan \
                     (live hot-swap); bare /v1/* routes hit the default model",
                    front.local_addr()
                );
                front.wait();
                return Ok(());
            }
            // The manifest (batching geometry, masks, params) loads without
            // the PJRT engine — only runtime-needing backends start one, so
            // `--backend qgemm` serves on `--no-default-features` builds.
            // Falls back to the synthetic TinyResNet fixture when no
            // artifacts exist, so a toolchain-only machine can still stand
            // up the whole serving stack.
            let (manifest, be, active_plan) = loadgen::fixture_or_artifacts(
                &backend_name,
                &source,
                frozen,
                None,
                seed,
                a.flag("synthetic"),
                "serve",
            )?;
            // Fault injection wraps *after* construction so `--fault` works
            // uniformly over every backend and plan source.
            let be = wrap_fault(&a, seed, be)?;
            // The degraded-mode fallback serves the same manifest/plan on a
            // different execution path (e.g. --backend qgemm --fallback
            // float); built through the same recipe so its geometry always
            // matches. Never fault-wrapped — it is the healthy path.
            let fallback = match a.get("fallback") {
                None => None,
                Some(fb_name) => {
                    backend::spec(fb_name)?;
                    let (_m, fb, _plan) = loadgen::fixture_or_artifacts(
                        fb_name,
                        &source,
                        frozen,
                        None,
                        seed,
                        a.flag("synthetic"),
                        "serve-fallback",
                    )?;
                    println!("fallback backend: {}", fb.name());
                    Some(fb)
                }
            };
            let mut cfg = ServeConfig {
                workers: a.usize_or("workers", 2),
                queue_depth: a.usize_or("queue-depth", 1024),
                plan: active_plan,
                device: a.str_or("device", "xc7z045").to_string(),
                frozen,
                ..Default::default()
            };
            apply_resilience(&a, &mut cfg);
            println!("backend: {}", be.name());
            let server = Server::start_with_fallback(&manifest, be, fallback, cfg)?;
            if let Some(p) = &server.plan {
                println!("plan {:?}: {}", p.name, p.provenance.describe());
            }
            println!("serving: sim FPGA {}", server.sim.row());
            if let Some(addr) = a.get("listen") {
                // Network mode: put the HTTP front door on the pipeline and
                // block until the process is killed.
                // Each handler owns one keep-alive connection at a time, so
                // the pool must cover the expected concurrent connections
                // (loadgen --conns defaults to 8; threads are cheap parked).
                let http_cfg = HttpConfig {
                    addr: addr.to_string(),
                    workers: a.usize_or("http-workers", 16),
                    ..Default::default()
                };
                let mut front = HttpServer::start(server, &manifest, http_cfg)?;
                println!(
                    "listening on http://{} — POST /v1/infer (application/json \
                     or application/x-raw-f32), GET /v1/healthz, GET /v1/metrics",
                    front.local_addr()
                );
                front.wait();
                return Ok(());
            }
            // The demo drive loop is the shared open-loop driver: same
            // pacing, reply classification, and report as `ilmpq loadgen`.
            let spec = loadgen::LoadSpec {
                requests: a.usize_or("requests", 512),
                rate: a.f64_or("rate", 2000.0),
                seed,
                ..Default::default()
            };
            let (report, metrics) = loadgen::run(server, &manifest, &spec);
            println!("{}\n{}", report.render(), metrics.report());
            Ok(())
        }
        "loadgen" => {
            let mut flags = vec![
                ("requests", "total requests (default 512)"),
                ("rate", "offered load req/s (default 2000; 0 = unpaced)"),
                ("workers", "worker threads (default 2)"),
                ("queue-depth", "admission queue bound (default 1024)"),
                ("max-wait-ms", "batcher deadline (default 5)"),
                ("backend", "execution backend (default qgemm; see `ilmpq backends`)"),
                ("ratio", "named plan from the manifest (default ilmpq2)"),
                ("plan", "drive a saved plan file (see `ilmpq plan derive`)"),
                ("derive", "derive fresh at this ratio (name or P:F4:F8)"),
                ("device", "FPGA-sim overlay device (default xc7z045)"),
                ("threads", "backend CPU threads (0 or absent: all cores)"),
                ("seed", "workload seed (default 42)"),
                ("malformed", "fraction of malformed-length requests (default 0)"),
                (
                    "scenario",
                    "workload shape: steady | burst (square-wave overload) | \
                     chaos (valid/malformed/poison blend; defaults \
                     --malformed 0.1 --poison 0.05) | multi (fan across a \
                     pool front end's models; requires --url)",
                ),
                (
                    "models",
                    "multi scenario: explicit name:weight,... traffic mix \
                     (default: discover the pool and skew 80/20 toward its \
                     default model)",
                ),
                (
                    "poison",
                    "fraction of requests carrying the poison sentinel a \
                     --fault backend fails on (default 0)",
                ),
                ("synthetic!", "force the artifact-free synthetic TinyResNet"),
                ("out", "also write the report as JSON to this path"),
                (
                    "url",
                    "drive a remote `ilmpq serve --listen` at this base URL \
                     (e.g. http://127.0.0.1:8080) over real sockets; the \
                     server-side options (backend/workers/...) are ignored",
                ),
                ("conns", "client connections for --url (default 8)"),
                (
                    "encoding",
                    "wire encoding for --url: json (an {\"image\": [...]} \
                     object, the default) or raw (the image as little-endian \
                     f32 bytes, Content-Type application/x-raw-f32)",
                ),
            ];
            flags.extend(RESILIENCE_FLAGS);
            let a = Args::parse_env("ilmpq loadgen", 2, &flags);
            let (scenario, malformed_frac, poison_frac) = workload_content(&a)?;
            let encoding = Encoding::parse(a.str_or("encoding", "json"))?;
            if scenario == loadgen::Scenario::Multi && a.get("url").is_none() {
                anyhow::bail!(
                    "--scenario multi drives a pool front end's per-model \
                     routes; pass --url http://host:port (see `ilmpq serve \
                     --pool`)"
                );
            }
            if let Some(url) = a.get("url") {
                // Remote mode: the same open-loop workload over HTTP,
                // statuses folded into the same outcome classes.
                let spec = loadgen::LoadSpec {
                    requests: a.usize_or("requests", 512),
                    rate: a.f64_or("rate", 2000.0),
                    malformed_frac,
                    poison_frac,
                    scenario,
                    seed: a.u64_or("seed", 42),
                    model_weights: match a.get("models") {
                        Some(s) => loadgen::parse_model_weights(s)?,
                        None => Vec::new(),
                    },
                    encoding,
                };
                let (report, server_metrics) =
                    loadgen::run_remote(url, &spec, a.usize_or("conns", 8))?;
                println!("target: {url}");
                println!("{}", report.render());
                if server_metrics != ilmpq::util::Json::Null {
                    println!(
                        "server /v1/metrics: {}",
                        server_metrics.to_string_compact()
                    );
                }
                if let Some(path) = a.get("out") {
                    std::fs::write(path, report.to_json().to_string_compact())?;
                    println!("wrote {path}");
                }
                return Ok(());
            }
            let backend_name = a.str_or("backend", "qgemm").to_string();
            backend::spec(&backend_name)?;
            let source = quant_source(&a, "ilmpq2")?;
            let seed = a.u64_or("seed", 42);
            let threads = match a.usize_or("threads", 0) {
                0 => None, // all cores — the documented default
                t => Some(t),
            };
            // Real artifacts when present, else the synthetic fixture — so
            // the pipeline runs end-to-end on a toolchain-only machine.
            let (manifest, be, active_plan) = loadgen::fixture_or_artifacts(
                &backend_name,
                &source,
                true,
                threads,
                seed,
                a.flag("synthetic"),
                "loadgen",
            )?;
            let be = wrap_fault(&a, seed, be)?;
            let fallback = match a.get("fallback") {
                None => None,
                Some(fb_name) => {
                    backend::spec(fb_name)?;
                    let (_m, fb, _plan) = loadgen::fixture_or_artifacts(
                        fb_name,
                        &source,
                        true,
                        threads,
                        seed,
                        a.flag("synthetic"),
                        "loadgen-fallback",
                    )?;
                    Some(fb)
                }
            };
            let mut cfg = ServeConfig {
                workers: a.usize_or("workers", 2),
                max_wait: Duration::from_millis(a.u64_or("max-wait-ms", 5)),
                queue_depth: a.usize_or("queue-depth", 1024),
                plan: active_plan,
                device: a.str_or("device", "xc7z045").to_string(),
                ..Default::default()
            };
            apply_resilience(&a, &mut cfg);
            let spec = loadgen::LoadSpec {
                requests: a.usize_or("requests", 512),
                rate: a.f64_or("rate", 2000.0),
                malformed_frac,
                poison_frac,
                scenario,
                seed,
                model_weights: Vec::new(),
                // In-process runs have no wire; the field is inert here.
                encoding,
            };
            println!("backend: {} (model {})", be.name(), manifest.model_name);
            let server = Server::start_with_fallback(&manifest, be, fallback, cfg)?;
            println!("sim-FPGA: {}", server.sim.row());
            let (report, metrics) = loadgen::run(server, &manifest, &spec);
            println!("{}\n{}", report.render(), metrics.report());
            if let Some(path) = a.get("out") {
                std::fs::write(path, report.to_json().to_string_compact())?;
                println!("wrote {path}");
            }
            Ok(())
        }
        "analyze" => {
            if std::env::args().skip(2).any(|t| t == "--help" || t == "-h") {
                println!("{ANALYZE_HELP}");
                return Ok(());
            }
            let a = Args::parse_env(
                "ilmpq analyze",
                2,
                &[("json!", "emit the machine-readable report (CI gate)")],
            );
            // Default to the crate's own source, resolved relative to the
            // working directory (`src` when run from rust/, `rust/src` from
            // the repo root).
            let dir = a
                .positional()
                .first()
                .map(String::as_str)
                .map(Path::new)
                .map(Path::to_path_buf)
                .unwrap_or_else(|| {
                    let local = Path::new("src");
                    if local.is_dir() { local.to_path_buf() } else { "rust/src".into() }
                });
            let project = analysis::Project::load(&dir)?;
            let findings = analysis::analyze(&project);
            if a.flag("json") {
                println!(
                    "{}",
                    analysis::report_json(&project, &findings).to_string_compact()
                );
            } else {
                print!("{}", analysis::render_text(&project, &findings));
            }
            if !findings.is_empty() {
                std::process::exit(1);
            }
            Ok(())
        }
        "backends" => {
            println!("registered execution backends (--backend NAME):");
            for s in backend::registry() {
                println!(
                    "  {:<8} {:<14} {}",
                    s.name,
                    if s.available { "[available]" } else { "[compiled out]" },
                    s.description
                );
            }
            println!(
                "\nany of them wraps as faulty:<name> (seeded fault injection; \
                 configure with --fault SPEC.json|chaos)"
            );
            Ok(())
        }
        "info" => {
            let rt = Runtime::load_default()?;
            let m = &rt.manifest;
            println!(
                "model {} ({}x{}x{}, {} classes), {} params, {} quantized layers",
                m.model_name,
                m.height,
                m.width,
                m.channels,
                m.classes,
                m.params.len(),
                m.quantized_layers.len()
            );
            println!("platform: {}", rt.engine.platform());
            for (name, a) in &m.artifacts {
                println!(
                    "  artifact {:<12} {} inputs, {} outputs ({})",
                    name,
                    a.inputs.len(),
                    a.outputs.len(),
                    a.file.file_name().unwrap().to_string_lossy()
                );
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n{HELP}");
            std::process::exit(2);
        }
    }
}

/// `ilmpq plan <derive|show|validate>` — the quantization-plan toolbox.
fn plan_cmd() -> Result<()> {
    let sub = std::env::args().nth(2).unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "derive" => {
            let a = Args::parse_env(
                "ilmpq plan derive",
                3,
                &[
                    (
                        "ratio",
                        "Table-I ratio name (e.g. ilmpq2) or P:F4:F8 split \
                         (default 65:30:5)",
                    ),
                    ("name", "plan name (default derived from the ratio)"),
                    (
                        "synthetic!",
                        "derive on the artifact-free synthetic TinyResNet fixture",
                    ),
                    (
                        "seed",
                        "synthetic fixture seed (default 7, matching `serve --synthetic`)",
                    ),
                    ("out", "output path (default plan.json)"),
                ],
            );
            let ratio = plan::parse_ratio_arg(a.str_or("ratio", "65:30:5"))?;
            let out = a.str_or("out", "plan.json").to_string();
            // One default spelling on both paths (`derived_plan_name`), so
            // `plan derive` and `serve --derive` artifacts carry the same
            // name however they were produced.
            let default_name = plan::derived_plan_name(ratio);
            let name = a.str_or("name", &default_name).to_string();
            let p = if a.flag("synthetic") {
                let seed = a.u64_or("seed", 7);
                let (_m, _params, p) = loadgen::synth_plan(&name, ratio, seed);
                p
            } else {
                let m = Manifest::load(&Manifest::default_dir())?;
                let params = m.load_init_params()?;
                plan::derive_from_manifest(&m, &params, ratio, &name)?
            };
            p.save(Path::new(&out))?;
            println!("wrote {out}");
            print!("{}", p.report());
            Ok(())
        }
        "show" => {
            let a = Args::parse_env(
                "ilmpq plan show",
                3,
                &[
                    ("plan", "plan file to render"),
                    ("ratio", "named plan from the manifest"),
                    ("figure!", "also render the full Figure-1 row map"),
                ],
            );
            let p = match (a.get("plan"), a.get("ratio")) {
                (Some(path), None) => QuantPlan::load(Path::new(path))?,
                (None, Some(name)) => {
                    Manifest::load(&Manifest::default_dir())?.plan(name)?
                }
                (None, None) => {
                    let m = Manifest::load(&Manifest::default_dir())?;
                    println!(
                        "named plans in the manifest: {}\n(`--ratio NAME` renders \
                         one; `--plan FILE` renders a saved plan)",
                        m.plan_names().join(", ")
                    );
                    return Ok(());
                }
                (Some(_), Some(_)) => {
                    anyhow::bail!("pass --plan FILE or --ratio NAME, not both")
                }
            };
            print!("{}", p.report());
            if a.flag("figure") {
                println!("{}", figure1::render(&p.masks));
            }
            Ok(())
        }
        "validate" => {
            let a = Args::parse_env(
                "ilmpq plan validate",
                3,
                &[
                    ("plan", "plan file to validate (required)"),
                    (
                        "synthetic!",
                        "validate against the synthetic TinyResNet fixture instead \
                         of the artifacts manifest",
                    ),
                ],
            );
            let path = a
                .get("plan")
                .ok_or_else(|| anyhow::anyhow!("--plan FILE is required"))?;
            let p = QuantPlan::load(Path::new(path))?;
            let m = if a.flag("synthetic") {
                synth::serving_manifest()
            } else {
                Manifest::load(&Manifest::default_dir())?
            };
            p.validate(&m)?;
            println!(
                "{path}: valid for model {} ({} quantized layers)",
                m.model_name,
                m.quantized_layers.len()
            );
            print!("{}", p.report());
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{PLAN_HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown plan subcommand {other:?}\n{PLAN_HELP}");
            std::process::exit(2);
        }
    }
}

/// `ilmpq bundle <pack|verify|show>` — the content-addressed artifact
/// toolbox (see [`ilmpq::artifact`]).
fn bundle_cmd() -> Result<()> {
    let sub = std::env::args().nth(2).unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "pack" => {
            let a = Args::parse_env(
                "ilmpq bundle pack",
                3,
                &[
                    ("synthetic!", "pack the built-in two-model synthetic pair"),
                    ("pool", "pack the models of a pool-config JSON path"),
                    (
                        "seed",
                        "synthetic fixture seed (default 7, matching `serve \
                         --synthetic`)",
                    ),
                    (
                        "store",
                        "content-addressed store directory (default \
                         $ILMPQ_STORE, else ~/.ilmpq/store)",
                    ),
                    ("out", "lockfile path (default ilmpq.lock.json)"),
                ],
            );
            let pool = match (a.flag("synthetic"), a.get("pool")) {
                (true, Some(_)) => {
                    anyhow::bail!("pass --synthetic or --pool CFG.json, not both")
                }
                (true, None) => ServerPool::synthetic_pair(a.u64_or("seed", 7))?,
                (false, Some(path)) => ServerPool::from_file(Path::new(path))?,
                (false, None) => anyhow::bail!(
                    "pass --synthetic (the built-in pair) or --pool CFG.json \
                     (which models to pack)"
                ),
            };
            let store = Store::open(&store_dir(&a))?;
            let bundle = pack_pool(&pool, &store)?;
            let out = a.str_or("out", "ilmpq.lock.json").to_string();
            bundle.save(Path::new(&out))?;
            println!(
                "packed {} models into {out} (store {})",
                bundle.models.len(),
                store.root().display()
            );
            for m in &bundle.models {
                println!(
                    "  {:<12} manifest {} params {} plan {}",
                    m.name, m.manifest, m.params, m.plan
                );
            }
            Ok(())
        }
        "verify" => {
            let a = Args::parse_env(
                "ilmpq bundle verify",
                3,
                &[
                    ("bundle", "lockfile path (default ilmpq.lock.json)"),
                    (
                        "store",
                        "content-addressed store directory (default \
                         $ILMPQ_STORE, else ~/.ilmpq/store)",
                    ),
                ],
            );
            let lock = a.str_or("bundle", "ilmpq.lock.json").to_string();
            let bundle = Bundle::load(Path::new(&lock))?;
            let store = Store::open(&store_dir(&a))?;
            let mut blobs = 0usize;
            for m in &bundle.models {
                for (what, d) in
                    [("manifest", &m.manifest), ("params", &m.params), ("plan", &m.plan)]
                {
                    store.verify(d, &format!("{}/{what}", m.name))?;
                    println!("ok {}/{what} {d}", m.name);
                    blobs += 1;
                }
            }
            println!(
                "{lock}: {} models, {blobs} blobs re-hashed clean against {}",
                bundle.models.len(),
                store.root().display()
            );
            Ok(())
        }
        "show" => {
            let a = Args::parse_env(
                "ilmpq bundle show",
                3,
                &[("bundle", "lockfile path (default ilmpq.lock.json)")],
            );
            let lock = a.str_or("bundle", "ilmpq.lock.json").to_string();
            let bundle = Bundle::load(Path::new(&lock))?;
            println!(
                "{lock}: bundle v{}, default model {:?}",
                bundle.version, bundle.default
            );
            for m in &bundle.models {
                println!(
                    "  {} (backend {}, geometry {}, model {})",
                    m.name, m.backend, m.geometry, m.model
                );
                println!("    manifest {}", m.manifest);
                println!("    params   {}", m.params);
                println!("    plan     {}", m.plan);
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{BUNDLE_HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown bundle subcommand {other:?}\n{BUNDLE_HELP}");
            std::process::exit(2);
        }
    }
}

const BUNDLE_HELP: &str = "\
ilmpq bundle — content-addressed artifact bundles (checksummed serving units)

subcommands:
  pack      hash a pool's manifest/params/plan blobs into the store
            (--synthetic for the built-in pair, --pool CFG.json for a
            config) and write the lockfile naming their digests
            (--out, default ilmpq.lock.json)
  verify    re-hash every blob the lockfile names against the store; a
            flipped byte anywhere fails loudly with the expected and
            actual digests
  show      render a lockfile: version, default model, per-model digests
the store lives at --store DIR ($ILMPQ_STORE, else ~/.ilmpq/store); blobs
are addressed by their SHA-256 and re-hashed on every read, so a torn or
tampered write is never served. `ilmpq serve --bundle ilmpq.lock.json
--listen ADDR` boots the pool from the store by digest — a mismatch is a
startup error, never a silent fallback — and GET /v1/models reports the
digests actually executing.
run `ilmpq bundle <sub> --help` for options.";

const ANALYZE_HELP: &str = "\
ilmpq analyze [--json] [DIR] — project-specific static analysis (the CI gate)

Lexes the crate's own source (no syn, no rustc) and enforces the serving
stack's documented invariants:

  P0  an `// analyze:allow(reason)` pragma must carry a non-empty reason
  R1  no unwrap()/expect()/panic! in serving-path non-test code
      (coordinator/, backend/, quant/plan.rs)
  R2  no `let _ =` on a send/reply call in server.rs/pool.rs/http.rs
      (answer-exactly-once)
  R3  every ServeError variant is mapped in http.rs and loadgen.rs
  R4  every Metrics counter is emitted by both report() and to_json()
  R5  no lock guard held across a blocking call in server.rs/pool.rs
  R6  every wire Encoding variant is handled in http.rs and loadgen.rs
  R7  every ArtifactError variant is mapped in main.rs (CLI error
      rendering) and http.rs (HTTP status mapping)

DIR defaults to the crate source (src, or rust/src from the repo root).
Findings print as `path:line [rule] message` and exit nonzero; --json emits
the machine report. A justified false positive is suppressed by starting a
comment on the flagged line (or the line above) with
`// analyze:allow(reason)` — the reason is mandatory and P0-checked.
The runtime twin is Metrics::audit(), which checks the ledger invariants
(outcome classes sum to admissions, slots drain to zero, breaker
transitions balance) at every drained server stop.";

const PLAN_HELP: &str = "\
ilmpq plan — quantization-plan artifacts (serializable precision assignments)

subcommands:
  derive    compute a plan (§II-C policy: Hessian rescue rows + variance-
            sorted PoT) from the artifacts manifest, or artifact-free with
            --synthetic; writes JSON (--out, default plan.json)
  show      render a plan file (--plan FILE) or a named manifest plan
            (--ratio NAME); bare `show` lists the named plans
  validate  check a plan file against the manifest (--synthetic for the
            fixture): layer names, row counts, 0/1 masks, scheme exclusivity
a saved plan is served with `ilmpq serve --plan FILE` and inspected live at
GET /v1/plan; `ratio-search --out` saves its winner in the same format.
run `ilmpq plan <sub> --help` for options.";

const HELP: &str = "\
ilmpq — Intra-Layer Multi-Precision Quantization framework (paper reproduction)

commands:
  table1        Table I hardware columns (FPGA sim, both devices)
  speedup       headline speedups vs the 8-bit fixed baseline
  ratio-search  offline PoT:Fixed4:Fixed8 sweep (paper §II-B); `--out
                p.json` saves the winner as a loadable quantization plan
  plan          quantization-plan artifacts: derive | show | validate
                (named, versioned, serializable precision assignments;
                `plan derive --synthetic` works artifact-free)
  assign        Figure 1: per-row scheme/precision map (--ratio NAME or
                --plan FILE)
  accuracy      Table I accuracy rows via QAT on the AOT model
  ptq           deterministic PTQ probe (train once, quantize each config)
  train         one QAT run with the loss curve (--ratio NAME | --plan FILE)
  serve         inference serving: `--listen ADDR` puts the HTTP/1.1 front
                end on the admission pipeline (POST /v1/infer — JSON or raw
                little-endian f32 bodies by Content-Type — GET /v1/healthz,
                GET /v1/metrics, GET /v1/plan); without it,
                the in-process demo loop runs (dynamic batching, --backend
                NAME); `--plan p.json` serves a saved quantization plan;
                `--pool cfg.json|synth` serves a multi-model pool (GET
                /v1/models, per-model /v1/models/{name}/* routes, live
                plan hot-swap via POST /v1/models/{name}/plan);
                self-healing execution via --execute-deadline-ms,
                --retries, --breaker-threshold, --fallback NAME, and
                --fault SPEC.json|chaos for fault injection;
                `--bundle ilmpq.lock.json` boots the pool from the
                content-addressed store by digest (verified startup)
  bundle        content-addressed artifact bundles: pack | verify | show
                (checksummed weights/plans in a SHA-256 store plus the
                ilmpq.lock.json lockfile `serve --bundle` boots from)
  loadgen       open-loop offered-load driver for the admission pipeline
                (--rate, --queue-depth, --malformed, --poison,
                --scenario steady|burst|chaos|multi; runs artifact-free);
                `--url http://host:port` drives a remote `serve --listen`
                over real sockets with the same outcome classes, in either
                wire encoding (--encoding json|raw); multi fans across a
                pool's models (--models name:weight,...)
  backends      list the registered execution backends
  analyze       project-specific static analysis over the crate's own source
                (serving-path panic freedom, answer-exactly-once reply
                handling, error-mapping and metrics-counter exhaustiveness,
                lock-scope hygiene); nonzero exit on findings — the CI gate
                (--json for the machine report, DIR to point elsewhere)
  info          manifest / artifacts summary
run `ilmpq <cmd> --help` for options.";
