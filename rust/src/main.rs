//! `ilmpq` — the coordinator CLI (launcher for every experiment).
//!
//! ```text
//! ilmpq table1   [--device xc7z020|xc7z045|all]     Table I hardware columns
//! ilmpq speedup                                     §III headline speedups
//! ilmpq ratio-search [--device D] [--fixed8 5]      offline ratio sweep (§II-B)
//! ilmpq assign --show [--ratio ilmpq2]              Figure 1 row map
//! ilmpq accuracy [--steps N] [--config LABEL]       Table I accuracy rows (QAT)
//! ilmpq train   [--steps N] [--ratio ilmpq2]        single QAT run + loss curve
//! ilmpq serve   [--listen ADDR] [--backend B]       serving (HTTP front end or demo loop)
//! ilmpq loadgen [--rate R] [--url U] [--backend B]  offered-load driver (in-process or remote)
//! ilmpq backends                                    list execution backends
//! ilmpq info                                        artifacts + manifest summary
//! ```

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use ilmpq::backend::{self, InferenceBackend};
use ilmpq::baselines::table1::accuracy_configs;
use ilmpq::coordinator::{
    loadgen, ratio_search, trainer::Trainer, HttpConfig, HttpServer, ServeConfig, Server,
};
use ilmpq::experiments::{accuracy, figure1, ptq, table1};
use ilmpq::fpga::DeviceModel;
use ilmpq::model::resnet18;
use ilmpq::runtime::Runtime;
use ilmpq::util::Args;

fn main() {
    let mut argv = std::env::args().skip(1);
    let cmd = argv.next().unwrap_or_else(|| "help".to_string());
    let code = match run(&cmd) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn devices(arg: &str) -> Vec<DeviceModel> {
    match arg {
        "all" => DeviceModel::all(),
        name => vec![DeviceModel::by_name(name)
            .unwrap_or_else(|| panic!("unknown device {name:?} (xc7z020|xc7z045|all)"))],
    }
}

fn run(cmd: &str) -> Result<()> {
    match cmd {
        "table1" => {
            let a = Args::parse_env("ilmpq table1", 2, &[("device", "xc7z020|xc7z045|all")]);
            let net = resnet18();
            for d in devices(a.str_or("device", "all")) {
                let rows = table1::run_device(&d, &net);
                println!("{}", table1::render(&d, &rows));
                println!(
                    "speedup vs (1): {:.2}x (paper: {})\n",
                    table1::speedup(&rows),
                    if d.name == "xc7z020" { "3.01x" } else { "3.65x" }
                );
            }
            Ok(())
        }
        "speedup" => {
            for (d, rows) in table1::run_all() {
                println!(
                    "{}: ILMPQ vs 8-bit-first/last fixed baseline: {:.2}x",
                    d.name,
                    table1::speedup(&rows)
                );
            }
            Ok(())
        }
        "ratio-search" => {
            let a = Args::parse_env(
                "ilmpq ratio-search",
                2,
                &[
                    ("device", "xc7z020|xc7z045|all"),
                    ("fixed8", "Fixed-8 percentage (default 5)"),
                    ("step", "sweep step in % (default 1)"),
                ],
            );
            let net = resnet18();
            for d in devices(a.str_or("device", "all")) {
                let r = ratio_search::search(
                    &net,
                    &d,
                    a.f64_or("fixed8", 5.0),
                    a.f64_or("step", 1.0),
                    95.0 - a.f64_or("fixed8", 5.0),
                );
                println!(
                    "{}: best ratio {} -> {:.1} GOP/s, {:.1} ms (paper optimum: {})",
                    d.name,
                    r.best.ratio.label(),
                    r.best.throughput_gops,
                    r.best.latency_s * 1e3,
                    if d.name == "xc7z020" { "60:35:5" } else { "65:30:5" }
                );
                for p in r.sweep.iter().step_by(10) {
                    println!(
                        "  pot {:>4.0}%  {:>7.1} GOP/s  {:>7.1} ms",
                        p.ratio.pot4,
                        p.throughput_gops,
                        p.latency_s * 1e3
                    );
                }
            }
            Ok(())
        }
        "assign" => {
            let a = Args::parse_env(
                "ilmpq assign",
                2,
                &[("show!", "render the row map"), ("ratio", "manifest ratio name")],
            );
            let rt = Runtime::load_default()?;
            let name = a.str_or("ratio", "ilmpq2");
            let masks = rt
                .manifest
                .default_masks
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("unknown ratio {name}"))?;
            println!("{}", figure1::render(masks));
            Ok(())
        }
        "accuracy" => {
            let a = Args::parse_env(
                "ilmpq accuracy",
                2,
                &[
                    ("steps", "QAT steps per config (default 300)"),
                    ("config", "run only rows whose label contains this"),
                    ("seed", "data order seed"),
                    ("qgemm-check!", "re-evaluate trained weights via the native packed GEMM"),
                ],
            );
            let rt = Runtime::load_default()?;
            let steps = a.usize_or("steps", 300);
            let seed = a.u64_or("seed", 2021);
            let qgemm_check = a.flag("qgemm-check");
            let filter = a.get("config").map(str::to_string);
            let mut rows = Vec::new();
            for cfg in accuracy_configs() {
                if let Some(f) = &filter {
                    if !cfg.label.contains(f.as_str()) {
                        continue;
                    }
                }
                println!("[accuracy] {} ({})", cfg.label, cfg.ratio.label());
                rows.push(accuracy::run_one(&rt, &cfg, steps, seed, qgemm_check, |s| {
                    println!("{s}")
                })?);
            }
            println!("{}", accuracy::render(&rows));
            Ok(())
        }
        "ptq" => {
            let a = Args::parse_env(
                "ilmpq ptq",
                2,
                &[
                    ("steps", "reference training steps (default 800)"),
                    ("seed", "reference training seed"),
                    ("policies!", "also run the §II-C policy ablation"),
                    ("backend", "frozen-model eval backend (see `ilmpq backends`)"),
                ],
            );
            // Resolve through the registry *before* loading the runtime so
            // a typo'd --backend errors with the list of names.
            let backend_name = a.str_or("backend", "pjrt").to_string();
            backend::spec(&backend_name)?;
            let rt = Arc::new(Runtime::load_default()?);
            let steps = a.usize_or("steps", 800);
            let (float_acc, rows) = ptq::run_all_with(
                &rt,
                steps,
                a.u64_or("seed", 2021),
                &backend_name,
                |s| println!("{s}"),
            )?;
            println!("{}", ptq::render(float_acc, &rows));
            if a.flag("policies") {
                let params =
                    ptq::train_reference(&rt, steps, a.u64_or("seed", 2021), |_| {})?;
                for (label, acc) in ptq::run_policies(&rt, &params, |s| println!("{s}"))? {
                    println!("{label:<24} {acc:.2}%");
                }
            }
            Ok(())
        }
        "train" => {
            let a = Args::parse_env(
                "ilmpq train",
                2,
                &[
                    ("steps", "QAT steps (default 400)"),
                    ("ratio", "manifest ratio name (default ilmpq2)"),
                    ("seed", "data order seed"),
                ],
            );
            let rt = Runtime::load_default()?;
            let name = a.str_or("ratio", "ilmpq2");
            let masks = rt
                .manifest
                .default_masks
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("unknown ratio {name}"))?
                .clone();
            let mut tr = Trainer::new(&rt, &masks, a.u64_or("seed", 2021))?;
            tr.train(a.usize_or("steps", 400), 20, |s| {
                println!(
                    "step {:>4}  loss {:.4}  acc {:.3}  lr {:.4}",
                    s.step, s.loss, s.acc, s.lr
                );
            })?;
            let ev = tr.evaluate()?;
            println!("final: test loss {:.4}  test acc {:.2}%", ev.loss, ev.acc * 100.0);
            Ok(())
        }
        "serve" => {
            let a = Args::parse_env(
                "ilmpq serve",
                2,
                &[
                    ("requests", "total requests (default 512; demo loop only)"),
                    ("rate", "arrival rate req/s (default 2000; demo loop only)"),
                    ("ratio", "manifest ratio name"),
                    ("device", "FPGA-sim overlay device"),
                    ("workers", "worker threads"),
                    ("queue-depth", "admission queue bound (default 1024)"),
                    ("backend", "execution backend (see `ilmpq backends`)"),
                    ("no-frozen!", "serve raw weights + per-request fake-quant"),
                    (
                        "listen",
                        "serve over HTTP/1.1 on this address until killed \
                         (e.g. 127.0.0.1:8080) instead of the demo loop",
                    ),
                    (
                        "http-workers",
                        "HTTP connection handler threads (default 16); size at or \
                         above the expected concurrent keep-alive connections",
                    ),
                    ("synthetic!", "force the artifact-free synthetic TinyResNet"),
                ],
            );
            let backend_name = a.str_or("backend", "pjrt").to_string();
            backend::spec(&backend_name)?;
            let name = a.str_or("ratio", "ilmpq2").to_string();
            let frozen = !a.flag("no-frozen");
            // The manifest (batching geometry, masks, params) loads without
            // the PJRT engine — only runtime-needing backends start one, so
            // `--backend qgemm` serves on `--no-default-features` builds.
            // Falls back to the synthetic TinyResNet fixture when no
            // artifacts exist, so a toolchain-only machine can still stand
            // up the whole serving stack.
            let (manifest, be) = loadgen::fixture_or_artifacts(
                &backend_name,
                &name,
                frozen,
                None,
                7,
                a.flag("synthetic"),
                "serve",
            )?;
            let cfg = ServeConfig {
                workers: a.usize_or("workers", 2),
                queue_depth: a.usize_or("queue-depth", 1024),
                ratio_name: name,
                device: a.str_or("device", "xc7z045").to_string(),
                frozen,
                ..Default::default()
            };
            println!("backend: {}", be.name());
            let server = Server::start(&manifest, be, cfg)?;
            println!("serving: sim FPGA {}", server.sim.row());
            if let Some(addr) = a.get("listen") {
                // Network mode: put the HTTP front door on the pipeline and
                // block until the process is killed.
                // Each handler owns one keep-alive connection at a time, so
                // the pool must cover the expected concurrent connections
                // (loadgen --conns defaults to 8; threads are cheap parked).
                let http_cfg = HttpConfig {
                    addr: addr.to_string(),
                    workers: a.usize_or("http-workers", 16),
                    ..Default::default()
                };
                let mut front = HttpServer::start(server, &manifest, http_cfg)?;
                println!(
                    "listening on http://{} — POST /v1/infer, GET /v1/healthz, \
                     GET /v1/metrics",
                    front.local_addr()
                );
                front.wait();
                return Ok(());
            }
            // The demo drive loop is the shared open-loop driver: same
            // pacing, reply classification, and report as `ilmpq loadgen`.
            let spec = loadgen::LoadSpec {
                requests: a.usize_or("requests", 512),
                rate: a.f64_or("rate", 2000.0),
                malformed_frac: 0.0,
                seed: 7,
            };
            let (report, metrics) = loadgen::run(server, &manifest, &spec);
            println!("{}\n{}", report.render(), metrics.report());
            Ok(())
        }
        "loadgen" => {
            let a = Args::parse_env(
                "ilmpq loadgen",
                2,
                &[
                    ("requests", "total requests (default 512)"),
                    ("rate", "offered load req/s (default 2000; 0 = unpaced)"),
                    ("workers", "worker threads (default 2)"),
                    ("queue-depth", "admission queue bound (default 1024)"),
                    ("max-wait-ms", "batcher deadline (default 5)"),
                    ("backend", "execution backend (default qgemm; see `ilmpq backends`)"),
                    ("ratio", "manifest ratio name (default ilmpq2)"),
                    ("device", "FPGA-sim overlay device (default xc7z045)"),
                    ("threads", "backend CPU threads (0 or absent: all cores)"),
                    ("seed", "workload seed (default 42)"),
                    ("malformed", "fraction of malformed-length requests (default 0)"),
                    ("synthetic!", "force the artifact-free synthetic TinyResNet"),
                    ("out", "also write the report as JSON to this path"),
                    (
                        "url",
                        "drive a remote `ilmpq serve --listen` at this base URL \
                         (e.g. http://127.0.0.1:8080) over real sockets; the \
                         server-side options (backend/workers/...) are ignored",
                    ),
                    ("conns", "client connections for --url (default 8)"),
                ],
            );
            if let Some(url) = a.get("url") {
                // Remote mode: the same open-loop Poisson workload over
                // HTTP, statuses folded into the same outcome classes.
                let spec = loadgen::LoadSpec {
                    requests: a.usize_or("requests", 512),
                    rate: a.f64_or("rate", 2000.0),
                    malformed_frac: a.f64_or("malformed", 0.0),
                    seed: a.u64_or("seed", 42),
                };
                let (report, server_metrics) =
                    loadgen::run_remote(url, &spec, a.usize_or("conns", 8))?;
                println!("target: {url}");
                println!("{}", report.render());
                if server_metrics != ilmpq::util::Json::Null {
                    println!(
                        "server /v1/metrics: {}",
                        server_metrics.to_string_compact()
                    );
                }
                if let Some(path) = a.get("out") {
                    std::fs::write(path, report.to_json().to_string_compact())?;
                    println!("wrote {path}");
                }
                return Ok(());
            }
            let backend_name = a.str_or("backend", "qgemm").to_string();
            backend::spec(&backend_name)?;
            let ratio = a.str_or("ratio", "ilmpq2").to_string();
            let seed = a.u64_or("seed", 42);
            let threads = match a.usize_or("threads", 0) {
                0 => None, // all cores — the documented default
                t => Some(t),
            };
            // Real artifacts when present, else the synthetic fixture — so
            // the pipeline runs end-to-end on a toolchain-only machine.
            let (manifest, be) = loadgen::fixture_or_artifacts(
                &backend_name,
                &ratio,
                true,
                threads,
                seed,
                a.flag("synthetic"),
                "loadgen",
            )?;
            let cfg = ServeConfig {
                workers: a.usize_or("workers", 2),
                max_wait: Duration::from_millis(a.u64_or("max-wait-ms", 5)),
                queue_depth: a.usize_or("queue-depth", 1024),
                ratio_name: ratio,
                device: a.str_or("device", "xc7z045").to_string(),
                ..Default::default()
            };
            let spec = loadgen::LoadSpec {
                requests: a.usize_or("requests", 512),
                rate: a.f64_or("rate", 2000.0),
                malformed_frac: a.f64_or("malformed", 0.0),
                seed,
            };
            println!("backend: {} (model {})", be.name(), manifest.model_name);
            let server = Server::start(&manifest, be, cfg)?;
            println!("sim-FPGA: {}", server.sim.row());
            let (report, metrics) = loadgen::run(server, &manifest, &spec);
            println!("{}\n{}", report.render(), metrics.report());
            if let Some(path) = a.get("out") {
                std::fs::write(path, report.to_json().to_string_compact())?;
                println!("wrote {path}");
            }
            Ok(())
        }
        "backends" => {
            println!("registered execution backends (--backend NAME):");
            for s in backend::registry() {
                println!(
                    "  {:<8} {:<14} {}",
                    s.name,
                    if s.available { "[available]" } else { "[compiled out]" },
                    s.description
                );
            }
            Ok(())
        }
        "info" => {
            let rt = Runtime::load_default()?;
            let m = &rt.manifest;
            println!(
                "model {} ({}x{}x{}, {} classes), {} params, {} quantized layers",
                m.model_name,
                m.height,
                m.width,
                m.channels,
                m.classes,
                m.params.len(),
                m.quantized_layers.len()
            );
            println!("platform: {}", rt.engine.platform());
            for (name, a) in &m.artifacts {
                println!(
                    "  artifact {:<12} {} inputs, {} outputs ({})",
                    name,
                    a.inputs.len(),
                    a.outputs.len(),
                    a.file.file_name().unwrap().to_string_lossy()
                );
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}\n{HELP}");
            std::process::exit(2);
        }
    }
}

const HELP: &str = "\
ilmpq — Intra-Layer Multi-Precision Quantization framework (paper reproduction)

commands:
  table1        Table I hardware columns (FPGA sim, both devices)
  speedup       headline speedups vs the 8-bit fixed baseline
  ratio-search  offline PoT:Fixed4:Fixed8 sweep (paper §II-B)
  assign        Figure 1: per-row scheme/precision map (--show --ratio NAME)
  accuracy      Table I accuracy rows via QAT on the AOT model
  ptq           deterministic PTQ probe (train once, quantize each config)
  train         one QAT run with the loss curve
  serve         inference serving: `--listen ADDR` puts the HTTP/1.1 front
                end on the admission pipeline (POST /v1/infer, GET
                /v1/healthz, GET /v1/metrics); without it, the in-process
                demo loop runs (dynamic batching, --backend NAME)
  loadgen       open-loop offered-load driver for the admission pipeline
                (--rate, --queue-depth, --malformed; runs artifact-free);
                `--url http://host:port` drives a remote `serve --listen`
                over real sockets with the same outcome classes
  backends      list the registered execution backends
  info          manifest / artifacts summary
run `ilmpq <cmd> --help` for options.";
