//! Layer descriptors: the network-geometry substrate.
//!
//! Every hardware number in Table I is a function of layer geometry (GEMM
//! dims, op counts, weight/activation footprints), so this module is the
//! single source of truth for those. Conv layers are described in their
//! im2col GEMM view: `M = out_channels` (rows, the ILMPQ granularity),
//! `K = k*k*in_channels` (fan-in), `N = out_h*out_w` (pixels).

/// One layer of a network, as the FPGA sees it.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerDesc {
    pub name: String,
    pub kind: LayerKind,
}

#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    Conv {
        k: usize,
        stride: usize,
        in_ch: usize,
        out_ch: usize,
        in_h: usize,
        in_w: usize,
    },
    Fc {
        in_f: usize,
        out_f: usize,
    },
}

/// im2col GEMM dimensions of a layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmDims {
    /// Rows = output channels (the ILMPQ row granularity).
    pub m: usize,
    /// Contraction = fan-in (k*k*in_ch).
    pub k: usize,
    /// Columns = output pixels (1 for fc).
    pub n: usize,
}

impl LayerDesc {
    pub fn conv(
        name: &str,
        k: usize,
        stride: usize,
        in_ch: usize,
        out_ch: usize,
        in_h: usize,
        in_w: usize,
    ) -> LayerDesc {
        LayerDesc {
            name: name.to_string(),
            kind: LayerKind::Conv { k, stride, in_ch, out_ch, in_h, in_w },
        }
    }

    pub fn fc(name: &str, in_f: usize, out_f: usize) -> LayerDesc {
        LayerDesc { name: name.to_string(), kind: LayerKind::Fc { in_f, out_f } }
    }

    /// Output spatial dims (SAME padding, as both the paper's ResNet and the
    /// L2 model use).
    pub fn out_hw(&self) -> (usize, usize) {
        match self.kind {
            LayerKind::Conv { stride, in_h, in_w, .. } => {
                (in_h.div_ceil(stride), in_w.div_ceil(stride))
            }
            LayerKind::Fc { .. } => (1, 1),
        }
    }

    pub fn gemm(&self) -> GemmDims {
        match self.kind {
            LayerKind::Conv { k, in_ch, out_ch, .. } => {
                let (oh, ow) = self.out_hw();
                GemmDims { m: out_ch, k: k * k * in_ch, n: oh * ow }
            }
            LayerKind::Fc { in_f, out_f } => GemmDims { m: out_f, k: in_f, n: 1 },
        }
    }

    /// Multiply-accumulates for one input image.
    pub fn macs(&self) -> u64 {
        let g = self.gemm();
        (g.m as u64) * (g.k as u64) * (g.n as u64)
    }

    /// Ops (2 per MAC, the GOP/s convention the paper reports).
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Weight element count.
    pub fn weights(&self) -> u64 {
        let g = self.gemm();
        (g.m as u64) * (g.k as u64)
    }

    /// ILMPQ rows (= output channels).
    pub fn rows(&self) -> usize {
        self.gemm().m
    }

    /// Input/output activation element counts for one image.
    pub fn activations(&self) -> (u64, u64) {
        match self.kind {
            LayerKind::Conv { in_ch, out_ch, in_h, in_w, .. } => {
                let (oh, ow) = self.out_hw();
                (
                    (in_ch * in_h * in_w) as u64,
                    (out_ch * oh * ow) as u64,
                )
            }
            LayerKind::Fc { in_f, out_f } => (in_f as u64, out_f as u64),
        }
    }
}

/// A whole network: ordered layers + metadata.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub layers: Vec<LayerDesc>,
}

impl Network {
    pub fn total_ops(&self) -> u64 {
        self.layers.iter().map(|l| l.ops()).sum()
    }

    pub fn total_gops(&self) -> f64 {
        self.total_ops() as f64 / 1e9
    }

    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weights()).sum()
    }

    pub fn total_rows(&self) -> usize {
        self.layers.iter().map(|l| l.rows()).sum()
    }

    /// First/last layer indices (the layers prior work kept at 8 bits).
    pub fn first_last(&self) -> (usize, usize) {
        (0, self.layers.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_gemm_dims() {
        let l = LayerDesc::conv("c", 3, 1, 16, 32, 8, 8);
        assert_eq!(l.gemm(), GemmDims { m: 32, k: 144, n: 64 });
        assert_eq!(l.macs(), 32 * 144 * 64);
        assert_eq!(l.ops(), 2 * 32 * 144 * 64);
        assert_eq!(l.rows(), 32);
    }

    #[test]
    fn strided_conv_same_padding() {
        let l = LayerDesc::conv("c", 3, 2, 16, 32, 9, 9);
        assert_eq!(l.out_hw(), (5, 5)); // ceil(9/2)
        let l = LayerDesc::conv("c", 7, 2, 3, 64, 224, 224);
        assert_eq!(l.out_hw(), (112, 112));
    }

    #[test]
    fn fc_dims() {
        let l = LayerDesc::fc("fc", 512, 1000);
        assert_eq!(l.gemm(), GemmDims { m: 1000, k: 512, n: 1 });
        assert_eq!(l.weights(), 512_000);
        assert_eq!(l.activations(), (512, 1000));
    }

    #[test]
    fn network_totals() {
        let net = Network {
            name: "t".into(),
            layers: vec![
                LayerDesc::conv("a", 3, 1, 3, 8, 4, 4),
                LayerDesc::fc("b", 8, 10),
            ],
        };
        assert_eq!(net.total_ops(), net.layers[0].ops() + net.layers[1].ops());
        assert_eq!(net.first_last(), (0, 1));
        assert_eq!(net.total_rows(), 18);
    }
}
