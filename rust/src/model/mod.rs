//! Network-geometry substrate: layer descriptors, the paper's ResNet-18
//! table, and the model zoo used by examples and benches.

pub mod layer;
pub mod resnet18;
pub mod zoo;

pub use layer::{GemmDims, LayerDesc, LayerKind, Network};
pub use resnet18::resnet18;
