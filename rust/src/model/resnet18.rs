//! The exact ImageNet ResNet-18 layer table (the paper's Table-I workload).
//!
//! All Table-I hardware numbers are simulated against this geometry; its
//! total of ~3.63 GOPs is what makes the paper's latency = GOPs / GOP/s
//! columns self-consistent (e.g. 115.6 GOP/s * 31.4 ms ~ 3.63 GOP), which the
//! tests assert as a calibration anchor.

use super::layer::{LayerDesc, Network};

/// Build the ResNet-18 (ImageNet, 224x224 input) conv/fc inventory.
///
/// Downsample (projection) 1x1 convs of stages 2-4 are included; max-pool
/// and batchnorm contribute no MACs and are folded into the buffer pass of
/// the performance model.
pub fn resnet18() -> Network {
    let mut layers = vec![LayerDesc::conv("conv1", 7, 2, 3, 64, 224, 224)];
    // After conv1 (112x112) + 3x3/2 maxpool -> 56x56.
    let cfg: &[(usize, usize, usize, usize)] = &[
        // (stage, in_ch, out_ch, in_hw at stage entry)
        (1, 64, 64, 56),
        (2, 64, 128, 56),
        (3, 128, 256, 28),
        (4, 256, 512, 14),
    ];
    for &(stage, in_ch, out_ch, in_hw) in cfg {
        let stride = if stage == 1 { 1 } else { 2 };
        let out_hw = in_hw / stride;
        // Block 1 (possibly strided, with projection shortcut).
        layers.push(LayerDesc::conv(
            &format!("layer{stage}.0.conv1"),
            3,
            stride,
            in_ch,
            out_ch,
            in_hw,
            in_hw,
        ));
        layers.push(LayerDesc::conv(
            &format!("layer{stage}.0.conv2"),
            3,
            1,
            out_ch,
            out_ch,
            out_hw,
            out_hw,
        ));
        if stride != 1 || in_ch != out_ch {
            layers.push(LayerDesc::conv(
                &format!("layer{stage}.0.downsample"),
                1,
                stride,
                in_ch,
                out_ch,
                in_hw,
                in_hw,
            ));
        }
        // Block 2 (identity shortcut).
        layers.push(LayerDesc::conv(
            &format!("layer{stage}.1.conv1"),
            3,
            1,
            out_ch,
            out_ch,
            out_hw,
            out_hw,
        ));
        layers.push(LayerDesc::conv(
            &format!("layer{stage}.1.conv2"),
            3,
            1,
            out_ch,
            out_ch,
            out_hw,
            out_hw,
        ));
    }
    layers.push(LayerDesc::fc("fc", 512, 1000));
    Network { name: "resnet18".into(), layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_count() {
        // 1 stem + 4 stages * (4 convs + downsample for stages 2-4) + fc
        // = 1 + (4 + 5*3) + 1 = 21 parametric layers.
        assert_eq!(resnet18().layers.len(), 21);
    }

    #[test]
    fn total_gops_matches_paper_anchor() {
        // Paper's implied total: throughput * latency ~ 3.62-3.64 GOPs
        // (e.g. XC7Z045 rows: 115.6 GOP/s * 31.4 ms = 3.63).
        let g = resnet18().total_gops();
        assert!((3.55..3.75).contains(&g), "GOPs {g}");
    }

    #[test]
    fn conv1_geometry() {
        let net = resnet18();
        let c1 = &net.layers[0];
        assert_eq!(c1.out_hw(), (112, 112));
        // 64 * 3*49 * 112^2 MACs = 118M -> 0.236 GOPs.
        assert!((c1.ops() as f64 / 1e9 - 0.236).abs() < 0.005);
    }

    #[test]
    fn weights_match_conv_fc_total() {
        // ResNet-18 conv+fc weights ~ 11.68M (excluding BN).
        let w = resnet18().total_weights() as f64 / 1e6;
        assert!((11.0..11.8).contains(&w), "weights {w}M");
    }

    #[test]
    fn first_last_share_of_ops_is_small() {
        // conv1 + fc ~ 6.6% of ops: the reason inter-layer schemes waste PEs.
        let net = resnet18();
        let (f, l) = net.first_last();
        let share = (net.layers[f].ops() + net.layers[l].ops()) as f64
            / net.total_ops() as f64;
        assert!((0.05..0.09).contains(&share), "share {share}");
    }
}
