//! Model zoo: the L2 TinyResNet (mirroring `python/compile/model.py`) plus
//! extra workloads (VGG-11, a 4-layer CNN) for the domain examples and the
//! generality ablation — the paper's claim is that one PE configuration
//! serves *any* network once the intra-layer mix is uniform.

use super::layer::{LayerDesc, Network};

/// The AOT-compiled TinyResNet geometry. Must mirror
/// `python/compile/model.py::layer_defs` — the manifest agreement test
/// cross-checks rows/fan-in per quantized layer.
pub fn tinyresnet(height: usize, width: usize, channels: usize, widths: &[usize], classes: usize) -> Network {
    let mut layers = Vec::new();
    let w0 = widths[0];
    layers.push(LayerDesc::conv("stem/w", 3, 1, channels, w0, height, width));
    let mut prev = w0;
    let (mut h, mut w) = (height, width);
    for (si, &wch) in widths.iter().enumerate() {
        let stride = if prev == wch { 1 } else { 2 };
        layers.push(LayerDesc::conv(&format!("s{si}/c1/w"), 3, stride, prev, wch, h, w));
        h = h.div_ceil(stride);
        w = w.div_ceil(stride);
        layers.push(LayerDesc::conv(&format!("s{si}/c2/w"), 3, 1, wch, wch, h, w));
        if prev != wch {
            layers.push(LayerDesc::conv(
                &format!("s{si}/proj/w"),
                1,
                stride,
                prev,
                wch,
                h * stride,
                w * stride,
            ));
        }
        prev = wch;
    }
    layers.push(LayerDesc::fc("fc/w", prev, classes));
    Network { name: "tinyresnet".into(), layers }
}

/// Default TinyResNet (16x16x3, widths 16/32/64, 10 classes).
pub fn tinyresnet_default() -> Network {
    tinyresnet(16, 16, 3, &[16, 32, 64], 10)
}

/// VGG-11 on 224x224 ImageNet — a second real workload for the benches.
pub fn vgg11() -> Network {
    let cfg: &[(usize, usize, usize)] = &[
        // (in_ch, out_ch, in_hw)
        (3, 64, 224),
        (64, 128, 112),
        (128, 256, 56),
        (256, 256, 56),
        (256, 512, 28),
        (512, 512, 28),
        (512, 512, 14),
        (512, 512, 14),
    ];
    let mut layers = Vec::new();
    for (i, &(ic, oc, hw)) in cfg.iter().enumerate() {
        layers.push(LayerDesc::conv(&format!("conv{}", i + 1), 3, 1, ic, oc, hw, hw));
    }
    layers.push(LayerDesc::fc("fc1", 512 * 7 * 7, 4096));
    layers.push(LayerDesc::fc("fc2", 4096, 4096));
    layers.push(LayerDesc::fc("fc3", 4096, 1000));
    Network { name: "vgg11".into(), layers }
}

/// A narrow VGG-style plain conv stack: `relu(conv)` chain with no
/// residual connections, layer names `s{i}/conv/w` + `fc/w`. The stride
/// rule mirrors TinyResNet's (stride 2 whenever the width changes), so the
/// same `widths` list downsamples identically on both recipes. This is the
/// second geometry the synthetic serving fixtures can build end-to-end
/// (see `backend::synth::vgg_manifest`), giving the multi-model pool a
/// genuinely different topology to serve next to TinyResNet.
pub fn vggnarrow(
    height: usize,
    width: usize,
    channels: usize,
    widths: &[usize],
    classes: usize,
) -> Network {
    let mut layers = Vec::new();
    let mut prev_ch = channels;
    let (mut h, mut w) = (height, width);
    let mut prev_width: Option<usize> = None;
    for (si, &wch) in widths.iter().enumerate() {
        let stride = match prev_width {
            Some(p) if p != wch => 2,
            _ => 1,
        };
        layers.push(LayerDesc::conv(&format!("s{si}/conv/w"), 3, stride, prev_ch, wch, h, w));
        h = h.div_ceil(stride);
        w = w.div_ceil(stride);
        prev_ch = wch;
        prev_width = Some(wch);
    }
    layers.push(LayerDesc::fc("fc/w", prev_ch, classes));
    Network { name: "vggnarrow".into(), layers }
}

/// The serving-overlay network for a manifest's model name: `vggnarrow*`
/// manifests get the plain conv stack, everything else (the artifact
/// manifests and `tiny-synth`) the TinyResNet recipe. This is what lets
/// `Server::start` simulate whichever geometry a pool entry serves instead
/// of hardcoding TinyResNet.
pub fn serving_network(
    model_name: &str,
    height: usize,
    width: usize,
    channels: usize,
    widths: &[usize],
    classes: usize,
) -> Network {
    if model_name.starts_with("vggnarrow") {
        vggnarrow(height, width, channels, widths, classes)
    } else {
        tinyresnet(height, width, channels, widths, classes)
    }
}

/// Small 4-conv CNN (edge-vision style) — third example workload.
pub fn cnn_small() -> Network {
    Network {
        name: "cnn-small".into(),
        layers: vec![
            LayerDesc::conv("c1", 3, 1, 3, 32, 32, 32),
            LayerDesc::conv("c2", 3, 2, 32, 64, 32, 32),
            LayerDesc::conv("c3", 3, 2, 64, 128, 16, 16),
            LayerDesc::conv("c4", 3, 2, 128, 128, 8, 8),
            LayerDesc::fc("fc", 128 * 4 * 4, 10),
        ],
    }
}

/// Look up a zoo network by name (CLI surface).
pub fn by_name(name: &str) -> Option<Network> {
    match name {
        "resnet18" => Some(super::resnet18::resnet18()),
        "tinyresnet" => Some(tinyresnet_default()),
        "vgg11" => Some(vgg11()),
        "vggnarrow" => Some(vggnarrow(16, 16, 3, &[8, 16], 10)),
        "cnn-small" => Some(cnn_small()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tinyresnet_matches_python_layer_list() {
        let net = tinyresnet_default();
        let names: Vec<&str> = net.layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "stem/w", "s0/c1/w", "s0/c2/w", "s1/c1/w", "s1/c2/w", "s1/proj/w",
                "s2/c1/w", "s2/c2/w", "s2/proj/w", "fc/w",
            ]
        );
        // Row counts = out channels.
        assert_eq!(net.layers[0].rows(), 16);
        assert_eq!(net.layers[5].rows(), 32);
        assert_eq!(net.layers[9].rows(), 10);
    }

    #[test]
    fn tinyresnet_spatial_dims() {
        let net = tinyresnet_default();
        // s1/c1 strides 16->8, s2/c1 strides 8->4.
        assert_eq!(net.layers[3].out_hw(), (8, 8));
        assert_eq!(net.layers[6].out_hw(), (4, 4));
    }

    #[test]
    fn vgg11_is_heavier_than_resnet18() {
        assert!(vgg11().total_gops() > super::super::resnet18::resnet18().total_gops());
        // VGG-11: ~15.2 GOPs.
        let g = vgg11().total_gops();
        assert!((14.0..16.5).contains(&g), "GOPs {g}");
    }

    #[test]
    fn zoo_lookup() {
        for n in ["resnet18", "tinyresnet", "vgg11", "vggnarrow", "cnn-small"] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn vggnarrow_layer_list_and_strides() {
        let net = vggnarrow(16, 16, 3, &[8, 16], 10);
        let names: Vec<&str> = net.layers.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["s0/conv/w", "s1/conv/w", "fc/w"]);
        // s0 keeps 16x16 (first conv is stride 1), s1 strides 16->8 on the
        // width change — the same rule as TinyResNet's c1.
        assert_eq!(net.layers[0].out_hw(), (16, 16));
        assert_eq!(net.layers[1].out_hw(), (8, 8));
        assert_eq!(net.layers[0].rows(), 8);
        assert_eq!(net.layers[1].rows(), 16);
        assert_eq!(net.layers[2].rows(), 10);
    }

    #[test]
    fn serving_network_dispatches_on_model_name() {
        let v = serving_network("vggnarrow-synth", 16, 16, 3, &[8, 16], 10);
        assert_eq!(v.layers[0].name, "s0/conv/w");
        let t = serving_network("tiny-synth", 16, 16, 3, &[8, 16], 10);
        assert_eq!(t.layers[0].name, "stem/w");
    }
}
