//! Assignment policy: which rows get 8 bits, which 4-bit rows get PoT.
//!
//! Mirror of `python/compile/assign.py` (paper §II-C): the top `frac8`
//! rows by Hessian eigenvalue are Fixed-8 (at least one row when
//! `frac8 > 0`), and among the remaining 4-bit rows the lowest-variance
//! `pot_share` fraction are PoT-4. Sorting matches numpy's stable argsort so
//! the Rust and Python masks are identical on identical inputs (checked by
//! `rust/tests/manifest_agreement.rs`).

use super::{Ratio, Scheme};
use crate::util::stats::variance_f32;

/// Per-layer row masks (the runtime inputs of every AOT artifact).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerMasks {
    pub layer: String,
    pub is8: Vec<f32>,
    pub is_pot: Vec<f32>,
}

impl LayerMasks {
    pub fn rows(&self) -> usize {
        self.is8.len()
    }

    pub fn scheme_of(&self, row: usize) -> Scheme {
        if self.is8[row] > 0.5 {
            Scheme::Fixed8
        } else if self.is_pot[row] > 0.5 {
            Scheme::Pot4
        } else {
            Scheme::Fixed4
        }
    }

    /// (n_pot4, n_fixed4, n_fixed8) row counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        let n8 = self.is8.iter().filter(|&&v| v > 0.5).count();
        let np = self.is_pot.iter().filter(|&&v| v > 0.5).count();
        (np, self.rows() - n8 - np, n8)
    }

    /// Fraction of *ops* in each scheme — rows are equal-cost within a layer
    /// (same fan-in), so op fractions equal row fractions.
    pub fn op_fractions(&self) -> (f64, f64, f64) {
        let (p, f4, f8) = self.counts();
        let n = self.rows() as f64;
        (p as f64 / n, f4 as f64 / n, f8 as f64 / n)
    }
}

/// All layers' masks for one configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct MaskSet {
    pub name: String,
    pub layers: Vec<LayerMasks>,
}

impl MaskSet {
    pub fn layer(&self, name: &str) -> Option<&LayerMasks> {
        self.layers.iter().find(|l| l.layer == name)
    }

    /// Aggregate scheme fractions over all rows (reporting).
    pub fn total_fractions(&self) -> (f64, f64, f64) {
        let (mut p, mut f4, mut f8, mut n) = (0usize, 0usize, 0usize, 0usize);
        for l in &self.layers {
            let (a, b, c) = l.counts();
            p += a;
            f4 += b;
            f8 += c;
            n += l.rows();
        }
        let n = n.max(1) as f64;
        (p as f64 / n, f4 as f64 / n, f8 as f64 / n)
    }
}

/// Stable argsort descending (numpy `argsort(-x, kind="stable")`).
fn argsort_desc(xs: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[b].partial_cmp(&xs[a]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx
}

/// Stable argsort ascending.
fn argsort_asc(xs: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| {
        xs[a].partial_cmp(&xs[b]).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    idx
}

/// Top-`frac8` rows by eigenvalue -> 8-bit. At least one row when frac8 > 0.
pub fn assign_bits(eigs: &[f64], frac8: f64) -> Vec<f32> {
    let rows = eigs.len();
    let n8 = if frac8 <= 0.0 {
        0
    } else {
        ((rows as f64 * frac8).round() as usize).max(1)
    };
    let mut is8 = vec![0f32; rows];
    for &i in argsort_desc(eigs).iter().take(n8) {
        is8[i] = 1.0;
    }
    is8
}

/// Lowest-variance 4-bit rows -> PoT. `rows` is the (rows, fan_in) GEMM view.
pub fn assign_schemes(rows: &[Vec<f32>], is8: &[f32], pot_share: f64) -> Vec<f32> {
    let var: Vec<f64> = rows.iter().map(|r| variance_f32(r)).collect();
    let four_bit: Vec<usize> = (0..rows.len()).filter(|&i| is8[i] < 0.5).collect();
    let n_pot = (four_bit.len() as f64 * pot_share).round() as usize;
    let mut is_pot = vec![0f32; rows.len()];
    if n_pot > 0 {
        let four_var: Vec<f64> = four_bit.iter().map(|&i| var[i]).collect();
        for &k in argsort_asc(&four_var).iter().take(n_pot) {
            is_pot[four_bit[k]] = 1.0;
        }
    }
    is_pot
}

/// Full assignment for one layer from its GEMM-view rows + sensitivities.
pub fn assign_layer(
    layer: &str,
    rows: &[Vec<f32>],
    eigs: &[f64],
    ratio: Ratio,
) -> LayerMasks {
    assert_eq!(rows.len(), eigs.len(), "{layer}: rows vs eigs mismatch");
    let is8 = assign_bits(eigs, ratio.frac8());
    let is_pot = assign_schemes(rows, &is8, ratio.pot_share_of_4bit());
    LayerMasks { layer: layer.to_string(), is8, is_pot }
}

/// The prior-work baseline: whole layer forced to one scheme, with optional
/// Fixed-8 first/last layers (Table I rows 1/3/5/7/8).
pub fn assign_uniform_layer(
    layer: &str,
    rows: usize,
    scheme: Scheme,
) -> LayerMasks {
    let (is8v, ipotv) = match scheme {
        Scheme::Fixed8 => (1.0, 0.0),
        Scheme::Pot4 => (0.0, 1.0),
        Scheme::Fixed4 => (0.0, 0.0),
    };
    LayerMasks {
        layer: layer.to_string(),
        is8: vec![is8v; rows],
        is_pot: vec![ipotv; rows],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};
    use crate::util::Rng;

    #[test]
    fn bits_pick_top_eigs() {
        let eigs = vec![0.1, 5.0, 0.2, 4.0, 0.3];
        let is8 = assign_bits(&eigs, 0.4); // 2 rows
        assert_eq!(is8, vec![0.0, 1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn bits_at_least_one_when_nonzero() {
        let is8 = assign_bits(&[1.0; 16], 0.05); // 0.8 rounds to 1
        assert_eq!(is8.iter().filter(|&&v| v > 0.5).count(), 1);
        assert_eq!(assign_bits(&[1.0; 16], 0.0).iter().sum::<f32>(), 0.0);
    }

    #[test]
    fn bits_tie_breaks_to_lower_index() {
        let is8 = assign_bits(&[2.0, 2.0, 2.0, 2.0], 0.5);
        assert_eq!(is8, vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn schemes_pick_low_variance() {
        let rows = vec![
            vec![0.0, 0.0, 0.1],   // tiny variance -> PoT
            vec![-3.0, 3.0, 0.0],  // large variance -> Fixed
            vec![0.0, 0.05, 0.0],  // tiny variance -> PoT
            vec![-2.0, 2.0, 1.0],  // large variance -> Fixed
        ];
        let is8 = vec![0.0; 4];
        let ipot = assign_schemes(&rows, &is8, 0.5);
        assert_eq!(ipot, vec![1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn eight_bit_rows_never_pot() {
        let rows = vec![vec![0.0, 0.01]; 6];
        let is8 = vec![1.0, 0.0, 1.0, 0.0, 0.0, 0.0];
        let ipot = assign_schemes(&rows, &is8, 1.0);
        for (i, &p) in ipot.iter().enumerate() {
            assert!(!(is8[i] > 0.5 && p > 0.5), "row {i} both 8-bit and PoT");
        }
        assert_eq!(ipot.iter().sum::<f32>(), 4.0);
    }

    #[test]
    fn prop_masks_disjoint_and_ratio_respected() {
        forall(
            31,
            64,
            |r: &mut Rng| {
                let rows = r.range_usize(4, 64);
                let fan = r.range_usize(3, 20);
                let data: Vec<Vec<f32>> = (0..rows)
                    .map(|_| (0..fan).map(|_| r.normal()).collect())
                    .collect();
                let eigs: Vec<f64> = (0..rows).map(|_| r.f64() * 10.0).collect();
                (data, eigs)
            },
            |(data, eigs)| {
                let ratio = Ratio::new(60.0, 35.0, 5.0);
                let m = assign_layer("t", data, eigs, ratio);
                let (np, nf4, n8) = m.counts();
                ensure(np + nf4 + n8 == m.rows(), || "counts don't partition".into())?;
                // n8 = max(1, round(5% rows))
                let want8 = ((m.rows() as f64 * 0.05).round() as usize).max(1);
                ensure(n8 == want8, || format!("n8 {n8} != {want8}"))?;
                // PoT share of 4-bit rows ~ 60/95.
                let want_pot =
                    (((m.rows() - n8) as f64) * (60.0 / 95.0)).round() as usize;
                ensure(np == want_pot, || format!("np {np} != {want_pot}"))
            },
        );
    }

    #[test]
    fn uniform_layers() {
        let m = assign_uniform_layer("l", 8, Scheme::Pot4);
        assert_eq!(m.counts(), (8, 0, 0));
        let m = assign_uniform_layer("l", 8, Scheme::Fixed8);
        assert_eq!(m.counts(), (0, 0, 8));
        assert_eq!(m.scheme_of(0), Scheme::Fixed8);
    }

    #[test]
    fn op_fractions_sum_to_one() {
        let m = LayerMasks {
            layer: "t".into(),
            is8: vec![1.0, 0.0, 0.0, 0.0],
            is_pot: vec![0.0, 1.0, 1.0, 0.0],
        };
        let (p, f4, f8) = m.op_fractions();
        assert!((p + f4 + f8 - 1.0).abs() < 1e-12);
        assert_eq!(m.counts(), (2, 1, 1));
    }
}
