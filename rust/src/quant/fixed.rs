//! Symmetric uniform fixed-point quantizer (Fixed-4 / Fixed-8).
//!
//! Bit-exact mirror of `python/compile/quant.py::quantize_fixed` /
//! `fixed_codes`: levels are `q/Q * scale` for integer `q in [-Q, Q]`,
//! `Q = 2^(bits-1) - 1`, with round-half-away-from-zero (numpy/jnp
//! `round` on `.5` boundaries after the multiply behaves like Rust's
//! `f32::round` for the magnitudes involved; the agreement test replays the
//! Python codes to confirm).

/// Largest magnitude code for a bit width: 7 for 4-bit, 127 for 8-bit.
pub fn qmax(bits: u32) -> f32 {
    ((1i32 << (bits - 1)) - 1) as f32
}

/// Integer code for one weight: `clip(round(w/scale * Q), -Q, Q)`.
pub fn code(w: f32, bits: u32, scale: f32) -> i32 {
    let q = qmax(bits);
    (w / scale * q).round().clamp(-q, q) as i32
}

/// Dequantize a code: `q * scale / Q`.
pub fn dequant(code: i32, bits: u32, scale: f32) -> f32 {
    code as f32 * (scale / qmax(bits))
}

/// Fake-quant one value (quantize -> dequantize).
pub fn fake_quant(w: f32, bits: u32, scale: f32) -> f32 {
    dequant(code(w, bits, scale), bits, scale)
}

/// Fake-quant a whole row with its own max-abs scale.
pub fn fake_quant_row(row: &[f32], bits: u32) -> Vec<f32> {
    let s = super::row_scale(row);
    row.iter().map(|&w| fake_quant(w, bits, s)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(4), 7.0);
        assert_eq!(qmax(8), 127.0);
        assert_eq!(qmax(2), 1.0);
    }

    #[test]
    fn known_codes() {
        // scale 1, 4 bits: levels k/7.
        assert_eq!(code(1.0, 4, 1.0), 7);
        assert_eq!(code(-1.0, 4, 1.0), -7);
        assert_eq!(code(0.0, 4, 1.0), 0);
        assert_eq!(code(0.5, 4, 1.0), 4); // 3.5 rounds away from zero
        assert_eq!(code(10.0, 4, 1.0), 7); // clipped
    }

    #[test]
    fn prop_error_bounded_by_half_step() {
        // |w - fq(w)| <= scale / (2 Q) for in-range w.
        forall(
            11,
            256,
            |r| {
                let bits = if r.bool(0.5) { 4 } else { 8 };
                let scale = r.range_f32(0.05, 10.0);
                let w = r.range_f32(-1.0, 1.0) * scale;
                (w, bits, scale)
            },
            |&(w, bits, scale)| {
                let err = (w - fake_quant(w, bits, scale)).abs();
                let half_step = scale / (2.0 * qmax(bits));
                ensure(err <= half_step * 1.0001, || {
                    format!("err {err} > half step {half_step}")
                })
            },
        );
    }

    #[test]
    fn prop_idempotent() {
        forall(
            12,
            256,
            |r| {
                let bits = if r.bool(0.5) { 4 } else { 8 };
                (r.normal() * 2.0, bits, r.range_f32(0.5, 4.0))
            },
            |&(w, bits, scale)| {
                let once = fake_quant(w, bits, scale);
                let twice = fake_quant(once, bits, scale);
                ensure((once - twice).abs() < 1e-7, || format!("{once} vs {twice}"))
            },
        );
    }

    #[test]
    fn prop_odd_symmetry() {
        forall(
            13,
            256,
            |r| (r.normal() * 3.0, r.range_f32(0.5, 4.0)),
            |&(w, scale)| {
                let a = fake_quant(w, 4, scale);
                let b = fake_quant(-w, 4, scale);
                ensure((a + b).abs() < 1e-7, || format!("{a} vs {b}"))
            },
        );
    }

    #[test]
    fn row_uses_maxabs_scale() {
        let row = [0.1f32, -2.0, 0.5];
        let fq = fake_quant_row(&row, 8);
        // max element is exactly representable (code ±127).
        assert!((fq[1] + 2.0).abs() < 1e-6);
    }
}
