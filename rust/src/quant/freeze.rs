//! Weight freezing: apply the row-wise mixed fake-quant to parameter
//! tensors *once*, in Rust — the software analogue of writing the
//! pre-quantized BRAM image on the FPGA.
//!
//! The serving fast path feeds frozen weights to the `infer_frozen_b{N}`
//! artifacts (no fake-quant ops in the graph). Because the Rust quantizers
//! are bit-exact mirrors of the Pallas kernel and fake-quant is idempotent
//! (both property-tested), `infer(params, masks) == infer_frozen(freeze(
//! params, masks))` to float tolerance — asserted by `e2e_runtime.rs`.

use super::{fixed, gemmview, pot, row_scale, LayerMasks, MaskSet, Scheme};
use crate::runtime::{HostTensor, Manifest};

/// Fake-quant one weight tensor under its layer masks.
pub fn freeze_tensor(t: &HostTensor, masks: &LayerMasks) -> HostTensor {
    let mut rows = gemmview::gemm_rows(t);
    assert_eq!(rows.len(), masks.rows(), "{}: rows mismatch", masks.layer);
    for (r, row) in rows.iter_mut().enumerate() {
        let scale = row_scale(row);
        match masks.scheme_of(r) {
            Scheme::Fixed8 => {
                for v in row.iter_mut() {
                    *v = fixed::fake_quant(*v, 8, scale);
                }
            }
            Scheme::Fixed4 => {
                for v in row.iter_mut() {
                    *v = fixed::fake_quant(*v, 4, scale);
                }
            }
            Scheme::Pot4 => {
                for v in row.iter_mut() {
                    *v = pot::fake_quant(*v, 4, scale);
                }
            }
        }
    }
    gemmview::from_gemm_rows(&rows, &t.shape)
}

/// Freeze a full parameter list (AOT order). `quantized` maps layer name ->
/// param index; non-quantized params (biases) pass through untouched.
pub fn freeze_params(
    params: &[HostTensor],
    param_names: &[String],
    masks: &MaskSet,
) -> Vec<HostTensor> {
    params
        .iter()
        .zip(param_names)
        .map(|(t, name)| match masks.layer(name) {
            Some(lm) => freeze_tensor(t, lm),
            None => t.clone(),
        })
        .collect()
}

/// Freeze a full parameter list using the manifest's AOT name order — the
/// one recipe every frozen-serving path (PJRT backend, float reference,
/// PTQ policies) shares.
pub fn freeze_for_manifest(
    m: &Manifest,
    params: &[HostTensor],
    masks: &MaskSet,
) -> Vec<HostTensor> {
    let names: Vec<String> = m.params.iter().map(|(n, _)| n.clone()).collect();
    freeze_params(params, &names, masks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::assign::assign_uniform_layer;
    use crate::util::prop::{assert_close, forall};
    use crate::util::Rng;

    fn random_tensor(r: &mut Rng, shape: Vec<usize>) -> HostTensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| r.normal()).collect();
        HostTensor::f32(shape, data)
    }

    #[test]
    fn prop_freeze_is_idempotent() {
        forall(
            101,
            32,
            |r: &mut Rng| {
                let rows = r.range_usize(2, 12);
                let t = random_tensor(r, vec![2, 2, 3, rows]);
                let masks = crate::fpga::sim::synth_masks(
                    "t",
                    rows,
                    crate::quant::Ratio::new(60.0, 35.0, 5.0),
                );
                (t, masks)
            },
            |(t, masks)| {
                let once = freeze_tensor(t, masks);
                let twice = freeze_tensor(&once, masks);
                assert_close(twice.as_f32(), once.as_f32(), 1e-6, "idempotence")
            },
        );
    }

    #[test]
    fn freeze_fixed8_bounded_error() {
        let mut r = Rng::new(3);
        let t = random_tensor(&mut r, vec![4, 16]);
        let masks = assign_uniform_layer("t", 4, Scheme::Fixed8);
        let f = freeze_tensor(&t, &masks);
        for (row_orig, row_q) in gemmview::gemm_rows(&t).iter().zip(gemmview::gemm_rows(&f)) {
            let scale = row_scale(row_orig);
            for (a, b) in row_orig.iter().zip(&row_q) {
                assert!((a - b).abs() <= scale / 254.0 + 1e-6);
            }
        }
    }

    #[test]
    fn non_quantized_params_pass_through() {
        let mut r = Rng::new(5);
        let w = random_tensor(&mut r, vec![3, 4]);
        let b = random_tensor(&mut r, vec![3]);
        let masks = MaskSet {
            name: "t".into(),
            layers: vec![assign_uniform_layer("w", 3, Scheme::Pot4)],
        };
        let out = freeze_params(
            &[w.clone(), b.clone()],
            &["w".to_string(), "b".to_string()],
            &masks,
        );
        assert_ne!(out[0], w); // quantized
        assert_eq!(out[1], b); // untouched
    }
}
