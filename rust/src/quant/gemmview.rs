//! GEMM (row-major filter) views of weight tensors.
//!
//! The L2 model stores conv weights HWIO (`h, w, in, out`) — JAX's default —
//! while ILMPQ reasons per *filter row* (`out, h*w*in`). This module extracts
//! that view from flat HostTensor data, mirroring
//! `python/compile/assign.py::gemm_view_np` exactly (transpose to OHWI then
//! flatten), so row variances and packed codes agree bit-for-bit across the
//! language boundary.

use crate::runtime::HostTensor;

/// Rows of the GEMM view: `(out_rows, fan_in)`.
///
/// * 4-D HWIO conv weight -> rows are output channels (last dim);
/// * 2-D fc weight (out, in) -> rows are the first dim;
/// * 1-D bias -> one row (never quantized, but the view is total).
pub fn gemm_rows(t: &HostTensor) -> Vec<Vec<f32>> {
    let d = t.as_f32();
    match t.shape.len() {
        4 => {
            let (h, w, i, o) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
            let fan = h * w * i;
            let mut rows = vec![Vec::with_capacity(fan); o];
            // flat index = ((hh*w + ww)*i + ii)*o + oo; iterate in (h,w,i)
            // order so each row comes out in python's reshape order.
            for hw_i in 0..fan {
                let base = hw_i * o;
                for (oo, row) in rows.iter_mut().enumerate() {
                    row.push(d[base + oo]);
                }
            }
            rows
        }
        2 => {
            let (o, fan) = (t.shape[0], t.shape[1]);
            (0..o).map(|r| d[r * fan..(r + 1) * fan].to_vec()).collect()
        }
        1 => vec![d.to_vec()],
        _ => panic!("unsupported weight rank {:?}", t.shape),
    }
}

/// Scatter GEMM rows back into a HostTensor of the original layout
/// (inverse of `gemm_rows`; used by tests and the packer round-trip).
pub fn from_gemm_rows(rows: &[Vec<f32>], shape: &[usize]) -> HostTensor {
    match shape.len() {
        4 => {
            let (h, w, i, o) = (shape[0], shape[1], shape[2], shape[3]);
            let fan = h * w * i;
            let mut flat = vec![0f32; fan * o];
            for (oo, row) in rows.iter().enumerate() {
                assert_eq!(row.len(), fan);
                for (hw_i, &v) in row.iter().enumerate() {
                    flat[hw_i * o + oo] = v;
                }
            }
            HostTensor::f32(shape.to_vec(), flat)
        }
        2 => {
            let flat: Vec<f32> = rows.iter().flatten().copied().collect();
            HostTensor::f32(shape.to_vec(), flat)
        }
        1 => HostTensor::f32(shape.to_vec(), rows[0].clone()),
        _ => panic!("unsupported weight rank {shape:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};
    use crate::util::Rng;

    #[test]
    fn fc_rows_are_contiguous() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let rows = gemm_rows(&t);
        assert_eq!(rows, vec![vec![1., 2., 3.], vec![4., 5., 6.]]);
    }

    #[test]
    fn hwio_rows_are_filters() {
        // shape (1,1,2,2): flat = [i0o0, i0o1, i1o0, i1o1]
        let t = HostTensor::f32(vec![1, 1, 2, 2], vec![10., 20., 11., 21.]);
        let rows = gemm_rows(&t);
        assert_eq!(rows, vec![vec![10., 11.], vec![20., 21.]]);
    }

    #[test]
    fn prop_roundtrip_4d() {
        forall(
            71,
            48,
            |r: &mut Rng| {
                let shape = vec![
                    r.range_usize(1, 4),
                    r.range_usize(1, 4),
                    r.range_usize(1, 6),
                    r.range_usize(1, 8),
                ];
                let n: usize = shape.iter().product();
                let data: Vec<f32> = (0..n).map(|_| r.normal()).collect();
                HostTensor::f32(shape, data)
            },
            |t| {
                let rows = gemm_rows(t);
                ensure(rows.len() == t.shape[3], || "row count".into())?;
                let back = from_gemm_rows(&rows, &t.shape);
                ensure(back == *t, || "roundtrip mismatch".into())
            },
        );
    }

    #[test]
    fn row_count_matches_out_channels() {
        let t = HostTensor::zeros(vec![3, 3, 16, 32]);
        assert_eq!(gemm_rows(&t).len(), 32);
        assert_eq!(gemm_rows(&t)[0].len(), 144);
    }
}
