//! Quantization substrate: schemes, codes, assignment policy, bit-packing.
//!
//! Rust mirror of `python/compile/{quant,assign}.py` — bit-exact on the same
//! inputs (the integration tests replay the manifest's default masks and
//! diff). The coordinator uses this module to (a) re-derive assignments from
//! on-device Hessian runs, (b) pack weights into the simulated FPGA BRAM
//! image, and (c) account ops per scheme for the performance model.

pub mod assign;
pub mod fixed;
pub mod freeze;
pub mod gemmview;
pub mod packing;
pub mod plan;
pub mod pot;
pub mod qgemm;

pub use assign::{assign_bits, assign_schemes, LayerMasks, MaskSet};
pub use gemmview::{from_gemm_rows, gemm_rows};
pub use packing::PackedMatrix;
pub use plan::{Provenance, QuantPlan, QuantSource};
pub use qgemm::QuantizedActs;

/// One weight row's quantization configuration (paper Figure 1: each filter
/// row carries a scheme + precision tag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// 4-bit symmetric uniform fixed-point (DSP lane, 2 MAC/DSP/cycle).
    Fixed4,
    /// 8-bit symmetric uniform fixed-point (DSP lane, 1 MAC/DSP/cycle).
    Fixed8,
    /// 4-bit power-of-two — multiplies become shifts (LUT lane).
    Pot4,
}

impl Scheme {
    pub fn bits(self) -> u32 {
        match self {
            Scheme::Fixed4 | Scheme::Pot4 => 4,
            Scheme::Fixed8 => 8,
        }
    }

    pub fn is_pot(self) -> bool {
        self == Scheme::Pot4
    }

    pub fn label(self) -> &'static str {
        match self {
            Scheme::Fixed4 => "Fixed-4",
            Scheme::Fixed8 => "Fixed-8",
            Scheme::Pot4 => "PoT-4",
        }
    }
}

/// PoT-4 : Fixed-4 : Fixed-8 percentage split (Table I, first column).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Ratio {
    pub pot4: f64,
    pub fixed4: f64,
    pub fixed8: f64,
}

impl Ratio {
    pub fn new(pot4: f64, fixed4: f64, fixed8: f64) -> Ratio {
        let r = Ratio { pot4, fixed4, fixed8 };
        assert!(
            (r.pot4 + r.fixed4 + r.fixed8 - 100.0).abs() < 1e-6,
            "ratio must sum to 100: {r:?}"
        );
        r
    }

    /// Parse "60:35:5".
    pub fn parse(s: &str) -> Result<Ratio, String> {
        let parts: Vec<f64> = s
            .split(':')
            .map(|p| p.trim().parse::<f64>().map_err(|e| format!("bad ratio {s:?}: {e}")))
            .collect::<Result<_, _>>()?;
        if parts.len() != 3 {
            return Err(format!("ratio must be P:F4:F8, got {s:?}"));
        }
        if (parts.iter().sum::<f64>() - 100.0).abs() > 1e-6 {
            return Err(format!("ratio must sum to 100, got {s:?}"));
        }
        Ok(Ratio::new(parts[0], parts[1], parts[2]))
    }

    pub fn frac8(&self) -> f64 {
        self.fixed8 / 100.0
    }

    /// Fraction of the 4-bit rows assigned PoT.
    pub fn pot_share_of_4bit(&self) -> f64 {
        let four = self.pot4 + self.fixed4;
        if four == 0.0 {
            0.0
        } else {
            self.pot4 / four
        }
    }

    pub fn label(&self) -> String {
        format!("{}:{}:{}", F(self.pot4), F(self.fixed4), F(self.fixed8))
    }
}

// `%g`-style float formatting shim (integers print without a fraction).
struct F(f64);
impl std::fmt::Display for F {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0.fract() == 0.0 {
            write!(f, "{}", self.0 as i64)
        } else {
            write!(f, "{}", self.0)
        }
    }
}

/// The named Table-I configurations.
pub fn named_ratios() -> Vec<(&'static str, Ratio)> {
    vec![
        ("fixed4", Ratio::new(0.0, 100.0, 0.0)),
        ("pot4", Ratio::new(100.0, 0.0, 0.0)),
        ("mixed_50_50", Ratio::new(50.0, 50.0, 0.0)),
        ("mixed_60_40", Ratio::new(60.0, 40.0, 0.0)),
        ("mixed_67_33", Ratio::new(67.0, 33.0, 0.0)),
        ("ilmpq1", Ratio::new(60.0, 35.0, 5.0)),
        ("ilmpq2", Ratio::new(65.0, 30.0, 5.0)),
    ]
}

pub fn ratio_by_name(name: &str) -> Option<Ratio> {
    named_ratios().into_iter().find(|(n, _)| *n == name).map(|(_, r)| r)
}

/// Per-row max-abs scale (the Python `quant.row_scale`).
pub fn row_scale(row: &[f32]) -> f32 {
    row.iter().fold(0f32, |m, &x| m.max(x.abs())).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_parse_roundtrip() {
        for s in ["60:35:5", "0:100:0", "100:0:0", "65:30:5"] {
            let r = Ratio::parse(s).unwrap();
            assert_eq!(r.label(), s);
        }
        assert!(Ratio::parse("60:35").is_err());
        assert!(Ratio::parse("60:35:10").is_err());
        assert!(Ratio::parse("a:b:c").is_err());
    }

    #[test]
    fn pot_share() {
        let r = Ratio::new(60.0, 35.0, 5.0);
        assert!((r.pot_share_of_4bit() - 60.0 / 95.0).abs() < 1e-12);
        assert!((r.frac8() - 0.05).abs() < 1e-12);
        assert_eq!(Ratio::new(0.0, 0.0, 100.0).pot_share_of_4bit(), 0.0);
    }

    #[test]
    fn scheme_bits() {
        assert_eq!(Scheme::Fixed4.bits(), 4);
        assert_eq!(Scheme::Fixed8.bits(), 8);
        assert_eq!(Scheme::Pot4.bits(), 4);
        assert!(Scheme::Pot4.is_pot());
        assert!(!Scheme::Fixed8.is_pot());
    }

    #[test]
    fn named_ratios_cover_table1() {
        let names: Vec<_> = named_ratios().iter().map(|(n, _)| *n).collect();
        assert!(names.contains(&"ilmpq1") && names.contains(&"ilmpq2"));
        assert_eq!(ratio_by_name("ilmpq2").unwrap().label(), "65:30:5");
        assert!(ratio_by_name("nope").is_none());
    }

    #[test]
    fn row_scale_is_maxabs() {
        assert_eq!(row_scale(&[-3.0, 2.0, 1.0]), 3.0);
        assert!(row_scale(&[0.0, 0.0]) > 0.0); // eps floor
    }
}
