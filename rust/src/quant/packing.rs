//! Bit-packing of quantized weights into the simulated FPGA BRAM image.
//!
//! On the real board, weights live in BRAM pre-quantized: 4-bit rows pack
//! two weights per byte, 8-bit rows one per byte, and each row carries one
//! f32 scale. This module produces that image (and unpacks it back), so the
//! memory model in `fpga/` can charge the *actual* quantized footprint and
//! the round-trip tests can assert pack ∘ unpack == fake-quant.
//!
//! Code conventions match `python/compile/kernels/quantize.py`:
//! fixed rows store the signed integer code, PoT rows store
//! `sign * (e + 1)` (0 = zero code) — both fit in a two's-complement nibble
//! for 4-bit schemes.

use super::{fixed, pot, LayerMasks, Scheme};

/// One packed weight matrix: per-row scheme tags, scales, and the bitstream.
#[derive(Debug, Clone)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub schemes: Vec<Scheme>,
    pub scales: Vec<f32>,
    /// Row-major packed codes: 4-bit rows use a nibble per weight (low
    /// nibble first), 8-bit rows a byte per weight. Rows are byte-aligned.
    pub data: Vec<u8>,
    /// Byte offset of each row in `data`.
    pub row_offsets: Vec<usize>,
}

fn nibble(code: i32) -> u8 {
    debug_assert!((-8..=7).contains(&code), "nibble overflow: {code}");
    (code as i8 as u8) & 0x0F
}

fn unnibble(n: u8) -> i32 {
    // Sign-extend the low nibble.
    ((n << 4) as i8 >> 4) as i32
}

/// Packed bytes one row occupies: a byte per code at 8 bits, a nibble per
/// code (byte-aligned row) at 4 bits.
pub fn row_byte_len(cols: usize, scheme: Scheme) -> usize {
    match scheme {
        Scheme::Fixed8 => cols,
        Scheme::Fixed4 | Scheme::Pot4 => cols.div_ceil(2),
    }
}

impl PackedMatrix {
    /// Quantize + pack a (rows, cols) GEMM-view matrix under `masks`.
    pub fn pack(w: &[Vec<f32>], masks: &LayerMasks) -> PackedMatrix {
        assert_eq!(w.len(), masks.rows(), "rows vs masks mismatch");
        let rows = w.len();
        let cols = if rows == 0 { 0 } else { w[0].len() };
        // Exact image size from the masks, so `data` never reallocates.
        let total: usize =
            (0..rows).map(|r| row_byte_len(cols, masks.scheme_of(r))).sum();
        let mut data = Vec::with_capacity(total);
        let mut row_offsets = Vec::with_capacity(rows);
        let mut schemes = Vec::with_capacity(rows);
        let mut scales = Vec::with_capacity(rows);
        for (r, row) in w.iter().enumerate() {
            assert_eq!(row.len(), cols, "ragged row {r}");
            let scheme = masks.scheme_of(r);
            let scale = super::row_scale(row);
            row_offsets.push(data.len());
            match scheme {
                Scheme::Fixed8 => {
                    for &v in row {
                        data.push(fixed::code(v, 8, scale) as i8 as u8);
                    }
                }
                Scheme::Fixed4 => {
                    for pair in row.chunks(2) {
                        let lo = nibble(fixed::code(pair[0], 4, scale));
                        let hi = if pair.len() > 1 {
                            nibble(fixed::code(pair[1], 4, scale))
                        } else {
                            0
                        };
                        data.push(lo | (hi << 4));
                    }
                }
                Scheme::Pot4 => {
                    for pair in row.chunks(2) {
                        let lo = nibble(pot::code(pair[0], 4, scale));
                        let hi = if pair.len() > 1 {
                            nibble(pot::code(pair[1], 4, scale))
                        } else {
                            0
                        };
                        data.push(lo | (hi << 4));
                    }
                }
            }
            schemes.push(scheme);
            scales.push(scale);
        }
        debug_assert_eq!(data.len(), total, "packed size prediction drifted");
        PackedMatrix { rows, cols, schemes, scales, data, row_offsets }
    }

    /// Scheme of one row.
    pub fn scheme(&self, r: usize) -> Scheme {
        self.schemes[r]
    }

    /// Per-row dequantization scale (max-abs of the source row).
    pub fn scale(&self, r: usize) -> f32 {
        self.scales[r]
    }

    /// The contiguous packed bytes of one row — what a compute kernel
    /// streams (`quant::qgemm` consumes these directly).
    pub fn row_bytes(&self, r: usize) -> &[u8] {
        let off = self.row_offsets[r];
        &self.data[off..off + row_byte_len(self.cols, self.schemes[r])]
    }

    /// Iterator over one row's integer codes (sign-extended; `cols` items).
    pub fn row_codes(&self, r: usize) -> RowCodes<'_> {
        RowCodes {
            bytes: self.row_bytes(r),
            cols: self.cols,
            i: 0,
            eight_bit: self.schemes[r] == Scheme::Fixed8,
        }
    }

    /// Dequantize one row back to f32 (must equal the fake-quant output).
    pub fn unpack_row(&self, r: usize) -> Vec<f32> {
        let scale = self.scales[r];
        match self.schemes[r] {
            Scheme::Fixed8 => {
                self.row_codes(r).map(|c| fixed::dequant(c, 8, scale)).collect()
            }
            Scheme::Fixed4 => {
                self.row_codes(r).map(|c| fixed::dequant(c, 4, scale)).collect()
            }
            Scheme::Pot4 => {
                self.row_codes(r).map(|c| pot::dequant(c, scale)).collect()
            }
        }
    }

    pub fn unpack(&self) -> Vec<Vec<f32>> {
        (0..self.rows).map(|r| self.unpack_row(r)).collect()
    }

    /// Packed weight bytes (the BRAM/DDR footprint the memory model charges).
    pub fn weight_bytes(&self) -> usize {
        self.data.len()
    }

    /// Total footprint including per-row scale + 1-byte scheme tag.
    pub fn total_bytes(&self) -> usize {
        self.data.len() + self.rows * (4 + 1)
    }

    /// Compression ratio vs f32 storage.
    pub fn compression_vs_f32(&self) -> f64 {
        (self.rows * self.cols * 4) as f64 / self.total_bytes().max(1) as f64
    }
}

/// Streaming decoder of one packed row's integer codes.
#[derive(Debug, Clone)]
pub struct RowCodes<'a> {
    bytes: &'a [u8],
    cols: usize,
    i: usize,
    eight_bit: bool,
}

impl Iterator for RowCodes<'_> {
    type Item = i32;

    fn next(&mut self) -> Option<i32> {
        if self.i >= self.cols {
            return None;
        }
        let c = if self.eight_bit {
            self.bytes[self.i] as i8 as i32
        } else {
            let byte = self.bytes[self.i / 2];
            unnibble(if self.i % 2 == 0 { byte & 0x0F } else { byte >> 4 })
        };
        self.i += 1;
        Some(c)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.cols - self.i;
        (left, Some(left))
    }
}

impl ExactSizeIterator for RowCodes<'_> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::assign::assign_uniform_layer;
    use crate::util::prop::{assert_close, ensure, forall};
    use crate::util::Rng;

    fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Vec<Vec<f32>> {
        (0..rows)
            .map(|_| (0..cols).map(|_| r.normal() * r.range_f32(0.1, 3.0)).collect())
            .collect()
    }

    fn random_masks(r: &mut Rng, rows: usize) -> LayerMasks {
        let is8: Vec<f32> = (0..rows).map(|_| if r.bool(0.2) { 1.0 } else { 0.0 }).collect();
        let is_pot: Vec<f32> = (0..rows)
            .map(|i| if is8[i] < 0.5 && r.bool(0.5) { 1.0 } else { 0.0 })
            .collect();
        LayerMasks { layer: "t".into(), is8, is_pot }
    }

    #[test]
    fn nibble_roundtrip() {
        for c in -8..=7 {
            assert_eq!(unnibble(nibble(c)), c, "code {c}");
        }
    }

    #[test]
    fn prop_pack_unpack_equals_fake_quant() {
        forall(
            41,
            64,
            |r| {
                let rows = r.range_usize(1, 20);
                let cols = r.range_usize(1, 33);
                (random_matrix(r, rows, cols), random_masks(r, rows))
            },
            |(w, masks)| {
                let packed = PackedMatrix::pack(w, masks);
                for (ri, row) in w.iter().enumerate() {
                    let got = packed.unpack_row(ri);
                    let scale = crate::quant::row_scale(row);
                    let want: Vec<f32> = match masks.scheme_of(ri) {
                        Scheme::Fixed8 => {
                            row.iter().map(|&v| fixed::fake_quant(v, 8, scale)).collect()
                        }
                        Scheme::Fixed4 => {
                            row.iter().map(|&v| fixed::fake_quant(v, 4, scale)).collect()
                        }
                        Scheme::Pot4 => {
                            row.iter().map(|&v| pot::fake_quant(v, 4, scale)).collect()
                        }
                    };
                    assert_close(&got, &want, 1e-6, &format!("row {ri}"))?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn footprint_4bit_half_of_8bit() {
        let mut r = Rng::new(5);
        let w = random_matrix(&mut r, 8, 64);
        let p4 = PackedMatrix::pack(&w, &assign_uniform_layer("l", 8, Scheme::Fixed4));
        let p8 = PackedMatrix::pack(&w, &assign_uniform_layer("l", 8, Scheme::Fixed8));
        assert_eq!(p4.weight_bytes() * 2, p8.weight_bytes());
        assert!(p4.compression_vs_f32() > 6.0); // ~8x minus scale overhead
    }

    #[test]
    fn odd_column_count_pads_per_row() {
        let mut r = Rng::new(6);
        let w = random_matrix(&mut r, 3, 7);
        let p = PackedMatrix::pack(&w, &assign_uniform_layer("l", 3, Scheme::Pot4));
        assert_eq!(p.weight_bytes(), 3 * 4); // ceil(7/2) = 4 bytes per row
        let u = p.unpack();
        assert_eq!(u[0].len(), 7);
    }

    #[test]
    fn row_bytes_and_codes_agree_with_unpack() {
        let mut r = Rng::new(9);
        let w = random_matrix(&mut r, 12, 9); // odd cols
        let masks = random_masks(&mut r, 12);
        let p = PackedMatrix::pack(&w, &masks);
        let mut total = 0usize;
        for ri in 0..p.rows {
            assert_eq!(p.row_bytes(ri).len(), row_byte_len(p.cols, p.scheme(ri)));
            total += p.row_bytes(ri).len();
            assert_eq!(p.row_codes(ri).len(), p.cols);
            // Codes re-dequantize to exactly the unpacked row.
            let scale = p.scale(ri);
            let via_codes: Vec<f32> = p
                .row_codes(ri)
                .map(|c| match p.scheme(ri) {
                    Scheme::Fixed8 => fixed::dequant(c, 8, scale),
                    Scheme::Fixed4 => fixed::dequant(c, 4, scale),
                    Scheme::Pot4 => pot::dequant(c, scale),
                })
                .collect();
            assert_eq!(via_codes, p.unpack_row(ri), "row {ri}");
        }
        // Rows tile `data` exactly: the preallocation in `pack` is exact.
        assert_eq!(total, p.data.len());
    }

    #[test]
    fn prop_compression_at_least_3x_for_ilmpq_mix() {
        forall(
            42,
            32,
            |r| {
                let rows = r.range_usize(8, 40);
                random_matrix(r, rows, 32)
            },
            |w| {
                let eigs: Vec<f64> = (0..w.len()).map(|i| i as f64).collect();
                let masks = crate::quant::assign::assign_layer(
                    "t",
                    w,
                    &eigs,
                    crate::quant::Ratio::new(60.0, 35.0, 5.0),
                );
                let p = PackedMatrix::pack(w, &masks);
                ensure(p.compression_vs_f32() > 3.0, || {
                    format!("compression {}", p.compression_vs_f32())
                })
            },
        );
    }
}
