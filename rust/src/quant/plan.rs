//! First-class quantization plans: the intra-layer precision assignment as
//! a named, versioned, serializable artifact.
//!
//! The per-row scheme assignment (paper §II-B/II-C: which rows of each
//! layer run PoT-4 / Fixed-4 / Fixed-8) *is* the ILMPQ contribution, and
//! MSP/FINN-R-style flows treat exactly this configuration as an explicit
//! artifact that travels from design-space exploration into deployment.
//! [`QuantPlan`] is that artifact for this stack: per-layer row masks plus
//! *provenance* (where the assignment came from), serialized as
//! dependency-free JSON via [`crate::util::Json`], validated against a
//! [`Manifest`] before anything executes it, and summarizable for
//! reporting (`ilmpq plan show`, `GET /v1/plan`).
//!
//! [`QuantSource`] is the single resolution path from "what the user asked
//! for" (a plan file, a named Table-I ratio, a fresh derivation, or
//! nothing) to a resolved plan — every consumer (`backend::create_serving`,
//! the `serve`/`loadgen`/`assign`/`train` CLI arms, the benches) goes
//! through [`QuantSource::resolve`] instead of re-plumbing the historic
//! `manifest.default_masks.get(name)` lookup.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::assign::{self, LayerMasks, MaskSet};
use super::gemmview::gemm_rows;
use super::Ratio;
use crate::runtime::{HostTensor, Manifest};
use crate::util::Json;

/// Serialization format version; bumped on incompatible schema changes so a
/// stale plan file fails with a clear message instead of misparsing.
pub const PLAN_VERSION: u64 = 1;

/// Where a plan's assignment came from — carried through serialization so a
/// deployed configuration stays auditable (`GET /v1/plan` reports it).
#[derive(Debug, Clone, PartialEq)]
pub enum Provenance {
    /// A named Table-I ratio resolved from the manifest's default
    /// assignment table (computed by `assign.py` at artifact build).
    NamedRatio { ratio: String },
    /// The winner of an offline `ratio-search` throughput sweep (§II-B).
    RatioSearch {
        device: String,
        ratio: String,
        throughput_gops: f64,
        latency_ms: f64,
    },
    /// Freshly derived by the §II-C policy: Hessian-eigenvalue rescue rows
    /// plus variance-sorted PoT, at the given ratio.
    Sensitivity { ratio: String },
    /// A uniform single-scheme baseline (Table-I prior-work rows).
    Uniform { scheme: String },
    /// The artifact-free synthetic fixture (random weights/eigs at a
    /// ratio, deterministic in `seed`). The seed is stored as a JSON
    /// number, so it must fit in 2^53 to round-trip exactly.
    Synthetic { seed: u64, ratio: String },
}

impl Provenance {
    /// The machine-readable `kind` tag used in serialized form.
    pub fn kind(&self) -> &'static str {
        match self {
            Provenance::NamedRatio { .. } => "named_ratio",
            Provenance::RatioSearch { .. } => "ratio_search",
            Provenance::Sensitivity { .. } => "sensitivity",
            Provenance::Uniform { .. } => "uniform",
            Provenance::Synthetic { .. } => "synthetic",
        }
    }

    /// One-line human description for reports and logs.
    pub fn describe(&self) -> String {
        match self {
            Provenance::NamedRatio { ratio } => format!("named ratio {ratio:?}"),
            Provenance::RatioSearch { device, ratio, throughput_gops, latency_ms } => {
                format!(
                    "ratio-search winner on {device} ({ratio} -> \
                     {throughput_gops:.1} GOP/s, {latency_ms:.1} ms)"
                )
            }
            Provenance::Sensitivity { ratio } => {
                format!("sensitivity-derived (§II-C policy at {ratio})")
            }
            Provenance::Uniform { scheme } => format!("uniform {scheme} baseline"),
            Provenance::Synthetic { seed, ratio } => {
                format!("synthetic fixture (seed {seed}, ratio {ratio})")
            }
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![("kind", Json::Str(self.kind().to_string()))];
        match self {
            Provenance::NamedRatio { ratio } => {
                fields.push(("ratio", Json::Str(ratio.clone())));
            }
            Provenance::RatioSearch { device, ratio, throughput_gops, latency_ms } => {
                fields.push(("device", Json::Str(device.clone())));
                fields.push(("ratio", Json::Str(ratio.clone())));
                fields.push(("throughput_gops", Json::Num(*throughput_gops)));
                fields.push(("latency_ms", Json::Num(*latency_ms)));
            }
            Provenance::Sensitivity { ratio } => {
                fields.push(("ratio", Json::Str(ratio.clone())));
            }
            Provenance::Uniform { scheme } => {
                fields.push(("scheme", Json::Str(scheme.clone())));
            }
            Provenance::Synthetic { seed, ratio } => {
                fields.push(("seed", Json::Num(*seed as f64)));
                fields.push(("ratio", Json::Str(ratio.clone())));
            }
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Provenance> {
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("provenance lacks a \"kind\" string"))?;
        let s = |key: &str| -> Result<String> {
            j.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("provenance {kind:?} lacks string field {key:?}"))
        };
        let n = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("provenance {kind:?} lacks numeric field {key:?}"))
        };
        Ok(match kind {
            "named_ratio" => Provenance::NamedRatio { ratio: s("ratio")? },
            "ratio_search" => Provenance::RatioSearch {
                device: s("device")?,
                ratio: s("ratio")?,
                throughput_gops: n("throughput_gops")?,
                latency_ms: n("latency_ms")?,
            },
            "sensitivity" => Provenance::Sensitivity { ratio: s("ratio")? },
            "uniform" => Provenance::Uniform { scheme: s("scheme")? },
            "synthetic" => {
                // Same strictness as the version field: a fractional or
                // negative seed in a hand-edited file must not silently
                // truncate into a seed that doesn't reproduce the masks.
                let seed = n("seed")?;
                if seed.fract() != 0.0 || seed < 0.0 {
                    bail!("synthetic seed must be a non-negative integer, got {seed}");
                }
                Provenance::Synthetic { seed: seed as u64, ratio: s("ratio")? }
            }
            other => bail!(
                "unknown provenance kind {other:?} (known: named_ratio, \
                 ratio_search, sensitivity, uniform, synthetic)"
            ),
        })
    }
}

/// A named, versioned precision-assignment artifact: per-layer row masks
/// plus provenance. Save/load round-trips are bit-identical on the masks
/// (mask values are exactly 0.0/1.0, which JSON represents exactly).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantPlan {
    pub name: String,
    /// Format version ([`PLAN_VERSION`] at creation).
    pub version: u64,
    /// The model the plan was derived for (empty = unstated). When set,
    /// [`QuantPlan::validate`] refuses a manifest for a different model.
    pub model: String,
    pub provenance: Provenance,
    /// The assignment itself (`masks.name` mirrors the plan name).
    pub masks: MaskSet,
}

impl QuantPlan {
    /// Wrap an existing mask set; the plan takes the mask set's name.
    pub fn from_mask_set(masks: MaskSet, provenance: Provenance) -> QuantPlan {
        QuantPlan {
            name: masks.name.clone(),
            version: PLAN_VERSION,
            model: String::new(),
            provenance,
            masks,
        }
    }

    /// Builder-style model stamp (see [`QuantPlan::model`]).
    pub fn with_model(mut self, model: &str) -> QuantPlan {
        self.model = model.to_string();
        self
    }

    // ---- serialization ----------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("quant_plan", Json::Num(self.version as f64)),
            ("name", Json::Str(self.name.clone())),
            ("model", Json::Str(self.model.clone())),
            ("provenance", self.provenance.to_json()),
            ("layers", self.layers_json()),
        ])
    }

    /// The per-layer mask array in serialized form — the part of the plan
    /// that actually changes logits.
    fn layers_json(&self) -> Json {
        Json::Arr(
            self.masks
                .layers
                .iter()
                .map(|l| {
                    Json::obj(vec![
                        ("layer", Json::Str(l.layer.clone())),
                        ("is8", mask_json(&l.is8)),
                        ("is_pot", mask_json(&l.is_pot)),
                    ])
                })
                .collect(),
        )
    }

    /// Content identity of the plan: the SHA-256 of its canonical compact
    /// JSON with `name` and `provenance` excluded. Two plans that assign
    /// the same masks to the same model compare equal no matter what they
    /// are called or where they came from — this is the digest the pool
    /// records on hot-swap and the serving endpoints report.
    /// (`Json` is BTreeMap-backed, so `to_string_compact` is canonical.)
    pub fn content_digest(&self) -> crate::artifact::Digest {
        let canonical = Json::obj(vec![
            ("quant_plan", Json::Num(self.version as f64)),
            ("model", Json::Str(self.model.clone())),
            ("layers", self.layers_json()),
        ]);
        crate::artifact::Digest::of(canonical.to_string_compact().as_bytes())
    }

    /// Strict parse: every structural problem is a typed error naming the
    /// offending field, never a panic (plan files are user input).
    pub fn from_json(j: &Json) -> Result<QuantPlan> {
        let v = j
            .get("quant_plan")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("not a quantization plan (no \"quant_plan\" version field)"))?;
        if v.fract() != 0.0 || v < 0.0 {
            bail!("plan version must be a non-negative integer, got {v}");
        }
        let version = v as u64;
        if version != PLAN_VERSION {
            bail!("plan format version {version} unsupported (this build reads version {PLAN_VERSION})");
        }
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("plan lacks a \"name\" string"))?
            .to_string();
        let model = j
            .get("model")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let provenance = Provenance::from_json(
            j.get("provenance")
                .ok_or_else(|| anyhow!("plan lacks a \"provenance\" object"))?,
        )?;
        let layers_json = j
            .get("layers")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("plan lacks a \"layers\" array"))?;
        let mut layers = Vec::with_capacity(layers_json.len());
        for (i, lj) in layers_json.iter().enumerate() {
            let lname = lj
                .get("layer")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("layers[{i}] lacks a \"layer\" name"))?
                .to_string();
            let is8 = mask_from_json(lj.get("is8"), &lname, "is8")?;
            let is_pot = mask_from_json(lj.get("is_pot"), &lname, "is_pot")?;
            if is8.len() != is_pot.len() {
                bail!(
                    "layer {lname:?}: is8 has {} rows but is_pot has {}",
                    is8.len(),
                    is_pot.len()
                );
            }
            layers.push(LayerMasks { layer: lname, is8, is_pot });
        }
        Ok(QuantPlan {
            name: name.clone(),
            version,
            model,
            provenance,
            masks: MaskSet { name, layers },
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_compact())
            .with_context(|| format!("write plan {path:?}"))
    }

    pub fn load(path: &Path) -> Result<QuantPlan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read plan {path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        QuantPlan::from_json(&j).with_context(|| format!("parse plan {path:?}"))
    }

    // ---- validation -------------------------------------------------------

    /// Check the plan fits `manifest`: same model (when the plan states
    /// one), exactly the manifest's quantized layers **in manifest order**
    /// (the FPGA-sim overlay consumes layers positionally, so a reordered
    /// plan would silently mistime every layer even though the name-keyed
    /// pack/freeze paths would execute it correctly), matching row counts,
    /// 0/1 mask values, and scheme exclusivity (no row both Fixed-8 and
    /// PoT). Everything that executes a plan calls this first, so a stale
    /// or hand-edited file fails loudly before it can corrupt a pack.
    pub fn validate(&self, manifest: &Manifest) -> Result<()> {
        let ctx = |msg: String| anyhow!("plan {:?}: {msg}", self.name);
        if !self.model.is_empty() && self.model != manifest.model_name {
            return Err(ctx(format!(
                "built for model {:?} but the manifest is {:?}",
                self.model, manifest.model_name
            )));
        }
        if self.masks.layers.len() != manifest.quantized_layers.len() {
            return Err(ctx(format!(
                "has {} layers but the manifest has {} quantized layers",
                self.masks.layers.len(),
                manifest.quantized_layers.len()
            )));
        }
        for ((lname, rows, _), lm) in
            manifest.quantized_layers.iter().zip(&self.masks.layers)
        {
            if &lm.layer != lname {
                return Err(ctx(format!(
                    "layer mismatch at the manifest's {lname:?} position: plan \
                     has {:?} (layers must cover the manifest's quantized \
                     layers in manifest order)",
                    lm.layer
                )));
            }
            // `rows()` measures is8 and the per-row zip below truncates to
            // the shorter vector, so a ragged pair must be caught here —
            // otherwise `scheme_of` indexes out of bounds mid-traffic.
            if lm.is8.len() != lm.is_pot.len() {
                return Err(ctx(format!(
                    "layer {lname:?}: is8 has {} rows but is_pot has {}",
                    lm.is8.len(),
                    lm.is_pot.len()
                )));
            }
            if lm.rows() != *rows {
                return Err(ctx(format!(
                    "layer {lname:?} has {} rows, manifest expects {rows}",
                    lm.rows()
                )));
            }
            for (i, (&a, &b)) in lm.is8.iter().zip(&lm.is_pot).enumerate() {
                if (a != 0.0 && a != 1.0) || (b != 0.0 && b != 1.0) {
                    return Err(ctx(format!(
                        "layer {lname:?} row {i}: mask values must be 0 or 1 \
                         (got is8={a}, is_pot={b})"
                    )));
                }
                if a > 0.5 && b > 0.5 {
                    return Err(ctx(format!(
                        "layer {lname:?} row {i}: marked both Fixed-8 and PoT \
                         (schemes are exclusive per row)"
                    )));
                }
            }
        }
        Ok(())
    }

    // ---- summaries --------------------------------------------------------

    /// `(pot4, fixed4, fixed8)` op fractions per layer, in plan order.
    pub fn layer_fractions(&self) -> Vec<(String, (f64, f64, f64))> {
        self.masks
            .layers
            .iter()
            .map(|l| (l.layer.clone(), l.op_fractions()))
            .collect()
    }

    /// Aggregate `(pot4, fixed4, fixed8)` fractions over all rows.
    pub fn total_fractions(&self) -> (f64, f64, f64) {
        self.masks.total_fractions()
    }

    /// The monitoring view (`GET /v1/plan`, `plan show --json` consumers):
    /// name, provenance, and per-layer + total scheme fractions.
    pub fn summary_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("version", Json::Num(self.version as f64)),
            ("model", Json::Str(self.model.clone())),
            ("digest", Json::Str(self.content_digest().to_hex())),
            ("provenance", self.provenance.to_json()),
            ("total", fractions_json(self.total_fractions())),
            (
                "layers",
                Json::Arr(
                    self.masks
                        .layers
                        .iter()
                        .map(|l| {
                            let (p, f4, f8) = l.op_fractions();
                            Json::obj(vec![
                                ("layer", Json::Str(l.layer.clone())),
                                ("rows", Json::Num(l.rows() as f64)),
                                ("pot4", Json::Num(p)),
                                ("fixed4", Json::Num(f4)),
                                ("fixed8", Json::Num(f8)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable multi-line report for the CLI.
    pub fn report(&self) -> String {
        let (p, f4, f8) = self.total_fractions();
        let mut s = format!(
            "plan {:?} (v{}{})\n  provenance: {}\n  total row mix: \
             {:.1}% PoT-4 / {:.1}% Fixed-4 / {:.1}% Fixed-8\n",
            self.name,
            self.version,
            if self.model.is_empty() {
                String::new()
            } else {
                format!(", model {}", self.model)
            },
            self.provenance.describe(),
            p * 100.0,
            f4 * 100.0,
            f8 * 100.0
        );
        for l in &self.masks.layers {
            let (lp, lf4, lf8) = l.op_fractions();
            s.push_str(&format!(
                "  {:<12} {:>4} rows  {:>5.1}% PoT  {:>5.1}% F4  {:>5.1}% F8\n",
                l.layer,
                l.rows(),
                lp * 100.0,
                lf4 * 100.0,
                lf8 * 100.0
            ));
        }
        s
    }
}

/// `{"pot4": p, "fixed4": f4, "fixed8": f8}`.
fn fractions_json((p, f4, f8): (f64, f64, f64)) -> Json {
    Json::obj(vec![
        ("pot4", Json::Num(p)),
        ("fixed4", Json::Num(f4)),
        ("fixed8", Json::Num(f8)),
    ])
}

fn mask_json(mask: &[f32]) -> Json {
    Json::Arr(mask.iter().map(|&v| Json::Num(v as f64)).collect())
}

fn mask_from_json(j: Option<&Json>, layer: &str, field: &str) -> Result<Vec<f32>> {
    let arr = j
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("layer {layer:?} lacks a numeric {field:?} array"))?;
    arr.iter()
        .enumerate()
        .map(|(i, v)| {
            let x = v
                .as_f64()
                .ok_or_else(|| anyhow!("layer {layer:?} {field}[{i}] is not a number"))?;
            if x != 0.0 && x != 1.0 {
                bail!("layer {layer:?} {field}[{i}] must be 0 or 1, got {x}");
            }
            Ok(x as f32)
        })
        .collect()
}

/// The canonical name of a freshly-derived plan at `ratio` — the one
/// spelling shared by `QuantSource::Derived` resolution (artifacts and
/// synthetic paths) and `ilmpq plan derive`'s default, so a derived plan
/// carries the same name however it was produced.
pub fn derived_plan_name(ratio: Ratio) -> String {
    format!("derived-{}", ratio.label())
}

/// Parse a ratio argument as either a Table-I name (`ilmpq2`) or an
/// explicit `P:F4:F8` split — the shared `--ratio` semantics of
/// `ilmpq plan derive` and `ratio-search`.
pub fn parse_ratio_arg(s: &str) -> Result<Ratio> {
    if let Some(r) = super::ratio_by_name(s) {
        return Ok(r);
    }
    Ratio::parse(s).map_err(|e| {
        let names: Vec<&str> = super::named_ratios().iter().map(|(n, _)| *n).collect();
        anyhow!("{e}; named ratios: {}", names.join(", "))
    })
}

/// Derive a plan from a manifest via the §II-C policy: the manifest's
/// Hessian eigenvalues pick the Fixed-8 rescue rows, weight-row variance
/// sorts the PoT share. `params` must be in AOT order (normally
/// [`Manifest::load_init_params`], or trained weights).
pub fn derive_from_manifest(
    m: &Manifest,
    params: &[HostTensor],
    ratio: Ratio,
    name: &str,
) -> Result<QuantPlan> {
    let mut layers = Vec::with_capacity(m.quantized_layers.len());
    for (lname, rows, _) in &m.quantized_layers {
        let idx = m
            .params
            .iter()
            .position(|(n, _)| n == lname)
            .ok_or_else(|| anyhow!("no parameter tensor for quantized layer {lname:?}"))?;
        let w_rows = gemm_rows(&params[idx]);
        let eigs = m.eigs.get(lname).ok_or_else(|| {
            anyhow!(
                "manifest has no Hessian eigenvalues for layer {lname:?} — \
                 cannot derive a plan (re-run `make artifacts`, or use \
                 --synthetic for the artifact-free fixture)"
            )
        })?;
        anyhow::ensure!(
            w_rows.len() == *rows && eigs.len() == *rows,
            "layer {lname:?}: {} weight rows / {} eigenvalues vs manifest {rows}",
            w_rows.len(),
            eigs.len()
        );
        layers.push(assign::assign_layer(lname, &w_rows, eigs, ratio));
    }
    Ok(QuantPlan {
        name: name.to_string(),
        version: PLAN_VERSION,
        model: m.model_name.clone(),
        provenance: Provenance::Sensitivity { ratio: ratio.label() },
        masks: MaskSet { name: name.to_string(), layers },
    })
}

/// What the user asked to quantize with — the single resolution path that
/// replaces the historic triplicated
/// `str_or("ratio", ...) -> default_masks.get(name)` lookup.
#[derive(Debug, Clone, PartialEq)]
pub enum QuantSource {
    /// Load (and validate against the manifest) a serialized plan file.
    PlanFile(PathBuf),
    /// A named plan from the manifest's default assignment table.
    NamedRatio(String),
    /// Derive fresh via the §II-C policy at this ratio (needs the
    /// manifest's eigenvalues + init params).
    Derived { ratio: Ratio },
    /// No quantization config (the unquantized reference path).
    Unquantized,
}

impl QuantSource {
    /// The one mapping from CLI flags to a source, shared by every binary
    /// (`ilmpq` and the examples): `--plan FILE` | `--ratio NAME` |
    /// `--derive RATIO` (name or `P:F4:F8`), mutually exclusive, with a
    /// named default when none is given.
    pub fn from_cli(
        plan: Option<&str>,
        ratio: Option<&str>,
        derive: Option<&str>,
        default_ratio: &str,
    ) -> Result<QuantSource> {
        match (plan, ratio, derive) {
            (Some(p), None, None) => Ok(QuantSource::PlanFile(PathBuf::from(p))),
            (None, Some(r), None) => Ok(QuantSource::NamedRatio(r.to_string())),
            (None, None, Some(d)) => {
                Ok(QuantSource::Derived { ratio: parse_ratio_arg(d)? })
            }
            (None, None, None) => {
                Ok(QuantSource::NamedRatio(default_ratio.to_string()))
            }
            _ => bail!(
                "--plan, --ratio, and --derive are mutually exclusive; pass at most one"
            ),
        }
    }

    /// One-line description for logs.
    pub fn describe(&self) -> String {
        match self {
            QuantSource::PlanFile(p) => format!("plan file {p:?}"),
            QuantSource::NamedRatio(n) => format!("named ratio {n:?}"),
            QuantSource::Derived { ratio } => format!("derive at {}", ratio.label()),
            QuantSource::Unquantized => "unquantized".to_string(),
        }
    }

    /// Resolve to a validated plan. `Unquantized` yields `None`; every
    /// other variant yields `Some` or a curated error (unknown names list
    /// the available plans, like `backend::registry` does for backends).
    pub fn resolve(&self, m: &Manifest) -> Result<Option<QuantPlan>> {
        match self {
            QuantSource::Unquantized => Ok(None),
            QuantSource::NamedRatio(name) => Ok(Some(m.plan(name)?)),
            QuantSource::PlanFile(path) => {
                let plan = QuantPlan::load(path)?;
                plan.validate(m)?;
                Ok(Some(plan))
            }
            QuantSource::Derived { ratio } => {
                let params = m
                    .load_init_params()
                    .context("deriving a plan needs the manifest's init params")?;
                self.resolve_with_params(m, &params)
            }
        }
    }

    /// As [`QuantSource::resolve`], but with already-loaded params — so a
    /// caller that needs the params anyway (backend construction) doesn't
    /// pay a second full weight load from disk for the `Derived` case.
    pub fn resolve_with_params(
        &self,
        m: &Manifest,
        params: &[HostTensor],
    ) -> Result<Option<QuantPlan>> {
        match self {
            QuantSource::Derived { ratio } => Ok(Some(derive_from_manifest(
                m,
                params,
                *ratio,
                &derived_plan_name(*ratio),
            )?)),
            other => other.resolve(m),
        }
    }

    /// [`QuantSource::resolve`] for contexts that cannot run unquantized.
    pub fn resolve_required(&self, m: &Manifest) -> Result<QuantPlan> {
        self.resolve(m)?.ok_or_else(|| {
            anyhow!("this path needs a quantization plan; pass --ratio NAME or --plan FILE")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::synth;
    use crate::quant::Scheme;
    use crate::util::Rng;

    fn fixture() -> (Manifest, QuantPlan) {
        let mut rng = Rng::new(3);
        let m = synth::tiny_manifest(8, 8, 3, &[4, 8], 5);
        let masks = synth::random_masks(&m, Ratio::new(65.0, 30.0, 5.0), &mut rng);
        let plan = QuantPlan::from_mask_set(
            MaskSet { name: "t".into(), layers: masks.layers },
            Provenance::Synthetic { seed: 3, ratio: "65:30:5".into() },
        )
        .with_model(&m.model_name);
        (m, plan)
    }

    #[test]
    fn json_roundtrip_is_identity() {
        let (_, plan) = fixture();
        let text = plan.to_json().to_string_compact();
        let back = QuantPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, plan, "plan JSON round-trip must be bit-identical");
    }

    #[test]
    fn provenance_kinds_roundtrip() {
        for p in [
            Provenance::NamedRatio { ratio: "ilmpq2".into() },
            Provenance::RatioSearch {
                device: "xc7z045".into(),
                ratio: "65:30:5".into(),
                throughput_gops: 421.1,
                latency_ms: 8.6,
            },
            Provenance::Sensitivity { ratio: "60:35:5".into() },
            Provenance::Uniform { scheme: "Fixed-8".into() },
            Provenance::Synthetic { seed: 42, ratio: "65:30:5".into() },
        ] {
            let back = Provenance::from_json(&p.to_json()).unwrap();
            assert_eq!(back, p);
            assert!(!p.describe().is_empty());
        }
        assert!(Provenance::from_json(&Json::obj(vec![(
            "kind",
            Json::Str("martian".into())
        )]))
        .is_err());
    }

    #[test]
    fn from_json_rejects_structural_garbage() {
        for (text, what) in [
            (r#"{"name": "x"}"#, "missing version"),
            (r#"{"quant_plan": 99, "name": "x"}"#, "future version"),
            (r#"{"quant_plan": 1.5, "name": "x"}"#, "fractional version"),
            (r#"{"quant_plan": -1, "name": "x"}"#, "negative version"),
            (
                r#"{"quant_plan": 1, "name": "x", "provenance": {"kind": "uniform", "scheme": "s"}, "layers": [{"layer": "l", "is8": [0.5], "is_pot": [0]}]}"#,
                "non-binary mask value",
            ),
            (
                r#"{"quant_plan": 1, "name": "x", "provenance": {"kind": "uniform", "scheme": "s"}, "layers": [{"layer": "l", "is8": [0, 1], "is_pot": [0]}]}"#,
                "mask length mismatch",
            ),
        ] {
            let j = Json::parse(text).unwrap();
            assert!(QuantPlan::from_json(&j).is_err(), "{what} must be rejected");
        }
    }

    #[test]
    fn validate_accepts_the_matching_manifest() {
        let (m, plan) = fixture();
        plan.validate(&m).unwrap();
    }

    #[test]
    fn validate_rejects_wrong_layer_names_rows_overlap_and_model() {
        let (m, good) = fixture();

        let mut p = good.clone();
        p.masks.layers[0].layer = "not-a-layer".into();
        let err = p.validate(&m).unwrap_err();
        assert!(format!("{err:#}").contains("layer mismatch"), "{err:#}");

        // Same layers, wrong order: the sim overlay is positional, so a
        // reordered plan must be rejected, not silently mistimed.
        let mut p = good.clone();
        p.masks.layers.swap(0, 1);
        let err = p.validate(&m).unwrap_err();
        assert!(format!("{err:#}").contains("manifest order"), "{err:#}");

        let mut p = good.clone();
        p.masks.layers[0].is8.push(0.0);
        p.masks.layers[0].is_pot.push(0.0);
        let err = p.validate(&m).unwrap_err();
        assert!(format!("{err:#}").contains("rows"), "{err:#}");

        // Ragged is8/is_pot: rows() only measures is8 and the value loop
        // zips (truncating), so the length check must catch this.
        let mut p = good.clone();
        p.masks.layers[0].is_pot.pop();
        let err = p.validate(&m).unwrap_err();
        assert!(format!("{err:#}").contains("is_pot"), "{err:#}");

        let mut p = good.clone();
        p.masks.layers[0].is8[0] = 1.0;
        p.masks.layers[0].is_pot[0] = 1.0;
        let err = p.validate(&m).unwrap_err();
        assert!(format!("{err:#}").contains("exclusive"), "{err:#}");

        let mut p = good.clone();
        p.masks.layers.pop();
        let err = p.validate(&m).unwrap_err();
        assert!(format!("{err:#}").contains("layers"), "{err:#}");

        let p = good.with_model("resnet-152");
        let err = p.validate(&m).unwrap_err();
        assert!(format!("{err:#}").contains("model"), "{err:#}");
    }

    #[test]
    fn file_roundtrip_preserves_masks_bit_exactly() {
        let (m, plan) = fixture();
        let dir = std::env::temp_dir().join("ilmpq_plan_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.json");
        plan.save(&path).unwrap();
        let back = QuantPlan::load(&path).unwrap();
        assert_eq!(back, plan);
        back.validate(&m).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn source_resolution_named_file_derived_unquantized() {
        let (mut m, plan) = fixture();
        // Named: registered plans resolve; unknown names list what exists.
        m.default_masks.insert("reg".into(), plan.masks.clone());
        let named = QuantSource::NamedRatio("reg".into())
            .resolve(&m)
            .unwrap()
            .unwrap();
        assert_eq!(named.masks.layers, plan.masks.layers);
        let err = QuantSource::NamedRatio("nope".into()).resolve(&m).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("reg") && msg.contains("nope"), "{msg}");

        // File: load + validate.
        let dir = std::env::temp_dir().join("ilmpq_plan_src");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.json");
        plan.save(&path).unwrap();
        let from_file = QuantSource::PlanFile(path.clone())
            .resolve(&m)
            .unwrap()
            .unwrap();
        assert_eq!(from_file.masks, plan.masks);
        std::fs::remove_dir_all(&dir).ok();

        // Unquantized: no plan, and resolve_required refuses.
        assert!(QuantSource::Unquantized.resolve(&m).unwrap().is_none());
        assert!(QuantSource::Unquantized.resolve_required(&m).is_err());
    }

    #[test]
    fn content_digest_survives_save_load_and_ignores_identity() {
        let (_, plan) = fixture();
        let digest = plan.content_digest();

        // derive→save→load preserves the digest bit-exactly.
        let dir = std::env::temp_dir().join("ilmpq_plan_digest");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("p.json");
        plan.save(&path).unwrap();
        let back = QuantPlan::load(&path).unwrap();
        assert_eq!(back.content_digest(), digest);
        std::fs::remove_dir_all(&dir).ok();

        // Renaming the plan or rewriting its provenance leaves the
        // content identity unchanged.
        let mut renamed = plan.clone();
        renamed.name = "an-entirely-different-name".into();
        renamed.provenance = Provenance::Uniform { scheme: "Fixed-8".into() };
        assert_eq!(renamed.content_digest(), digest);

        // Flipping one mask row changes it.
        let mut flipped = plan.clone();
        let row = &mut flipped.masks.layers[0];
        let was_f8 = row.is8[0] > 0.5;
        row.is8[0] = if was_f8 { 0.0 } else { 1.0 };
        row.is_pot[0] = 0.0;
        assert_ne!(flipped.content_digest(), digest);

        // And the summary reports it.
        let j = plan.summary_json();
        assert_eq!(j.get("digest").and_then(Json::as_str), Some(digest.to_hex().as_str()));
    }

    #[test]
    fn summary_fractions_sum_to_one() {
        let (_, plan) = fixture();
        let (p, f4, f8) = plan.total_fractions();
        assert!((p + f4 + f8 - 1.0).abs() < 1e-12);
        let j = plan.summary_json();
        assert_eq!(j.get("name").and_then(Json::as_str), Some("t"));
        let total = j.get("total").unwrap();
        let jp = total.get("pot4").and_then(Json::as_f64).unwrap();
        assert!((jp - p).abs() < 1e-12);
        assert_eq!(
            j.get("layers").and_then(Json::as_arr).unwrap().len(),
            plan.masks.layers.len()
        );
        assert!(plan.report().contains("total row mix"));
    }

    #[test]
    fn uniform_plan_fractions_are_pure() {
        let m = synth::tiny_manifest(8, 8, 3, &[4], 5);
        let plan = QuantPlan::from_mask_set(
            synth::uniform_masks(&m, Scheme::Pot4),
            Provenance::Uniform { scheme: Scheme::Pot4.label().into() },
        );
        assert_eq!(plan.total_fractions().0, 1.0);
        plan.validate(&m).unwrap();
    }

    #[test]
    fn from_cli_maps_flags_to_sources_exclusively() {
        assert_eq!(
            QuantSource::from_cli(Some("p.json"), None, None, "ilmpq2").unwrap(),
            QuantSource::PlanFile("p.json".into())
        );
        assert_eq!(
            QuantSource::from_cli(None, Some("pot4"), None, "ilmpq2").unwrap(),
            QuantSource::NamedRatio("pot4".into())
        );
        assert_eq!(
            QuantSource::from_cli(None, None, Some("60:35:5"), "ilmpq2").unwrap(),
            QuantSource::Derived { ratio: Ratio::new(60.0, 35.0, 5.0) }
        );
        assert_eq!(
            QuantSource::from_cli(None, None, Some("ilmpq1"), "ilmpq2").unwrap(),
            QuantSource::Derived { ratio: Ratio::new(60.0, 35.0, 5.0) }
        );
        assert_eq!(
            QuantSource::from_cli(None, None, None, "ilmpq2").unwrap(),
            QuantSource::NamedRatio("ilmpq2".into())
        );
        for (p, r, d) in [
            (Some("f"), Some("r"), None),
            (Some("f"), None, Some("60:35:5")),
            (None, Some("r"), Some("60:35:5")),
        ] {
            let err = QuantSource::from_cli(p, r, d, "ilmpq2").unwrap_err();
            assert!(format!("{err:#}").contains("exclusive"), "{err:#}");
        }
    }

    #[test]
    fn ratio_arg_parses_names_and_splits() {
        assert_eq!(parse_ratio_arg("ilmpq2").unwrap().label(), "65:30:5");
        assert_eq!(parse_ratio_arg("60:35:5").unwrap().label(), "60:35:5");
        let err = parse_ratio_arg("bogus").unwrap_err();
        assert!(format!("{err:#}").contains("ilmpq2"), "{err:#}");
    }

    #[test]
    fn derive_from_manifest_needs_eigs() {
        // The synthetic manifest carries no eigs: derive must say so
        // instead of panicking or silently assigning.
        let mut rng = Rng::new(5);
        let m = synth::tiny_manifest(8, 8, 3, &[4], 5);
        let params = synth::random_params(&m, &mut rng);
        let err =
            derive_from_manifest(&m, &params, Ratio::new(65.0, 30.0, 5.0), "d").unwrap_err();
        assert!(format!("{err:#}").contains("eigenvalues"), "{err:#}");
    }
}
