//! Power-of-Two (PoT) quantizer — multiplications become shifts.
//!
//! Bit-exact mirror of `python/compile/quant.py::quantize_pot` /
//! `pot_codes`. With `b` bits the levels are `{0} ∪ {± scale * 2^-e}` for
//! `e in [0, 2^(b-1) - 2]` (4-bit: e in [0, 6]); the exponent is the nearest
//! integer to `-log2(|w|/scale)` and magnitudes below `2^-(emax + 0.5)` take
//! the zero code. Code convention (shared with the Python kernels and the
//! packer): `0` is zero, otherwise `sign * (e + 1)`.

/// Largest exponent for a bit width (4-bit -> 6).
pub fn emax(bits: u32) -> i32 {
    (1i32 << (bits - 1)) - 2
}

/// PoT code for one weight: 0, or `sign * (e + 1)` with e in [0, emax].
pub fn code(w: f32, bits: u32, scale: f32) -> i32 {
    let em = emax(bits);
    let wn = w / scale;
    let mag = wn.abs();
    if mag < (2f32).powf(-(em as f32 + 0.5)) {
        return 0;
    }
    let e = (-(mag.max(1e-12).log2())).round().clamp(0.0, em as f32) as i32;
    if wn < 0.0 {
        -(e + 1)
    } else {
        e + 1
    }
}

/// Dequantize a PoT code.
pub fn dequant(code: i32, scale: f32) -> f32 {
    if code == 0 {
        return 0.0;
    }
    let e = code.abs() - 1;
    let mag = (2f32).powi(-e) * scale;
    if code < 0 {
        -mag
    } else {
        mag
    }
}

/// Fake-quant one value.
pub fn fake_quant(w: f32, bits: u32, scale: f32) -> f32 {
    dequant(code(w, bits, scale), scale)
}

/// Fake-quant a whole row with its own max-abs scale.
pub fn fake_quant_row(row: &[f32], bits: u32) -> Vec<f32> {
    let s = super::row_scale(row);
    row.iter().map(|&w| fake_quant(w, bits, s)).collect()
}

/// Relative quantization step around a magnitude — PoT's pitch: resolution
/// is *relative* (dense near zero), vs fixed-point's absolute step. Used by
/// the ablation bench to show why low-variance rows prefer PoT.
pub fn relative_step_at(mag_over_scale: f32) -> f32 {
    // Between levels 2^-e and 2^-(e+1) the gap is 2^-(e+1), i.e. half the
    // larger level: relative step ~ 0.5 at every scale.
    if mag_over_scale <= 0.0 {
        0.0
    } else {
        0.5 * mag_over_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{ensure, forall};

    #[test]
    fn emax_for_4bit_is_6() {
        assert_eq!(emax(4), 6);
        assert_eq!(emax(3), 2);
    }

    #[test]
    fn known_codes() {
        // scale 1: 1.0 -> e=0 -> code 1; 0.5 -> e=1 -> code 2; -0.25 -> -3.
        assert_eq!(code(1.0, 4, 1.0), 1);
        assert_eq!(code(0.5, 4, 1.0), 2);
        assert_eq!(code(-0.25, 4, 1.0), -3);
        assert_eq!(code(0.0, 4, 1.0), 0);
        // Below the deadzone threshold 2^-6.5 ~ 0.011.
        assert_eq!(code(0.005, 4, 1.0), 0);
    }

    #[test]
    fn dequant_levels_are_powers_of_two() {
        for c in 1..=7 {
            let v = dequant(c, 1.0);
            assert_eq!(v, (2f32).powi(-(c - 1)));
            assert_eq!(dequant(-c, 1.0), -v);
        }
        assert_eq!(dequant(0, 1.0), 0.0);
    }

    #[test]
    fn prop_output_is_exact_pot_level() {
        forall(
            21,
            512,
            |r| (r.normal() * 2.0, r.range_f32(0.3, 5.0)),
            |&(w, scale)| {
                let q = fake_quant(w, 4, scale);
                if q == 0.0 {
                    return Ok(());
                }
                let ratio = (q / scale).abs();
                let log = ratio.log2();
                ensure((log - log.round()).abs() < 1e-5, || {
                    format!("level {ratio} is not a power of two")
                })
            },
        );
    }

    #[test]
    fn prop_idempotent() {
        forall(
            22,
            256,
            |r| (r.normal() * 2.0, r.range_f32(0.3, 5.0)),
            |&(w, scale)| {
                let once = fake_quant(w, 4, scale);
                let twice = fake_quant(once, 4, scale);
                ensure((once - twice).abs() < 1e-7, || format!("{once} vs {twice}"))
            },
        );
    }

    #[test]
    fn prop_log_domain_rounding_bound() {
        // For w in the representable band, the log2 error is <= 0.5.
        forall(
            23,
            256,
            |r| {
                let scale = r.range_f32(0.5, 2.0);
                let e = r.range_f32(0.0, 6.0);
                let sign = if r.bool(0.5) { 1.0 } else { -1.0 };
                (sign * (2f32).powf(-e) * scale, scale)
            },
            |&(w, scale)| {
                let q = fake_quant(w, 4, scale);
                ensure(q != 0.0, || format!("in-band value {w} flushed to zero"))?;
                let err = ((w / scale).abs().log2() - (q / scale).abs().log2()).abs();
                ensure(err <= 0.5 + 1e-4, || format!("log-domain err {err}"))
            },
        );
    }

    #[test]
    fn codes_fit_four_bits() {
        // |code| <= 7 always: sign + 3 magnitude bits.
        forall(
            24,
            256,
            |r| (r.normal() * 10.0, r.range_f32(0.1, 3.0)),
            |&(w, scale)| {
                let c = code(w, 4, scale);
                ensure(c.abs() <= 7, || format!("code {c} out of range"))
            },
        );
    }
}
