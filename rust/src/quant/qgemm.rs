//! Native packed-code quantized GEMM — the CPU twin of the paper's two
//! FPGA compute lanes (§II–III).
//!
//! The ILMPQ board never touches f32 weights: BRAM holds packed integer
//! codes and the two arithmetic lanes consume them directly. This module is
//! that execution model in software, computing `y = x · Wᵀ` straight from a
//! [`PackedMatrix`] bitstream with one inner loop per scheme:
//!
//! * **Fixed-8 → DSP lane (1 MAC/DSP/cycle).** One signed byte per weight;
//!   the inner loop is an `i8 × i8 → i32` multiply-accumulate — exactly the
//!   18×27 DSP48 multiplier the paper assigns 8-bit rows to.
//! * **Fixed-4 → DSP lane (2 MAC/DSP/cycle).** Two codes per byte; the loop
//!   nibble-decodes a byte and issues both MACs per iteration, the software
//!   analogue of the paper's double-pumped DSP packing.
//! * **PoT-4 → LUT lane (shift-add fabric).** Codes are `sign·(e+1)`; the
//!   loop is branch-free shift/sign arithmetic — `±(x << (emax − e))` with a
//!   single `2^-emax` fold into the row epilogue — i.e. the multiplierless
//!   shift-add PE the paper builds from LUTs.
//!
//! Activations are quantized **once per call** to signed 8-bit codes with a
//! per-row max-abs scale (the FPGA's 8-bit activation datapath), so every
//! inner loop is pure integer arithmetic; each output element gets a single
//! f32 epilogue multiply `acc · (act_scale · row_scale/Q)`. Integer
//! accumulation makes results bit-identical regardless of thread count —
//! the kernel row-blocks the weight matrix across a scoped `std::thread`
//! pool sized from `available_parallelism`, and every (weight row,
//! activation row) dot product is computed identically in any partition.
//! Workers are spawned per call, so small GEMMs (early ResNet layers at low
//! batch) are clamped to fewer threads by [`MIN_MACS_PER_THREAD`] — below
//! that, spawn overhead would eat the parallel win.
//!
//! `im2col` (fan-in order `(kh, kw, in_ch)`, matching
//! [`gemm_rows`](super::gemm_rows) and `jax.lax` SAME padding) turns conv
//! layers into this GEMM; [`crate::model::GemmDims`] describes the result.

use crate::model::GemmDims;

use super::packing::PackedMatrix;
use super::Scheme;

/// Activation quantization granularity: signed 8-bit, per-row max-abs scale.
pub const ACT_QMAX: f32 = 127.0;

/// Largest contraction depth K with overflow-free `i32` accumulation:
/// the worst per-element product magnitude is `127 · 127` (Fixed-8 row ×
/// 8-bit activation), so `K ≤ i32::MAX / 127²` (~133k; ResNet-18's largest
/// fan-in is 4608).
pub const MAX_K: usize = (i32::MAX / (127 * 127)) as usize;

/// Activations quantized to signed 8-bit codes, one scale per row.
///
/// Rows are zero-padded to an even number of codes so the 4-bit kernels can
/// consume activation pairs with `chunks_exact(2)` — pad codes multiply the
/// packed zero hi-nibble of an odd-column row, so they never contribute.
#[derive(Debug, Clone)]
pub struct QuantizedActs {
    pub m: usize,
    pub k: usize,
    stride: usize,
    codes: Vec<i8>,
    /// Per-row dequantization factor `max|x| / 127`.
    pub scales: Vec<f32>,
}

impl QuantizedActs {
    /// Quantize a row-major `(m, k)` f32 matrix (per-row max-abs scale).
    pub fn quantize(x: &[f32], m: usize, k: usize) -> QuantizedActs {
        assert_eq!(x.len(), m * k, "activation shape mismatch");
        let stride = k + (k & 1);
        let mut codes = vec![0i8; m * stride];
        let mut scales = Vec::with_capacity(m);
        for i in 0..m {
            let row = &x[i * k..(i + 1) * k];
            let s = super::row_scale(row);
            let inv = ACT_QMAX / s;
            let dst = &mut codes[i * stride..i * stride + k];
            for (d, &v) in dst.iter_mut().zip(row) {
                *d = (v * inv).round().clamp(-ACT_QMAX, ACT_QMAX) as i8;
            }
            scales.push(s / ACT_QMAX);
        }
        QuantizedActs { m, k, stride, codes, scales }
    }

    /// One padded code row (length `k` rounded up to even).
    fn row(&self, i: usize) -> &[i8] {
        &self.codes[i * self.stride..(i + 1) * self.stride]
    }

    /// The f32 values the integer kernel actually sees (row-major `(m, k)`)
    /// — the reference operand for parity tests.
    pub fn dequant(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.m * self.k);
        for i in 0..self.m {
            let s = self.scales[i];
            out.extend(self.row(i)[..self.k].iter().map(|&c| c as f32 * s));
        }
        out
    }
}

/// Worker-pool size: one thread per available core.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(std::num::NonZeroUsize::get).unwrap_or(1)
}

/// Minimum MACs per worker before another scoped thread pays for itself:
/// a spawn costs ~10–20µs, while 128k integer MACs keep a core busy for
/// roughly an order of magnitude longer. The packed eval path issues one
/// GEMM per layer per batch, so the small early-layer GEMMs would
/// otherwise pay thousands of spawns per test-split eval for no win.
/// Clamping never changes results — the kernel is bit-identical at every
/// thread count.
pub const MIN_MACS_PER_THREAD: usize = 1 << 17;

/// Threads actually worth using for an `n`-row GEMM of `work` total MACs.
fn effective_threads(requested: usize, n: usize, work: usize) -> usize {
    requested.min(1 + work / MIN_MACS_PER_THREAD).clamp(1, n.max(1))
}

/// Packed-code GEMM: `y[i][r] = Σ_c x[i][c] · dequant(w[r][c])`, computed in
/// integer arithmetic per scheme. Returns row-major `(m, rows)`.
///
/// Weight rows are split into contiguous blocks across `threads` scoped
/// workers; output is bit-identical for every thread count (integer
/// accumulation + a fixed-shape f32 epilogue per element).
pub fn qgemm(acts: &QuantizedActs, w: &PackedMatrix, threads: usize) -> Vec<f32> {
    assert_eq!(acts.k, w.cols, "contraction mismatch: acts k={} vs w cols={}", acts.k, w.cols);
    assert!(w.cols <= MAX_K, "K={} overflows i32 accumulation (max {MAX_K})", w.cols);
    let work = w.rows * acts.m * w.cols;
    row_blocked(w.rows, acts.m, threads, work, |r, orow| row_block(acts, w, r, orow))
}

/// Shared dispatch for both GEMM paths: fill an `(n, m)` buffer one weight
/// row at a time via `kernel(r, out_row)`, contiguous row blocks across
/// scoped workers (at most `threads`, fewer when `work` — total MACs — is
/// too small to amortize the spawns), then hand back `(m, n)` row-major.
fn row_blocked(
    n: usize,
    m: usize,
    threads: usize,
    work: usize,
    kernel: impl Fn(usize, &mut [f32]) + Sync,
) -> Vec<f32> {
    if m == 0 || n == 0 {
        return vec![0.0; m * n];
    }
    let mut out_nm = vec![0f32; n * m];
    let threads = effective_threads(threads, n, work);
    if threads == 1 {
        for (r, orow) in out_nm.chunks_mut(m).enumerate() {
            kernel(r, orow);
        }
    } else {
        let block = n.div_ceil(threads);
        std::thread::scope(|s| {
            for (t, chunk) in out_nm.chunks_mut(block * m).enumerate() {
                let kernel = &kernel;
                s.spawn(move || {
                    for (j, orow) in chunk.chunks_mut(m).enumerate() {
                        kernel(t * block + j, orow);
                    }
                });
            }
        });
    }
    transpose(&out_nm, n, m)
}

/// One weight row against every activation row (the per-thread work item).
fn row_block(acts: &QuantizedActs, w: &PackedMatrix, r: usize, out: &mut [f32]) {
    let bytes = w.row_bytes(r);
    match w.scheme(r) {
        Scheme::Fixed8 => {
            let post = w.scale(r) / 127.0;
            for (i, o) in out.iter_mut().enumerate() {
                let mut acc = 0i32;
                for (&wb, &xb) in bytes.iter().zip(acts.row(i)) {
                    acc += (wb as i8 as i32) * (xb as i32);
                }
                *o = acc as f32 * (acts.scales[i] * post);
            }
        }
        Scheme::Fixed4 => {
            let post = w.scale(r) / 7.0;
            for (i, o) in out.iter_mut().enumerate() {
                let mut acc = 0i32;
                for (&wb, x) in bytes.iter().zip(acts.row(i).chunks_exact(2)) {
                    let lo = ((wb << 4) as i8 >> 4) as i32;
                    let hi = (wb as i8 >> 4) as i32;
                    acc += lo * (x[0] as i32) + hi * (x[1] as i32);
                }
                *o = acc as f32 * (acts.scales[i] * post);
            }
        }
        Scheme::Pot4 => {
            // Codes are sign·(e+1); each term is ±(x << (6 − e)) and the
            // 2^-6 radix correction folds into the epilogue — no multiplies
            // in the loop, mirroring the LUT shift-add lane. A zero code has
            // signum 0, so the (defined, in-range) dummy shift contributes
            // nothing: the loop is branch-free.
            let post = w.scale(r) / 64.0;
            for (i, o) in out.iter_mut().enumerate() {
                let mut acc = 0i32;
                for (&wb, x) in bytes.iter().zip(acts.row(i).chunks_exact(2)) {
                    let lo = ((wb << 4) as i8 >> 4) as i32;
                    let hi = (wb as i8 >> 4) as i32;
                    acc += lo.signum() * ((x[0] as i32) << (7 - lo.abs()));
                    acc += hi.signum() * ((x[1] as i32) << (7 - hi.abs()));
                }
                *o = acc as f32 * (acts.scales[i] * post);
            }
        }
    }
}

/// `(rows, cols)` row-major → `(cols, rows)` row-major.
fn transpose(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; src.len()];
    for r in 0..rows {
        for (c, &v) in src[r * cols..(r + 1) * cols].iter().enumerate() {
            out[c * rows + r] = v;
        }
    }
    out
}

/// The pre-qgemm baseline: plain f32 GEMM over dequantized weight rows,
/// with the same row-blocked threading (so benches compare arithmetic, not
/// scheduling). `x` is row-major `(m, k)`; returns `(m, rows)`.
pub fn f32_gemm_rows(
    x: &[f32],
    m: usize,
    k: usize,
    w_rows: &[Vec<f32>],
    threads: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), m * k, "activation shape mismatch");
    row_blocked(w_rows.len(), m, threads, w_rows.len() * m * k, |r, orow| {
        let wr = &w_rows[r];
        assert_eq!(wr.len(), k, "w row {r} length");
        for (i, o) in orow.iter_mut().enumerate() {
            let mut acc = 0f32;
            for (&wv, &xv) in wr.iter().zip(&x[i * k..(i + 1) * k]) {
                acc += wv * xv;
            }
            *o = acc;
        }
    })
}

/// An im2col'd activation tensor: `(m, k)` patch matrix + output geometry.
#[derive(Debug, Clone)]
pub struct Im2col {
    /// Row-major `(m, k)`: one row per output pixel, fan-in order
    /// `(kh, kw, in_ch)` — the same order as [`super::gemm_rows`].
    pub data: Vec<f32>,
    pub m: usize,
    pub k: usize,
    pub oh: usize,
    pub ow: usize,
}

impl Im2col {
    /// The GEMM this patch matrix induces against an `out_ch`-row filter.
    pub fn gemm_dims(&self, out_ch: usize) -> GemmDims {
        GemmDims { m: out_ch, k: self.k, n: self.m }
    }
}

/// Lower a SAME-padded convolution input to a patch matrix.
///
/// `x` is NHWC `(b, ih, iw, ic)`; output pixels are `ceil(ih/stride) ×
/// ceil(iw/stride)` with TF/JAX SAME padding (`pad_total = (out−1)·stride +
/// k − in`, floor-half before, rest after). Patch rows come out in
/// `(batch, oy, ox)` order, so `qgemm` output is directly NHWC.
pub fn im2col(
    x: &[f32],
    b: usize,
    ih: usize,
    iw: usize,
    ic: usize,
    kh: usize,
    kw: usize,
    stride: usize,
) -> Im2col {
    assert_eq!(x.len(), b * ih * iw * ic, "input shape mismatch");
    assert!(stride > 0, "stride must be positive");
    let oh = ih.div_ceil(stride);
    let ow = iw.div_ceil(stride);
    let pt = ((oh - 1) * stride + kh).saturating_sub(ih) / 2;
    let pl = ((ow - 1) * stride + kw).saturating_sub(iw) / 2;
    let k = kh * kw * ic;
    let m = b * oh * ow;
    let mut data = vec![0f32; m * k];
    let mut row = 0usize;
    for bi in 0..b {
        let img = &x[bi * ih * iw * ic..(bi + 1) * ih * iw * ic];
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut data[row * k..(row + 1) * k];
                let mut d = 0usize;
                for ky in 0..kh {
                    let iy = (oy * stride + ky) as isize - pt as isize;
                    if iy < 0 || iy >= ih as isize {
                        d += kw * ic;
                        continue;
                    }
                    for kx in 0..kw {
                        let ix = (ox * stride + kx) as isize - pl as isize;
                        if ix < 0 || ix >= iw as isize {
                            d += ic;
                            continue;
                        }
                        let src = (iy as usize * iw + ix as usize) * ic;
                        dst[d..d + ic].copy_from_slice(&img[src..src + ic]);
                        d += ic;
                    }
                }
                row += 1;
            }
        }
    }
    Im2col { data, m, k, oh, ow }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::assign::assign_uniform_layer;
    use crate::quant::LayerMasks;
    use crate::util::prop::{assert_close, forall};
    use crate::util::Rng;

    fn random_matrix(r: &mut Rng, rows: usize, cols: usize) -> Vec<Vec<f32>> {
        (0..rows)
            .map(|_| (0..cols).map(|_| r.normal() * r.range_f32(0.1, 3.0)).collect())
            .collect()
    }

    fn random_masks(r: &mut Rng, rows: usize) -> LayerMasks {
        let is8: Vec<f32> = (0..rows).map(|_| if r.bool(0.3) { 1.0 } else { 0.0 }).collect();
        let is_pot: Vec<f32> = (0..rows)
            .map(|i| if is8[i] < 0.5 && r.bool(0.5) { 1.0 } else { 0.0 })
            .collect();
        LayerMasks { layer: "t".into(), is8, is_pot }
    }

    /// Reference: f32 GEMM of the kernel's dequantized operands.
    fn reference(acts: &QuantizedActs, w: &PackedMatrix) -> Vec<f32> {
        f32_gemm_rows(&acts.dequant(), acts.m, acts.k, &w.unpack(), 1)
    }

    #[test]
    fn prop_qgemm_matches_dequant_f32_gemm() {
        forall(
            81,
            48,
            |r| {
                let m = r.range_usize(1, 7);
                let rows = r.range_usize(1, 16);
                let cols = r.range_usize(1, 34); // odd counts included
                let w = random_matrix(r, rows, cols);
                let masks = random_masks(r, rows);
                let x: Vec<f32> = (0..m * cols).map(|_| r.normal() * 2.0).collect();
                let threads = r.range_usize(1, 5);
                (w, masks, x, m, cols, threads)
            },
            |(w, masks, x, m, cols, threads)| {
                let packed = PackedMatrix::pack(w, masks);
                let acts = QuantizedActs::quantize(x, *m, *cols);
                let got = qgemm(&acts, &packed, *threads);
                let want = reference(&acts, &packed);
                assert_close(&got, &want, 1e-4, "qgemm vs dequant GEMM")
            },
        );
    }

    #[test]
    fn prop_uniform_scheme_parity() {
        // Each scheme exercised alone (the mixed prop can under-sample one).
        forall(
            82,
            36,
            |r| {
                let scheme = match r.below(3) {
                    0 => Scheme::Fixed8,
                    1 => Scheme::Fixed4,
                    _ => Scheme::Pot4,
                };
                let m = r.range_usize(1, 5);
                let rows = r.range_usize(1, 10);
                let cols = r.range_usize(1, 41);
                let w = random_matrix(r, rows, cols);
                let x: Vec<f32> = (0..m * cols).map(|_| r.normal()).collect();
                (w, scheme, x, m, cols)
            },
            |(w, scheme, x, m, cols)| {
                let masks = assign_uniform_layer("t", w.len(), *scheme);
                let packed = PackedMatrix::pack(w, &masks);
                let acts = QuantizedActs::quantize(x, *m, *cols);
                let got = qgemm(&acts, &packed, 2);
                let want = reference(&acts, &packed);
                assert_close(&got, &want, 1e-4, &format!("{scheme:?}"))
            },
        );
    }

    #[test]
    fn fixed8_bit_exact_across_thread_counts() {
        // Sized past MIN_MACS_PER_THREAD so multiple workers really spawn
        // (48·384·32 MACs supports 5): the guarantee under test is the
        // multi-threaded partition, not the single-thread fallback.
        let mut r = Rng::new(17);
        let w = random_matrix(&mut r, 48, 384);
        let masks = assign_uniform_layer("t", 48, Scheme::Fixed8);
        let packed = PackedMatrix::pack(&w, &masks);
        let x: Vec<f32> = (0..32 * 384).map(|_| r.normal()).collect();
        let acts = QuantizedActs::quantize(&x, 32, 384);
        let y1 = qgemm(&acts, &packed, 1);
        for threads in [2, 3, 5, 8, 64] {
            let yt = qgemm(&acts, &packed, threads);
            assert_eq!(y1.len(), yt.len());
            for (a, b) in y1.iter().zip(&yt) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn mixed_masks_bit_exact_across_thread_counts() {
        let mut r = Rng::new(18);
        let w = random_matrix(&mut r, 48, 256);
        let masks = random_masks(&mut r, 48);
        let packed = PackedMatrix::pack(&w, &masks);
        let x: Vec<f32> = (0..24 * 256).map(|_| r.normal()).collect();
        let acts = QuantizedActs::quantize(&x, 24, 256);
        let y1 = qgemm(&acts, &packed, 1);
        let y7 = qgemm(&acts, &packed, 7);
        assert!(y1.iter().zip(&y7).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn thread_clamp_scales_with_work() {
        // Tiny GEMMs stay single-threaded; big ones use what's requested;
        // the row count still bounds the partition.
        assert_eq!(effective_threads(8, 64, 1000), 1);
        assert_eq!(effective_threads(8, 64, MIN_MACS_PER_THREAD), 2);
        assert_eq!(effective_threads(8, 64, 100 * MIN_MACS_PER_THREAD), 8);
        assert_eq!(effective_threads(16, 3, 100 * MIN_MACS_PER_THREAD), 3);
        assert_eq!(effective_threads(0, 64, 100 * MIN_MACS_PER_THREAD), 1);
    }

    #[test]
    fn act_quantization_error_is_bounded() {
        let mut r = Rng::new(19);
        let x: Vec<f32> = (0..256).map(|_| r.normal() * 1.5).collect();
        let acts = QuantizedActs::quantize(&x, 4, 64);
        let dq = acts.dequant();
        for (i, (&a, &b)) in x.iter().zip(&dq).enumerate() {
            let s = acts.scales[i / 64] * ACT_QMAX;
            assert!((a - b).abs() <= s / 254.0 + 1e-6, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        let w = random_matrix(&mut Rng::new(20), 3, 4);
        let packed =
            PackedMatrix::pack(&w, &assign_uniform_layer("t", 3, Scheme::Fixed4));
        let acts = QuantizedActs::quantize(&[], 0, 4);
        assert!(qgemm(&acts, &packed, 4).is_empty());
    }

    #[test]
    fn im2col_matches_direct_conv() {
        // 1x1 and 3x3, stride 1 and 2, vs a naive padded convolution.
        let mut r = Rng::new(21);
        for (ih, iw, ic, kk, stride, oc) in
            [(6, 6, 3, 3, 1, 4), (7, 5, 2, 3, 2, 3), (8, 8, 4, 1, 2, 5), (5, 5, 1, 3, 1, 2)]
        {
            let b = 2usize;
            let x: Vec<f32> = (0..b * ih * iw * ic).map(|_| r.normal()).collect();
            let w = random_matrix(&mut r, oc, kk * kk * ic);
            let col = im2col(&x, b, ih, iw, ic, kk, kk, stride);
            assert_eq!(col.m, b * col.oh * col.ow);
            let got = f32_gemm_rows(&col.data, col.m, col.k, &w, 1);
            let want = naive_conv(&x, b, ih, iw, ic, &w, kk, stride, col.oh, col.ow);
            assert_close(&got, &want, 1e-5, &format!("conv {ih}x{iw} k{kk} s{stride}"))
                .unwrap();
        }
    }

    /// Direct SAME-padded conv, NHWC in, `(b·oh·ow, oc)` out.
    #[allow(clippy::too_many_arguments)]
    fn naive_conv(
        x: &[f32],
        b: usize,
        ih: usize,
        iw: usize,
        ic: usize,
        w_rows: &[Vec<f32>],
        kk: usize,
        stride: usize,
        oh: usize,
        ow: usize,
    ) -> Vec<f32> {
        let pt = ((oh - 1) * stride + kk).saturating_sub(ih) / 2;
        let pl = ((ow - 1) * stride + kk).saturating_sub(iw) / 2;
        let oc = w_rows.len();
        let mut out = vec![0f32; b * oh * ow * oc];
        for bi in 0..b {
            for oy in 0..oh {
                for ox in 0..ow {
                    for (co, wr) in w_rows.iter().enumerate() {
                        let mut acc = 0f32;
                        for ky in 0..kk {
                            for kx in 0..kk {
                                let iy = (oy * stride + ky) as isize - pt as isize;
                                let ix = (ox * stride + kx) as isize - pl as isize;
                                if iy < 0
                                    || ix < 0
                                    || iy >= ih as isize
                                    || ix >= iw as isize
                                {
                                    continue;
                                }
                                for ci in 0..ic {
                                    let xi = ((bi * ih + iy as usize) * iw
                                        + ix as usize)
                                        * ic
                                        + ci;
                                    acc += x[xi] * wr[(ky * kk + kx) * ic + ci];
                                }
                            }
                        }
                        out[((bi * oh + oy) * ow + ox) * oc + co] = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn im2col_gemm_dims_match_layer_desc() {
        use crate::model::LayerDesc;
        let l = LayerDesc::conv("c", 3, 2, 5, 8, 9, 9);
        let x = vec![0f32; 9 * 9 * 5];
        let col = im2col(&x, 1, 9, 9, 5, 3, 3, 2);
        assert_eq!(col.gemm_dims(8), l.gemm());
    }

    #[test]
    fn max_k_is_sane() {
        assert!(MAX_K > 100_000);
        // ResNet-18's deepest fan-in fits with a wide margin.
        assert!(MAX_K > 512 * 3 * 3 * 20);
    }
}
