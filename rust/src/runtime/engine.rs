//! PJRT execution engine: load HLO text, compile once, execute many.
//!
//! Wraps the `xla` crate's CPU PJRT client (the /opt/xla-example pattern):
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Executables are compiled lazily and
//! cached per artifact name; the coordinator threads share the engine
//! behind a `Mutex` (PJRT CPU executions are single-stream here — the
//! batcher, not intra-op parallelism, is the concurrency story).
//!
//! The whole XLA/PJRT backend sits behind the `pjrt` cargo feature (on by
//! default): building the feature requires the prebuilt `xla_extension`
//! C++ library (`XLA_EXTENSION_DIR`). Without the feature, [`Engine`] is an
//! uninhabited stub so the rest of the crate — the pure-CPU quant/qgemm
//! paths, the FPGA simulator, the CLI — still compiles and tests.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::sync::{Arc, Mutex};
#[cfg(feature = "pjrt")]
use std::time::Instant;

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::Result;
#[cfg(feature = "pjrt")]
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::HostTensor;

/// Compile/execute statistics for the metrics endpoint + perf logs.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub compiles: u64,
    /// Wall-clock spent in `client.compile` (parse + XLA compilation).
    pub compile_seconds: f64,
    pub executions: u64,
    pub execute_seconds: f64,
    pub stage_seconds: f64,
    pub fetch_seconds: f64,
}

/// The PJRT engine: client + executable cache.
///
/// Executables are cached behind `Arc` so `run` can clone a handle out of
/// the map and execute outside the lock — the `xla` crate's
/// `PjRtLoadedExecutable` is a raw-pointer wrapper with a `Drop` impl and
/// no `Clone`, so the refcount is the only safe way to share one compiled
/// executable across concurrent coordinator threads.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: PjRtClient,
    executables: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
    stats: Mutex<EngineStats>,
}

// SAFETY: the PJRT C API is documented thread-safe for client compilation
// and executable execution (the CPU plugin serializes internally where
// needed); the raw pointers inside `PjRtClient`/`PjRtLoadedExecutable` are
// only reached through `&self` methods here, and all mutable Rust-side
// state (caches, stats) is Mutex-guarded. The `xla` crate just never added
// the auto-impls because of the raw pointers.
#[cfg(feature = "pjrt")]
unsafe impl Send for Engine {}
#[cfg(feature = "pjrt")]
unsafe impl Sync for Engine {}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Create a CPU engine.
    pub fn cpu() -> Result<Engine> {
        let client = PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            client,
            executables: Mutex::new(HashMap::new()),
            stats: Mutex::new(EngineStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    ///
    /// Compilation happens *outside* the cache lock: PJRT compiles can take
    /// seconds, and holding the mutex across them would serialize every
    /// coordinator thread behind the first cold load. Two threads racing on
    /// the same cold artifact may both compile; the first insert wins and
    /// only it is counted in the stats.
    pub fn load(&self, spec: &ArtifactSpec) -> Result<()> {
        if self.executables.lock().unwrap().contains_key(&spec.name) {
            return Ok(());
        }
        let t = Instant::now();
        let proto = HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parse HLO text {:?}", spec.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile artifact {}", spec.name))?;
        let compile_s = t.elapsed().as_secs_f64();
        let mut cache = self.executables.lock().unwrap();
        if cache.contains_key(&spec.name) {
            return Ok(()); // lost the race; keep the winner's executable
        }
        cache.insert(spec.name.clone(), Arc::new(exe));
        drop(cache);
        let mut s = self.stats.lock().unwrap();
        s.compiles += 1;
        s.compile_seconds += compile_s;
        Ok(())
    }

    /// Load every artifact in the manifest (eager warm-up for serving).
    pub fn load_all(&self, manifest: &Manifest) -> Result<()> {
        for spec in manifest.artifacts.values() {
            self.load(spec)?;
        }
        Ok(())
    }

    /// Execute an artifact on host tensors; returns the output tuple as
    /// host tensors. Input count/shapes are validated against the spec so a
    /// manifest drift fails with a clear message instead of a PJRT abort.
    pub fn run(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.validate_inputs(spec, inputs)?;
        self.load(spec)?;

        let t_stage = Instant::now();
        let literals: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let stage_s = t_stage.elapsed().as_secs_f64();

        // Clone the `Arc` out of the cache so `execute` runs outside the
        // lock — concurrent coordinator threads must not serialize their
        // PJRT executions on the map mutex.
        let exe = self
            .executables
            .lock()
            .unwrap()
            .get(&spec.name)
            .expect("loaded above")
            .clone();
        let t_exec = Instant::now();
        let out_buffers = exe
            .execute::<Literal>(&literals)
            .with_context(|| format!("execute {}", spec.name))?;
        let exec_s = t_exec.elapsed().as_secs_f64();

        let t_fetch = Instant::now();
        let tuple = out_buffers[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = tuple.to_tuple().context("decompose result tuple")?;
        let outputs: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        let fetch_s = t_fetch.elapsed().as_secs_f64();

        if outputs.len() != spec.outputs.len() {
            anyhow::bail!(
                "{}: expected {} outputs, got {}",
                spec.name,
                spec.outputs.len(),
                outputs.len()
            );
        }
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.execute_seconds += exec_s;
        s.stage_seconds += stage_s;
        s.fetch_seconds += fetch_s;
        Ok(outputs)
    }

    fn validate_inputs(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            anyhow::bail!(
                "{}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape {
                anyhow::bail!(
                    "{}: input {i} ({}) shape {:?} != manifest {:?}",
                    spec.name,
                    s.name,
                    t.shape,
                    s.shape
                );
            }
        }
        Ok(())
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.lock().unwrap().clone()
    }
}

/// Built without the `pjrt` feature: the engine type exists so the rest of
/// the crate (coordinator, experiments, CLI, benches) type-checks, but it
/// cannot be constructed — `Engine::cpu()` reports the missing backend and
/// every other method is statically unreachable (the enum is uninhabited).
#[cfg(not(feature = "pjrt"))]
pub enum Engine {}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always fails: this build has no XLA/PJRT backend.
    pub fn cpu() -> Result<Engine> {
        anyhow::bail!(
            "ilmpq was built without the `pjrt` feature; the XLA/PJRT engine is \
             unavailable (rebuild with default features and XLA_EXTENSION_DIR set)"
        )
    }

    pub fn platform(&self) -> String {
        match *self {}
    }

    pub fn load(&self, _spec: &ArtifactSpec) -> Result<()> {
        match *self {}
    }

    pub fn load_all(&self, _manifest: &Manifest) -> Result<()> {
        match *self {}
    }

    pub fn run(&self, _spec: &ArtifactSpec, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        match *self {}
    }

    pub fn stats(&self) -> EngineStats {
        match *self {}
    }
}

/// Convenience: manifest + engine bundled, with the paths resolved.
pub struct Runtime {
    pub manifest: Manifest,
    pub engine: Engine,
}

impl Runtime {
    /// Load from the default artifacts dir (or `$ILMPQ_ARTIFACTS`).
    pub fn load_default() -> Result<Runtime> {
        Runtime::load(&Manifest::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Runtime> {
        Runtime::from_manifest(Manifest::load(dir)?)
    }

    /// Attach a PJRT engine to an already-loaded manifest (callers that
    /// parse the manifest first — e.g. to decide whether an engine is
    /// needed at all — reuse it instead of re-reading manifest.json).
    pub fn from_manifest(manifest: Manifest) -> Result<Runtime> {
        let engine = Engine::cpu()?;
        Ok(Runtime { manifest, engine })
    }

    pub fn run(&self, artifact: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.artifact(artifact)?;
        self.engine.run(spec, inputs)
    }
}
