//! PJRT execution engine: load HLO text, compile once, execute many.
//!
//! Wraps the `xla` crate's CPU PJRT client (the /opt/xla-example pattern):
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. Executables are compiled lazily and
//! cached per artifact name; the coordinator threads share the engine
//! behind a `Mutex` (PJRT CPU executions are single-stream here — the
//! batcher, not intra-op parallelism, is the concurrency story).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::HostTensor;

/// Compile/execute statistics for the metrics endpoint + perf logs.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub compiles: u64,
    /// Wall-clock spent in `client.compile` (parse + XLA compilation).
    pub compile_seconds: f64,
    pub executions: u64,
    pub execute_seconds: f64,
    pub stage_seconds: f64,
    pub fetch_seconds: f64,
}

/// The PJRT engine: client + executable cache.
pub struct Engine {
    client: PjRtClient,
    executables: Mutex<HashMap<String, PjRtLoadedExecutable>>,
    stats: Mutex<EngineStats>,
}

// SAFETY: the PJRT C API is documented thread-safe for client compilation
// and executable execution (the CPU plugin serializes internally where
// needed); the raw pointers inside `PjRtClient`/`PjRtLoadedExecutable` are
// only reached through `&self` methods here, and all mutable Rust-side
// state (caches, stats) is Mutex-guarded. The `xla` crate just never added
// the auto-impls because of the raw pointers.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU engine.
    pub fn cpu() -> Result<Engine> {
        let client = PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            client,
            executables: Mutex::new(HashMap::new()),
            stats: Mutex::new(EngineStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for an artifact.
    ///
    /// Compilation happens *outside* the cache lock: PJRT compiles can take
    /// seconds, and holding the mutex across them would serialize every
    /// coordinator thread behind the first cold load. Two threads racing on
    /// the same cold artifact may both compile; the first insert wins and
    /// only it is counted in the stats.
    pub fn load(&self, spec: &ArtifactSpec) -> Result<()> {
        if self.executables.lock().unwrap().contains_key(&spec.name) {
            return Ok(());
        }
        let t = Instant::now();
        let proto = HloModuleProto::from_text_file(&spec.file)
            .with_context(|| format!("parse HLO text {:?}", spec.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile artifact {}", spec.name))?;
        let compile_s = t.elapsed().as_secs_f64();
        let mut cache = self.executables.lock().unwrap();
        if cache.contains_key(&spec.name) {
            return Ok(()); // lost the race; keep the winner's executable
        }
        cache.insert(spec.name.clone(), exe);
        drop(cache);
        let mut s = self.stats.lock().unwrap();
        s.compiles += 1;
        s.compile_seconds += compile_s;
        Ok(())
    }

    /// Load every artifact in the manifest (eager warm-up for serving).
    pub fn load_all(&self, manifest: &Manifest) -> Result<()> {
        for spec in manifest.artifacts.values() {
            self.load(spec)?;
        }
        Ok(())
    }

    /// Execute an artifact on host tensors; returns the output tuple as
    /// host tensors. Input count/shapes are validated against the spec so a
    /// manifest drift fails with a clear message instead of a PJRT abort.
    pub fn run(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.validate_inputs(spec, inputs)?;
        self.load(spec)?;

        let t_stage = Instant::now();
        let literals: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let stage_s = t_stage.elapsed().as_secs_f64();

        // Clone the handle out of the cache (a cheap refcounted pointer) so
        // `execute` runs outside the lock — concurrent coordinator threads
        // must not serialize their PJRT executions on the map mutex.
        let exe = self
            .executables
            .lock()
            .unwrap()
            .get(&spec.name)
            .expect("loaded above")
            .clone();
        let t_exec = Instant::now();
        let out_buffers = exe
            .execute::<Literal>(&literals)
            .with_context(|| format!("execute {}", spec.name))?;
        let exec_s = t_exec.elapsed().as_secs_f64();

        let t_fetch = Instant::now();
        let tuple = out_buffers[0][0]
            .to_literal_sync()
            .context("fetch result literal")?;
        let parts = tuple.to_tuple().context("decompose result tuple")?;
        let outputs: Vec<HostTensor> = parts
            .iter()
            .map(HostTensor::from_literal)
            .collect::<Result<_>>()?;
        let fetch_s = t_fetch.elapsed().as_secs_f64();

        if outputs.len() != spec.outputs.len() {
            anyhow::bail!(
                "{}: expected {} outputs, got {}",
                spec.name,
                spec.outputs.len(),
                outputs.len()
            );
        }
        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.execute_seconds += exec_s;
        s.stage_seconds += stage_s;
        s.fetch_seconds += fetch_s;
        Ok(outputs)
    }

    fn validate_inputs(&self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != spec.inputs.len() {
            anyhow::bail!(
                "{}: expected {} inputs, got {}",
                spec.name,
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape != s.shape {
                anyhow::bail!(
                    "{}: input {i} ({}) shape {:?} != manifest {:?}",
                    spec.name,
                    s.name,
                    t.shape,
                    s.shape
                );
            }
        }
        Ok(())
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.lock().unwrap().clone()
    }
}

/// Convenience: manifest + engine bundled, with the paths resolved.
pub struct Runtime {
    pub manifest: Manifest,
    pub engine: Engine,
}

impl Runtime {
    /// Load from the default artifacts dir (or `$ILMPQ_ARTIFACTS`).
    pub fn load_default() -> Result<Runtime> {
        Runtime::load(&Manifest::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let engine = Engine::cpu()?;
        Ok(Runtime { manifest, engine })
    }

    pub fn run(&self, artifact: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.artifact(artifact)?;
        self.engine.run(spec, inputs)
    }
}
