//! Artifact manifest loader — the contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! The manifest pins down everything the coordinator needs to drive the AOT
//! executables without Python: parameter order/shapes, per-layer row counts,
//! artifact input/output signatures, dataset files, default ILMPQ masks and
//! the per-filter Hessian eigenvalues computed at init.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::tensor::{read_f32_file, read_i32_file, HostTensor};
use crate::quant::{LayerMasks, MaskSet, Provenance, QuantPlan};
use crate::util::Json;

/// One named array in an artifact signature.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

/// One AOT-lowered executable.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// Dataset description + file paths.
#[derive(Debug, Clone)]
pub struct DataSpec {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub dir: PathBuf,
}

impl DataSpec {
    pub fn image_elems(&self) -> usize {
        self.height * self.width * self.channels
    }

    pub fn load_train(&self) -> Result<(Vec<f32>, Vec<i32>)> {
        let x = read_f32_file(&self.dir.join("x_train.bin"))?;
        let y = read_i32_file(&self.dir.join("y_train.bin"))?;
        if x.len() != self.n_train * self.image_elems() || y.len() != self.n_train {
            bail!("train data size mismatch");
        }
        Ok((x, y))
    }

    pub fn load_test(&self) -> Result<(Vec<f32>, Vec<i32>)> {
        let x = read_f32_file(&self.dir.join("x_test.bin"))?;
        let y = read_i32_file(&self.dir.join("y_test.bin"))?;
        if x.len() != self.n_test * self.image_elems() || y.len() != self.n_test {
            bail!("test data size mismatch");
        }
        Ok((x, y))
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model_name: String,
    pub widths: Vec<usize>,
    pub classes: usize,
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    /// (name, shape) in AOT positional order.
    pub params: Vec<(String, Vec<usize>)>,
    /// (name, rows, fan_in) for every quantized layer, in order.
    pub quantized_layers: Vec<(String, usize, usize)>,
    pub data: DataSpec,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub infer_batches: Vec<usize>,
    pub hvp_batch: usize,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Per-layer Hessian eigenvalues at init (paper §II-C step 1).
    pub eigs: BTreeMap<String, Vec<f64>>,
    /// Ratio-name -> per-layer default masks computed by `assign.py`.
    pub default_masks: BTreeMap<String, MaskSet>,
}

fn io_specs(arr: &Json) -> Vec<IoSpec> {
    arr.as_arr()
        .expect("io spec array")
        .iter()
        .map(|e| IoSpec {
            name: e.at("name").as_str().unwrap().to_string(),
            shape: e.at("shape").usize_vec(),
            dtype: e.at("dtype").as_str().unwrap().to_string(),
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;

        let model = j.at("model");
        let data = j.at("data");
        let mut artifacts = BTreeMap::new();
        for (name, a) in j.at("artifacts").as_obj().unwrap() {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: dir.join(a.at("file").as_str().unwrap()),
                    inputs: io_specs(a.at("inputs")),
                    outputs: io_specs(a.at("outputs")),
                },
            );
        }

        let mut eigs = BTreeMap::new();
        for (name, e) in j.at("eigs").as_obj().unwrap() {
            eigs.insert(name.clone(), e.num_vec());
        }

        let quantized_layers: Vec<(String, usize, usize)> = j
            .at("quantized_layers")
            .as_arr()
            .unwrap()
            .iter()
            .map(|q| {
                (
                    q.at("name").as_str().unwrap().to_string(),
                    q.at("rows").as_usize().unwrap(),
                    q.at("fan_in").as_usize().unwrap(),
                )
            })
            .collect();

        let mut default_masks = BTreeMap::new();
        for (rname, masks) in j.at("default_masks").as_obj().unwrap() {
            let mut layers = Vec::new();
            for (lname, _rows, _) in &quantized_layers {
                let is8: Vec<f32> = masks
                    .at(&format!("{lname}:is8"))
                    .num_vec()
                    .into_iter()
                    .map(|v| v as f32)
                    .collect();
                let is_pot: Vec<f32> = masks
                    .at(&format!("{lname}:is_pot"))
                    .num_vec()
                    .into_iter()
                    .map(|v| v as f32)
                    .collect();
                layers.push(LayerMasks { layer: lname.clone(), is8, is_pot });
            }
            default_masks.insert(rname.clone(), MaskSet { name: rname.clone(), layers });
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model_name: model.at("name").as_str().unwrap().to_string(),
            widths: model.at("widths").usize_vec(),
            classes: model.at("classes").as_usize().unwrap(),
            height: model.at("height").as_usize().unwrap(),
            width: model.at("width").as_usize().unwrap(),
            channels: model.at("channels").as_usize().unwrap(),
            params: j
                .at("params")
                .as_arr()
                .unwrap()
                .iter()
                .map(|p| {
                    (
                        p.at("name").as_str().unwrap().to_string(),
                        p.at("shape").usize_vec(),
                    )
                })
                .collect(),
            quantized_layers,
            data: DataSpec {
                height: data.at("height").as_usize().unwrap(),
                width: data.at("width").as_usize().unwrap(),
                channels: data.at("channels").as_usize().unwrap(),
                classes: data.at("classes").as_usize().unwrap(),
                n_train: data.at("n_train").as_usize().unwrap(),
                n_test: data.at("n_test").as_usize().unwrap(),
                dir: dir.to_path_buf(),
            },
            train_batch: j.at("train_batch").as_usize().unwrap(),
            eval_batch: j.at("eval_batch").as_usize().unwrap(),
            infer_batches: j.at("infer_batches").usize_vec(),
            hvp_batch: j.at("hvp_batch").as_usize().unwrap(),
            artifacts,
            eigs,
            default_masks,
        })
    }

    /// Standard artifacts dir: `$ILMPQ_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("ILMPQ_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    /// Names of the plans this manifest can resolve (the `default_masks`
    /// table computed by `assign.py`), for listings and error messages.
    pub fn plan_names(&self) -> Vec<&str> {
        self.default_masks.keys().map(String::as_str).collect()
    }

    /// A named default assignment as a first-class [`QuantPlan`] — the one
    /// place `default_masks` is resolved by name, so the legacy table and
    /// the plan API cannot drift. Unknown names get the curated error
    /// listing what exists (same UX contract as `backend::registry`).
    pub fn plan(&self, name: &str) -> Result<QuantPlan> {
        let masks = self.default_masks.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown quantization plan {name:?}; available plans: {}",
                self.plan_names().join(", ")
            )
        })?;
        Ok(QuantPlan::from_mask_set(
            masks.clone(),
            Provenance::NamedRatio { ratio: name.to_string() },
        )
        .with_model(&self.model_name))
    }

    /// Load the initial parameters (He init written by aot.py) as tensors in
    /// AOT positional order.
    pub fn load_init_params(&self) -> Result<Vec<HostTensor>> {
        let flat = read_f32_file(&self.dir.join("params_init.bin"))?;
        let mut out = Vec::with_capacity(self.params.len());
        let mut off = 0usize;
        for (name, shape) in &self.params {
            let n: usize = shape.iter().product();
            if off + n > flat.len() {
                bail!("params_init.bin too short at {name}");
            }
            out.push(HostTensor::f32(shape.clone(), flat[off..off + n].to_vec()));
            off += n;
        }
        if off != flat.len() {
            bail!("params_init.bin has {} trailing floats", flat.len() - off);
        }
        Ok(out)
    }

    /// Masks for a named ratio as AOT-ordered tensors (is8, is_pot per layer).
    pub fn mask_tensors(&self, masks: &MaskSet) -> Vec<HostTensor> {
        let mut out = Vec::new();
        for (lname, rows, _) in &self.quantized_layers {
            let lm = masks
                .layer(lname)
                .unwrap_or_else(|| panic!("mask set missing layer {lname}"));
            assert_eq!(lm.rows(), *rows, "{lname}: mask rows mismatch");
            out.push(HostTensor::f32(vec![*rows], lm.is8.clone()));
            out.push(HostTensor::f32(vec![*rows], lm.is_pot.clone()));
        }
        out
    }
}
