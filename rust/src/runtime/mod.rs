//! Runtime layer: PJRT engine + artifact manifest + host tensors.
//!
//! Loads the HLO-text artifacts produced by `python/compile/aot.py` and
//! executes them on the PJRT CPU client. Python never runs here — the Rust
//! binary is self-contained once `make artifacts` has been run.

pub mod engine;
pub mod manifest;
pub mod qforward;
pub mod tensor;

pub use engine::{Engine, EngineStats, Runtime};
pub use manifest::{ArtifactSpec, DataSpec, IoSpec, Manifest};
pub use qforward::PackedModel;
pub use tensor::{HostTensor, TensorData};
