//! Packed-weight CPU forward pass of the AOT TinyResNet — the execution
//! path that never dequantizes.
//!
//! The PJRT frozen path (`infer_frozen_b{N}`) evaluates fake-quantized f32
//! weights through XLA; this module instead packs every quantized layer into
//! its [`PackedMatrix`] BRAM image once and drives the whole network through
//! `quant::qgemm` — conv layers via `im2col`, fc directly — so inference
//! arithmetic happens on the integer codes, exactly as on the board. A
//! float mode (no masks) keeps f32 GEMM-view rows instead, giving a
//! pure-Rust reference with the PJRT path's numerics for cross-checks.
//!
//! Topology is reconstructed from the manifest's param names. A `stem/w`
//! param rebuilds the TinyResNet recipe (the same one as
//! `python/compile/model.py::apply`): stem conv → per-stage
//! `relu(c1) → c2 (+ proj skip) → relu` residual blocks; a plain
//! `s{i}/conv/w` stack (zoo `vggnarrow`) rebuilds a relu-conv chain. Both
//! end in global average pool → fc + bias. All convs are SAME-padded NHWC.

use anyhow::{bail, Context, Result};

use crate::quant::qgemm::{self, QuantizedActs};
use crate::quant::{gemm_rows, MaskSet, PackedMatrix};

use super::manifest::Manifest;
use super::tensor::HostTensor;

/// One layer's weights: packed integer codes or the f32 reference rows.
enum LayerWeights {
    Packed(PackedMatrix),
    Float(Vec<Vec<f32>>),
}

struct ConvLayer {
    w: LayerWeights,
    kh: usize,
    kw: usize,
    stride: usize,
    in_ch: usize,
    out_ch: usize,
}

struct Stage {
    c1: ConvLayer,
    c2: ConvLayer,
    proj: Option<ConvLayer>,
}

/// The reconstructed conv topology. A manifest with a `stem/w` param
/// rebuilds the TinyResNet residual recipe; one with a plain `s{i}/conv/w`
/// stack (zoo `vggnarrow`) rebuilds a relu-conv chain. Both feed the shared
/// GAP → fc head.
enum Arch {
    Residual { stem: ConvLayer, stages: Vec<Stage> },
    Plain { convs: Vec<ConvLayer> },
}

impl Arch {
    /// Channel count entering the GAP head.
    fn last_ch(&self) -> usize {
        match self {
            Arch::Residual { stem, stages } => {
                stages.last().map_or(stem.out_ch, |s| s.c2.out_ch)
            }
            Arch::Plain { convs } => convs.last().expect("build rejects empty stacks").out_ch,
        }
    }
}

/// The packed network, ready to run on host CPU.
pub struct PackedModel {
    height: usize,
    width: usize,
    channels: usize,
    classes: usize,
    arch: Arch,
    fc: LayerWeights,
    fc_bias: Vec<f32>,
    threads: usize,
}

fn param<'p>(m: &Manifest, params: &'p [HostTensor], name: &str) -> Result<&'p HostTensor> {
    let idx = m
        .params
        .iter()
        .position(|(n, _)| n == name)
        .with_context(|| format!("param {name:?} not in manifest"))?;
    params
        .get(idx)
        .with_context(|| format!("param list too short for {name:?}"))
}

fn layer_weights(
    m: &Manifest,
    params: &[HostTensor],
    masks: Option<&MaskSet>,
    name: &str,
) -> Result<(LayerWeights, Vec<usize>)> {
    let t = param(m, params, name)?;
    let rows = gemm_rows(t);
    let w = match masks {
        Some(ms) => {
            let lm = ms
                .layer(name)
                .with_context(|| format!("mask set {:?} missing layer {name:?}", ms.name))?;
            LayerWeights::Packed(PackedMatrix::pack(&rows, lm))
        }
        None => LayerWeights::Float(rows),
    };
    Ok((w, t.shape.clone()))
}

impl PackedModel {
    /// Pack `params` under `masks` (the freeze-time mask set — packing
    /// frozen weights under the same masks reproduces the identical codes,
    /// since fake-quant is idempotent and scale-preserving). `masks = None`
    /// keeps f32 rows: the float reference backend.
    pub fn build(
        m: &Manifest,
        params: &[HostTensor],
        masks: Option<&MaskSet>,
    ) -> Result<PackedModel> {
        if m.widths.is_empty() {
            bail!("manifest has no stage widths");
        }
        let conv = |name: &str, stride: usize| -> Result<ConvLayer> {
            let (w, shape) = layer_weights(m, params, masks, name)?;
            if shape.len() != 4 {
                bail!("{name}: expected 4-D HWIO conv weight, got {shape:?}");
            }
            Ok(ConvLayer {
                w,
                kh: shape[0],
                kw: shape[1],
                stride,
                in_ch: shape[2],
                out_ch: shape[3],
            })
        };
        let has = |name: &str| m.params.iter().any(|(n, _)| n == name);
        let arch = if has("stem/w") {
            let stem = conv("stem/w", 1)?;
            let mut stages = Vec::with_capacity(m.widths.len());
            let mut prev = m.widths[0];
            for (si, &wch) in m.widths.iter().enumerate() {
                let stride = if prev == wch { 1 } else { 2 };
                let c1 = conv(&format!("s{si}/c1/w"), stride)?;
                let c2 = conv(&format!("s{si}/c2/w"), 1)?;
                let proj = if prev == wch {
                    None
                } else {
                    Some(conv(&format!("s{si}/proj/w"), stride)?)
                };
                stages.push(Stage { c1, c2, proj });
                prev = wch;
            }
            Arch::Residual { stem, stages }
        } else if has("s0/conv/w") {
            // Plain stack: same stride rule as zoo::vggnarrow — first conv
            // stride 1, stride 2 whenever the width changes.
            let mut convs = Vec::with_capacity(m.widths.len());
            let mut prev_width: Option<usize> = None;
            for (si, &wch) in m.widths.iter().enumerate() {
                let stride = match prev_width {
                    Some(p) if p != wch => 2,
                    _ => 1,
                };
                convs.push(conv(&format!("s{si}/conv/w"), stride)?);
                prev_width = Some(wch);
            }
            Arch::Plain { convs }
        } else {
            bail!("manifest params have neither a TinyResNet stem/w nor a plain s0/conv/w stack");
        };
        let (fc, fc_shape) = layer_weights(m, params, masks, "fc/w")?;
        if fc_shape.len() != 2 {
            bail!("fc/w: expected 2-D weight, got {fc_shape:?}");
        }
        let fc_bias = param(m, params, "fc/b")?.as_f32().to_vec();
        if fc_bias.len() != m.classes {
            bail!("fc/b: {} entries for {} classes", fc_bias.len(), m.classes);
        }
        Ok(PackedModel {
            height: m.height,
            width: m.width,
            channels: m.channels,
            classes: m.classes,
            arch,
            fc,
            fc_bias,
            threads: qgemm::default_threads(),
        })
    }

    /// Override the worker-pool size (default: `available_parallelism`).
    pub fn with_threads(mut self, threads: usize) -> PackedModel {
        self.threads = threads.max(1);
        self
    }

    /// Logits `(batch, classes)` for an NHWC f32 input batch.
    pub fn forward(&self, x: &[f32], batch: usize) -> Vec<f32> {
        assert_eq!(
            x.len(),
            batch * self.height * self.width * self.channels,
            "input shape mismatch"
        );
        let (h, hw) = match &self.arch {
            Arch::Residual { stem, stages } => {
                let (mut h, mut hw) = self.conv(x, batch, (self.height, self.width), stem);
                relu(&mut h);
                for stage in stages {
                    let (mut y, yhw) = self.conv(&h, batch, hw, &stage.c1);
                    relu(&mut y);
                    let (mut y2, y2hw) = self.conv(&y, batch, yhw, &stage.c2);
                    let skip = match &stage.proj {
                        Some(p) => self.conv(&h, batch, hw, p).0,
                        None => h,
                    };
                    debug_assert_eq!(y2.len(), skip.len(), "residual shape mismatch");
                    for (a, b) in y2.iter_mut().zip(&skip) {
                        *a += b;
                    }
                    relu(&mut y2);
                    h = y2;
                    hw = y2hw;
                }
                (h, hw)
            }
            Arch::Plain { convs } => {
                let (first, rest) = convs.split_first().expect("build rejects empty stacks");
                let (mut h, mut hw) = self.conv(x, batch, (self.height, self.width), first);
                relu(&mut h);
                for l in rest {
                    let (mut y, yhw) = self.conv(&h, batch, hw, l);
                    relu(&mut y);
                    h = y;
                    hw = yhw;
                }
                (h, hw)
            }
        };
        // Global average pool -> (batch, ch).
        let ch = self.arch.last_ch();
        let px = hw.0 * hw.1;
        let mut gap = vec![0f32; batch * ch];
        for bi in 0..batch {
            let img = &h[bi * px * ch..(bi + 1) * px * ch];
            let g = &mut gap[bi * ch..(bi + 1) * ch];
            for pix in img.chunks_exact(ch) {
                for (gv, &v) in g.iter_mut().zip(pix) {
                    *gv += v;
                }
            }
            for gv in g.iter_mut() {
                *gv /= px as f32;
            }
        }
        let mut logits = self.matmul(&gap, batch, ch, &self.fc);
        for (i, l) in logits.iter_mut().enumerate() {
            *l += self.fc_bias[i % self.classes];
        }
        logits
    }

    fn conv(
        &self,
        x: &[f32],
        b: usize,
        (ih, iw): (usize, usize),
        l: &ConvLayer,
    ) -> (Vec<f32>, (usize, usize)) {
        let col = qgemm::im2col(x, b, ih, iw, l.in_ch, l.kh, l.kw, l.stride);
        let y = self.matmul(&col.data, col.m, col.k, &l.w);
        debug_assert_eq!(y.len(), col.m * l.out_ch);
        (y, (col.oh, col.ow))
    }

    fn matmul(&self, x: &[f32], m: usize, k: usize, w: &LayerWeights) -> Vec<f32> {
        match w {
            LayerWeights::Packed(p) => {
                let acts = QuantizedActs::quantize(x, m, k);
                qgemm::qgemm(&acts, p, self.threads)
            }
            LayerWeights::Float(rows) => qgemm::f32_gemm_rows(x, m, k, rows, self.threads),
        }
    }
}

fn relu(xs: &mut [f32]) {
    for v in xs.iter_mut() {
        *v = v.max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::synth;
    use crate::quant::{Ratio, Scheme};
    use crate::util::Rng;

    /// The shared synthetic 8x8x3 TinyResNet manifest (widths 4, 8) —
    /// `backend::synth` mirrors the python layer_defs recipe.
    fn tiny_manifest() -> Manifest {
        synth::tiny_manifest(8, 8, 3, &[4, 8], 5)
    }

    fn random_params(m: &Manifest, rng: &mut Rng) -> Vec<HostTensor> {
        synth::random_params(m, rng)
    }

    fn mixed_masks(m: &Manifest, rng: &mut Rng) -> MaskSet {
        synth::random_masks(m, Ratio::new(60.0, 35.0, 5.0), rng)
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = tiny_manifest();
        let mut rng = Rng::new(3);
        let params = random_params(&m, &mut rng);
        let masks = mixed_masks(&m, &mut rng);
        let model = PackedModel::build(&m, &params, Some(&masks)).unwrap();
        let b = 3usize;
        let x: Vec<f32> = (0..b * 8 * 8 * 3).map(|_| rng.normal()).collect();
        let logits = model.forward(&x, b);
        assert_eq!(logits.len(), b * 5);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fixed8_packed_tracks_float_backend() {
        // With every row at 8 bits the packed path only adds ~1/254 relative
        // weight + activation noise per layer: logits must stay close to the
        // float backend and argmax must agree on well-separated inputs.
        let m = tiny_manifest();
        let mut rng = Rng::new(5);
        let params = random_params(&m, &mut rng);
        let masks = synth::uniform_masks(&m, Scheme::Fixed8);
        let packed = PackedModel::build(&m, &params, Some(&masks)).unwrap();
        let float = PackedModel::build(&m, &params, None).unwrap();
        let b = 4usize;
        let x: Vec<f32> = (0..b * 8 * 8 * 3).map(|_| rng.normal()).collect();
        let lq = packed.forward(&x, b);
        let lf = float.forward(&x, b);
        let scale = lf.iter().fold(0f32, |a, &v| a.max(v.abs())).max(1e-3);
        for (a, c) in lq.iter().zip(&lf) {
            assert!(
                (a - c).abs() < 0.05 * scale + 0.05,
                "packed {a} vs float {c} (scale {scale})"
            );
        }
    }

    #[test]
    fn forward_deterministic_across_threads() {
        let m = tiny_manifest();
        let mut rng = Rng::new(7);
        let params = random_params(&m, &mut rng);
        let masks = mixed_masks(&m, &mut rng);
        let x: Vec<f32> = (0..2 * 8 * 8 * 3).map(|_| rng.normal()).collect();
        let m1 = PackedModel::build(&m, &params, Some(&masks)).unwrap().with_threads(1);
        let m4 = PackedModel::build(&m, &params, Some(&masks)).unwrap().with_threads(4);
        let a = m1.forward(&x, 2);
        let b = m4.forward(&x, 2);
        assert!(a.iter().zip(&b).all(|(x1, x2)| x1.to_bits() == x2.to_bits()));
    }

    #[test]
    fn plain_stack_builds_and_forwards() {
        // The vggnarrow geometry: no stem, no residuals — the Arch::Plain
        // reconstruction path.
        let m = synth::vgg_manifest(8, 8, 3, &[4, 8], 5);
        let mut rng = Rng::new(11);
        let params = random_params(&m, &mut rng);
        let masks = mixed_masks(&m, &mut rng);
        let model = PackedModel::build(&m, &params, Some(&masks)).unwrap();
        let b = 2usize;
        let x: Vec<f32> = (0..b * 8 * 8 * 3).map(|_| rng.normal()).collect();
        let logits = model.forward(&x, b);
        assert_eq!(logits.len(), b * 5);
        assert!(logits.iter().all(|v| v.is_finite()));
        // Determinism across thread counts holds for the plain arch too —
        // the pool's hot-swap bit-identity guarantee rests on this.
        let m1 = PackedModel::build(&m, &params, Some(&masks)).unwrap().with_threads(1);
        let l1 = m1.forward(&x, b);
        assert!(logits.iter().zip(&l1).all(|(a, c)| a.to_bits() == c.to_bits()));
    }

    #[test]
    fn build_rejects_missing_mask_layer() {
        let m = tiny_manifest();
        let mut rng = Rng::new(9);
        let params = random_params(&m, &mut rng);
        let masks = MaskSet { name: "empty".into(), layers: vec![] };
        assert!(PackedModel::build(&m, &params, Some(&masks)).is_err());
    }
}
