//! Host-side tensors and conversion to/from PJRT literals.
//!
//! `HostTensor` is the coordinator's in-memory array type: shape + flat f32
//! (or i32) storage, little-endian on disk (the `aot.py` binary format).
//! The PJRT literal conversions are only compiled with the `pjrt` feature;
//! everything else is plain std and builds everywhere.

use anyhow::{bail, Context, Result};
#[cfg(feature = "pjrt")]
use xla::{ElementType, Literal};

/// Dense host tensor (f32 or i32 payload).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    pub fn scalar(v: f32) -> HostTensor {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            TensorData::I32(_) => panic!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            TensorData::F32(_) => panic!("tensor is f32, expected i32"),
        }
    }

    /// Scalar value (f32 tensors of any single-element shape).
    pub fn item(&self) -> f32 {
        assert_eq!(self.len(), 1, "item() on non-scalar");
        self.as_f32()[0]
    }

    /// Convert to a PJRT literal (host copy).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<Literal> {
        let (ty, bytes): (ElementType, &[u8]) = match &self.data {
            TensorData::F32(v) => (ElementType::F32, bytemuck_f32(v)),
            TensorData::I32(v) => (ElementType::S32, bytemuck_i32(v)),
        };
        Literal::create_from_shape_and_untyped_data(ty, &self.shape, bytes)
            .with_context(|| format!("literal from shape {:?}", self.shape))
    }

    /// Read back from a PJRT literal.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?)),
            ElementType::S32 => Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(feature = "pjrt")]
fn bytemuck_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(feature = "pjrt")]
fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// Read a little-endian f32 binary file (the aot.py dataset format).
pub fn read_f32_file(path: &std::path::Path) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path:?}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a little-endian i32 binary file.
pub fn read_i32_file(path: &std::path::Path) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
    if bytes.len() % 4 != 0 {
        bail!("{path:?}: length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32(vec![4], vec![-1, 0, 7, 42]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar(0.05);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert!((back.item() - 0.05).abs() < 1e-9);
        assert_eq!(back.shape, Vec::<usize>::new());
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn file_io_roundtrip() {
        let dir = std::env::temp_dir().join("ilmpq_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        let vals = [1.5f32, -2.25, 3e6];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), vals);
        std::fs::remove_file(&p).ok();
    }
}
