//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands; typed getters with defaults; `--help` text assembled from
//! registered options. Strict: unknown `--options` are an error so typos in
//! bench invocations fail loudly instead of silently benchmarking the
//! default config.
//!
//! Boolean flags are declared by suffixing the registered name with `!`
//! (e.g. `("verbose!", "chatty")`) — they never consume the next token, so
//! `--verbose positional` parses unambiguously.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    /// Binary / subcommand name chain, for help text.
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    known: Vec<(String, String, bool)>, // (name, help, is_flag)
}

impl Args {
    /// Parse from an explicit token list (tests) — `known` declares the
    /// accepted option/flag names with help strings.
    pub fn parse_from(
        command: &str,
        tokens: &[String],
        known: &[(&str, &str)],
    ) -> Result<Args, String> {
        let mut a = Args {
            command: command.to_string(),
            opts: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
            known: known
                .iter()
                .map(|(n, h)| match n.strip_suffix('!') {
                    Some(flag) => (flag.to_string(), h.to_string(), true),
                    None => (n.to_string(), h.to_string(), false),
                })
                .collect(),
        };
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(body) = t.strip_prefix("--") {
                if body == "help" {
                    return Err(a.help());
                }
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let Some((_, _, is_flag)) =
                    a.known.iter().find(|(n, _, _)| *n == key).cloned()
                else {
                    return Err(format!("unknown option --{key}\n{}", a.help()));
                };
                if let Some(v) = inline_val {
                    a.opts.insert(key, v);
                } else if !is_flag
                    && i + 1 < tokens.len()
                    && !tokens[i + 1].starts_with("--")
                {
                    a.opts.insert(key, tokens[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(key);
                }
            } else {
                a.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(a)
    }

    /// Parse the process args (after the subcommand at `skip`).
    ///
    /// `cargo bench`/`cargo test` append a bare `--bench` to harness
    /// binaries — dropped here so `harness = false` benches parse cleanly.
    pub fn parse_env(command: &str, skip: usize, known: &[(&str, &str)]) -> Args {
        let tokens: Vec<String> = std::env::args()
            .skip(skip)
            .filter(|t| t != "--bench")
            .collect();
        match Args::parse_from(command, &tokens, known) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    pub fn help(&self) -> String {
        let mut s = format!("usage: {} [options]\noptions:\n", self.command);
        for (n, h, _) in &self.known {
            s.push_str(&format!("  --{n:<18} {h}\n"));
        }
        s
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name}: expected integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name}: expected number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name}: expected integer, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated number list (e.g. `--rates 500,2000,8000`); `default`
    /// is the spec string used when the option is absent. An effectively
    /// empty list (e.g. `--rates ,`) is an error, not a silent no-op sweep.
    pub fn f64_list_or(&self, name: &str, default: &str) -> Vec<f64> {
        let list: Vec<f64> = self
            .str_or(name, default)
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| {
                t.parse().unwrap_or_else(|_| {
                    panic!("--{name}: expected comma-separated numbers, got {t:?}")
                })
            })
            .collect();
        assert!(!list.is_empty(), "--{name}: expected at least one number");
        list
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    const KNOWN: &[(&str, &str)] = &[
        ("device", "fpga device"),
        ("steps", "train steps"),
        ("verbose!", "chatty"),
    ];

    #[test]
    fn parses_key_value_both_styles() {
        let a = Args::parse_from("t", &toks("--device xc7z020 --steps=10"), KNOWN).unwrap();
        assert_eq!(a.get("device"), Some("xc7z020"));
        assert_eq!(a.usize_or("steps", 0), 10);
    }

    #[test]
    fn flags_and_positionals() {
        let a = Args::parse_from("t", &toks("pos1 --verbose pos2"), KNOWN).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn rejects_unknown_option() {
        assert!(Args::parse_from("t", &toks("--bogus 1"), KNOWN).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse_from("t", &[], KNOWN).unwrap();
        assert_eq!(a.usize_or("steps", 7), 7);
        assert_eq!(a.str_or("device", "xc7z045"), "xc7z045");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn f64_list_parses_with_default() {
        let known = &[("rates", "req/s list")];
        let a = Args::parse_from("t", &toks("--rates 500,2e3,8000,"), known).unwrap();
        assert_eq!(a.f64_list_or("rates", "1"), vec![500.0, 2000.0, 8000.0]);
        let a = Args::parse_from("t", &[], known).unwrap();
        assert_eq!(a.f64_list_or("rates", "1,2"), vec![1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "at least one number")]
    fn f64_list_rejects_effectively_empty() {
        let known = &[("rates", "req/s list")];
        let a = Args::parse_from("t", &toks("--rates ,"), known).unwrap();
        let _ = a.f64_list_or("rates", "1");
    }

    #[test]
    fn help_lists_options() {
        let a = Args::parse_from("t", &[], KNOWN).unwrap();
        assert!(a.help().contains("--device"));
        let err = Args::parse_from("t", &toks("--help"), KNOWN).unwrap_err();
        assert!(err.contains("usage:"));
    }
}
